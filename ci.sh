#!/usr/bin/env bash
# CI entry point: format, lint, and test the rust crate with bench
# runtimes scaled down so grid smoke runs finish in CI time, then a
# microbench whose per-step trajectory is enforced across runs (>2x
# regression fails), then a distributed smoke stage that drives
# serve --listen + worker + grid --remote end to end over loopback and
# cross-checks the gateway's /metrics exposition against /stats.
#
# Usage: ./ci.sh                      # full gate
#        OMGD_BENCH_SCALE=1 ./ci.sh   # paper-shaped runtimes
#        OMGD_CI_SKIP_SMOKE=1 ./ci.sh # skip the distributed smoke
# The microbench stage always runs: every revision files a bench point,
# so the perf trajectory has no gaps.
set -euo pipefail
cd "$(dirname "$0")"

# Self-describing CI logs: the toolchain is pinned by
# rust-toolchain.toml, so print what actually resolved.
echo "== toolchain"
rustc --version
cargo --version

# Shrink epochs/steps for smoke runs unless the caller pinned a scale
# (see experiments::bench_scale; value must be finite and in (0, 1]).
export OMGD_BENCH_SCALE="${OMGD_BENCH_SCALE:-0.05}"
# Keep CI deterministic and small: single grid worker unless overridden
# (the ci.yml matrix also runs OMGD_WORKERS=4).
export OMGD_WORKERS="${OMGD_WORKERS:-1}"

echo "== cargo fmt --check"
cargo fmt --all --check

echo "== cargo clippy --workspace -D warnings"
cargo clippy --workspace --all-targets -- -D warnings

# Rustdoc rot (broken intra-doc links, bad code fences) fails the
# build: the docs/ handbook leans on `cargo doc` staying truthful.
# Per-crate so one crate's breakage names itself in the log.
for crate in omgd-util omgd-core omgd-jobs omgd-train omgd; do
  echo "== cargo doc --no-deps -p $crate (rustdoc warnings denied)"
  RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet -p "$crate"
done

echo "== cargo test --workspace (OMGD_BENCH_SCALE=$OMGD_BENCH_SCALE)"
cargo test -q --workspace

# Thread-matrix pass: engines built from the environment must stay
# bitwise-identical when they come up multi-threaded, so the training
# suite runs a second time with a 4-wide step pool.
echo "== cargo test -p omgd-train (OMGD_THREADS=4)"
OMGD_THREADS=4 cargo test -q -p omgd-train

# ---------------------------------------------------------------------
# Layering guard: omgd-core is the numerics layer — it must never grow
# a dependency on the job/network layer. Two teeth: the dependency
# graph (cargo tree) and a source grep for network types, so neither a
# manifest edit nor a sneaky `std::net` import slips through.
# ---------------------------------------------------------------------
echo "== layering guard: omgd-core stays free of jobs/network code"
if cargo tree -p omgd-core -e normal --prefix none 2>/dev/null \
    | grep -q '^omgd-jobs'; then
  echo "layering guard FAILED: omgd-core depends on omgd-jobs" >&2
  exit 1
fi
if LEAKS=$(grep -rnE 'omgd_jobs|std::net|TcpListener|TcpStream' \
        rust/crates/omgd-core/src --include='*.rs'); then
  echo "layering guard FAILED: jobs/network references inside" \
       "omgd-core:" >&2
  echo "$LEAKS" >&2
  exit 1
fi
echo "   clean (omgd-core sees neither omgd-jobs nor the network)"

# ---------------------------------------------------------------------
# Mask-API surface guard: the dense vector is a lazy, explicitly
# requested bridge now. Only coordinator/mask.rs (owns the bridge) and
# optim/reference.rs (the dense mirrors) may touch `.values()` /
# `.to_dense(` — anything else is a dense-path regression and fails
# the gate.
# ---------------------------------------------------------------------
echo "== mask-API guard: no dense mask access outside sanctioned files"
if LEAKS=$(grep -rnE '\.values\(\)|\.to_dense\(' rust/crates examples \
        --include='*.rs' \
    | grep -vE '^rust/crates/omgd-core/src/(coordinator/mask\.rs|optim/reference\.rs):'); then
  echo "mask-API guard FAILED: dense mask access outside" \
       "coordinator/mask.rs and optim/reference.rs:" >&2
  echo "$LEAKS" >&2
  exit 1
fi
echo "   clean (dense bridge confined to mask.rs + reference.rs)"

# ---------------------------------------------------------------------
# Scratch guard: the HLO-bridge dense-multiplier scratch is owned per
# engine. The old `Mutex<RunsScratch>` inside ModelBundle serialized
# every HLO step across engines sharing a bundle — it must not return.
# ---------------------------------------------------------------------
echo "== scratch guard: no Mutex<RunsScratch> in runtime/bundle.rs"
if grep -nE 'Mutex<\s*RunsScratch\s*>' \
    rust/crates/omgd-core/src/runtime/bundle.rs; then
  echo "scratch guard FAILED: Mutex<RunsScratch> is back in" \
       "runtime/bundle.rs — the per-step lock must stay dead" >&2
  exit 1
fi
echo "   clean (RunsScratch is per-engine, lock-free)"

# ---------------------------------------------------------------------
# Mask-runs micro-bench: native masked-AdamW steps swept across
# keep-ratios {0.05, 0.25, 1.0}, runs-descriptor path vs stepping over
# the lazy dense bridge, plus a mask-refresh stage (splice +
# on_mask_refresh churn) and a thread sweep ({1,2,4} threads × keep
# {0.05,0.25}, every arm bitwise-verified against the serial walk
# before its timing counts). 10⁴ steps at scale 1; OMGD_BENCH_SCALE
# shrinks it like every other bench. The binary bails if anything
# densified a mask mid-bench, prints the ratios, and writes
# BENCH_maskruns.json at the repo root so the trajectories are tracked
# across PRs. This stage always runs — no skip knob — so every
# revision files a point.
# ---------------------------------------------------------------------
num_field() { # num_field FILE KEY → numeric value of "KEY":N
  sed -n "s/.*\"$2\":\([0-9.eE+-]*\).*/\1/p" "$1" | head -n1
}

{
  echo "== mask-runs microbench (keep sweep + refresh + thread sweep)"
  cargo build -q --release --bin omgd
  target/release/omgd microbench --keep 0.25 \
      --out BENCH_maskruns.json

  # Thread-sweep gate: on a machine with ≥4 cores the 4-thread sharded
  # step must be ≥2x faster than the 1-thread arm at keep 0.25 (the
  # arms were already bitwise-verified by the binary). Narrower
  # machines log the speedup and skip the teeth.
  SP4=$(grep -o '{"threads":4,"k":0.25,[^}]*}' BENCH_maskruns.json \
      | sed -n 's/.*"speedup":\([0-9.eE+-]*\).*/\1/p' | head -n1)
  CORES=$(nproc 2>/dev/null || echo 1)
  if [[ -n "$SP4" ]] && (( CORES >= 4 )); then
    if awk -v s="$SP4" 'BEGIN { exit !(s < 2.0) }'; then
      echo "bench thread-sweep FAILED: 4-thread speedup ${SP4}x < 2x" \
           "at keep=0.25" >&2
      exit 1
    fi
    echo "   thread sweep: 4-thread speedup ${SP4}x at keep=0.25 (≥2x)"
  else
    echo "   thread sweep: 4-thread speedup ${SP4:-n/a}x at keep=0.25" \
         "(gate needs ≥4 cores; have $CORES)"
  fi

  # Bench trajectory: file this run's point under its git revision
  # (the row itself is stamped with rev/scale/workers/unix_secs by the
  # binary) and compare per-step runs-path time against the most
  # recent prior point on record. A >2x regression fails the gate —
  # that is the enforcement teeth, not just a log line.
  REV=$(git rev-parse --short HEAD 2>/dev/null || echo unknown)
  PREV_FILE=""
  best_ts=0
  for f in BENCH_*.json; do
    [[ -e "$f" ]] || continue
    [[ "$f" == BENCH_maskruns.json ]] && continue
    [[ "$f" == "BENCH_${REV}.json" ]] && continue
    ts=$(num_field "$f" unix_secs)
    [[ -z "$ts" ]] && continue   # pre-metadata point: not comparable
    if (( ts > best_ts )); then best_ts=$ts; PREV_FILE="$f"; fi
  done
  cp BENCH_maskruns.json "BENCH_${REV}.json"
  echo "   filed bench point BENCH_${REV}.json"
  if [[ -n "$PREV_FILE" ]]; then
    NEW_PS=$(awk -v s="$(num_field BENCH_maskruns.json runs_secs)" \
                 -v n="$(num_field BENCH_maskruns.json steps)" \
                 'BEGIN { printf "%.9g", s / n }')
    OLD_PS=$(awk -v s="$(num_field "$PREV_FILE" runs_secs)" \
                 -v n="$(num_field "$PREV_FILE" steps)" \
                 'BEGIN { printf "%.9g", s / n }')
    echo "   per-step runs path: ${NEW_PS}s now vs ${OLD_PS}s" \
         "in $(basename "$PREV_FILE")"
    if awk -v new="$NEW_PS" -v old="$OLD_PS" \
        'BEGIN { exit !(old > 0 && new > 2.0 * old) }'; then
      echo "bench trajectory FAILED: per-step runs-path time" \
           "regressed >2x vs $(basename "$PREV_FILE")" >&2
      exit 1
    fi
    # Refresh stage rides the same >2x gate once both points carry it
    # (older bench rows predate the stage and are skipped).
    NEW_RS=$(num_field BENCH_maskruns.json refresh_secs)
    NEW_RN=$(num_field BENCH_maskruns.json refreshes)
    OLD_RS=$(num_field "$PREV_FILE" refresh_secs)
    OLD_RN=$(num_field "$PREV_FILE" refreshes)
    if [[ -n "$NEW_RS" && -n "$NEW_RN" && -n "$OLD_RS" && -n "$OLD_RN" ]]
    then
      NEW_PR=$(awk -v s="$NEW_RS" -v n="$NEW_RN" \
                   'BEGIN { printf "%.9g", s / n }')
      OLD_PR=$(awk -v s="$OLD_RS" -v n="$OLD_RN" \
                   'BEGIN { printf "%.9g", s / n }')
      echo "   per-refresh: ${NEW_PR}s now vs ${OLD_PR}s" \
           "in $(basename "$PREV_FILE")"
      if awk -v new="$NEW_PR" -v old="$OLD_PR" \
          'BEGIN { exit !(old > 0 && new > 2.0 * old) }'; then
        echo "bench trajectory FAILED: per-refresh time regressed" \
             ">2x vs $(basename "$PREV_FILE")" >&2
        exit 1
      fi
    else
      echo "   prior point has no refresh stage; refresh gate arms" \
           "next run"
    fi
  else
    echo "   no prior bench point; trajectory gate arms next run"
  fi
}

# ---------------------------------------------------------------------
# Distributed smoke: boot a quota'd coordinator-only gateway, attach
# one worker agent, run two tiny grids through `--remote` under two
# client tokens (keep-alive connections, per-client fair queuing), and
# diff their merged CSV against the same grids on the local pool. The
# cells fail fast in CI (no artifacts are generated here) — which is
# exactly what we want: the lease/report/aggregate path is exercised
# end to end, and failed cells must aggregate byte-identically on both
# paths too.
# ---------------------------------------------------------------------
if [[ "${OMGD_CI_SKIP_SMOKE:-0}" == "1" ]]; then
  echo "== distributed smoke: skipped (OMGD_CI_SKIP_SMOKE=1)"
else
  echo "== distributed smoke: serve --listen + worker + grid --remote"
  cargo build -q --bin omgd
  BIN=target/debug/omgd
  SMOKE=$(mktemp -d)
  SERVE_PID=""
  WORKER_PID=""
  GRID_PID=""
  cleanup() {
    [[ -n "$GRID_PID" ]] && kill "$GRID_PID" 2>/dev/null || true
    [[ -n "$WORKER_PID" ]] && kill "$WORKER_PID" 2>/dev/null || true
    [[ -n "$SERVE_PID" ]] && kill "$SERVE_PID" 2>/dev/null || true
    rm -rf "$SMOKE"
  }
  trap cleanup EXIT

  # The grid is split across two client identities (ci-a / ci-b) so
  # the smoke exercises per-client fair queuing on a quota'd gateway;
  # each half rides `grid --remote`'s keep-alive connection (429
  # retries and the chunked session stream share one socket).
  GRID_A=(--kind finetune --tasks CoLA --methods full
          --seeds 0,1 --epochs 1)
  GRID_B=(--kind finetune --tasks CoLA --methods lisa-wor
          --seeds 0,1 --epochs 1)

  # The gateway runs with bearer auth so the smoke drives the token
  # path on every hop: worker leases, grid submission, and the final
  # authenticated /shutdown. Probe endpoints stay open (checked below).
  AUTH=ci-secret-token
  "$BIN" serve --listen 127.0.0.1:0 --workers 0 --poll-secs 2 \
      --client-quota 4 --auth-token "$AUTH" \
      --cache-dir "$SMOKE/gateway-cache" 2> "$SMOKE/serve.log" &
  SERVE_PID=$!
  ADDR=""
  for _ in $(seq 1 100); do
    ADDR=$(sed -n 's!.*listening on http://\([0-9.]*:[0-9]*\).*!\1!p' \
        "$SMOKE/serve.log" | head -n1)
    [[ -n "$ADDR" ]] && break
    sleep 0.1
  done
  if [[ -z "$ADDR" ]]; then
    echo "distributed smoke FAILED: gateway never bound" >&2
    cat "$SMOKE/serve.log" >&2
    exit 1
  fi
  echo "   gateway on $ADDR"

  "$BIN" worker --connect "$ADDR" --workers 2 --id ci-smoke \
      --token "$AUTH" \
      --cache-dir "$SMOKE/worker-cache" \
      --artifact-store "$SMOKE/worker-store" 2> "$SMOKE/worker.log" &
  WORKER_PID=$!

  # Auth teeth: a tokenless submission must bounce with 401 before the
  # authenticated runs go through.
  if "$BIN" grid --remote "$ADDR" --client ci-x "${GRID_A[@]}" \
      > "$SMOKE/unauth.log" 2>&1; then
    echo "auth smoke FAILED: tokenless grid submission succeeded" >&2
    cat "$SMOKE/unauth.log" >&2
    exit 1
  fi
  if ! grep -q '401' "$SMOKE/unauth.log"; then
    echo "auth smoke FAILED: tokenless submission failed without a 401" >&2
    cat "$SMOKE/unauth.log" >&2
    exit 1
  fi
  echo "   auth smoke: tokenless submission refused with 401"

  # Remote runs, one per client token (cells fail without artifacts →
  # non-zero exit; the CSV aggregates are still written and are what
  # the smoke checks).
  "$BIN" grid --remote "$ADDR" --client ci-a --token "$AUTH" \
      "${GRID_A[@]}" \
      --out "$SMOKE/remote-a.csv" > "$SMOKE/remote-a.log" 2>&1 || true
  "$BIN" grid --remote "$ADDR" --client ci-b --token "$AUTH" \
      "${GRID_B[@]}" \
      --out "$SMOKE/remote-b.csv" > "$SMOKE/remote-b.log" 2>&1 || true
  # Local-pool runs of the identical splits, isolated cache.
  "$BIN" grid "${GRID_A[@]}" --workers 1 \
      --cache-dir "$SMOKE/local-cache" \
      --out "$SMOKE/local-a.csv" > "$SMOKE/local-a.log" 2>&1 || true
  "$BIN" grid "${GRID_B[@]}" --workers 1 \
      --cache-dir "$SMOKE/local-cache" \
      --out "$SMOKE/local-b.csv" > "$SMOKE/local-b.log" 2>&1 || true

  for f in remote-a remote-b local-a local-b; do
    if [[ ! -s "$SMOKE/$f.csv" ]]; then
      echo "distributed smoke FAILED: $f wrote no CSV" >&2
      tail -n 40 "$SMOKE"/*.log >&2
      exit 1
    fi
  done
  # Merge each pair (second header dropped) and compare the fleet's
  # aggregate against the local pool's, byte for byte.
  cat "$SMOKE/remote-a.csv" > "$SMOKE/remote.csv"
  tail -n +2 "$SMOKE/remote-b.csv" >> "$SMOKE/remote.csv"
  cat "$SMOKE/local-a.csv" > "$SMOKE/local.csv"
  tail -n +2 "$SMOKE/local-b.csv" >> "$SMOKE/local.csv"
  if ! diff -u "$SMOKE/local.csv" "$SMOKE/remote.csv" >&2; then
    echo "distributed smoke FAILED: merged remote aggregate differs" >&2
    tail -n 40 "$SMOKE"/*.log >&2
    exit 1
  fi

  # Telemetry smoke: with both grids finished and the queue quiescent,
  # scrape the gateway (bash /dev/tcp: no curl dependency) and check
  # the Prometheus counters agree with the /stats JSON exactly — the
  # two surfaces must never drift apart.
  HOST="${ADDR%:*}"; PORT="${ADDR##*:}"
  http_get() { # http_get PATH OUTFILE (body only; headers stripped)
    exec 4<>"/dev/tcp/$HOST/$PORT"
    printf 'GET %s HTTP/1.1\r\nHost: ci\r\nContent-Length: 0\r\nConnection: close\r\n\r\n' "$1" >&4
    cat <&4 | sed '1,/^\r*$/d' > "$2" || true
    exec 4>&- || true
  }
  http_get /metrics "$SMOKE/metrics.body"
  http_get /stats "$SMOKE/stats.body"
  FAMILIES=$(grep -c '^# TYPE ' "$SMOKE/metrics.body" || true)
  if (( FAMILIES < 12 )); then
    echo "telemetry smoke FAILED: only $FAMILIES metric families" >&2
    cat "$SMOKE/metrics.body" >&2
    exit 1
  fi
  prom() { awk -v m="$1" '$1 == m { print $2 }' "$SMOKE/metrics.body"; }
  stat_field() {
    sed -n "s/.*\"$1\":\([0-9]*\).*/\1/p" "$SMOKE/stats.body" | head -n1
  }
  for pair in \
      "omgd_jobs_completed_total done" \
      "omgd_cache_hits_total cached" \
      "omgd_leases_granted_total leased"; do
    set -- $pair
    M=$(prom "$1"); S=$(stat_field "$2")
    if [[ -z "$M" || -z "$S" || "$M" != "$S" ]]; then
      echo "telemetry smoke FAILED: /metrics $1=${M:-missing} but" \
           "/stats $2=${S:-missing}" >&2
      cat "$SMOKE/metrics.body" "$SMOKE/stats.body" >&2
      exit 1
    fi
  done
  # Durability telemetry: the gateway journals under its cache dir
  # (serve --listen always does), so the journal/checkpoint families
  # must be exposed and the record counter must have moved.
  for fam in omgd_journal_records_total omgd_journal_replayed_total \
             omgd_journal_torn_total omgd_journal_compactions_total \
             omgd_ckpt_writes_total omgd_ckpt_resumes_total \
             omgd_ckpt_parked_total; do
    if ! grep -q "^# TYPE $fam " "$SMOKE/metrics.body"; then
      echo "telemetry smoke FAILED: /metrics is missing $fam" >&2
      cat "$SMOKE/metrics.body" >&2
      exit 1
    fi
  done
  JR=$(prom omgd_journal_records_total)
  if [[ -z "$JR" || "$JR" == "0" ]]; then
    echo "telemetry smoke FAILED: the gateway journaled nothing" \
         "(omgd_journal_records_total=${JR:-missing})" >&2
    exit 1
  fi
  echo "   telemetry smoke passed ($FAMILIES metric families;" \
       "/metrics agrees with /stats; $JR journal records)"

  # A tokenless shutdown must bounce too (the gateway keeps serving),
  # then the authenticated one drains it and the worker exits on its
  # own.
  exec 3<>"/dev/tcp/$HOST/$PORT"
  printf 'POST /shutdown HTTP/1.1\r\nHost: ci\r\nContent-Length: 0\r\nConnection: close\r\n\r\n' >&3
  if ! head -n1 <&3 | grep -q ' 401 '; then
    echo "auth smoke FAILED: tokenless /shutdown was not a 401" >&2
    exit 1
  fi
  exec 3>&- || true
  exec 3<>"/dev/tcp/$HOST/$PORT"
  printf 'POST /shutdown HTTP/1.1\r\nHost: ci\r\nAuthorization: Bearer %s\r\nContent-Length: 0\r\nConnection: close\r\n\r\n' "$AUTH" >&3
  cat <&3 > /dev/null || true
  exec 3>&- || true
  wait "$SERVE_PID" || true
  SERVE_PID=""
  wait "$WORKER_PID" || true
  WORKER_PID=""
  echo "   distributed smoke passed (two-client merged CSV" \
       "byte-identical to local)"

  # -------------------------------------------------------------------
  # Durability smoke: the same remote path, but the coordinator is
  # OMGD_FAULT-killed (a real abort(): no destructors, no flushes) at
  # a mid-grid journal append, then restarted on the same cache dir.
  # The still-running `grid --remote` client must recover on its own —
  # journal replay re-dispatches the interrupted jobs and the client
  # re-polls its acked seqs — and the recovered CSV must be
  # byte-identical to the local pool's (docs/durability.md).
  # -------------------------------------------------------------------
  echo "== durability smoke: kill coordinator at journal.append," \
       "restart, recover"
  OMGD_FAULT=journal.append:4 "$BIN" serve --listen 127.0.0.1:0 \
      --workers 0 --poll-secs 2 \
      --cache-dir "$SMOKE/dur-cache" 2> "$SMOKE/dur-serve1.log" &
  SERVE_PID=$!
  ADDR=""
  for _ in $(seq 1 100); do
    ADDR=$(sed -n 's!.*listening on http://\([0-9.]*:[0-9]*\).*!\1!p' \
        "$SMOKE/dur-serve1.log" | head -n1)
    [[ -n "$ADDR" ]] && break
    sleep 0.1
  done
  if [[ -z "$ADDR" ]]; then
    echo "durability smoke FAILED: gateway never bound" >&2
    cat "$SMOKE/dur-serve1.log" >&2
    exit 1
  fi
  echo "   doomed gateway on $ADDR (dies at the 4th journal append)"
  # --max-failures is generous: the agent must survive the coordinator
  # outage and reattach to the restarted one.
  "$BIN" worker --connect "$ADDR" --workers 2 --id ci-dur \
      --max-failures 200 \
      --cache-dir "$SMOKE/dur-worker-cache" \
      --artifact-store "$SMOKE/dur-worker-store" \
      2> "$SMOKE/dur-worker.log" &
  WORKER_PID=$!
  "$BIN" grid --remote "$ADDR" "${GRID_A[@]}" \
      --out "$SMOKE/dur-remote.csv" > "$SMOKE/dur-remote.log" 2>&1 &
  GRID_PID=$!

  # Appends 1-2 are the grid's admissions, 3-4 the worker's leases /
  # first completion: the abort lands mid-grid, after the client holds
  # acked seqs. 60s budget for the crash to happen.
  for _ in $(seq 1 600); do
    kill -0 "$SERVE_PID" 2>/dev/null || break
    sleep 0.1
  done
  if kill -0 "$SERVE_PID" 2>/dev/null; then
    echo "durability smoke FAILED: faultpoint never fired" >&2
    cat "$SMOKE/dur-serve1.log" >&2
    exit 1
  fi
  wait "$SERVE_PID" 2>/dev/null || true
  SERVE_PID=""
  if ! grep -q 'faultpoint "journal.append"' "$SMOKE/dur-serve1.log"; then
    echo "durability smoke FAILED: coordinator exited without hitting" \
         "the faultpoint" >&2
    cat "$SMOKE/dur-serve1.log" >&2
    exit 1
  fi
  if [[ ! -s "$SMOKE/dur-cache/journal.log" ]]; then
    echo "durability smoke FAILED: no journal survived the crash" >&2
    exit 1
  fi

  # Restart on the SAME address and cache dir. The port can linger in
  # TIME_WAIT for a moment after the abort — retry the bind.
  RESTARTED=0
  for _ in $(seq 1 40); do
    "$BIN" serve --listen "$ADDR" --workers 0 --poll-secs 2 \
        --cache-dir "$SMOKE/dur-cache" 2> "$SMOKE/dur-serve2.log" &
    SERVE_PID=$!
    sleep 0.3
    if kill -0 "$SERVE_PID" 2>/dev/null \
        && grep -q 'listening on' "$SMOKE/dur-serve2.log"; then
      RESTARTED=1
      break
    fi
    wait "$SERVE_PID" 2>/dev/null || true
    SERVE_PID=""
    sleep 0.25
  done
  if (( ! RESTARTED )); then
    echo "durability smoke FAILED: could not rebind $ADDR" >&2
    cat "$SMOKE/dur-serve2.log" >&2
    exit 1
  fi
  if ! grep -q 'journal replay' "$SMOKE/dur-serve2.log"; then
    echo "durability smoke FAILED: restart did not replay the journal" >&2
    cat "$SMOKE/dur-serve2.log" >&2
    exit 1
  fi
  echo "   restarted on $ADDR:" \
       "$(grep 'journal replay' "$SMOKE/dur-serve2.log" | head -n1)"

  # The client recovers without operator action (cells fail fast in CI
  # — no artifacts — so the grid exits non-zero like the main smoke;
  # the CSV is what matters).
  wait "$GRID_PID" || true
  GRID_PID=""
  if [[ ! -s "$SMOKE/dur-remote.csv" ]]; then
    echo "durability smoke FAILED: recovered grid wrote no CSV" >&2
    tail -n 40 "$SMOKE"/dur-*.log >&2
    exit 1
  fi
  # local-a.csv is the same split on the local pool (computed above).
  if ! diff -u "$SMOKE/local-a.csv" "$SMOKE/dur-remote.csv" >&2; then
    echo "durability smoke FAILED: recovered aggregate differs from" \
         "the local pool's" >&2
    tail -n 40 "$SMOKE"/dur-*.log >&2
    exit 1
  fi

  HOST="${ADDR%:*}"; PORT="${ADDR##*:}"
  exec 3<>"/dev/tcp/$HOST/$PORT"
  printf 'POST /shutdown HTTP/1.1\r\nHost: ci\r\nContent-Length: 0\r\nConnection: close\r\n\r\n' >&3
  cat <&3 > /dev/null || true
  exec 3>&- || true
  wait "$SERVE_PID" || true
  SERVE_PID=""
  wait "$WORKER_PID" || true
  WORKER_PID=""
  echo "   durability smoke passed (crash at journal.append:4," \
       "replayed, recovered CSV byte-identical to local)"
fi

echo "CI gate passed."
