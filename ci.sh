#!/usr/bin/env bash
# CI entry point: format, lint, and test the rust crate with bench
# runtimes scaled down so grid smoke runs finish in CI time.
#
# Usage: ./ci.sh            # full gate
#        OMGD_BENCH_SCALE=1 ./ci.sh   # paper-shaped runtimes
set -euo pipefail
cd "$(dirname "$0")/rust"

# Shrink epochs/steps for smoke runs unless the caller pinned a scale
# (see experiments::bench_scale; value must be finite and in (0, 1]).
export OMGD_BENCH_SCALE="${OMGD_BENCH_SCALE:-0.05}"
# Keep CI deterministic and small: single grid worker unless overridden.
export OMGD_WORKERS="${OMGD_WORKERS:-1}"

echo "== cargo fmt --check"
cargo fmt --check

echo "== cargo clippy -D warnings"
cargo clippy --all-targets -- -D warnings

# Rustdoc rot (broken intra-doc links, bad code fences) fails the
# build: the docs/ handbook leans on `cargo doc` staying truthful.
echo "== cargo doc --no-deps (rustdoc warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

echo "== cargo test (OMGD_BENCH_SCALE=$OMGD_BENCH_SCALE)"
cargo test -q

echo "CI gate passed."
