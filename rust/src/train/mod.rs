//! Trainer: drives (sampler × coordinator × runtime × optimizer).
//!
//! The hot loop is pure rust + PJRT: pack batch → execute the AOT `train`
//! HLO (loss, grad) → refresh the method's mask on period boundaries →
//! apply the fused masked-update HLO (the L1 Pallas kernel) or a native
//! baseline optimizer. Python is never invoked.
//!
//! [`MethodEngine`] encapsulates the paper's method roster behind one
//! interface, so every experiment (Tables 3–6, Fig. 3–5, 7) is a loop
//! over `Method` values with shared data and seeds.

pub mod checkpoint;
pub mod engine;

pub use checkpoint::Checkpoint;
pub use engine::MethodEngine;

use crate::config::RunConfig;
use crate::coordinator::DataSampler;
use crate::data::{ClassTask, Corpus};
use crate::metrics::Timer;
use crate::rng::Rng;
use crate::runtime::ModelBundle;
use anyhow::{ensure, Result};

/// Outcome of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    /// (step, train loss) at every step.
    pub loss_series: Vec<(usize, f64)>,
    /// (step, eval loss, eval accuracy%) at eval points (acc 0 for LM).
    pub eval_series: Vec<(usize, f64, f64)>,
    /// Final test accuracy % (classifier) or final eval loss (LM).
    pub final_metric: f64,
    /// Wall-clock seconds in the train loop.
    pub train_secs: f64,
    /// Steps per second.
    pub steps_per_sec: f64,
    /// Final flat parameter vector (checkpointing / further eval).
    pub final_params: Vec<f32>,
    /// Residency diagnostics sampled at every period boundary:
    /// `(step, keep_ratio, optimizer state bytes)`, both derived from
    /// the mask's segment-run view in O(1) — a metrics tick never
    /// rescans the parameter space.
    pub residency_series: Vec<(usize, f64, usize)>,
}

impl TrainOutcome {
    /// Mean train loss over the last `k` logged steps (smoothing for
    /// table comparisons).
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.loss_series.len();
        let k = k.min(n).max(1);
        self.loss_series[n - k..]
            .iter()
            .map(|&(_, l)| l)
            .sum::<f64>()
            / k as f64
    }
}

/// Fine-tune the MLP classifier bundle on a [`ClassTask`].
///
/// Period unit = *epochs* (the paper's fine-tuning setting: LISA switches
/// layers every K epochs).
pub fn train_classifier(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    task: &ClassTask,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    ensure!(bundle.man.kind == "mlp", "classifier needs an mlp bundle");
    ensure!(task.d_in == bundle.man.data.d_in, "task d_in mismatch");

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut engine = MethodEngine::new(&bundle.man, cfg, &mut rng)?;
    let mut flat = bundle.init_params()?;
    let mut sampler = DataSampler::rr(task.n_train());
    let batch = bundle.man.data.batch;

    let mut out = TrainOutcome::default();
    let timer = Timer::start();
    let mut epoch = 0usize;
    let mut epochs_since_period = 0usize;
    engine.on_period(&mut rng)?; // initial mask
    out.residency_series.push((0, engine.keep_ratio(),
                               engine.state_bytes()));

    for step in 0..cfg.steps {
        // Epoch bookkeeping: an epoch is ⌈N/B⌉ batches.
        let steps_per_epoch = task.n_train().div_ceil(batch);
        if step > 0 && step % steps_per_epoch == 0 {
            epoch += 1;
            epochs_since_period += 1;
            if epochs_since_period >= cfg.mask.period {
                epochs_since_period = 0;
                engine.on_period(&mut rng)?;
                out.residency_series.push((step, engine.keep_ratio(),
                                           engine.state_bytes()));
            }
        }
        let idx = sampler.next_batch(batch, &mut rng);
        let (x, y) = task.pack_train(&idx, batch);
        let (loss, grad) = bundle.train_step_clf(&flat, &x, &y)?;
        let lr = cfg.schedule.lr_at(cfg.opt.lr, step) as f32;
        engine.apply(bundle, &mut flat, &grad, lr)?;
        out.loss_series.push((step, loss as f64));

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let (el, acc) = eval_classifier(bundle, &flat, task)?;
            out.eval_series.push((step, el, acc));
        }
    }
    let _ = epoch;
    out.train_secs = timer.total();
    out.steps_per_sec = cfg.steps as f64 / out.train_secs.max(1e-9);
    let (_, acc) = eval_classifier(bundle, &flat, task)?;
    out.final_metric = acc;
    out.final_params = flat;
    Ok(out)
}

/// Evaluate classifier accuracy (%) and mean loss over the test split.
pub fn eval_classifier(
    bundle: &ModelBundle,
    flat: &[f32],
    task: &ClassTask,
) -> Result<(f64, f64)> {
    let batch = bundle.man.data.batch;
    let n = task.test_x.len();
    let mut correct = 0.0f64;
    let mut loss_sum = 0.0f64;
    let mut batches = 0usize;
    let mut start = 0usize;
    while start < n {
        let (x, y) = task.pack_test(start, batch);
        let take = batch.min(n - start);
        let (loss, c) = bundle.eval_step_clf(flat, &x, &y)?;
        // pack_test wraps; only credit the non-wrapped prefix on the
        // final partial batch by rescaling.
        correct += c as f64 * take as f64 / batch as f64;
        loss_sum += loss as f64;
        batches += 1;
        start += batch;
    }
    Ok((loss_sum / batches as f64, 100.0 * correct / n as f64))
}

/// Pre-train the GPT bundle on a synthetic [`Corpus`].
///
/// Period unit = *steps* (the paper's pre-training setting: switch active
/// layers every K iterations).
pub fn train_lm(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    corpus: &Corpus,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    ensure!(bundle.man.kind == "gpt", "LM training needs a gpt bundle");
    ensure!(corpus.seq == bundle.man.data.seq, "corpus seq mismatch");

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut engine = MethodEngine::new(&bundle.man, cfg, &mut rng)?;
    let mut flat = bundle.init_params()?;
    let n_train = corpus.n_samples().saturating_sub(8).max(1);
    let mut sampler = DataSampler::rr(n_train);
    let batch = bundle.man.data.batch;

    let mut out = TrainOutcome::default();
    let timer = Timer::start();
    engine.on_period(&mut rng)?;
    out.residency_series.push((0, engine.keep_ratio(),
                               engine.state_bytes()));

    for step in 0..cfg.steps {
        if step > 0 && step % cfg.mask.period == 0 {
            engine.on_period(&mut rng)?;
            out.residency_series.push((step, engine.keep_ratio(),
                                       engine.state_bytes()));
        }
        let idx = sampler.next_batch(batch, &mut rng);
        let (x, y) = corpus.pack(&idx, batch);
        let (loss, grad) = bundle.train_step_lm(&flat, &x, &y)?;
        let lr = cfg.schedule.lr_at(cfg.opt.lr, step) as f32;
        engine.apply(bundle, &mut flat, &grad, lr)?;
        out.loss_series.push((step, loss as f64));

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let el = eval_lm(bundle, &flat, corpus, n_train)?;
            out.eval_series.push((step, el, 0.0));
        }
    }
    out.train_secs = timer.total();
    out.steps_per_sec = cfg.steps as f64 / out.train_secs.max(1e-9);
    out.final_metric = eval_lm(bundle, &flat, corpus, n_train)?;
    out.final_params = flat;
    Ok(out)
}

/// Held-out LM loss over the last 8 windows (disjoint from training).
pub fn eval_lm(
    bundle: &ModelBundle,
    flat: &[f32],
    corpus: &Corpus,
    train_n: usize,
) -> Result<f64> {
    let batch = bundle.man.data.batch;
    let held: Vec<usize> =
        (train_n..corpus.n_samples()).take(batch.max(1)).collect();
    if held.is_empty() {
        return Ok(f64::NAN);
    }
    let (x, y) = corpus.pack(&held, batch);
    Ok(bundle.eval_step_lm(flat, &x, &y)? as f64)
}
