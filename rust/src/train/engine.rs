//! Method engine: one interface over the paper's method roster.
//!
//! Owns the current mask, the period-boundary refresh logic (the OMGD
//! traversal state), and the optimizer backend:
//!
//! * HLO backend — the fused masked-update Pallas kernel via PJRT, used
//!   by Full / mask / LISA methods (the paper's "plug-and-play into
//!   mainstream optimizers" path — this IS the request-path hot loop).
//!   The kernel consumes the mask's dense bridge and keeps full-length
//!   `m`/`v` device-shaped buffers; its **native mirror**
//!   ([`MethodEngine::apply_native`] — tests, benches, and the pure-rust
//!   §5.1-style long runs) walks the mask's segment-run view instead,
//!   so a native step costs O(active), never touching frozen
//!   coordinates.
//! * native backend — GaLore/GoLore/SIFT baselines, whose projections
//!   don't fit the fused elementwise kernel. Driven through
//!   [`crate::optim::Optimizer::step_runs`]; period boundaries rebuild
//!   their active-region index maps via `on_mask_refresh`.

use crate::config::{Method, OptFamily, RunConfig};
use crate::coordinator::{LisaScheduler, LisaVariant, Mask, MaskRuns,
                         MaskSet};
use crate::manifest::Manifest;
use crate::metrics::Timer;
use crate::obs;
use crate::optim::{galore, Optimizer, SiftOptimizer};
use crate::rng::Rng;
use crate::runtime::bundle::UpdateKind;
use crate::runtime::ModelBundle;
use anyhow::{ensure, Result};

/// Which update path executes the step.
enum Backend {
    /// Fused HLO kernel; optimizer state lives in rust-owned flat vecs
    /// (the kernel's contract is full-length buffers).
    HloAdamW { m: Vec<f32>, v: Vec<f32>, t: u64 },
    HloSgdm { buf: Vec<f32> },
    /// Native baseline optimizer (run-aware).
    Native(Box<dyn Optimizer>),
}

/// Mask-refresh strategy at period boundaries.
enum MaskPlan {
    /// Fixed full mask.
    Full,
    /// Tensorwise i.i.d. resample (scale 1, the §5.2 naïve baseline).
    TensorIid { r: f64 },
    /// Tensorwise WOR: walk an eq.-(3) partition; fresh set per cycle.
    TensorWor { r: f64, set: MaskSet, order: Vec<usize>, pos: usize },
    /// LISA family via the Algorithm 2 scheduler.
    Lisa { sched: LisaScheduler },
    /// Mask fixed to full; the method lives in the native backend.
    Passthrough,
}

/// The per-run method engine.
pub struct MethodEngine {
    pub method: Method,
    man: Manifest,
    mask: Mask,
    plan: MaskPlan,
    backend: Backend,
    opt: crate::config::OptConfig,
    /// Period boundaries seen (diagnostics).
    pub periods: usize,
}

impl MethodEngine {
    pub fn new(man: &Manifest, cfg: &RunConfig, rng: &mut Rng)
               -> Result<Self> {
        let n = man.padded_len;
        let r = cfg.mask.keep_ratio;
        let plan = match cfg.method {
            Method::Full => MaskPlan::Full,
            Method::IidMask => MaskPlan::TensorIid { r },
            Method::WorMask => {
                let set = MaskSet::tensor_partition(man, r, rng)?;
                let order = rng.permutation(set.m());
                MaskPlan::TensorWor { r, set, order, pos: 0 }
            }
            Method::Lisa | Method::LisaScale | Method::LisaWorNoScale
            | Method::LisaWor => {
                let variant = match cfg.method {
                    Method::Lisa => LisaVariant::Lisa,
                    Method::LisaScale => LisaVariant::LisaScale,
                    Method::LisaWorNoScale => LisaVariant::LisaWorNoScale,
                    _ => LisaVariant::LisaWor,
                };
                let middle = man.middle_layers();
                ensure!(!middle.is_empty(),
                        "{} has no middle layers for LISA", man.name);
                MaskPlan::Lisa {
                    sched: LisaScheduler::new(variant, middle,
                                              cfg.mask.gamma),
                }
            }
            Method::Galore | Method::Golore | Method::Sift => {
                MaskPlan::Passthrough
            }
        };

        let backend = match cfg.method {
            Method::Galore => Backend::Native(Box::new(galore::galore(
                &man.params, n, cfg.mask.rank, refresh_steps(cfg),
                cfg.seed,
            ))),
            Method::Golore => Backend::Native(Box::new(galore::golore(
                &man.params, n, cfg.mask.rank, refresh_steps(cfg),
                cfg.seed,
            ))),
            Method::Sift => Backend::Native(Box::new(SiftOptimizer::new(
                n, man.total_len, cfg.mask.topk, refresh_steps(cfg),
            ))),
            _ => match cfg.opt.family {
                OptFamily::AdamW => Backend::HloAdamW {
                    m: vec![0.0; n],
                    v: vec![0.0; n],
                    t: 0,
                },
                OptFamily::Sgdm => Backend::HloSgdm { buf: vec![0.0; n] },
            },
        };

        // Mask starts full-over-real-params (padding frozen).
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, man.total_len, 1.0)?;
        Ok(Self {
            method: cfg.method,
            man: man.clone(),
            mask,
            plan,
            backend,
            opt: cfg.opt.clone(),
            periods: 0,
        })
    }

    /// Refresh the mask at a period boundary (K epochs / K steps) and
    /// rebuild the native backend's active-region index map for the new
    /// support. Errors (e.g. a malformed manifest's tensor table)
    /// surface to the caller instead of panicking a worker thread.
    pub fn on_period(&mut self, rng: &mut Rng) -> Result<()> {
        let t = Timer::start();
        self.periods += 1;
        let total = self.man.total_len;
        match &mut self.plan {
            MaskPlan::Full | MaskPlan::Passthrough => {}
            MaskPlan::TensorIid { r } => {
                let mut mask = MaskSet::tensor_iid(&self.man, *r, rng)?;
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
            MaskPlan::TensorWor { r, set, order, pos } => {
                if *pos >= order.len() {
                    // Cycle exhausted: fresh partition + fresh order
                    // (Algorithm 1 line 4, epochwise instantiation).
                    *set = MaskSet::tensor_partition(&self.man, *r, rng)?;
                    *order = rng.permutation(set.m());
                    *pos = 0;
                }
                let j = order[*pos];
                *pos += 1;
                let mut mask = set.masks[j].clone();
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
            MaskPlan::Lisa { sched } => {
                let act = sched.next_period(rng);
                let mut mask =
                    MaskSet::layerwise(&self.man, &act.layers, act.scale)?;
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
        }
        // Period boundary = the one place compact optimizer state is
        // remapped (carry still-active, reset re-activated, free the
        // rest). The step path then only walks the runs.
        if let Backend::Native(opt) = &mut self.backend {
            opt.on_mask_refresh(self.mask.runs());
        }
        obs::MASK_REFRESH_SECONDS.observe(t.total());
        obs::STATE_BYTES.set(self.state_bytes() as f64);
        obs::KEEP_RATIO.set(self.keep_ratio());
        Ok(())
    }

    /// Apply one optimizer step (dispatches HLO kernel or native).
    pub fn apply(&mut self, bundle: &ModelBundle, p: &mut Vec<f32>,
                 g: &[f32], lr: f32) -> Result<()> {
        let t = Timer::start();
        let Self { backend, mask, opt, .. } = self;
        let out = match backend {
            Backend::HloAdamW { m, v, t } => {
                ensure!(bundle.update_kind == UpdateKind::AdamW,
                        "bundle update kind mismatch");
                *t += 1;
                let bc1 = 1.0 - (opt.beta1 as f32).powi(*t as i32);
                let bc2 = 1.0 - (opt.beta2 as f32).powi(*t as i32);
                let hp = [
                    lr,
                    opt.beta1 as f32,
                    opt.beta2 as f32,
                    opt.eps as f32,
                    opt.weight_decay as f32,
                    bc1,
                    bc2,
                    0.0,
                ];
                bundle.adamw_update(p, g, mask.values(), m, v, &hp)
            }
            Backend::HloSgdm { buf } => {
                ensure!(bundle.update_kind == UpdateKind::Sgdm,
                        "bundle update kind mismatch");
                let hp = [
                    lr,
                    opt.momentum as f32,
                    opt.weight_decay as f32,
                    if opt.nesterov { 1.0 } else { 0.0 },
                ];
                bundle.sgdm_update(p, g, mask.values(), buf, &hp)
            }
            Backend::Native(o) => {
                o.step_runs(p, g, mask.runs(), lr);
                Ok(())
            }
        };
        obs::STEP_SECONDS.observe(t.total());
        out
    }

    /// Apply a step with a *native* optimizer mirroring the HLO kernel —
    /// used by tests and the pure-rust fast path (no PJRT dispatch).
    /// Walks the mask's segment runs: O(active) work, frozen
    /// coordinates are never read.
    pub fn apply_native(&mut self, p: &mut [f32], g: &[f32], lr: f32) {
        let t = Timer::start();
        let Self { backend, mask, opt, .. } = self;
        match backend {
            Backend::HloAdamW { m, v, t } => {
                *t += 1;
                let bc1 = 1.0 - (opt.beta1 as f32).powi(*t as i32);
                let bc2 = 1.0 - (opt.beta2 as f32).powi(*t as i32);
                let (b1, b2) = (opt.beta1 as f32, opt.beta2 as f32);
                let (eps, wd) =
                    (opt.eps as f32, opt.weight_decay as f32);
                for r in mask.runs().runs() {
                    for i in r.offset..r.end() {
                        let gm = r.scale * g[i];
                        let mi = b1 * m[i] + (1.0 - b1) * gm;
                        let vi = b2 * v[i] + (1.0 - b2) * gm * gm;
                        m[i] = mi;
                        v[i] = vi;
                        p[i] -= lr
                            * ((mi / bc1) / ((vi / bc2).sqrt() + eps)
                                + wd * p[i]);
                    }
                }
            }
            Backend::HloSgdm { buf } => {
                let mu = opt.momentum as f32;
                let wd = opt.weight_decay as f32;
                let nesterov = opt.nesterov;
                for r in mask.runs().runs() {
                    for i in r.offset..r.end() {
                        let gm = r.scale * g[i] + wd * p[i];
                        let b = mu * buf[i] + gm;
                        buf[i] = b;
                        let upd = if nesterov { gm + mu * b } else { b };
                        p[i] -= lr * upd;
                    }
                }
            }
            Backend::Native(o) => o.step_runs(p, g, mask.runs(), lr),
        }
        obs::STEP_SECONDS.observe(t.total());
    }

    /// Current mask (read-only view).
    pub fn mask(&self) -> &Mask {
        &self.mask
    }

    /// Current mask's segment-run view (O(1)).
    pub fn runs(&self) -> &MaskRuns {
        self.mask.runs()
    }

    /// Current mask keep-ratio (runs-derived, O(1)).
    pub fn keep_ratio(&self) -> f64 {
        self.mask.keep_ratio()
    }

    /// Bytes of optimizer state under the paper's residency model
    /// (frozen coordinates hold no state). For the native backends this
    /// is the *live* figure reported by the optimizer itself; for the
    /// HLO arms it is runs-derived (the kernel bridge keeps full-length
    /// buffers device-side).
    pub fn state_bytes(&self) -> usize {
        match &self.backend {
            Backend::HloAdamW { .. } => self.mask.active_count() * 8,
            Backend::HloSgdm { .. } => self.mask.active_count() * 4,
            Backend::Native(opt) => opt.state_bytes(),
        }
    }
}

fn refresh_steps(cfg: &RunConfig) -> usize {
    cfg.mask.period.max(1)
}

/// Freeze the padding tail `total..len` (defensive: the constructors
/// already leave padding at zero).
fn clamp_to_total(mask: &mut Mask, total: usize) -> Result<()> {
    let n = mask.len();
    if total < n {
        mask.set_segment(total, n - total, 0.0)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn toy_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 4,
 "total_len": 20, "padded_len": 24,
 "params": [
  {"name": "in_w", "shape": [4], "layer": "embed", "offset": 0, "len": 4},
  {"name": "block_0.w", "shape": [4], "layer": "block_0", "offset": 4, "len": 4},
  {"name": "block_1.w", "shape": [4], "layer": "block_1", "offset": 8, "len": 4},
  {"name": "block_2.w", "shape": [4], "layer": "block_2", "offset": 12, "len": 4},
  {"name": "out_w", "shape": [4], "layer": "head", "offset": 16, "len": 4}
 ],
 "data": {"batch": 2},
 "artifacts": {"train": "t", "eval": "e", "init": "i",
               "update": {"adamw": "a", "sgdm": "s"}}
}"#,
        )
        .unwrap();
        Manifest::from_json(&j, Path::new("/tmp")).unwrap()
    }

    fn cfg_with(method: Method) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.method = method;
        cfg.mask.gamma = 1;
        cfg.mask.keep_ratio = 0.5;
        cfg
    }

    #[test]
    fn full_mask_covers_real_params_only() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(0);
        let eng =
            MethodEngine::new(&man, &cfg_with(Method::Full), &mut rng)
                .unwrap();
        assert_eq!(eng.mask().active_count(), 20);
        assert!(eng.mask().values()[20..].iter().all(|&v| v == 0.0));
        // the run view is the single segment over the real params
        assert_eq!(eng.runs().runs().len(), 1);
        assert_eq!(eng.runs().active_count(), 20);
    }

    #[test]
    fn lisa_wor_traverses_all_middle_layers() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(1);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        let mut active_union = vec![false; 24];
        for _ in 0..3 {
            eng.on_period(&mut rng).unwrap();
            for (i, &v) in eng.mask().values().iter().enumerate() {
                if v != 0.0 {
                    active_union[i] = true;
                }
            }
            // exactly embed + head + 1 middle layer active
            assert_eq!(eng.mask().active_count(), 12);
            // middle scale = N_L/γ = 3
            let mid_scales: Vec<f32> = eng.mask().values()[4..16]
                .iter()
                .cloned()
                .filter(|&v| v != 0.0)
                .collect();
            assert!(mid_scales.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
        // after 3 periods every middle layer was visited
        assert!(active_union[..20].iter().all(|&b| b));
    }

    #[test]
    fn lisa_no_scale_uses_unit_scale() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(2);
        let mut eng = MethodEngine::new(
            &man, &cfg_with(Method::LisaWorNoScale), &mut rng,
        )
        .unwrap();
        eng.on_period(&mut rng).unwrap();
        assert!(eng.mask().values().iter()
            .all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn wor_mask_cycles_cover_everything_with_scale_m() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(3);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::WorMask), &mut rng)
                .unwrap();
        let mut sum = vec![0.0f32; 24];
        for _ in 0..2 {
            // one cycle = M = 2 periods
            eng.on_period(&mut rng).unwrap();
            for (s, &v) in sum.iter_mut().zip(eng.mask().values()) {
                *s += v;
            }
        }
        // eq. (3): over a cycle, Σ masks = M·1 on real params
        assert!(sum[..20].iter().all(|&s| (s - 2.0).abs() < 1e-6),
                "{sum:?}");
        assert!(sum[20..].iter().all(|&s| s == 0.0));
    }

    #[test]
    fn iid_mask_varies_across_periods() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(4);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::IidMask), &mut rng)
                .unwrap();
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..12 {
            eng.on_period(&mut rng).unwrap();
            distinct.insert(
                eng.mask()
                    .values()
                    .iter()
                    .map(|&v| v != 0.0)
                    .collect::<Vec<bool>>(),
            );
        }
        assert!(distinct.len() > 1, "iid mask never changed");
    }

    #[test]
    fn native_backends_step_without_bundle() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(5);
        for method in [Method::Galore, Method::Golore, Method::Sift,
                       Method::Full] {
            let mut eng =
                MethodEngine::new(&man, &cfg_with(method), &mut rng)
                    .unwrap();
            eng.on_period(&mut rng).unwrap();
            let mut p = vec![0.5f32; 24];
            let g = vec![0.1f32; 24];
            eng.apply_native(&mut p, &g, 0.01);
            // some coordinate moved (SIFT may pick a non-head subset)
            assert!(p.iter().any(|&x| (x - 0.5).abs() > 0.0),
                    "{method:?} did not update");
        }
    }

    #[test]
    fn state_bytes_reflect_masking() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(6);
        let mut full =
            MethodEngine::new(&man, &cfg_with(Method::Full), &mut rng)
                .unwrap();
        full.on_period(&mut rng).unwrap();
        let mut lisa =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        lisa.on_period(&mut rng).unwrap();
        assert!(lisa.state_bytes() < full.state_bytes());
    }

    #[test]
    fn native_mirror_skips_frozen_runs_but_matches_dense_math() {
        // The run-walking HLO mirror must equal the dense reference on
        // a LISA-shaped mask, and leave frozen coords bit-identical.
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(7);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        eng.on_period(&mut rng).unwrap();
        let n = 24;
        let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut p = p0.clone();
        eng.apply_native(&mut p, &g, 1e-3);
        let mut pd = p0.clone();
        let mut dense =
            crate::optim::reference::DenseAdamW::default_hp(n);
        dense.step(&mut pd, &g, eng.mask().values(), 1e-3);
        for i in 0..n {
            assert_eq!(p[i].to_bits(), pd[i].to_bits(), "coord {i}");
            if eng.mask().value(i) == 0.0 {
                assert_eq!(p[i].to_bits(), p0[i].to_bits());
            }
        }
    }
}
