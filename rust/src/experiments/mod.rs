//! Experiment drivers shared by `benches/` and `examples/`.
//!
//! Each paper table/figure maps to one driver here (see DESIGN.md's
//! experiment index); the bench binaries are thin wrappers that call
//! these and print/persist the rows. Keeping the logic in the library
//! means integration tests can assert on the *shape* of each result
//! (who wins, slopes, reduction factors) without duplicating setup.

use crate::config::{Method, OptFamily, RunConfig, Schedule};
use crate::data::{ClassTask, Corpus, CorpusConfig, TaskSpec};
use crate::runtime::bundle::UpdateKind;
use crate::runtime::{artifacts_dir, ModelBundle, Runtime};
use crate::train::{train_classifier, train_lm, TrainOutcome};
use anyhow::Result;
use std::path::Path;

/// Scale knob for bench runtimes: `OMGD_BENCH_SCALE` ∈ (0, 1] shrinks
/// epochs/steps for smoke runs (default 1.0 = paper-shaped runs).
pub fn bench_scale() -> f64 {
    std::env::var("OMGD_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .filter(|&x| x > 0.0 && x <= 1.0)
        .unwrap_or(1.0)
}

/// Scaled count, at least `min`.
pub fn scaled(base: usize, min: usize) -> usize {
    ((base as f64 * bench_scale()).round() as usize).max(min)
}

/// Common fine-tuning configuration for the Tables 3/5/6 experiments.
#[derive(Clone, Debug)]
pub struct FinetuneSetup {
    pub model: String,
    pub epochs: usize,
    pub lr: f64,
    pub gamma: usize,
    pub period: usize,
    pub keep_ratio: f64,
    pub rank: usize,
    pub seed: u64,
}

impl Default for FinetuneSetup {
    fn default() -> Self {
        Self {
            model: "mlp-glue".into(),
            epochs: 12,
            lr: 2e-3,
            gamma: 4,
            period: 1,
            keep_ratio: 0.5,
            rank: 8,
            seed: 0,
        }
    }
}

/// Load a bundle for a config (AdamW update artifact).
pub fn load_bundle(rt: &Runtime, model: &str) -> Result<ModelBundle> {
    let dir = artifacts_dir(None);
    ModelBundle::load(rt, &dir, model, UpdateKind::AdamW)
}

/// Load a bundle with the SGDM update artifact (Table 4).
pub fn load_bundle_sgdm(rt: &Runtime, model: &str) -> Result<ModelBundle> {
    let dir = artifacts_dir(None);
    ModelBundle::load(rt, &dir, model, UpdateKind::Sgdm)
}

/// Fine-tune one (method, task) cell.
pub fn finetune_cell(
    bundle: &ModelBundle,
    task: &ClassTask,
    method: Method,
    setup: &FinetuneSetup,
    opt_family: OptFamily,
) -> Result<TrainOutcome> {
    let steps_per_epoch =
        task.n_train().div_ceil(bundle.man.data.batch);
    let mut cfg = RunConfig::default();
    cfg.model = setup.model.clone();
    cfg.method = method;
    cfg.opt.family = opt_family;
    cfg.opt.lr = setup.lr;
    cfg.mask.gamma = setup.gamma;
    cfg.mask.period = setup.period;
    cfg.mask.keep_ratio = setup.keep_ratio;
    cfg.mask.rank = setup.rank;
    cfg.steps = setup.epochs * steps_per_epoch;
    cfg.eval_every = 0;
    cfg.seed = setup.seed;
    train_classifier(bundle, &cfg, task)
}

/// Build the task for a spec sized to the bundle.
pub fn task_for(bundle: &ModelBundle, spec: &TaskSpec) -> ClassTask {
    ClassTask::from_spec(spec, bundle.man.data.d_in,
                         bundle.man.data.n_class)
}

/// Table 3/5-style method roster.
pub fn adamw_method_roster() -> Vec<Method> {
    vec![
        Method::Full,
        Method::Golore,
        Method::Sift,
        Method::Lisa,
        Method::LisaScale,
        Method::LisaWorNoScale,
        Method::LisaWor,
    ]
}

/// Table 4 roster (SGDM tensorwise masks).
pub fn sgdm_method_roster() -> Vec<Method> {
    vec![Method::Full, Method::IidMask, Method::WorMask]
}

/// Pre-training setup for Fig. 5 (LISA vs LISA-WOR on the LM).
pub struct PretrainSetup {
    pub model: String,
    pub steps: usize,
    pub lr: f64,
    pub gamma: usize,
    pub period: usize,
    pub seed: u64,
    pub eval_every: usize,
}

impl Default for PretrainSetup {
    fn default() -> Self {
        Self {
            model: "gpt-tiny".into(),
            steps: 300,
            lr: 6e-4,
            gamma: 2,
            period: 20,
            seed: 0,
            eval_every: 25,
        }
    }
}

/// Run one pre-training leg; the corpus is derived from the bundle
/// geometry so all methods share data.
pub fn pretrain_cell(
    bundle: &ModelBundle,
    method: Method,
    setup: &PretrainSetup,
) -> Result<TrainOutcome> {
    let corpus = pretrain_corpus(bundle, setup.steps);
    let mut cfg = RunConfig::default();
    cfg.model = setup.model.clone();
    cfg.method = method;
    cfg.opt.lr = setup.lr;
    cfg.mask.gamma = setup.gamma;
    cfg.mask.period = setup.period;
    cfg.steps = setup.steps;
    cfg.eval_every = setup.eval_every;
    cfg.seed = setup.seed;
    cfg.schedule = Schedule::CosineWarmup {
        warmup: setup.steps / 10,
        total: setup.steps,
        min_lr: setup.lr * 0.1,
    };
    train_lm(bundle, &cfg, &corpus)
}

/// Corpus sized so an experiment sees a few epochs of distinct windows.
pub fn pretrain_corpus(bundle: &ModelBundle, steps: usize) -> Corpus {
    let windows = (bundle.man.data.batch * steps / 4).clamp(64, 4096);
    Corpus::generate(
        CorpusConfig {
            vocab: bundle.man.data.vocab,
            tokens: windows * (bundle.man.data.seq + 1),
            branching: 8,
            zipf_s: 1.1,
            seed: 7,
        },
        bundle.man.data.seq,
    )
}

/// True if the artifacts for `model` exist (benches skip gracefully
/// when `make artifacts` hasn't been run for larger configs).
pub fn artifacts_present(model: &str) -> bool {
    artifacts_dir(None).join(format!("{model}.json")).exists()
}

/// Results directory for bench CSV outputs.
pub fn results_dir() -> std::path::PathBuf {
    let p = Path::new("results");
    std::fs::create_dir_all(p).ok();
    p.to_path_buf()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    #[test]
    fn scaled_respects_minimum() {
        // With no env override the scale is 1.0.
        assert_eq!(scaled(100, 5), (100.0 * bench_scale()) as usize);
        assert!(scaled(1, 5) >= 5);
        assert!(scaled(0, 3) >= 3);
    }

    #[test]
    fn rosters_cover_the_paper_tables() {
        let adamw = adamw_method_roster();
        // Table 3/5 roster: full + 2 compressors + 4 LISA variants.
        assert_eq!(adamw.len(), 7);
        assert!(adamw.contains(&Method::Full));
        assert!(adamw.contains(&Method::LisaWor));
        assert!(adamw.contains(&Method::Golore));
        assert!(adamw.contains(&Method::Sift));
        // exactly two wor methods (lisa-wor and its no-scale ablation)
        assert_eq!(adamw.iter().filter(|m| m.is_wor()).count(), 2);
        let sgdm = sgdm_method_roster();
        assert_eq!(sgdm,
                   vec![Method::Full, Method::IidMask, Method::WorMask]);
    }

    #[test]
    fn setups_have_sane_defaults() {
        let f = FinetuneSetup::default();
        assert!(f.epochs > 0 && f.gamma > 0 && f.period > 0);
        assert!(f.lr > 0.0 && f.keep_ratio > 0.0);
        let p = PretrainSetup::default();
        assert!(p.steps > 0 && p.period > 0 && p.lr > 0.0);
    }
}
