//! # OMGD — Omni-Masked Gradient Descent (reproduction)
//!
//! Production-shaped reproduction of *"Omni-Masked Gradient Descent:
//! Memory-Efficient Optimization via Mask Traversal with Improved
//! Convergence"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the training coordinator: Algorithm 1's
//!   `[M]×[N]` without-replacement traversal ([`coordinator`]), the
//!   LISA/LISA-WOR layer scheduler (Algorithm 2) — masks carried as
//!   canonical segment runs ([`coordinator::MaskRuns`]), runs-first
//!   end to end: native masked steps, residency accounting, and the
//!   HLO dispatch all consume `(offset, len, scale)` runs, O(active)
//!   not O(d), while the dense vector is a lazy, explicitly requested
//!   bridge (`Mask::dense_bridge`) — runs-first native optimizers
//!   with active-region-only moment state ([`optim`]), the analytic
//!   memory model ([`memory`]), the
//!   §5.1 quadratic testbed ([`quadratic`]), data pipelines ([`data`]),
//!   the PJRT runtime ([`runtime`]) that executes AOT-compiled HLO, and
//!   the job-orchestration subsystem ([`jobs`]): hashed [`jobs::JobSpec`]
//!   grid cells sharded across a panic-isolated worker pool, with an
//!   on-disk result cache (true-LRU age/size GC), transport-agnostic
//!   serve sessions over a shared [`jobs::JobHub`], the HTTP/1.1
//!   gateway ([`jobs::net`], `omgd serve --listen`), and distributed
//!   execution over that gateway ([`jobs::remote`] /
//!   [`jobs::sync`]: `omgd worker --connect` lease-pull agents with
//!   content-addressed artifact sync, `omgd grid --remote`
//!   submission).
//! * **L2 (python/compile, build-time)** — JAX models over a flat
//!   parameter vector, lowered once to HLO text.
//! * **L1 (python/compile/kernels, build-time)** — Pallas masked-update
//!   kernels fused into the L2 HLO.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `omgd` binary is self-contained.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod jobs;
pub mod linalg;
pub mod manifest;
pub mod memory;
pub mod metrics;
pub mod obs;
pub mod optim;
pub mod prop;
pub mod quadratic;
pub mod rng;
pub mod runtime;
pub mod train;
pub mod util;
