//! Model bundle: manifest + compiled executables for one AOT config.
//!
//! All hot-path calls go through [`Executable::run_args`] (host slices →
//! rust-owned device buffers → `execute_b`), which avoids both the
//! literal-intermediate copy and the input-buffer leak of the crate's
//! literal `execute` (see runtime/mod.rs).

use super::{to_scalar_f32, to_vec_f32, Arg, Executable, Runtime};
use crate::manifest::Manifest;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Which optimizer-update artifact to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    AdamW,
    Sgdm,
}

/// A loaded model: train / eval / fused-update executables + layout.
pub struct ModelBundle {
    pub man: Manifest,
    pub train: Executable,
    pub eval: Executable,
    pub update: Executable,
    pub update_kind: UpdateKind,
}

impl ModelBundle {
    pub fn load(
        rt: &Runtime,
        artifacts_dir: &Path,
        config: &str,
        update_kind: UpdateKind,
    ) -> Result<Self> {
        let man = Manifest::load(artifacts_dir, config)?;
        let train = rt.load(&man.hlo_path(&man.train_hlo))?;
        let eval = rt.load(&man.hlo_path(&man.eval_hlo))?;
        let upd_file = match update_kind {
            UpdateKind::AdamW => &man.update_adamw_hlo,
            UpdateKind::Sgdm => &man.update_sgdm_hlo,
        };
        let update = rt.load(&man.hlo_path(upd_file))?;
        Ok(Self { man, train, eval, update, update_kind })
    }

    pub fn padded_len(&self) -> usize {
        self.man.padded_len
    }

    /// Initial flat parameters from the AOT init dump.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        self.man.load_init()
    }

    /// One LM forward/backward step: `(loss, grad)`. `x`/`y` are packed
    /// row-major `i32[B, S]`.
    pub fn train_step_lm(&self, flat: &[f32], x: &[i32], y: &[i32])
                         -> Result<(f32, Vec<f32>)> {
        ensure!(self.man.kind == "gpt", "train_step_lm on {}", self.man.kind);
        let (b, s) = (self.man.data.batch, self.man.data.seq);
        ensure!(x.len() == b * s && y.len() == b * s, "bad batch shape");
        let out = self.train.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::I32(x, &[b, s]),
            Arg::I32(y, &[b, s]),
        ])?;
        ensure!(out.len() == 2, "train returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// One classifier step: `(loss, grad)`. `x` is packed `f32[B, d_in]`.
    pub fn train_step_clf(&self, flat: &[f32], x: &[f32], y: &[i32])
                          -> Result<(f32, Vec<f32>)> {
        ensure!(self.man.kind == "mlp", "train_step_clf on {}",
                self.man.kind);
        let (b, d) = (self.man.data.batch, self.man.data.d_in);
        ensure!(x.len() == b * d && y.len() == b, "bad batch shape");
        let out = self.train.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::F32(x, &[b, d]),
            Arg::I32(y, &[b]),
        ])?;
        ensure!(out.len() == 2, "train returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// Held-out LM eval loss.
    pub fn eval_step_lm(&self, flat: &[f32], x: &[i32], y: &[i32])
                        -> Result<f32> {
        let (b, s) = (self.man.data.batch, self.man.data.seq);
        let out = self.eval.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::I32(x, &[b, s]),
            Arg::I32(y, &[b, s]),
        ])?;
        to_scalar_f32(out.first().context("no eval output")?)
    }

    /// Classifier eval: `(loss, n_correct)`.
    pub fn eval_step_clf(&self, flat: &[f32], x: &[f32], y: &[i32])
                         -> Result<(f32, f32)> {
        let (b, d) = (self.man.data.batch, self.man.data.d_in);
        let out = self.eval.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::F32(x, &[b, d]),
            Arg::I32(y, &[b]),
        ])?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_scalar_f32(&out[1])?))
    }

    /// Fused masked-AdamW update (the L1 Pallas kernel, AOT-compiled):
    /// `(p, m, v) ← kernel(hp, p, g, mask, m, v)`.
    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        mask: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        hp: &[f32; 8],
    ) -> Result<()> {
        ensure!(self.update_kind == UpdateKind::AdamW, "not an adamw bundle");
        let n = p.len();
        let out = self.update.run_args(&[
            Arg::F32(hp, &[8]),
            Arg::F32(p, &[n]),
            Arg::F32(g, &[n]),
            Arg::F32(mask, &[n]),
            Arg::F32(m, &[n]),
            Arg::F32(v, &[n]),
        ])?;
        ensure!(out.len() == 3, "update returned {} outputs", out.len());
        *p = to_vec_f32(&out[0])?;
        *m = to_vec_f32(&out[1])?;
        *v = to_vec_f32(&out[2])?;
        Ok(())
    }

    /// Fused masked-SGDM update: `(p, buf) ← kernel(hp, p, g, mask, buf)`.
    pub fn sgdm_update(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        mask: &[f32],
        buf: &mut Vec<f32>,
        hp: &[f32; 4],
    ) -> Result<()> {
        ensure!(self.update_kind == UpdateKind::Sgdm, "not an sgdm bundle");
        let n = p.len();
        let out = self.update.run_args(&[
            Arg::F32(hp, &[4]),
            Arg::F32(p, &[n]),
            Arg::F32(g, &[n]),
            Arg::F32(mask, &[n]),
            Arg::F32(buf, &[n]),
        ])?;
        ensure!(out.len() == 2, "update returned {} outputs", out.len());
        *p = to_vec_f32(&out[0])?;
        *buf = to_vec_f32(&out[1])?;
        Ok(())
    }
}
