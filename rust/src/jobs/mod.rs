//! Async job orchestration: grids of training runs as schedulable work.
//!
//! The paper's sweeps (Tables 3/5/6, Fig. 5) are embarrassingly parallel
//! across methods × seeds × keep-ratios — every cell is one
//! [`JobSpec`]. This subsystem turns the repo's one-run-per-process
//! entry points into a schedulable system:
//!
//! * [`spec`] — [`JobSpec`] (experiment kind + `RunConfig` + seed) with
//!   a stable content hash;
//! * [`queue`] — bounded MPMC priority queue with cancellation;
//! * [`pool`] — `std::thread` worker pool, one PJRT runtime per worker,
//!   panic isolation per job;
//! * [`cache`] — on-disk result cache keyed by spec hash (`--force`
//!   invalidates; age/size GC via [`cache::GcPolicy`], run at open and
//!   as `omgd cache-gc`);
//! * [`journal`] — crash-safe write-ahead job journal (`journal.log`
//!   under the cache dir): fsynced admission/lease/completion records,
//!   replayed by `omgd serve` at startup so queued work and completed
//!   results survive a coordinator crash;
//! * [`report`] — aggregation into [`crate::bench::TablePrinter`] /
//!   [`crate::metrics::CsvWriter`] sinks;
//! * [`serve`] — transport-agnostic JSONL sessions multiplexed over a
//!   shared [`serve::JobHub`] (queue + worker pool + result routing);
//! * [`net`] — HTTP/1.1 gateway (`omgd serve --listen`): N concurrent
//!   connections share one hub, with `429` backpressure (global queue
//!   saturation + per-client `X-OMGD-Client` quotas), HTTP keep-alive
//!   (chunked `POST /jobs` streams), and graceful drain;
//! * [`remote`] — distributed execution over the gateway: the
//!   `omgd worker --connect` pull agent (lease → sync → run → report)
//!   and the `omgd grid --remote` submission client;
//! * [`sync`] — content-addressed artifact sync (frame format +
//!   worker-side [`sync::ArtifactStore`]), keyed by
//!   [`artifact_fingerprint`].
//!
//! Front-ends: `omgd grid` (local pool or `--remote` gateway),
//! `omgd serve` (stdin or `--listen`), `omgd worker`, and
//! `omgd cache-gc` (see `main.rs`), plus the Table 3/5/6 bench
//! binaries, which submit grids built by [`crate::experiments`].

pub mod cache;
pub mod journal;
pub mod net;
pub mod pool;
pub mod queue;
pub mod remote;
pub mod report;
pub mod serve;
pub mod spec;
pub mod sync;

pub use cache::{
    CacheStats, GcPolicy, GcStats, ResultCache, DEFAULT_CACHE_DIR,
};
pub use journal::{JobJournal, PendingJob, Record, Replay};
pub use net::{run_gateway, GatewayStats, ListenOptions};
pub use pool::{run_pool, JobOutcome, JobResult, JobStatus};
pub use queue::{Job, JobQueue, PopScan, PopTimeout, TryPush};
pub use remote::{
    gateway_get, run_grid_remote, run_worker, run_worker_with,
    WorkerOptions, WorkerStats,
};
pub use report::GridReport;
pub use serve::{
    JobHub, LeaseInfo, LeaseReply, PhaseSecs, RemoteDone, RemoteStats,
    ResultLookup, ServeStats, SessionOptions,
};
pub use spec::{ExperimentKind, JobSpec};
pub use sync::{ArtifactStore, DEFAULT_STORE_DIR};

use crate::config::{OptFamily, RunConfig};
use crate::data::ClassTask;
use crate::obs;
use crate::runtime::bundle::UpdateKind;
use crate::runtime::{artifacts_dir, ModelBundle, Runtime};
use crate::train::{train_classifier_ckpt, train_lm_ckpt, CkptCtl};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Options shared by `omgd grid`, `omgd serve`, and the bench drivers.
#[derive(Clone, Debug)]
pub struct GridOptions {
    /// Worker threads; each owns its own PJRT runtime + bundle cache.
    pub workers: usize,
    /// Invalidate and recompute cached cells.
    pub force: bool,
    /// Cache directory override (default [`DEFAULT_CACHE_DIR`]).
    pub cache_dir: Option<String>,
    /// Cache GC policy, run once at cache open (default: no-op).
    pub gc: GcPolicy,
}

impl Default for GridOptions {
    fn default() -> Self {
        Self {
            workers: 1,
            force: false,
            cache_dir: None,
            gc: GcPolicy::default(),
        }
    }
}

/// `OMGD_FORCE` env override for the bench drivers: truthy values only
/// (`1`/`true`/`yes`), matching [`crate::cli::Args::bool`] — a merely
/// *present* `OMGD_FORCE=0` must not blow the cache away.
pub fn force_from_env() -> bool {
    matches!(
        std::env::var("OMGD_FORCE").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// Worker-count default: `OMGD_WORKERS` env override, else available
/// parallelism clamped to 4 (each worker compiles its own executables,
/// so memory — not cores — is the practical ceiling).
pub fn default_workers() -> usize {
    if let Some(n) = std::env::var("OMGD_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
    {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// Run a grid of specs to completion: enqueue all cells, shard them
/// across `opts.workers` threads, reuse cached results unless
/// `opts.force`, and return the (submission-ordered) report.
pub fn run_grid(specs: Vec<JobSpec>, opts: &GridOptions) -> Result<GridReport> {
    let cache = open_cache(opts)?;
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0)?;
    }
    queue.close();
    // Per-cell progress to stderr as workers finish — a paper-shaped
    // grid runs for hours, and silence is indistinguishable from a hung
    // runtime. (Panicked cells get no line here; the report's failure
    // summary covers them.)
    let results = run_pool(&queue, opts.workers, |_wid| {
        let mut inner = cached_runner(&cache, opts.force);
        move |spec: &JobSpec| {
            let r = inner(spec);
            match &r {
                Ok((_, true)) => eprintln!("  [cache] {}", spec.label()),
                Ok((_, false)) => eprintln!("  [done ] {}", spec.label()),
                Err(e) => {
                    eprintln!("  [fail ] {}: {e:#}", spec.label())
                }
            }
            r
        }
    });
    Ok(GridReport::new(results))
}

/// Open the result cache, run the configured GC policy once, and
/// report evictions to stderr — the shared open path for every
/// front-end (grid, serve, gateway).
pub(crate) fn open_cache(opts: &GridOptions) -> Result<ResultCache> {
    let (cache, gc) =
        ResultCache::open_with(opts.cache_dir.as_deref(), &opts.gc)?;
    report_gc(&gc);
    Ok(cache)
}

/// One shared eviction report, so the at-open and periodic GC paths
/// cannot drift apart.
pub(crate) fn report_gc(st: &GcStats) {
    if st.evicted > 0 {
        eprintln!(
            "cache gc: evicted {} entries ({} bytes)",
            st.evicted, st.evicted_bytes
        );
    }
}

/// The production worker function: consult the cache, else execute the
/// spec with this worker's lazily-created runtime, then persist the
/// fresh outcome. Returns `(outcome, from_cache)`.
pub fn cached_runner(
    cache: &ResultCache,
    force: bool,
) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> + '_ {
    let mut runner = SpecRunner::new();
    move |spec| {
        let afp = artifact_fingerprint(&spec.cfg);
        if force {
            cache.invalidate(spec);
        } else if let Some(out) = cache.get(spec, &afp) {
            return Ok((out, true));
        }
        let out = runner.run(spec)?;
        // The cache is best-effort: a full disk or read-only cache dir
        // must not discard an outcome that already cost a training run.
        if let Err(e) = cache.put(spec, &afp, &out) {
            eprintln!(
                "warning: cache write failed for {} ({}): {e:#}",
                spec.label(),
                spec.hash_hex()
            );
        }
        Ok((out, false))
    }
}

/// Fingerprint of the on-disk artifact files backing `cfg.model`
/// (`<model>.*`: manifest, HLO texts, init dump): FNV over sorted
/// (name, size, mtime) triples. Part of the cache-entry identity, so
/// regenerating artifacts under the same model name invalidates cached
/// cells instead of silently replaying pre-regeneration results.
/// mtime-based, so an identical regeneration also misses — conservative
/// in the safe direction.
///
/// The fingerprint is also the content address of artifact sync
/// ([`sync`] / `GET /artifacts/<fp>`): a remote worker caches synced
/// artifact sets — and its results — under the *gateway's* fingerprint,
/// so both ends key their caches identically.
pub fn artifact_fingerprint(cfg: &RunConfig) -> String {
    artifact_fingerprint_at(&resolve_artifacts(&cfg.artifacts_dir), &cfg.model)
}

/// [`artifact_fingerprint`] with the directory already resolved — the
/// shape `GET /artifacts/<fp>` uses to re-verify a fingerprint against
/// the current on-disk state before packing.
pub(crate) fn artifact_fingerprint_at(
    dir: &std::path::Path,
    model: &str,
) -> String {
    let prefix = format!("{model}.");
    let mut entries: Vec<String> = match std::fs::read_dir(dir) {
        Err(_) => return "absent".to_string(),
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().starts_with(&prefix)
            })
            .filter_map(|e| {
                let meta = e.metadata().ok()?;
                let mtime = meta
                    .modified()
                    .ok()?
                    .duration_since(std::time::UNIX_EPOCH)
                    .ok()?;
                Some(format!(
                    "{}:{}:{}.{:09}",
                    e.file_name().to_string_lossy(),
                    meta.len(),
                    mtime.as_secs(),
                    mtime.subsec_nanos()
                ))
            })
            .collect(),
    };
    if entries.is_empty() {
        return "absent".to_string();
    }
    entries.sort();
    format!("{:016x}", spec::fnv1a64(entries.join(";").as_bytes()))
}

/// Per-worker execution state: one PJRT runtime (created on the first
/// non-cached job, so cache replays never touch XLA) plus compiled
/// bundles keyed by `(model, optimizer family)`.
pub struct SpecRunner {
    rt: Option<Runtime>,
    bundles: HashMap<String, ModelBundle>,
    /// Checkpointing: `(cache dir, period in steps)`. Set by workers
    /// running under `--ckpt-period`; `None` (the default) trains
    /// straight through like before.
    ckpt: Option<(PathBuf, usize)>,
}

impl Default for SpecRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl SpecRunner {
    pub fn new() -> Self {
        Self { rt: None, bundles: HashMap::new(), ckpt: None }
    }

    /// Enable periodic checkpointing into `cache_dir` (see
    /// [`crate::train::CkptCtl`]); `period == 0` disables it.
    pub fn set_ckpt(&mut self, cache_dir: &Path, period: usize) {
        self.ckpt = (period > 0)
            .then(|| (cache_dir.to_path_buf(), period));
    }

    /// Build the checkpoint control for one spec: resume from the
    /// newest parked checkpoint (if any) and park new ones every
    /// `period` steps under the spec's hash. Checkpointing is strictly
    /// best-effort at this layer — an unopenable cache dir degrades to
    /// a plain straight-through run.
    fn ckpt_ctl(&self, spec: &JobSpec) -> CkptCtl<'static> {
        let Some((dir, period)) = self.ckpt.clone() else {
            return CkptCtl::default();
        };
        let dir = dir.to_string_lossy().into_owned();
        let Ok(cache) = ResultCache::open(Some(&dir)) else {
            return CkptCtl::default();
        };
        let hash = spec.hash_hex();
        let resume = cache.latest_checkpoint(&hash);
        if let Some(ck) = &resume {
            obs::CKPT_RESUMES.inc();
            eprintln!(
                "  [ckpt ] resuming {} from step {}",
                spec.label(),
                ck.step
            );
        }
        CkptCtl {
            period,
            resume,
            sink: Some(Box::new(move |ck| {
                cache.put_checkpoint(&hash, ck).map(|_| ())
            })),
        }
    }

    fn bundle(&mut self, cfg: &RunConfig) -> Result<&ModelBundle> {
        let key = format!("{}:{}", cfg.model, cfg.opt.family.name());
        if !self.bundles.contains_key(&key) {
            let dir = resolve_artifacts(&cfg.artifacts_dir);
            let man = dir.join(format!("{}.json", cfg.model));
            // Cheap existence check before spinning up PJRT.
            if !man.exists() {
                bail!(
                    "artifacts for {:?} missing at {} (run `make artifacts`)",
                    cfg.model,
                    man.display()
                );
            }
            if self.rt.is_none() {
                self.rt = Some(Runtime::cpu()?);
            }
            let update = match cfg.opt.family {
                OptFamily::AdamW => UpdateKind::AdamW,
                OptFamily::Sgdm => UpdateKind::Sgdm,
            };
            let bundle = ModelBundle::load(
                self.rt.as_ref().unwrap(),
                &dir,
                &cfg.model,
                update,
            )?;
            self.bundles.insert(key.clone(), bundle);
        }
        Ok(&self.bundles[&key])
    }

    /// Execute one spec to completion on this worker's runtime,
    /// resuming from a parked checkpoint when one exists.
    pub fn run(&mut self, spec: &JobSpec) -> Result<JobOutcome> {
        spec.cfg.validate()?;
        let ctl = self.ckpt_ctl(spec);
        match &spec.kind {
            ExperimentKind::Finetune { task, epochs } => {
                let ts = crate::data::find_task(task)
                    .ok_or_else(|| anyhow!("unknown task {task:?}"))?;
                let bundle = self.bundle(&spec.cfg)?;
                let t = ClassTask::from_spec(
                    ts,
                    bundle.man.data.d_in,
                    bundle.man.data.n_class,
                );
                classifier_outcome(bundle, &spec.cfg, &t, *epochs, ctl)
            }
            ExperimentKind::Blobs { dataset, spread, data_seed, epochs } => {
                let bundle = self.bundle(&spec.cfg)?;
                let t = ClassTask::gaussian_blobs(
                    dataset,
                    bundle.man.data.d_in,
                    bundle.man.data.n_class,
                    spec::BLOBS_N_TRAIN,
                    spec::BLOBS_N_TEST,
                    *spread,
                    *data_seed,
                );
                classifier_outcome(bundle, &spec.cfg, &t, *epochs, ctl)
            }
            ExperimentKind::Pretrain => {
                let bundle = self.bundle(&spec.cfg)?;
                let corpus =
                    crate::experiments::pretrain_corpus(bundle, spec.cfg.steps);
                let out = train_lm_ckpt(bundle, &spec.cfg, &corpus, ctl)?;
                Ok(JobOutcome::from_train(&out))
            }
        }
    }
}

/// For classifier kinds the spec's `steps`/`eval_every` are in *epochs*
/// (the bundle's batch size is unknown at spec-build time); resolve them
/// to steps here.
fn classifier_outcome(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    task: &ClassTask,
    epochs: usize,
    ctl: CkptCtl<'_>,
) -> Result<JobOutcome> {
    let steps_per_epoch = task.n_train().div_ceil(bundle.man.data.batch);
    let mut cfg = cfg.clone();
    cfg.steps = epochs.max(1) * steps_per_epoch;
    cfg.eval_every = cfg.eval_every.saturating_mul(steps_per_epoch);
    let out = train_classifier_ckpt(bundle, &cfg, task, ctl)?;
    Ok(JobOutcome::from_train(&out))
}

/// An explicitly-configured artifacts dir is honored verbatim (a typo'd
/// path then fails loudly in [`SpecRunner::bundle`]'s existence check,
/// naming that path). Only the unset/default value falls back to the
/// usual env/CWD/manifest-dir resolution, so grids built from
/// `RunConfig::default()` work under `cargo test` too.
pub(crate) fn resolve_artifacts(configured: &str) -> PathBuf {
    if configured.is_empty()
        || configured == RunConfig::default().artifacts_dir
    {
        artifacts_dir(None)
    } else {
        PathBuf::from(configured)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn missing_model_spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        // A model name no artifacts dir can contain, so the runner fails
        // fast without touching PJRT.
        cfg.model = "no-such-model-xyz".into();
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 1 },
            cfg,
        }
    }

    fn tmp_dir(tag: &str) -> String {
        let d = std::env::temp_dir()
            .join(format!("omgd-grid-test-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d.to_string_lossy().into_owned()
    }

    #[test]
    fn grid_reports_missing_artifacts_as_failed_cells() {
        let dir = tmp_dir("missing");
        let opts = GridOptions {
            workers: 2,
            force: false,
            cache_dir: Some(dir.clone()),
            ..GridOptions::default()
        };
        let specs = vec![missing_model_spec(0), missing_model_spec(1)];
        let report = run_grid(specs, &opts).unwrap();
        assert_eq!(report.n_jobs(), 2);
        assert_eq!(report.n_failed(), 2);
        assert_eq!(report.n_cached(), 0);
        match &report.results[0].status {
            JobStatus::Failed(msg) => assert!(msg.contains("artifacts")),
            other => panic!("expected Failed, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_cells_are_not_cached() {
        let dir = tmp_dir("nocache");
        let opts = GridOptions {
            workers: 1,
            force: false,
            cache_dir: Some(dir.clone()),
            ..GridOptions::default()
        };
        let report =
            run_grid(vec![missing_model_spec(0)], &opts).unwrap();
        assert_eq!(report.n_failed(), 1);
        // Re-running must fail again (no poisoned cache entry), not hit.
        let report2 =
            run_grid(vec![missing_model_spec(0)], &opts).unwrap();
        assert_eq!(report2.n_failed(), 1);
        assert_eq!(report2.n_cached(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }
}
