//! `omgd serve`: long-lived JSONL job loop — the seed of a
//! request-serving path.
//!
//! Protocol (one JSON object per line):
//!
//! * request  → `{"kind":"finetune","task":"CoLA","method":"lisa-wor",
//!   "seed":1,"epochs":4,"priority":5}` (see [`JobSpec::from_json`] for
//!   the full field set; `priority` is optional, higher runs first)
//! * control  → `{"cmd":"shutdown"}` stops accepting and drains
//! * ack      → `{"accepted":<seq>,"hash":"<spec hash>","label":"..."}`
//! * result   → `{"seq":N,"label":"...","hash":"...","status":"done",
//!   "cached":false,"final_metric":X,"tail_loss":X,"steps":N,"secs":X}`
//!   or `{"seq":N,...,"status":"failed","error":"..."}`
//! * reject   → `{"error":"...","line":N}`
//!
//! Requests are sharded across the worker pool as they arrive; results
//! stream back in *completion* order (match on `seq`). Acks and rejects
//! are written from the reader, results from the collector, both behind
//! one writer lock, each line flushed — a client can pipeline requests
//! and consume results concurrently.

use super::cache::ResultCache;
use super::pool::{worker_loop, JobOutcome, JobResult, JobStatus};
use super::queue::JobQueue;
use super::spec::JobSpec;
use super::{cached_runner, GridOptions};
use crate::util::json::Json;
use anyhow::Result;
use std::io::{BufRead, Write};
use std::sync::{mpsc, Mutex};

/// Counters for one serve session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub accepted: usize,
    pub rejected: usize,
    pub done: usize,
    pub failed: usize,
    pub cached: usize,
}

/// Serve with the production cache-aware runner.
pub fn serve<R, W>(input: R, output: W, opts: &GridOptions) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
{
    let cache = ResultCache::open(opts.cache_dir.as_deref())?;
    serve_with(input, output, opts.workers, |_wid| {
        cached_runner(&cache, opts.force)
    })
}

/// Serve with an arbitrary worker factory (tests inject stubs).
///
/// Deadlock discipline: nothing inside the thread scope early-returns —
/// the queue is always closed before the scope joins, so workers can
/// never be left blocked on `pop()`.
pub fn serve_with<R, W, M, F>(
    input: R,
    output: W,
    workers: usize,
    make_worker: M,
) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let workers = workers.max(1);
    let queue = JobQueue::bounded((2 * workers).max(8));
    let out = Mutex::new(output);
    let (tx, rx) = mpsc::channel::<JobResult>();

    let stats = std::thread::scope(|s| {
        let make = &make_worker;
        let queue_ref = &queue;
        for wid in 0..workers {
            let tx = tx.clone();
            s.spawn(move || {
                let mut work = make(wid);
                worker_loop(queue_ref, &mut work, &tx);
            });
        }
        drop(tx);

        let out_ref = &out;
        let collector = s.spawn(move || {
            let (mut done, mut failed, mut cached) = (0usize, 0usize, 0usize);
            for r in rx {
                if r.from_cache {
                    cached += 1;
                }
                if r.is_ok() {
                    done += 1;
                } else {
                    failed += 1;
                }
                write_line(out_ref, &result_line(&r));
            }
            (done, failed, cached)
        });

        let (mut accepted, mut rejected) = (0usize, 0usize);
        let mut lineno = 0usize;
        for line in input.lines() {
            lineno += 1;
            let line = match line {
                Ok(l) => l,
                Err(_) => break, // treat a broken pipe as EOF
            };
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let j = match Json::parse(text) {
                Ok(j) => j,
                Err(e) => {
                    rejected += 1;
                    write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&e.to_string())
                        ),
                    );
                    continue;
                }
            };
            if j.get("cmd").and_then(Json::as_str) == Some("shutdown") {
                break;
            }
            let priority =
                j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i32;
            match JobSpec::from_json(&j) {
                Ok(spec) => {
                    let (hash, label) = (spec.hash_hex(), spec.label());
                    // Hold the writer lock across push + ack: a cached
                    // job can complete in microseconds, and the
                    // protocol promises the ack (seq ↔ request
                    // mapping) reaches the client before its result
                    // line. Workers drain the queue without this lock,
                    // so a full-queue push still makes progress.
                    let mut o = out_ref.lock().unwrap();
                    match queue.push(spec, priority) {
                        Ok(seq) => {
                            accepted += 1;
                            let _ = writeln!(
                                o,
                                "{{\"accepted\":{seq},\"hash\":\
                                 \"{hash}\",\"label\":\"{}\"}}",
                                esc(&label)
                            );
                            let _ = o.flush();
                        }
                        Err(_) => rejected += 1,
                    }
                }
                Err(e) => {
                    rejected += 1;
                    write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&format!("{e:#}"))
                        ),
                    );
                }
            }
        }
        queue.close();
        let (done, failed, cached) = collector.join().unwrap();
        ServeStats { accepted, rejected, done, failed, cached }
    });
    Ok(stats)
}

fn write_line<W: Write>(out: &Mutex<W>, line: &str) {
    let mut o = out.lock().unwrap();
    let _ = writeln!(o, "{line}");
    let _ = o.flush(); // stream each line: clients read results live
}

fn result_line(r: &JobResult) -> String {
    let head = format!(
        "{{\"seq\":{},\"label\":\"{}\",\"hash\":\"{}\",\"status\":\"{}\",\
         \"cached\":{}",
        r.seq,
        esc(&r.spec.label()),
        r.spec.hash_hex(),
        r.status.tag(),
        r.from_cache,
    );
    match &r.status {
        JobStatus::Done(o) => format!(
            "{head},\"final_metric\":{},\"tail_loss\":{},\"steps\":{},\
             \"secs\":{}}}",
            ser_f(o.final_metric),
            ser_f(o.tail_loss),
            o.steps,
            ser_f(r.secs),
        ),
        JobStatus::Failed(e) | JobStatus::Panicked(e) => {
            format!("{head},\"error\":\"{}\"}}", esc(e))
        }
    }
}

use crate::util::json::{escape_str as esc, ser_f64 as ser_f};

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_factory(
        _wid: usize,
    ) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> {
        |spec: &JobSpec| {
            if spec.cfg.seed == 99 {
                anyhow::bail!("rigged failure");
            }
            Ok((
                JobOutcome {
                    final_metric: spec.cfg.seed as f64 + 0.5,
                    tail_loss: 0.25,
                    steps: 2,
                    train_secs: 0.0,
                    loss_series: vec![(0, 1.0)],
                    eval_series: vec![],
                },
                false,
            ))
        }
    }

    fn run_serve(input: &str, workers: usize) -> (ServeStats, Vec<Json>) {
        let mut out: Vec<u8> = Vec::new();
        let stats = serve_with(
            input.as_bytes(),
            &mut out,
            workers,
            stub_factory,
        )
        .unwrap();
        let lines = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every output line is JSON"))
            .collect();
        (stats, lines)
    }

    #[test]
    fn serves_requests_and_streams_results() {
        let input = "\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":0,\"epochs\":1}\n\
{\"kind\":\"finetune\",\"task\":\"SST-2\",\"seed\":1,\"epochs\":1}\n\
{\"cmd\":\"shutdown\"}\n";
        let (stats, lines) = run_serve(input, 2);
        assert_eq!(stats.accepted, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.done, 2);
        assert_eq!(stats.failed, 0);
        let acks =
            lines.iter().filter(|j| j.get("accepted").is_some()).count();
        let results: Vec<&Json> =
            lines.iter().filter(|j| j.get("status").is_some()).collect();
        assert_eq!(acks, 2);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.at("status").as_str(), Some("done"));
            assert!(r.at("final_metric").as_f64().is_some());
        }
    }

    #[test]
    fn bad_lines_are_rejected_not_fatal() {
        let input = "\
this is not json\n\
{\"kind\":\"nope\"}\n\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":2,\"epochs\":1}\n";
        // No shutdown line: EOF also drains cleanly.
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.done, 1);
        let errors =
            lines.iter().filter(|j| j.get("error").is_some()).count();
        assert_eq!(errors, 2);
    }

    #[test]
    fn failed_jobs_stream_an_error_result() {
        let input =
            "{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":99,\"epochs\":1}\n";
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.failed, 1);
        let r = lines
            .iter()
            .find(|j| j.get("status").is_some())
            .expect("one result line");
        assert_eq!(r.at("status").as_str(), Some("failed"));
        assert!(r.at("error").as_str().unwrap().contains("rigged"));
    }
}
