//! Transport-agnostic JSONL serve sessions over a shared [`JobHub`].
//!
//! One [`JobHub`] owns the bounded [`JobQueue`], the result router, and
//! the hub-lifetime counters; any number of concurrent sessions — the
//! classic stdin/stdout loop of `omgd serve`, or one per HTTP
//! connection in [`super::net`] — multiplex jobs into the same worker
//! pool and result cache. Each session speaks the JSONL protocol (one
//! JSON object per line):
//!
//! * request  → `{"kind":"finetune","task":"CoLA","method":"lisa-wor",
//!   "seed":1,"epochs":4,"priority":5}` (see [`JobSpec::from_json`] for
//!   the full field set; `priority` is optional, higher runs first)
//! * control  → `{"cmd":"shutdown"}` ends the session (input EOF too)
//! * ack      → `{"accepted":<seq>,"hash":"<spec hash>","label":"..."}`
//! * result   → `{"seq":N,"label":"...","hash":"...","status":"done",
//!   "cached":false,"final_metric":X,"tail_loss":X,"steps":N,"secs":X}`
//!   or `{"seq":N,...,"status":"failed","error":"..."}`
//! * reject   → `{"error":"...","line":N}`
//!
//! Results stream back in *completion* order (match on `seq`); a
//! request's ack always precedes its result line. The hub routes each
//! result only to the session that submitted it, so concurrent clients
//! sharing one hub never see each other's lines. Per-session
//! backpressure is [`SessionOptions::max_in_flight`]: submission of the
//! next request blocks until a result drains. Full protocol spec with
//! examples: `docs/serve-protocol.md`.
//!
//! Besides the local pool, queued jobs can be **leased** to remote
//! workers ([`JobHub::try_lease`] / [`JobHub::complete_remote`], used
//! by the gateway's `/work/*` endpoints — see [`super::net`] and
//! [`super::remote`]): a lease parks the job in a table with a TTL, a
//! completed lease dispatches through the same seq-routed channel a
//! local result would, and an expired lease is requeued **with its
//! original seq** so the submitting session's ack stays valid across
//! worker crashes.

use super::pool::{worker_loop, JobOutcome, JobResult, JobStatus};
use super::queue::{Job, JobQueue, PopTimeout, TryPush};
use super::spec::JobSpec;
use super::{cached_runner, open_cache, GridOptions};
use crate::util::json::Json;
use anyhow::Result;
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Counters for one serve session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub accepted: usize,
    pub rejected: usize,
    pub done: usize,
    pub failed: usize,
    pub cached: usize,
}

/// Per-session knobs.
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// Cap on this session's unfinished jobs: submission of the next
    /// request blocks until a result drains. `0` = unlimited (the stdin
    /// loop's historical behavior — the bounded queue is then the only
    /// backpressure).
    pub max_in_flight: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { max_in_flight: 0 }
    }
}

/// Shared serving core: the bounded queue plus the seq → session result
/// routing that lets N concurrent sessions share one worker pool.
///
/// Workers drain [`JobHub::queue`] via [`worker_loop`] and send
/// [`JobResult`]s to a single router thread (one per hub), which
/// dispatches each result to the reply channel registered by
/// [`JobHub::submit`]. [`with_hub`] wires all of that up around a
/// caller-supplied body; [`super::net`] builds the same shape with its
/// own accept loop.
pub struct JobHub {
    pub queue: JobQueue,
    routes: Mutex<HashMap<u64, mpsc::Sender<JobResult>>>,
    /// Jobs currently leased to remote workers, keyed by seq. An
    /// expired entry is requeued (same seq) by [`Self::requeue_expired`]
    /// so a crashed or partitioned worker's jobs are re-dispatched.
    leases: Mutex<HashMap<u64, LeaseEntry>>,
    accepted: AtomicUsize,
    rejected: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    cached: AtomicUsize,
    leased: AtomicUsize,
    requeued: AtomicUsize,
    conflicts: AtomicUsize,
}

struct LeaseEntry {
    spec: JobSpec,
    priority: i32,
    afp: String,
    worker: String,
    expires: Instant,
}

/// Hub-lifetime remote-worker counters (the `"remote"` block of
/// `GET /stats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Leases granted to remote workers.
    pub leased: usize,
    /// Expired leases re-dispatched into the queue.
    pub requeued: usize,
    /// Stale remote completions/renewals rejected (lease lost).
    pub conflicts: usize,
}

/// What a lease request got.
#[derive(Debug)]
pub enum LeaseReply {
    /// One job, now owned by the requesting worker until `ttl` elapses
    /// (renewable).
    Granted(LeaseInfo),
    /// Queue open but empty for the whole wait window.
    Idle,
    /// Queue closed/cancelled: no job will ever arrive again.
    Closed,
}

/// The leased job plus everything a remote worker needs to run it.
#[derive(Debug)]
pub struct LeaseInfo {
    pub seq: u64,
    pub priority: i32,
    pub spec: JobSpec,
    /// The gateway's artifact fingerprint for the spec's model
    /// (`"absent"` when the gateway has no artifacts for it) — the
    /// worker's sync key *and* the cache key on both ends.
    pub afp: String,
    pub ttl: Duration,
}

/// Outcome of a remote completion ([`JobHub::complete_remote`]).
pub enum RemoteDone {
    /// The result was dispatched; the gateway may now cache it under
    /// `(spec, afp)`.
    Accepted { spec: JobSpec, afp: String },
    /// The caller no longer holds the lease (it expired and was
    /// re-dispatched, or another worker owns it): the result was
    /// dropped. Exactly-once dispatch is preserved by the re-run.
    Conflict,
}

impl JobHub {
    /// A hub whose queue holds at most `queue_capacity` pending jobs.
    pub fn new(queue_capacity: usize) -> Self {
        Self {
            queue: JobQueue::bounded(queue_capacity),
            routes: Mutex::new(HashMap::new()),
            leases: Mutex::new(HashMap::new()),
            accepted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            cached: AtomicUsize::new(0),
            leased: AtomicUsize::new(0),
            requeued: AtomicUsize::new(0),
            conflicts: AtomicUsize::new(0),
        }
    }

    /// True when the pending queue is at capacity — the signal the HTTP
    /// gateway turns into `429` + `Retry-After`.
    pub fn is_saturated(&self) -> bool {
        self.queue.len() >= self.queue.capacity()
    }

    /// Submit one job; its eventual [`JobResult`] goes to `reply`.
    /// Blocks while the queue is full; fails only once the hub drains
    /// (queue closed).
    ///
    /// The push and the route registration happen together under the
    /// routes lock, so a job that completes in microseconds still finds
    /// its reply channel — results are never lost to that race. The
    /// push itself is non-blocking ([`JobQueue::try_push`]); waiting
    /// for queue space happens *outside* the lock, so one session
    /// stuck on a full queue never stalls result dispatch for the
    /// others.
    pub fn submit(
        &self,
        mut spec: JobSpec,
        priority: i32,
        reply: &mpsc::Sender<JobResult>,
    ) -> Result<u64> {
        loop {
            {
                let mut routes = self.routes.lock().unwrap();
                match self.queue.try_push(spec, priority) {
                    TryPush::Pushed(seq) => {
                        routes.insert(seq, reply.clone());
                        self.accepted.fetch_add(1, Ordering::Relaxed);
                        return Ok(seq);
                    }
                    TryPush::Closed(_) => {
                        anyhow::bail!("job queue is closed")
                    }
                    TryPush::Full(s) => spec = s,
                }
            }
            self.queue.wait_not_full();
        }
    }

    /// Count one request that never became a job (parse/validation
    /// reject) so `GET /stats` stays coherent with the live counters.
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Hub-lifetime job counters:
    /// (accepted, rejected, done, failed, cached) — all updated live.
    pub fn counters(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.done.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.cached.load(Ordering::Relaxed),
        )
    }

    /// Router loop: drain worker results and dispatch each one.
    pub(crate) fn route(&self, rx: mpsc::Receiver<JobResult>) {
        for r in rx {
            self.dispatch(r);
        }
    }

    /// Bump the completion counters and hand one result to the session
    /// that submitted it. A vanished session (send fails) is fine — the
    /// job still ran and was cached. Shared by the local-pool router and
    /// the remote completion path, so both provide exactly-once dispatch
    /// through the same `routes.remove`.
    fn dispatch(&self, r: JobResult) {
        if r.from_cache {
            self.cached.fetch_add(1, Ordering::Relaxed);
        }
        if r.is_ok() {
            self.done.fetch_add(1, Ordering::Relaxed);
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
        }
        let reply = self.routes.lock().unwrap().remove(&r.seq);
        if let Some(tx) = reply {
            let _ = tx.send(r);
        }
    }

    /// Lease one queued job to a remote worker: wait up to `wait` for
    /// work, then record the lease (expiring after `ttl`, renewable via
    /// [`Self::renew`]). Expired leases are swept first, so a single
    /// polling worker also drives re-dispatch.
    pub fn try_lease(
        &self,
        worker: &str,
        ttl: Duration,
        wait: Duration,
    ) -> LeaseReply {
        self.requeue_expired();
        match self.queue.pop_timeout(wait) {
            PopTimeout::Job(job) => {
                let afp = super::artifact_fingerprint(&job.spec.cfg);
                let info = LeaseInfo {
                    seq: job.seq,
                    priority: job.priority,
                    spec: job.spec.clone(),
                    afp: afp.clone(),
                    ttl,
                };
                self.leases.lock().unwrap().insert(
                    job.seq,
                    LeaseEntry {
                        spec: job.spec,
                        priority: job.priority,
                        afp,
                        worker: worker.to_string(),
                        expires: Instant::now() + ttl,
                    },
                );
                self.leased.fetch_add(1, Ordering::Relaxed);
                LeaseReply::Granted(info)
            }
            PopTimeout::Empty => LeaseReply::Idle,
            PopTimeout::Closed => LeaseReply::Closed,
        }
    }

    /// Extend `worker`'s lease on `seq` by `ttl` from now. `false` when
    /// the lease is gone (expired and re-dispatched) or owned by
    /// another worker — the caller should stop renewing and expect its
    /// eventual result to be rejected as a conflict.
    pub fn renew(&self, seq: u64, worker: &str, ttl: Duration) -> bool {
        let renewed = {
            let mut leases = self.leases.lock().unwrap();
            match leases.get_mut(&seq) {
                Some(e) if e.worker == worker => {
                    e.expires = Instant::now() + ttl;
                    true
                }
                _ => false,
            }
        };
        if !renewed {
            self.conflicts.fetch_add(1, Ordering::Relaxed);
        }
        renewed
    }

    /// Complete a remotely-leased job: verify the caller still holds
    /// the lease, then dispatch the result exactly like a local
    /// worker's. A late result from an expired lease is dropped
    /// ([`RemoteDone::Conflict`]) — the re-dispatched copy will produce
    /// the (deterministic) result instead, so a session never sees two
    /// results for one seq.
    pub fn complete_remote(
        &self,
        seq: u64,
        worker: &str,
        status: JobStatus,
        from_cache: bool,
        secs: f64,
    ) -> RemoteDone {
        let entry = {
            let mut leases = self.leases.lock().unwrap();
            let owned =
                matches!(leases.get(&seq), Some(e) if e.worker == worker);
            if owned {
                leases.remove(&seq)
            } else {
                None
            }
        };
        match entry {
            Some(e) => {
                self.dispatch(JobResult {
                    seq,
                    spec: e.spec.clone(),
                    status,
                    from_cache,
                    secs,
                });
                RemoteDone::Accepted { spec: e.spec, afp: e.afp }
            }
            None => {
                self.conflicts.fetch_add(1, Ordering::Relaxed);
                RemoteDone::Conflict
            }
        }
    }

    /// Requeue every expired lease (same seq, same priority) so the
    /// job is re-dispatched to the local pool or the next leasing
    /// worker. If the queue refuses (cancelled), the job is reported
    /// failed instead of leaving its session waiting forever. Returns
    /// how many leases were re-dispatched.
    pub fn requeue_expired(&self) -> usize {
        let now = Instant::now();
        let expired: Vec<(u64, LeaseEntry)> = {
            let mut leases = self.leases.lock().unwrap();
            let seqs: Vec<u64> = leases
                .iter()
                .filter(|(_, e)| e.expires <= now)
                .map(|(&s, _)| s)
                .collect();
            seqs.into_iter()
                .filter_map(|s| leases.remove(&s).map(|e| (s, e)))
                .collect()
        };
        let mut n = 0;
        for (seq, e) in expired {
            let spec = e.spec.clone();
            let job = Job { seq, priority: e.priority, spec: e.spec };
            match self.queue.requeue(job) {
                Ok(()) => {
                    n += 1;
                    self.requeued.fetch_add(1, Ordering::Relaxed);
                }
                Err(err) => self.dispatch(JobResult {
                    seq,
                    spec,
                    status: JobStatus::Failed(format!(
                        "worker lease expired and re-dispatch failed: {err}"
                    )),
                    from_cache: false,
                    secs: 0.0,
                }),
            }
        }
        n
    }

    /// Number of jobs currently leased out to remote workers.
    pub fn n_leased(&self) -> usize {
        self.leases.lock().unwrap().len()
    }

    /// Hub-lifetime remote-lease counters.
    pub fn remote_counters(&self) -> RemoteStats {
        RemoteStats {
            leased: self.leased.load(Ordering::Relaxed),
            requeued: self.requeued.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
        }
    }
}

/// Run `body` against a live hub: spawns `workers` worker threads (each
/// with per-thread state from `make_worker`) plus the result router,
/// then closes the queue and drains once `body` returns.
///
/// `workers == 0` is allowed and spawns no local pool — the
/// coordinator-only shape of `omgd serve --listen --workers 0`, where
/// every job is drained by remotely-leased workers instead
/// ([`JobHub::try_lease`]). With zero workers *and* no remote leasing,
/// submitted jobs wait forever; front-ends that cannot lease remotely
/// must pass ≥ 1.
///
/// Deadlock discipline: nothing between the spawns and `queue.close()`
/// early-returns, so workers can never be left blocked on `pop()`.
pub fn with_hub<M, F, T>(
    workers: usize,
    queue_capacity: usize,
    make_worker: M,
    body: impl FnOnce(&JobHub) -> T,
) -> T
where
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let hub = JobHub::new(queue_capacity);
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<JobResult>();
        let make = &make_worker;
        let hub_ref = &hub;
        for wid in 0..workers {
            let tx = tx.clone();
            s.spawn(move || {
                let mut work = make(wid);
                worker_loop(&hub_ref.queue, &mut work, &tx);
            });
        }
        drop(tx);
        let router = s.spawn(move || hub_ref.route(rx));
        // Catch a panicking body so the queue still gets closed —
        // otherwise the scoped workers would block in `pop()` forever
        // and the panic would wedge instead of propagate.
        let out = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| body(&hub)),
        );
        hub.queue.close();
        router.join().unwrap();
        match out {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    })
}

/// Drive one JSONL session: read requests from `input`, submit into
/// `hub`, write acks/rejects/results to `output`. Returns once input
/// hits EOF or `{"cmd":"shutdown"}` *and* every job this session
/// submitted has streamed its result (per-session drain).
///
/// A dead sink stops the session: once a write to `output` fails (the
/// client hung up), no further input lines are read or submitted, so a
/// vanished client cannot keep feeding the shared pool. Jobs already
/// submitted still drain — and still populate the cache.
pub fn run_session<R, W>(
    hub: &JobHub,
    input: R,
    output: W,
    opts: &SessionOptions,
) -> ServeStats
where
    R: BufRead,
    W: Write + Send,
{
    let out = Mutex::new(output);
    let (reply_tx, reply_rx) = mpsc::channel::<JobResult>();
    // (outstanding jobs, drained signal) — per-session backpressure.
    let in_flight = (Mutex::new(0usize), Condvar::new());
    let sink_dead = AtomicBool::new(false);

    std::thread::scope(|s| {
        let out_ref = &out;
        let infl = &in_flight;
        let dead = &sink_dead;
        let writer = s.spawn(move || {
            let (mut done, mut failed, mut cached) = (0usize, 0usize, 0usize);
            for r in reply_rx {
                if r.from_cache {
                    cached += 1;
                }
                if r.is_ok() {
                    done += 1;
                } else {
                    failed += 1;
                }
                if !write_line(out_ref, &result_line(&r)) {
                    dead.store(true, Ordering::Relaxed);
                }
                let mut n = infl.0.lock().unwrap();
                *n -= 1;
                infl.1.notify_all();
            }
            (done, failed, cached)
        });

        let (mut accepted, mut rejected) = (0usize, 0usize);
        let mut lineno = 0usize;
        for line in input.lines() {
            if dead.load(Ordering::Relaxed) {
                break; // client hung up: stop consuming input
            }
            lineno += 1;
            let line = match line {
                Ok(l) => l,
                Err(_) => break, // treat a broken pipe as EOF
            };
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let j = match Json::parse(text) {
                Ok(j) => j,
                Err(e) => {
                    rejected += 1;
                    hub.note_rejected();
                    if !write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&e.to_string())
                        ),
                    ) {
                        dead.store(true, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            if j.get("cmd").and_then(Json::as_str) == Some("shutdown") {
                break;
            }
            let priority =
                j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i32;
            // Two request shapes: the operator-facing field set
            // (`JobSpec::from_json`), or — under a `"spec"` key — the
            // full-fidelity wire object `grid --remote` submits so no
            // RunConfig field is lost in transit.
            let parsed = match j.get("spec") {
                Some(sj) => JobSpec::from_wire(sj),
                None => JobSpec::from_json(&j),
            };
            let spec = match parsed {
                Ok(spec) => spec,
                Err(e) => {
                    rejected += 1;
                    hub.note_rejected();
                    if !write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&format!("{e:#}"))
                        ),
                    ) {
                        dead.store(true, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            let (hash, label) = (spec.hash_hex(), spec.label());
            // Backpressure: cap this session's outstanding jobs,
            // draining a result before submitting the next request.
            {
                let mut n = infl.0.lock().unwrap();
                while opts.max_in_flight > 0 && *n >= opts.max_in_flight {
                    n = infl.1.wait(n).unwrap();
                }
                *n += 1;
            }
            // Hold the writer lock across submit + ack: a cached job
            // can complete in microseconds, and the protocol promises
            // the ack (seq ↔ request mapping) reaches the client before
            // its result line. The hub drains without this lock, so a
            // full-queue submit still makes progress.
            let mut o = out_ref.lock().unwrap();
            match hub.submit(spec, priority, &reply_tx) {
                Ok(seq) => {
                    accepted += 1;
                    let wrote = writeln!(
                        o,
                        "{{\"accepted\":{seq},\"hash\":\
                         \"{hash}\",\"label\":\"{}\"}}",
                        esc(&label)
                    )
                    .is_ok()
                        && o.flush().is_ok();
                    if !wrote {
                        dead.store(true, Ordering::Relaxed);
                    }
                }
                Err(_) => {
                    // Hub is draining: undo the in-flight reservation
                    // and keep the one-ack-or-reject-per-line promise.
                    rejected += 1;
                    hub.note_rejected();
                    let wrote = writeln!(
                        o,
                        "{{\"error\":\"job queue is closed\",\
                         \"line\":{lineno}}}"
                    )
                    .is_ok()
                        && o.flush().is_ok();
                    drop(o);
                    if !wrote {
                        dead.store(true, Ordering::Relaxed);
                    }
                    let mut n = infl.0.lock().unwrap();
                    *n -= 1;
                    infl.1.notify_all();
                }
            }
        }
        // The writer ends once the hub dispatches this session's last
        // outstanding result (each routed sender clone drops as it is
        // consumed) — the per-session drain.
        drop(reply_tx);
        let (done, failed, cached) = writer.join().unwrap();
        ServeStats { accepted, rejected, done, failed, cached }
    })
}

/// Serve one stdin/stdout-style session with the production cache-aware
/// runner (runs the configured cache GC policy at open).
pub fn serve<R, W>(input: R, output: W, opts: &GridOptions) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
{
    let cache = open_cache(opts)?;
    serve_with(input, output, opts.workers, |_wid| {
        cached_runner(&cache, opts.force)
    })
}

/// Serve one session with an arbitrary worker factory (tests inject
/// stubs): a hub with the historical `(2·workers).max(8)` queue bound
/// and an unthrottled session.
pub fn serve_with<R, W, M, F>(
    input: R,
    output: W,
    workers: usize,
    make_worker: M,
) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let workers = workers.max(1);
    Ok(with_hub(workers, (2 * workers).max(8), make_worker, |hub| {
        run_session(hub, input, output, &SessionOptions::default())
    }))
}

/// Write one protocol line and flush (clients read results live).
/// `false` = the sink is dead (client hung up).
fn write_line<W: Write>(out: &Mutex<W>, line: &str) -> bool {
    let mut o = out.lock().unwrap();
    writeln!(o, "{line}").is_ok() && o.flush().is_ok()
}

fn result_line(r: &JobResult) -> String {
    let head = format!(
        "{{\"seq\":{},\"label\":\"{}\",\"hash\":\"{}\",\"status\":\"{}\",\
         \"cached\":{}",
        r.seq,
        esc(&r.spec.label()),
        r.spec.hash_hex(),
        r.status.tag(),
        r.from_cache,
    );
    match &r.status {
        JobStatus::Done(o) => format!(
            "{head},\"final_metric\":{},\"tail_loss\":{},\"steps\":{},\
             \"secs\":{}}}",
            ser_f(o.final_metric),
            ser_f(o.tail_loss),
            o.steps,
            ser_f(r.secs),
        ),
        JobStatus::Failed(e) | JobStatus::Panicked(e) => {
            format!("{head},\"error\":\"{}\"}}", esc(e))
        }
    }
}

use crate::util::json::{escape_str as esc, ser_f64 as ser_f};

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_factory(
        _wid: usize,
    ) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> {
        |spec: &JobSpec| {
            if spec.cfg.seed == 99 {
                anyhow::bail!("rigged failure");
            }
            Ok((
                JobOutcome {
                    final_metric: spec.cfg.seed as f64 + 0.5,
                    tail_loss: 0.25,
                    steps: 2,
                    train_secs: 0.0,
                    loss_series: vec![(0, 1.0)],
                    eval_series: vec![],
                },
                false,
            ))
        }
    }

    fn run_serve(input: &str, workers: usize) -> (ServeStats, Vec<Json>) {
        let mut out: Vec<u8> = Vec::new();
        let stats = serve_with(
            input.as_bytes(),
            &mut out,
            workers,
            stub_factory,
        )
        .unwrap();
        let lines = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every output line is JSON"))
            .collect();
        (stats, lines)
    }

    fn request(seed: u64) -> String {
        format!(
            "{{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":{seed},\
             \"epochs\":1}}\n"
        )
    }

    #[test]
    fn serves_requests_and_streams_results() {
        let input = "\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":0,\"epochs\":1}\n\
{\"kind\":\"finetune\",\"task\":\"SST-2\",\"seed\":1,\"epochs\":1}\n\
{\"cmd\":\"shutdown\"}\n";
        let (stats, lines) = run_serve(input, 2);
        assert_eq!(stats.accepted, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.done, 2);
        assert_eq!(stats.failed, 0);
        let acks =
            lines.iter().filter(|j| j.get("accepted").is_some()).count();
        let results: Vec<&Json> =
            lines.iter().filter(|j| j.get("status").is_some()).collect();
        assert_eq!(acks, 2);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.at("status").as_str(), Some("done"));
            assert!(r.at("final_metric").as_f64().is_some());
        }
    }

    #[test]
    fn bad_lines_are_rejected_not_fatal() {
        let input = "\
this is not json\n\
{\"kind\":\"nope\"}\n\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":2,\"epochs\":1}\n";
        // No shutdown line: EOF also drains cleanly.
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.done, 1);
        let errors =
            lines.iter().filter(|j| j.get("error").is_some()).count();
        assert_eq!(errors, 2);
    }

    #[test]
    fn failed_jobs_stream_an_error_result() {
        let input =
            "{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":99,\"epochs\":1}\n";
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.failed, 1);
        let r = lines
            .iter()
            .find(|j| j.get("status").is_some())
            .expect("one result line");
        assert_eq!(r.at("status").as_str(), Some("failed"));
        assert!(r.at("error").as_str().unwrap().contains("rigged"));
    }

    #[test]
    fn in_flight_cap_still_completes_every_job() {
        let input: String = (0..6).map(request).collect();
        let mut out: Vec<u8> = Vec::new();
        let stats = with_hub(2, 8, stub_factory, |hub| {
            run_session(
                hub,
                input.as_bytes(),
                &mut out,
                &SessionOptions { max_in_flight: 1 },
            )
        });
        assert_eq!(stats.accepted, 6);
        assert_eq!(stats.done, 6);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 12, "6 acks + 6 results");
        // With one in-flight slot the session fully drains each job
        // before submitting the next: ack/result strictly alternate.
        for (i, l) in text.lines().enumerate() {
            let j = Json::parse(l).unwrap();
            if i % 2 == 0 {
                assert!(j.get("accepted").is_some(), "line {i}: {l}");
            } else {
                assert!(j.get("status").is_some(), "line {i}: {l}");
            }
        }
    }

    fn mk_spec(seed: u64) -> JobSpec {
        let mut cfg = crate::config::RunConfig::default();
        cfg.seed = seed;
        // Point at a directory that cannot exist so the artifact
        // fingerprint is deterministically "absent".
        cfg.artifacts_dir = "/nonexistent/omgd-test-artifacts".into();
        JobSpec {
            kind: crate::jobs::spec::ExperimentKind::Pretrain,
            cfg,
        }
    }

    #[test]
    fn lease_renew_and_complete_lifecycle() {
        let hub = JobHub::new(4);
        let seq = hub.queue.push(mk_spec(1), 0).unwrap();
        // Grant
        let info = match hub.try_lease(
            "w1",
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, seq);
        assert_eq!(info.afp, "absent");
        assert_eq!(hub.n_leased(), 1);
        // Empty queue now → Idle
        assert!(matches!(
            hub.try_lease("w2", Duration::from_secs(60), Duration::ZERO),
            LeaseReply::Idle
        ));
        // Renewal: owner only
        assert!(hub.renew(seq, "w1", Duration::from_secs(60)));
        assert!(!hub.renew(seq, "w2", Duration::from_secs(60)));
        assert!(!hub.renew(999, "w1", Duration::from_secs(60)));
        // Wrong-worker completion is a conflict and dispatches nothing.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w2",
                JobStatus::Failed("hijack".into()),
                false,
                0.0
            ),
            RemoteDone::Conflict
        ));
        assert_eq!(hub.n_leased(), 1);
        // Owner completion dispatches and frees the lease.
        let done = hub.complete_remote(
            seq,
            "w1",
            JobStatus::Done(JobOutcome::default()),
            false,
            0.5,
        );
        match done {
            RemoteDone::Accepted { spec, afp } => {
                assert_eq!(spec.cfg.seed, 1);
                assert_eq!(afp, "absent");
            }
            RemoteDone::Conflict => panic!("owner completion conflicted"),
        }
        assert_eq!(hub.n_leased(), 0);
        let (_, _, done_n, failed_n, _) = hub.counters();
        assert_eq!((done_n, failed_n), (1, 0));
        // A duplicate (late) completion is a conflict.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w1",
                JobStatus::Done(JobOutcome::default()),
                false,
                0.5
            ),
            RemoteDone::Conflict
        ));
        // Two failed renewals + wrong-worker + duplicate completion.
        assert_eq!(hub.remote_counters().conflicts, 4);
    }

    #[test]
    fn expired_lease_requeues_with_the_same_seq() {
        let hub = JobHub::new(4);
        let seq = hub.queue.push(mk_spec(2), 7).unwrap();
        let info = match hub.try_lease(
            "dead-worker",
            Duration::from_millis(5),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, seq);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(hub.requeue_expired(), 1);
        assert_eq!(hub.n_leased(), 0);
        assert_eq!(hub.queue.len(), 1);
        // Re-leased to a healthy worker with identity intact.
        let again = match hub.try_lease(
            "w2",
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!((again.seq, again.priority), (seq, 7));
        // The dead worker's late result is rejected...
        assert!(matches!(
            hub.complete_remote(
                seq,
                "dead-worker",
                JobStatus::Done(JobOutcome::default()),
                false,
                1.0
            ),
            RemoteDone::Conflict
        ));
        // ...and the healthy worker's lands.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w2",
                JobStatus::Done(JobOutcome::default()),
                false,
                1.0
            ),
            RemoteDone::Accepted { .. }
        ));
        assert_eq!(hub.remote_counters().requeued, 1);
    }

    #[test]
    fn remote_completion_routes_to_the_submitting_session() {
        let hub = JobHub::new(4);
        let (tx, rx) = mpsc::channel::<JobResult>();
        let seq = hub.submit(mk_spec(3), 0, &tx).unwrap();
        let _info = match hub.try_lease(
            "w1",
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        hub.complete_remote(
            seq,
            "w1",
            JobStatus::Done(JobOutcome {
                final_metric: 3.5,
                ..JobOutcome::default()
            }),
            true,
            0.0,
        );
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.seq, seq);
        assert!(r.from_cache);
        assert_eq!(r.outcome().unwrap().final_metric, 3.5);
        let (_, _, _, _, cached) = hub.counters();
        assert_eq!(cached, 1);
    }

    #[test]
    fn lease_replies_closed_once_the_queue_closes() {
        let hub = JobHub::new(4);
        hub.queue.close();
        assert!(matches!(
            hub.try_lease("w", Duration::from_secs(1), Duration::ZERO),
            LeaseReply::Closed
        ));
    }

    #[test]
    fn concurrent_sessions_share_a_hub_without_crosstalk() {
        let input_a: String = (0..4).map(request).collect();
        let input_b: String = (10..14).map(request).collect();
        let ((st_a, out_a), (st_b, out_b)) =
            with_hub(2, 4, stub_factory, |hub| {
                std::thread::scope(|s| {
                    let a = s.spawn(|| {
                        let mut out = Vec::new();
                        let st = run_session(
                            hub,
                            input_a.as_bytes(),
                            &mut out,
                            &SessionOptions { max_in_flight: 2 },
                        );
                        (st, out)
                    });
                    let b = s.spawn(|| {
                        let mut out = Vec::new();
                        let st = run_session(
                            hub,
                            input_b.as_bytes(),
                            &mut out,
                            &SessionOptions { max_in_flight: 2 },
                        );
                        (st, out)
                    });
                    (a.join().unwrap(), b.join().unwrap())
                })
            });
        assert_eq!((st_a.accepted, st_a.done), (4, 4));
        assert_eq!((st_b.accepted, st_b.done), (4, 4));
        // Each session sees exactly its own results (metric = seed+0.5)
        // even though both drained through one queue and worker pool.
        let metrics = |out: Vec<u8>| -> Vec<f64> {
            let mut m: Vec<f64> = String::from_utf8(out)
                .unwrap()
                .lines()
                .map(|l| Json::parse(l).unwrap())
                .filter(|j| j.get("status").is_some())
                .map(|j| j.at("final_metric").as_f64().unwrap())
                .collect();
            m.sort_by(f64::total_cmp);
            m
        };
        assert_eq!(metrics(out_a), vec![0.5, 1.5, 2.5, 3.5]);
        assert_eq!(metrics(out_b), vec![10.5, 11.5, 12.5, 13.5]);
    }
}
