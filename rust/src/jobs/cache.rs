//! On-disk result cache keyed by [`JobSpec`] content hash.
//!
//! Layout: one JSON file per completed cell under `target/omgd-cache/`
//! (override with `--cache-dir` / [`ResultCache::open`]). Writes are
//! atomic (unique temp file + rename) so concurrent workers — or two
//! grids racing on the same cell — can never leave a torn entry; a
//! reader either sees a complete file or a miss.
//!
//! Entries store the spec's canonical string alongside the outcome and
//! [`ResultCache::get`] verifies it, so a (vanishingly unlikely) 64-bit
//! hash collision degrades to a cache miss, never a wrong result. An
//! artifact fingerprint (`afp`, supplied by the runner from the model's
//! on-disk artifact files) is stored and verified the same way, so
//! regenerating artifacts — same model name, new weights/HLO — reads
//! as a miss instead of replaying stale results. Unparseable or
//! version-skewed entries also read as misses.

use super::pool::JobOutcome;
use super::spec::JobSpec;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Bump when the entry format or [`JobOutcome`] fields change.
const SCHEMA_VERSION: u64 = 1;

/// Default cache location, relative to the working directory.
pub const DEFAULT_CACHE_DIR: &str = "target/omgd-cache";

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Handle to one cache directory.
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) the cache at `dir`, or the default.
    pub fn open(dir: Option<&str>) -> Result<Self> {
        let dir = PathBuf::from(dir.unwrap_or(DEFAULT_CACHE_DIR));
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {dir:?}"))?;
        Ok(Self { dir })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn entry_path(&self, hash: &str) -> PathBuf {
        self.dir.join(format!("{hash}.json"))
    }

    /// Look up a completed outcome for `spec` computed against the
    /// artifacts identified by `afp`. Any read/parse/version/canonical/
    /// fingerprint mismatch is a miss.
    pub fn get(&self, spec: &JobSpec, afp: &str) -> Option<JobOutcome> {
        let text =
            fs::read_to_string(self.entry_path(&spec.hash_hex())).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.get("v").and_then(Json::as_f64) != Some(SCHEMA_VERSION as f64) {
            return None;
        }
        if j.get("canon").and_then(Json::as_str)
            != Some(spec.canonical().as_str())
        {
            return None;
        }
        if j.get("afp").and_then(Json::as_str) != Some(afp) {
            return None;
        }
        parse_outcome(j.get("outcome")?)
    }

    /// Persist `outcome` for `spec` (atomic: temp file + rename).
    pub fn put(
        &self,
        spec: &JobSpec,
        afp: &str,
        outcome: &JobOutcome,
    ) -> Result<()> {
        let path = self.entry_path(&spec.hash_hex());
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, serialize_entry(spec, afp, outcome))
            .with_context(|| format!("writing cache temp {tmp:?}"))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {path:?}"))?;
        Ok(())
    }

    /// Remove one entry; returns true if it existed.
    pub fn invalidate(&self, spec: &JobSpec) -> bool {
        fs::remove_file(self.entry_path(&spec.hash_hex())).is_ok()
    }

    /// Number of completed entries on disk.
    pub fn len(&self) -> usize {
        self.iter_entries().count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Remove every entry; returns how many were deleted.
    pub fn clear(&self) -> Result<usize> {
        let mut n = 0;
        for p in self.iter_entries().collect::<Vec<_>>() {
            fs::remove_file(&p)?;
            n += 1;
        }
        Ok(n)
    }

    fn iter_entries(&self) -> impl Iterator<Item = PathBuf> {
        fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|x| x == "json").unwrap_or(false)
            })
    }
}

/// Serialize one entry. Floats use Rust's shortest round-trip `Display`;
/// non-finite values become `null` (JSON has no NaN) and read back as
/// NaN.
fn serialize_entry(spec: &JobSpec, afp: &str, o: &JobOutcome) -> String {
    let loss: Vec<String> = o
        .loss_series
        .iter()
        .map(|(s, l)| format!("[{s},{}]", ser_f(*l)))
        .collect();
    let eval: Vec<String> = o
        .eval_series
        .iter()
        .map(|(s, l, a)| format!("[{s},{},{}]", ser_f(*l), ser_f(*a)))
        .collect();
    format!(
        "{{\"v\":{SCHEMA_VERSION},\"hash\":\"{}\",\"label\":\"{}\",\
         \"canon\":\"{}\",\"afp\":\"{}\",\"outcome\":{{\"final_metric\":{},\
         \"tail_loss\":{},\"steps\":{},\"train_secs\":{},\
         \"loss_series\":[{}],\"eval_series\":[{}]}}}}",
        spec.hash_hex(),
        esc(&spec.label()),
        esc(&spec.canonical()),
        esc(afp),
        ser_f(o.final_metric),
        ser_f(o.tail_loss),
        o.steps,
        ser_f(o.train_secs),
        loss.join(","),
        eval.join(","),
    )
}

fn parse_outcome(j: &Json) -> Option<JobOutcome> {
    let f = |k: &str| -> Option<f64> {
        match j.get(k)? {
            Json::Null => Some(f64::NAN),
            v => v.as_f64(),
        }
    };
    let mut out = JobOutcome {
        final_metric: f("final_metric")?,
        tail_loss: f("tail_loss")?,
        steps: j.get("steps")?.as_usize()?,
        train_secs: f("train_secs")?,
        loss_series: Vec::new(),
        eval_series: Vec::new(),
    };
    for row in j.get("loss_series")?.as_arr()? {
        let row = row.as_arr()?;
        if row.len() != 2 {
            return None;
        }
        out.loss_series
            .push((row[0].as_usize()?, null_to_nan(&row[1])?));
    }
    for row in j.get("eval_series")?.as_arr()? {
        let row = row.as_arr()?;
        if row.len() != 3 {
            return None;
        }
        out.eval_series.push((
            row[0].as_usize()?,
            null_to_nan(&row[1])?,
            null_to_nan(&row[2])?,
        ));
    }
    Some(out)
}

fn null_to_nan(j: &Json) -> Option<f64> {
    match j {
        Json::Null => Some(f64::NAN),
        v => v.as_f64(),
    }
}

use crate::util::json::{escape_str as esc, ser_f64 as ser_f};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::jobs::spec::ExperimentKind;

    fn tmp_cache(tag: &str) -> ResultCache {
        let dir = std::env::temp_dir()
            .join(format!("omgd-cache-test-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ResultCache::open(Some(dir.to_str().unwrap())).unwrap()
    }

    fn spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 2 },
            cfg,
        }
    }

    fn outcome() -> JobOutcome {
        JobOutcome {
            final_metric: 91.25,
            tail_loss: 0.123456789012345,
            steps: 3,
            train_secs: 1.5,
            loss_series: vec![(0, 2.5), (1, 1.25), (2, 0.625)],
            eval_series: vec![(1, 1.0, 50.0), (2, 0.5, 75.0)],
        }
    }

    #[test]
    fn miss_then_hit_round_trips_exactly() {
        let c = tmp_cache("roundtrip");
        let s = spec(0);
        assert!(c.get(&s, "afp-1").is_none());
        c.put(&s, "afp-1", &outcome()).unwrap();
        let got = c.get(&s, "afp-1").expect("hit after put");
        let want = outcome();
        assert_eq!(got.final_metric, want.final_metric);
        assert_eq!(got.tail_loss, want.tail_loss);
        assert_eq!(got.steps, want.steps);
        assert_eq!(got.train_secs, want.train_secs);
        assert_eq!(got.loss_series, want.loss_series);
        assert_eq!(got.eval_series, want.eval_series);
        assert_eq!(c.len(), 1);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn entries_are_per_spec() {
        let c = tmp_cache("perspec");
        c.put(&spec(0), "afp-1", &outcome()).unwrap();
        assert!(c.get(&spec(1), "afp-1").is_none(), "different seed, different cell");
        assert_eq!(c.len(), 1);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn invalidate_and_clear() {
        let c = tmp_cache("inval");
        c.put(&spec(0), "afp-1", &outcome()).unwrap();
        c.put(&spec(1), "afp-1", &outcome()).unwrap();
        assert!(c.invalidate(&spec(0)));
        assert!(!c.invalidate(&spec(0)), "second invalidate is a no-op");
        assert!(c.get(&spec(0), "afp-1").is_none());
        assert!(c.get(&spec(1), "afp-1").is_some());
        assert_eq!(c.clear().unwrap(), 1);
        assert!(c.is_empty());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn nan_survives_the_round_trip_as_nan() {
        let c = tmp_cache("nan");
        let s = spec(2);
        let mut o = outcome();
        o.final_metric = f64::NAN;
        o.eval_series = vec![(0, f64::NAN, 0.0)];
        c.put(&s, "afp-1", &o).unwrap();
        let got = c.get(&s, "afp-1").unwrap();
        assert!(got.final_metric.is_nan());
        assert!(got.eval_series[0].1.is_nan());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn corrupt_entry_reads_as_miss() {
        let c = tmp_cache("corrupt");
        let s = spec(3);
        c.put(&s, "afp-1", &outcome()).unwrap();
        std::fs::write(c.entry_path(&s.hash_hex()), "{not json").unwrap();
        assert!(c.get(&s, "afp-1").is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn canonical_mismatch_reads_as_miss() {
        let c = tmp_cache("canon");
        let a = spec(4);
        c.put(&a, "afp-1", &outcome()).unwrap();
        // Simulate a hash collision: copy a's entry under b's hash.
        let b = spec(5);
        std::fs::copy(
            c.entry_path(&a.hash_hex()),
            c.entry_path(&b.hash_hex()),
        )
        .unwrap();
        assert!(c.get(&b, "afp-1").is_none(), "foreign canon must not hit");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn regenerated_artifacts_read_as_miss() {
        let c = tmp_cache("afp");
        let s = spec(6);
        c.put(&s, "afp-old", &outcome()).unwrap();
        assert!(c.get(&s, "afp-old").is_some());
        // Same spec, regenerated artifacts → different fingerprint →
        // miss, never a stale replay.
        assert!(c.get(&s, "afp-new").is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }
}
