//! Job specification: one grid cell = one [`JobSpec`], identified by a
//! stable content hash over every field that can change its result.
//!
//! The hash keys the on-disk result cache ([`super::cache`]), so it must
//! be (a) stable across processes and platforms — no `DefaultHasher`,
//! whose seed changes per process — and (b) derived only from
//! result-relevant fields. Machine-local paths (`artifacts_dir`,
//! `out_dir`) are deliberately excluded: two hosts with the same
//! artifacts produce the same cells.

use crate::config::{RunConfig, Schedule};
use crate::util::json::Json;
use anyhow::{bail, Result};

/// Blob-dataset sizes used by the job runner. They live here — next to
/// the hash — so the canonical string sees the same values the runner
/// uses, and a change to either invalidates stale cache entries.
pub const BLOBS_N_TRAIN: usize = 1000;
pub const BLOBS_N_TEST: usize = 400;

/// What kind of experiment a job runs (mirrors the paper tables).
///
/// For the classifier kinds, `cfg.steps` is a placeholder (the builders
/// set it to `epochs`); the runner resolves the real step count as
/// `epochs × ⌈N/B⌉` once the bundle's batch size is known, and
/// `cfg.eval_every` is interpreted in *epochs* (0 = no mid-run eval).
/// `Pretrain` uses `cfg.steps` / `cfg.eval_every` directly in steps.
#[derive(Clone, Debug, PartialEq)]
pub enum ExperimentKind {
    /// Fine-tune the classifier bundle on a named GLUE-like task from
    /// [`crate::data::GLUE_LIKE_TASKS`] (Tables 3 and 6).
    Finetune { task: String, epochs: usize },
    /// Fine-tune on a synthetic Gaussian-blob dataset (Table 5 shape).
    Blobs { dataset: String, spread: f64, data_seed: u64, epochs: usize },
    /// LM pre-training on the synthetic corpus (Fig. 5 shape).
    Pretrain,
}

impl ExperimentKind {
    /// Short dataset/workload label for tables and log lines.
    pub fn dataset(&self) -> &str {
        match self {
            ExperimentKind::Finetune { task, .. } => task,
            ExperimentKind::Blobs { dataset, .. } => dataset,
            ExperimentKind::Pretrain => "pretrain",
        }
    }

    /// Dataset-generation parameters are part of the canonical string,
    /// not just the dataset *name* — editing a task definition (or the
    /// blob sizes above) must read as a different cell, never a stale
    /// cache hit.
    fn canonical(&self) -> String {
        match self {
            ExperimentKind::Finetune { task, epochs } => {
                let def = crate::data::find_task(task)
                    .map(|t| {
                        format!(
                            "{}:{}:{}:{}:{}",
                            t.n_train, t.n_test, t.noise,
                            t.teacher_depth, t.seed
                        )
                    })
                    .unwrap_or_else(|| "unresolved".to_string());
                format!("finetune:{task}:{epochs}:def={def}")
            }
            ExperimentKind::Blobs { dataset, spread, data_seed, epochs } => {
                format!(
                    "blobs:{dataset}:{spread}:{data_seed}:{epochs}:\
                     n={BLOBS_N_TRAIN}+{BLOBS_N_TEST}"
                )
            }
            ExperimentKind::Pretrain => "pretrain".to_string(),
        }
    }
}

/// One unit of schedulable work: an experiment kind plus the full run
/// configuration (method, optimizer, mask hyper-parameters, seed).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub kind: ExperimentKind,
    pub cfg: RunConfig,
}

impl JobSpec {
    /// Canonical serialization of every result-relevant field, in a fixed
    /// order. Version-prefixed so a format change invalidates old caches
    /// instead of mis-hitting them.
    pub fn canonical(&self) -> String {
        let c = &self.cfg;
        format!(
            "omgd-spec-v1;kind={};model={};method={};opt={};lr={};b1={};\
             b2={};eps={};wd={};mom={};nesterov={};keep={};gamma={};\
             period={};rank={};topk={};sched={};steps={};eval={};seed={};\
             dsize={};dseed={}",
            self.kind.canonical(),
            c.model,
            c.method.name(),
            c.opt.family.name(),
            c.opt.lr,
            c.opt.beta1,
            c.opt.beta2,
            c.opt.eps,
            c.opt.weight_decay,
            c.opt.momentum,
            c.opt.nesterov,
            c.mask.keep_ratio,
            c.mask.gamma,
            c.mask.period,
            c.mask.rank,
            c.mask.topk,
            canonical_schedule(&c.schedule),
            c.steps,
            c.eval_every,
            c.seed,
            c.dataset_size,
            c.data_seed,
        )
    }

    /// Stable 64-bit content hash (FNV-1a over [`Self::canonical`]).
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.canonical().as_bytes())
    }

    /// Hash as the fixed-width hex string used for cache file names.
    pub fn hash_hex(&self) -> String {
        format!("{:016x}", self.content_hash())
    }

    /// Human-readable cell label: `kind/dataset/method/s<seed>`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/s{}",
            self.kind.dataset(),
            self.cfg.method.name(),
            self.cfg.seed
        )
    }

    /// Build a spec from a JSONL request object (the `omgd serve`
    /// protocol). Unknown fields are ignored; everything has a default.
    ///
    /// ```json
    /// {"kind":"finetune","task":"CoLA","method":"lisa-wor","seed":1,
    ///  "epochs":4,"model":"mlp-glue","lr":2e-3,"gamma":4,"period":1}
    /// ```
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let f_usize = |k: &str, d: usize| {
            j.get(k).and_then(Json::as_usize).unwrap_or(d)
        };
        let f_f64 =
            |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let f_str = |k: &str| j.get(k).and_then(Json::as_str);

        let mut cfg = RunConfig::default();
        let kind_tag = f_str("kind").unwrap_or("finetune");
        let kind = match kind_tag {
            "finetune" => {
                let epochs = f_usize("epochs", 4);
                cfg.model = f_str("model").unwrap_or("mlp-glue").to_string();
                cfg.steps = epochs.max(1);
                // Epoch units for classifier kinds (0 = no mid-run eval).
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Finetune {
                    task: f_str("task").unwrap_or("CoLA").to_string(),
                    epochs,
                }
            }
            "blobs" => {
                let epochs = f_usize("epochs", 4);
                cfg.model = f_str("model").unwrap_or("mlp-img").to_string();
                cfg.steps = epochs.max(1);
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Blobs {
                    dataset: f_str("dataset").unwrap_or("IMG-mid").to_string(),
                    spread: f_f64("spread", 4.0),
                    data_seed: f_usize("data_seed", 6002) as u64,
                    epochs,
                }
            }
            "pretrain" => {
                cfg.model = f_str("model").unwrap_or("gpt-tiny").to_string();
                cfg.steps = f_usize("steps", 100);
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Pretrain
            }
            other => bail!("unknown job kind {other:?}"),
        };
        if let Some(m) = f_str("method") {
            cfg.method = crate::config::Method::parse(m)?;
        }
        if let Some(o) = f_str("opt") {
            cfg.opt.family = crate::config::OptFamily::parse(o)?;
        }
        cfg.opt.lr = f_f64("lr", cfg.opt.lr);
        cfg.opt.weight_decay = f_f64("wd", cfg.opt.weight_decay);
        cfg.mask.keep_ratio = f_f64("keep_ratio", cfg.mask.keep_ratio);
        cfg.mask.gamma = f_usize("gamma", cfg.mask.gamma);
        cfg.mask.period = f_usize("period", cfg.mask.period);
        cfg.mask.rank = f_usize("rank", cfg.mask.rank);
        cfg.seed = f_usize("seed", cfg.seed as usize) as u64;
        cfg.validate()?;
        Ok(JobSpec { kind, cfg })
    }
}

fn canonical_schedule(s: &Schedule) -> String {
    match s {
        Schedule::Constant => "constant".to_string(),
        Schedule::MultiStep { milestones, gamma } => {
            let ms = milestones
                .iter()
                .map(|m| m.to_string())
                .collect::<Vec<_>>()
                .join("+");
            format!("multistep:{ms}:{gamma}")
        }
        Schedule::CosineWarmup { warmup, total, min_lr } => {
            format!("cosine:{warmup}:{total}:{min_lr}")
        }
        Schedule::InvT { c0 } => format!("inv_t:{c0}"),
    }
}

/// FNV-1a 64-bit: tiny, dependency-free, stable across platforms.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn spec() -> JobSpec {
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 4 },
            cfg: RunConfig::default(),
        }
    }

    #[test]
    fn hash_is_stable_and_sensitive() {
        let a = spec();
        let b = spec();
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.hash_hex().len(), 16);

        let mut c = spec();
        c.cfg.seed = 1;
        assert_ne!(a.content_hash(), c.content_hash());
        let mut d = spec();
        d.cfg.method = Method::LisaWor;
        assert_ne!(a.content_hash(), d.content_hash());
        let mut e = spec();
        e.kind = ExperimentKind::Finetune { task: "SST-2".into(), epochs: 4 };
        assert_ne!(a.content_hash(), e.content_hash());
    }

    #[test]
    fn canonical_embeds_dataset_definitions() {
        // Editing a task's generative params (or the blob sizes) must
        // change the cell identity, not silently replay stale caches.
        assert!(spec().canonical().contains("def="));
        let b = JobSpec {
            kind: ExperimentKind::Blobs {
                dataset: "X".into(),
                spread: 1.0,
                data_seed: 1,
                epochs: 1,
            },
            cfg: RunConfig::default(),
        };
        assert!(b
            .canonical()
            .contains(&format!("n={BLOBS_N_TRAIN}+{BLOBS_N_TEST}")));
    }

    #[test]
    fn hash_ignores_local_paths() {
        let a = spec();
        let mut b = spec();
        b.cfg.artifacts_dir = "/somewhere/else".into();
        b.cfg.out_dir = "/tmp/out".into();
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") per the reference implementation.
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
    }

    #[test]
    fn from_json_round_trip() {
        let j = Json::parse(
            r#"{"kind":"finetune","task":"SST-2","method":"lisa-wor",
                "seed":3,"epochs":2,"gamma":4,"period":1,"lr":0.002}"#,
        )
        .unwrap();
        let s = JobSpec::from_json(&j).unwrap();
        assert_eq!(s.kind.dataset(), "SST-2");
        assert_eq!(s.cfg.method, Method::LisaWor);
        assert_eq!(s.cfg.seed, 3);
        assert_eq!(s.cfg.mask.gamma, 4);
        assert!((s.cfg.opt.lr - 0.002).abs() < 1e-12);
        assert_eq!(s.label(), "SST-2/lisa-wor/s3");
    }

    #[test]
    fn from_json_rejects_unknown_kind_and_method() {
        let j = Json::parse(r#"{"kind":"nope"}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind":"pretrain","method":"zzz"}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
    }
}
