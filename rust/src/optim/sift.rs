//! SIFT baseline (Song et al., 2023): gradient-magnitude-based sparse
//! fine-tuning. Each period the optimizer re-selects the top-k fraction
//! of coordinates by |g| and only updates (and keeps Adam state for)
//! those — "sparse is enough" component sparsification.

use crate::coordinator::Mask;
use crate::optim::{MaskedAdamW, Optimizer};

pub struct SiftOptimizer {
    inner: MaskedAdamW,
    /// Fraction of coordinates kept.
    pub topk: f64,
    /// Steps between re-selections.
    pub refresh: usize,
    /// Current selection mask (1.0 on kept coords).
    sel: Mask,
    t: u64,
    /// Only the first `total` coords participate (padding excluded).
    total: usize,
}

impl SiftOptimizer {
    pub fn new(n: usize, total: usize, topk: f64, refresh: usize) -> Self {
        assert!(topk > 0.0 && topk <= 1.0);
        Self {
            inner: MaskedAdamW::default_hp(n),
            topk,
            refresh: refresh.max(1),
            sel: Mask::zeros(n),
            t: 0,
            total,
        }
    }

    fn reselect(&mut self, g: &[f32]) {
        let k = ((self.total as f64) * self.topk).ceil() as usize;
        // Partial select: nth_element by |g|.
        let mut idx: Vec<usize> = (0..self.total).collect();
        let kk = k.min(self.total).max(1);
        idx.select_nth_unstable_by(kk - 1, |&a, &b| {
            g[b].abs().partial_cmp(&g[a].abs()).unwrap()
        });
        self.sel = Mask::zeros(self.sel.len());
        for &i in &idx[..kk] {
            self.sel.values[i] = 1.0;
        }
    }

    pub fn selected(&self) -> usize {
        self.sel.active_count()
    }
}

impl Optimizer for SiftOptimizer {
    fn step(&mut self, p: &mut [f32], g: &[f32], mask: &Mask, lr: f32) {
        if self.t % self.refresh as u64 == 0 {
            self.reselect(g);
        }
        self.t += 1;
        // Intersect the caller's mask with the top-k selection, keeping
        // the caller's scale.
        let mut eff = mask.clone();
        for (e, &s) in eff.values.iter_mut().zip(&self.sel.values) {
            if s == 0.0 {
                *e = 0.0;
            }
        }
        self.inner.step(p, g, &eff, lr);
    }

    fn state_bytes(&self) -> usize {
        // Residency model: only selected coordinates need moments.
        self.sel.active_count() * 8
    }

    fn name(&self) -> &'static str {
        "sift"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn selects_topk_by_magnitude() {
        let n = 100;
        let mut opt = SiftOptimizer::new(n, n, 0.1, 1000);
        let mut g = vec![0.01f32; n];
        for i in 0..10 {
            g[i * 10] = 10.0 - i as f32; // 10 large coords
        }
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, &Mask::ones(n), 0.1);
        assert_eq!(opt.selected(), 10);
        // only those ten moved
        let moved: Vec<usize> =
            (0..n).filter(|&i| p[i] != 0.0).collect();
        assert_eq!(moved.len(), 10);
        assert!(moved.iter().all(|&i| i % 10 == 0));
    }

    #[test]
    fn refresh_reselects() {
        let n = 32;
        let mut opt = SiftOptimizer::new(n, n, 0.25, 1);
        let mut p = vec![0.0f32; n];
        let mut g1 = vec![0.0f32; n];
        g1[0] = 1.0;
        g1[1] = 1.0;
        let mut g2 = vec![0.0f32; n];
        g2[30] = 1.0;
        g2[31] = 1.0;
        opt.step(&mut p, &g1, &Mask::ones(n), 0.1);
        assert!(p[0] != 0.0);
        let p30_before = p[30];
        opt.step(&mut p, &g2, &Mask::ones(n), 0.1);
        assert!(p[30] != p30_before, "reselection failed");
    }

    #[test]
    fn respects_outer_mask() {
        let n = 16;
        let mut opt = SiftOptimizer::new(n, n, 1.0, 1);
        let mut p = vec![0.0f32; n];
        let g = vec![1.0f32; n];
        let mut outer = Mask::zeros(n);
        outer.set_segment(0, 8, 1.0);
        opt.step(&mut p, &g, &outer, 0.1);
        assert!(p[..8].iter().all(|&x| x != 0.0));
        assert!(p[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn padding_excluded_from_selection() {
        let n = 64;
        let total = 48;
        let mut opt = SiftOptimizer::new(n, total, 1.0, 1);
        let g = vec![1.0f32; n];
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, &Mask::ones(n), 0.1);
        assert!(p[total..].iter().all(|&x| x == 0.0));
        assert_eq!(opt.selected(), total);
    }

    #[test]
    fn state_bytes_tracks_selection() {
        let n = 1000;
        let mut opt = SiftOptimizer::new(n, n, 0.1, 1);
        let mut rng = Rng::seed_from_u64(0);
        let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, &Mask::ones(n), 0.01);
        assert_eq!(opt.state_bytes(), 100 * 8);
    }
}
