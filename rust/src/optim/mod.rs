//! Native optimizers over the flat parameter vector.
//!
//! [`MaskedAdamW`] and [`MaskedSgdm`] mirror the L1 Pallas kernels'
//! semantics *exactly* (same hard-freeze masking, same bias-correction
//! convention) — the integration tests cross-check native vs HLO outputs
//! elementwise. They serve the baselines and any path where dispatching
//! to PJRT would dominate (e.g. the 10⁶-step §5.1 runs).
//!
//! [`galore`]/[`golore`] implement the low-rank gradient-projection
//! baselines, and [`sift`] the top-k magnitude-masking baseline.

pub mod galore;
pub mod golore;
pub mod sift;

pub use galore::GaloreOptimizer;
pub use golore::{GoloreOptimizer, ProjectionKind};
pub use sift::SiftOptimizer;

use crate::coordinator::Mask;

/// Common interface: one update step on the flat parameter vector.
/// `mask` carries both selection and scale (see kernels/ref.py); `lr` is
/// supplied per step so schedules stay outside the optimizer.
pub trait Optimizer {
    fn step(&mut self, p: &mut [f32], g: &[f32], mask: &Mask, lr: f32);

    /// Bytes of optimizer state currently held (memory accounting).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// AdamW with hard-freeze masking (matches `masked_adamw` kernel).
pub struct MaskedAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// Global step count (bias correction).
    pub t: u64,
}

impl MaskedAdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32,
               weight_decay: f32) -> Self {
        Self {
            beta1,
            beta2,
            eps,
            weight_decay,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn default_hp(n: usize) -> Self {
        Self::new(n, 0.9, 0.999, 1e-8, 0.01)
    }

    /// Bias corrections for the *next* step (what the HLO kernel receives
    /// as `hp[5]`, `hp[6]`).
    pub fn next_bias_corrections(&self) -> (f32, f32) {
        let t = (self.t + 1) as i32;
        (1.0 - self.beta1.powi(t), 1.0 - self.beta2.powi(t))
    }
}

impl Optimizer for MaskedAdamW {
    fn step(&mut self, p: &mut [f32], g: &[f32], mask: &Mask, lr: f32) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), mask.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2) = (self.beta1, self.beta2);
        for i in 0..p.len() {
            let mk = mask.values[i];
            if mk == 0.0 {
                continue;
            }
            let gm = mk * g[i];
            let m = b1 * self.m[i] + (1.0 - b1) * gm;
            let v = b2 * self.v[i] + (1.0 - b2) * gm * gm;
            self.m[i] = m;
            self.v[i] = v;
            let mhat = m / bc1;
            let vhat = v / bc2;
            p[i] -= lr
                * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * p[i]);
        }
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

/// SGD with momentum and hard-freeze masking (matches `masked_sgdm`).
pub struct MaskedSgdm {
    pub momentum: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
    pub buf: Vec<f32>,
}

impl MaskedSgdm {
    pub fn new(n: usize, momentum: f32, weight_decay: f32,
               nesterov: bool) -> Self {
        Self { momentum, weight_decay, nesterov, buf: vec![0.0; n] }
    }
}

impl Optimizer for MaskedSgdm {
    fn step(&mut self, p: &mut [f32], g: &[f32], mask: &Mask, lr: f32) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), mask.len());
        let mu = self.momentum;
        for i in 0..p.len() {
            let mk = mask.values[i];
            if mk == 0.0 {
                continue;
            }
            let gm = mk * g[i] + self.weight_decay * p[i];
            let b = mu * self.buf[i] + gm;
            self.buf[i] = b;
            let upd = if self.nesterov { gm + mu * b } else { b };
            p[i] -= lr * upd;
        }
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    fn name(&self) -> &'static str {
        "sgdm"
    }
}

/// Plain SGD (no state) — the Algorithm 1 reference instantiation.
pub struct MaskedSgd;

impl Optimizer for MaskedSgd {
    fn step(&mut self, p: &mut [f32], g: &[f32], mask: &Mask, lr: f32) {
        for i in 0..p.len() {
            let mk = mask.values[i];
            if mk != 0.0 {
                p[i] -= lr * mk * g[i];
            }
        }
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal32()).collect()
    }

    #[test]
    fn adamw_full_mask_first_step_closed_form() {
        let n = 64;
        let mut rng = Rng::seed_from_u64(1);
        let p0 = randv(n, &mut rng);
        let g = randv(n, &mut rng);
        let mut p = p0.clone();
        let mut opt = MaskedAdamW::new(n, 0.9, 0.999, 1e-8, 0.01);
        opt.step(&mut p, &g, &Mask::ones(n), 1e-3);
        for i in 0..n {
            // step 1: mhat = g, vhat = g² → update = lr*(sign-ish + wd p)
            let want = p0[i]
                - 1e-3
                    * (g[i] / (g[i].abs() + 1e-8) + 0.01 * p0[i]);
            assert!((p[i] - want).abs() < 1e-6, "{} vs {}", p[i], want);
        }
    }

    #[test]
    fn adamw_zero_mask_is_identity() {
        let n = 32;
        let mut rng = Rng::seed_from_u64(2);
        let p0 = randv(n, &mut rng);
        let g = randv(n, &mut rng);
        let mut p = p0.clone();
        let mut opt = MaskedAdamW::default_hp(n);
        opt.step(&mut p, &g, &Mask::zeros(n), 1e-3);
        assert_eq!(p, p0);
        assert!(opt.m.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn adamw_frozen_coords_keep_state() {
        let n = 8;
        let mut rng = Rng::seed_from_u64(3);
        let g = randv(n, &mut rng);
        let mut p = randv(n, &mut rng);
        let mut opt = MaskedAdamW::default_hp(n);
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, 4, 2.0);
        opt.step(&mut p, &g, &mask, 1e-3);
        // active half has state, frozen half does not
        assert!(opt.m[..4].iter().all(|&x| x != 0.0));
        assert!(opt.m[4..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn sgdm_matches_manual_two_steps() {
        let n = 4;
        let mut p = vec![0.0f32; n];
        let g = vec![1.0f32; n];
        let mut opt = MaskedSgdm::new(n, 0.9, 0.0, false);
        opt.step(&mut p, &g, &Mask::ones(n), 0.1);
        // buf = 1, p = -0.1
        assert!((p[0] + 0.1).abs() < 1e-7);
        opt.step(&mut p, &g, &Mask::ones(n), 0.1);
        // buf = 1.9, p = -0.1 - 0.19 = -0.29
        assert!((p[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn sgdm_nesterov_differs() {
        let n = 4;
        let g = vec![1.0f32; n];
        let mut p1 = vec![0.0f32; n];
        let mut p2 = vec![0.0f32; n];
        let mut o1 = MaskedSgdm::new(n, 0.9, 0.0, false);
        let mut o2 = MaskedSgdm::new(n, 0.9, 0.0, true);
        o1.step(&mut p1, &g, &Mask::ones(n), 0.1);
        o2.step(&mut p2, &g, &Mask::ones(n), 0.1);
        assert!((p1[0] + 0.1).abs() < 1e-7);
        assert!((p2[0] + 0.19).abs() < 1e-7); // g + mu*buf = 1.9
    }

    #[test]
    fn sgd_descends_quadratic() {
        // minimize ½‖p‖²: g = p
        let n = 16;
        let mut rng = Rng::seed_from_u64(4);
        let mut p = randv(n, &mut rng);
        let mut opt = MaskedSgd;
        for _ in 0..100 {
            let g = p.clone();
            opt.step(&mut p, &g, &Mask::ones(n), 0.1);
        }
        let norm: f32 = p.iter().map(|x| x * x).sum();
        assert!(norm < 1e-4, "norm {norm}");
    }

    #[test]
    fn state_bytes() {
        let a = MaskedAdamW::default_hp(100);
        assert_eq!(a.state_bytes(), 800);
        let s = MaskedSgdm::new(100, 0.9, 0.0, false);
        assert_eq!(s.state_bytes(), 400);
        assert_eq!(MaskedSgd.state_bytes(), 0);
    }

    #[test]
    fn mask_scale_equals_prescaled_gradient() {
        let n = 32;
        let mut rng = Rng::seed_from_u64(5);
        let g = randv(n, &mut rng);
        let p0 = randv(n, &mut rng);

        let mut pa = p0.clone();
        let mut oa = MaskedAdamW::default_hp(n);
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, n, 4.0);
        oa.step(&mut pa, &g, &mask, 1e-3);

        let mut pb = p0.clone();
        let mut ob = MaskedAdamW::default_hp(n);
        let g4: Vec<f32> = g.iter().map(|x| 4.0 * x).collect();
        ob.step(&mut pb, &g4, &Mask::ones(n), 1e-3);

        for (a, b) in pa.iter().zip(&pb) {
            assert!((a - b).abs() < 1e-7);
        }
    }
}
