//! Masks and mask sets.
//!
//! A [`Mask`] is a dense `f32` vector over the flat parameter space whose
//! non-zero entries both *select* coordinates and carry the OMGD rescale
//! factor. A [`MaskSet`] is the per-cycle collection `{S⁽ʲ⁾}` required to
//! satisfy eq. (3): `Σⱼ S⁽ʲ⁾ = M·1_d` over the *maskable* region (the
//! paper's LISA instantiation keeps embed/head always active with scale 1
//! and splits only middle layers — the §5.2 worked example shows exactly
//! this shape: `S⁽¹⁾ = (1, 4, 0, 0, 0, 1)ᵀ`, ...).

use crate::manifest::Manifest;
use crate::rng::Rng;

/// Dense coordinate mask with scale values.
#[derive(Clone, Debug, PartialEq)]
pub struct Mask {
    pub values: Vec<f32>,
}

impl Mask {
    pub fn zeros(n: usize) -> Self {
        Self { values: vec![0.0; n] }
    }

    pub fn ones(n: usize) -> Self {
        Self { values: vec![1.0; n] }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Number of active (non-zero) coordinates.
    pub fn active_count(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Keep ratio = active / total.
    pub fn keep_ratio(&self) -> f64 {
        self.active_count() as f64 / self.len().max(1) as f64
    }

    /// Set a contiguous segment to `scale`.
    pub fn set_segment(&mut self, offset: usize, len: usize, scale: f32) {
        for v in &mut self.values[offset..offset + len] {
            *v = scale;
        }
    }

    /// Apply in place to a gradient: `g ← mask ⊙ g`.
    pub fn apply(&self, grad: &mut [f32]) {
        assert_eq!(grad.len(), self.values.len());
        for (g, &m) in grad.iter_mut().zip(&self.values) {
            *g *= m;
        }
    }
}

/// A cycle's worth of masks satisfying the eq. (3) coverage condition.
#[derive(Clone, Debug)]
pub struct MaskSet {
    pub masks: Vec<Mask>,
}

impl MaskSet {
    pub fn m(&self) -> usize {
        self.masks.len()
    }

    /// Verify `Σⱼ S⁽ʲ⁾ = c·1` over `0..total` (padding excluded) for a
    /// *single* scalar c; returns c or None if violated.
    pub fn coverage_scalar(&self, total: usize) -> Option<f32> {
        if self.masks.is_empty() || total == 0 {
            return None;
        }
        let mut c = None;
        for i in 0..total {
            let s: f32 = self.masks.iter().map(|m| m.values[i]).sum();
            match c {
                None => c = Some(s),
                Some(prev) if (prev - s).abs() > 1e-4 => return None,
                _ => {}
            }
        }
        c
    }

    /// Remark 4.11 construction over raw coordinates: `M = ⌈1/r⌉` masks;
    /// masks 1..M−1 each own `⌊r·d⌋` random coordinates (scale M), the
    /// last mask owns the remainder. Coordinates in `total..n` (padding)
    /// stay zero in every mask.
    pub fn coordinate_partition(
        n: usize,
        total: usize,
        keep_ratio: f64,
        rng: &mut Rng,
    ) -> MaskSet {
        assert!(total <= n);
        let m = (1.0 / keep_ratio).ceil().max(1.0) as usize;
        let chunk = ((total as f64) * keep_ratio).floor() as usize;
        let perm = rng.permutation(total);
        let scale = m as f32;
        let mut masks = vec![Mask::zeros(n); m];
        for (rank, &coord) in perm.iter().enumerate() {
            let j = (rank / chunk.max(1)).min(m - 1);
            masks[j].values[coord] = scale;
        }
        MaskSet { masks }
    }

    /// Tensorwise partition (§5.2 SGDM-wor): randomly split the
    /// manifest's tensors into `M` groups of approximately equal
    /// parameter count; mask `j` activates group `j` with scale `M`.
    pub fn tensor_partition(
        man: &Manifest,
        keep_ratio: f64,
        rng: &mut Rng,
    ) -> MaskSet {
        let m = (1.0 / keep_ratio).ceil().max(1.0) as usize;
        let n = man.padded_len;
        let mut order: Vec<usize> = (0..man.params.len()).collect();
        rng.shuffle(&mut order);
        // Greedy balance: assign each tensor (in random order) to the
        // currently lightest group.
        let mut group_load = vec![0usize; m];
        let mut masks = vec![Mask::zeros(n); m];
        let scale = m as f32;
        for &pi in &order {
            let p = &man.params[pi];
            let j = (0..m).min_by_key(|&j| group_load[j]).unwrap();
            group_load[j] += p.len;
            masks[j].set_segment(p.offset, p.len, scale);
        }
        MaskSet { masks }
    }

    /// I.i.d. tensorwise baseline (§5.2 SGDM-iid): each tensor kept
    /// independently with probability `keep_ratio`, scale 1 (the naïve
    /// freeze scheme — no rescale, matching the paper's baseline).
    pub fn tensor_iid(man: &Manifest, keep_ratio: f64, rng: &mut Rng)
                      -> Mask {
        let mut mask = Mask::zeros(man.padded_len);
        for p in &man.params {
            if rng.f64() < keep_ratio {
                mask.set_segment(p.offset, p.len, 1.0);
            }
        }
        mask
    }

    /// I.i.d. coordinate mask (Remark 4.10): each coordinate kept with
    /// probability `r`, active entries scaled by `1/r` (unbiased).
    pub fn coordinate_iid(n: usize, total: usize, r: f64, rng: &mut Rng)
                          -> Mask {
        let mut mask = Mask::zeros(n);
        let scale = (1.0 / r) as f32;
        for v in &mut mask.values[..total] {
            if rng.f64() < r {
                *v = scale;
            }
        }
        mask
    }

    /// Layerwise mask (LISA family): embed/head/final always active at
    /// scale 1; the given middle layers active at `mid_scale`; everything
    /// else frozen.
    pub fn layerwise(
        man: &Manifest,
        active_middle: &[String],
        mid_scale: f32,
    ) -> Mask {
        let mut mask = Mask::zeros(man.padded_len);
        for p in &man.params {
            let scale = if p.layer == "embed"
                || p.layer == "head"
                || p.layer == "final"
            {
                1.0
            } else if active_middle.iter().any(|l| *l == p.layer) {
                mid_scale
            } else {
                continue;
            };
            mask.set_segment(p.offset, p.len, scale);
        }
        mask
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn toy_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 4,
 "total_len": 20, "padded_len": 24,
 "params": [
  {"name": "in_w", "shape": [4], "layer": "embed", "offset": 0, "len": 4},
  {"name": "block_0.w", "shape": [4], "layer": "block_0", "offset": 4, "len": 4},
  {"name": "block_1.w", "shape": [4], "layer": "block_1", "offset": 8, "len": 4},
  {"name": "block_2.w", "shape": [4], "layer": "block_2", "offset": 12, "len": 4},
  {"name": "out_w", "shape": [4], "layer": "head", "offset": 16, "len": 4}
 ],
 "data": {"batch": 2},
 "artifacts": {"train": "t", "eval": "e", "init": "i",
               "update": {"adamw": "a", "sgdm": "s"}}
}"#,
        )
        .unwrap();
        Manifest::from_json(&j, Path::new("/tmp")).unwrap()
    }

    #[test]
    fn coordinate_partition_satisfies_eq3() {
        let mut rng = Rng::seed_from_u64(1);
        for r in [0.5, 0.25, 0.34] {
            let set = MaskSet::coordinate_partition(128, 100, r, &mut rng);
            let m = (1.0f64 / r).ceil() as usize;
            assert_eq!(set.m(), m);
            let c = set.coverage_scalar(100).expect("coverage violated");
            assert!((c - m as f32).abs() < 1e-5, "c={c} m={m}");
            // padding untouched
            for mask in &set.masks {
                assert!(mask.values[100..].iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn coordinate_partition_disjoint() {
        let mut rng = Rng::seed_from_u64(2);
        let set = MaskSet::coordinate_partition(64, 64, 0.25, &mut rng);
        for i in 0..64 {
            let active =
                set.masks.iter().filter(|m| m.values[i] != 0.0).count();
            assert_eq!(active, 1, "coord {i} owned by {active} masks");
        }
    }

    #[test]
    fn coordinate_partition_keep_ratio() {
        let mut rng = Rng::seed_from_u64(3);
        let set = MaskSet::coordinate_partition(1024, 1000, 0.5, &mut rng);
        // first M-1 masks hold exactly floor(r d); last holds remainder
        assert_eq!(set.masks[0].active_count(), 500);
        assert_eq!(set.masks[1].active_count(), 500);
    }

    #[test]
    fn tensor_partition_satisfies_eq3() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(4);
        let set = MaskSet::tensor_partition(&man, 0.5, &mut rng);
        assert_eq!(set.m(), 2);
        let c = set.coverage_scalar(man.total_len).unwrap();
        assert!((c - 2.0).abs() < 1e-6);
        // groups are tensor-aligned: a tensor is fully in or fully out
        for mask in &set.masks {
            for p in &man.params {
                let seg = &mask.values[p.offset..p.offset + p.len];
                let first = seg[0];
                assert!(seg.iter().all(|&v| v == first), "{} split", p.name);
            }
        }
    }

    #[test]
    fn tensor_partition_balances_load() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(5);
        let set = MaskSet::tensor_partition(&man, 0.5, &mut rng);
        let loads: Vec<usize> =
            set.masks.iter().map(|m| m.active_count()).collect();
        // 5 tensors of 4 params in 2 groups → 12 vs 8
        assert_eq!(loads.iter().sum::<usize>(), 20);
        assert!(loads.iter().all(|&l| l >= 8), "{loads:?}");
    }

    #[test]
    fn tensor_iid_keeps_whole_tensors() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(6);
        let mask = MaskSet::tensor_iid(&man, 0.5, &mut rng);
        for p in &man.params {
            let seg = &mask.values[p.offset..p.offset + p.len];
            assert!(seg.iter().all(|&v| v == seg[0]));
        }
    }

    #[test]
    fn coordinate_iid_scale_unbiased() {
        let mut rng = Rng::seed_from_u64(7);
        let mask = MaskSet::coordinate_iid(4096, 4000, 0.25, &mut rng);
        let active = mask.values[..4000].iter()
            .filter(|&&v| v != 0.0).count();
        // ~1000 expected
        assert!((active as f64 - 1000.0).abs() < 150.0, "active {active}");
        assert!(mask.values.iter().all(|&v| v == 0.0 || v == 4.0));
        assert!(mask.values[4000..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn layerwise_mask_shape() {
        let man = toy_manifest();
        let mask = MaskSet::layerwise(&man, &["block_1".into()], 3.0);
        // embed active at 1
        assert!(mask.values[0..4].iter().all(|&v| v == 1.0));
        // block_0 frozen
        assert!(mask.values[4..8].iter().all(|&v| v == 0.0));
        // block_1 active at 3 (= N_L/γ with N_L=3, γ=1)
        assert!(mask.values[8..12].iter().all(|&v| v == 3.0));
        // block_2 frozen
        assert!(mask.values[12..16].iter().all(|&v| v == 0.0));
        // head active at 1
        assert!(mask.values[16..20].iter().all(|&v| v == 1.0));
        // padding zero
        assert!(mask.values[20..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn lisa_wor_cycle_satisfies_eq3_on_middle_layers() {
        // Across a full WOR traversal (γ=1 over 3 middle layers) with
        // scale N_L/γ = 3, middle coordinates sum to 3 = M while
        // embed/head sum to 3·1 — i.e. Σ S⁽ʲ⁾ = M·1 exactly as in the
        // §5.2 worked example.
        let man = toy_manifest();
        let masks: Vec<Mask> = ["block_0", "block_1", "block_2"]
            .iter()
            .map(|l| MaskSet::layerwise(&man, &[l.to_string()], 3.0))
            .collect();
        let set = MaskSet { masks };
        let c = set.coverage_scalar(man.total_len).unwrap();
        assert!((c - 3.0).abs() < 1e-6, "c={c}");
    }

    #[test]
    fn apply_masks_gradient() {
        let mut mask = Mask::zeros(4);
        mask.set_segment(1, 2, 2.0);
        let mut g = vec![1.0f32, 1.0, 1.0, 1.0];
        mask.apply(&mut g);
        assert_eq!(g, vec![0.0, 2.0, 2.0, 0.0]);
        assert_eq!(mask.active_count(), 2);
        assert!((mask.keep_ratio() - 0.5).abs() < 1e-12);
    }
}
