//! Table 3 regenerator: GLUE-like fine-tuning, 8 tasks × 7 methods.
//!
//! Substitution (DESIGN.md): synthetic planted-teacher tasks stand in for
//! GLUE; the comparison structure (same data, same budget, method-only
//! variation) is preserved. Expected shape: LISA-WOR ≥ {LISA, ablations,
//! GoLore, SIFT} with Full params as the ceiling; the wor+scale combo
//! beats either modification alone on average.
//!
//! Also emits Fig. 4/7-style training-loss curves for CoLA to
//! `results/fig4_cola_loss.csv`.

use omgd::bench::TablePrinter;
use omgd::config::OptFamily;
use omgd::data::GLUE_LIKE_TASKS;
use omgd::experiments::*;
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, "mlp-glue")?;
    let setup = FinetuneSetup {
        epochs: scaled(30, 4),
        gamma: 4,
        period: 1,
        ..FinetuneSetup::default()
    };
    let methods = adamw_method_roster();
    println!(
        "Table 3: {} tasks × {} methods, {} epochs each",
        GLUE_LIKE_TASKS.len(), methods.len(), setup.epochs
    );

    let mut headers: Vec<&str> = vec!["Algorithm"];
    let task_names: Vec<&str> =
        GLUE_LIKE_TASKS.iter().map(|t| t.name).collect();
    headers.extend(task_names.iter());
    headers.push("Avg");
    let mut table = TablePrinter::new(&headers);

    let csv_path = results_dir().join("table3.csv");
    let mut csv = CsvWriter::create(
        &csv_path, &["method", "task", "acc", "tail_loss"],
    )?;
    let mut cola_curves = CsvWriter::create(
        results_dir().join("fig4_cola_loss.csv"),
        &["method", "step", "loss"],
    )?;

    // Synthetic tasks carry more per-run noise than real GLUE, so each
    // cell averages over independent training seeds (shared data).
    let seeds: &[u64] = &[0, 1];
    for method in &methods {
        let mut cells = vec![method.name().to_string()];
        let mut sum = 0.0;
        for spec in &GLUE_LIKE_TASKS {
            let task = task_for(&bundle, spec);
            let mut acc = 0.0;
            let mut tail = 0.0;
            for (si, &seed) in seeds.iter().enumerate() {
                let s = FinetuneSetup { seed, ..setup.clone() };
                let out = finetune_cell(&bundle, &task, *method, &s,
                                        OptFamily::AdamW)?;
                acc += out.final_metric / seeds.len() as f64;
                tail += out.tail_loss(20) / seeds.len() as f64;
                if spec.name == "CoLA" && si == 0 {
                    for &(st, l) in &out.loss_series {
                        cola_curves.row_mixed(&[
                            CsvCell::S(method.name().into()),
                            CsvCell::I(st as i64),
                            CsvCell::F(l),
                        ])?;
                    }
                }
            }
            cells.push(format!("{acc:.2}"));
            sum += acc;
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::S(spec.name.into()),
                CsvCell::F(acc),
                CsvCell::F(tail),
            ])?;
        }
        cells.push(format!("{:.2}", sum / GLUE_LIKE_TASKS.len() as f64));
        table.row(cells);
        println!("  finished {}", method.name());
    }
    csv.flush()?;
    cola_curves.flush()?;
    table.print("Table 3 — fine-tuning accuracy (%) on GLUE-like tasks");
    println!("rows written to {}", csv_path.display());
    println!("CoLA loss curves (Fig. 4/7) in results/fig4_cola_loss.csv");
    Ok(())
}
