//! Table 6 regenerator: LISA-WOR hyper-parameter ablation on CoLA-like —
//! sampling layers γ ∈ {1,2,3,4,6} × period K ∈ {1,2,3,5,6}.
//!
//! Paper shape: accuracy improves with γ (more unfrozen capacity per
//! period); K has a milder, non-monotone effect with very frequent
//! switching (small K at small γ) slightly hurting.

use omgd::bench::TablePrinter;
use omgd::config::{Method, OptFamily};
use omgd::data::GLUE_LIKE_TASKS;
use omgd::experiments::*;
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, "mlp-glue")?;
    let cola = &GLUE_LIKE_TASKS[0];
    let task = task_for(&bundle, cola);
    let epochs = scaled(20, 4);
    let gammas = [1usize, 2, 3, 4, 6];
    let periods = [1usize, 2, 3, 5, 6];
    println!("Table 6: γ × K sweep on {} ({} epochs per cell, {} cells)",
             task.name, epochs, gammas.len() * periods.len());

    let mut headers: Vec<String> = vec!["γ \\ K".into()];
    headers.extend(periods.iter().map(|k| format!("K={k}")));
    let headers_ref: Vec<&str> =
        headers.iter().map(|s| s.as_str()).collect();
    let mut table = TablePrinter::new(&headers_ref);

    let csv_path = results_dir().join("table6.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["gamma", "period", "acc"])?;

    for &gamma in &gammas {
        let mut cells = vec![format!("γ={gamma}")];
        for &period in &periods {
            let setup = FinetuneSetup {
                epochs,
                gamma,
                period,
                ..FinetuneSetup::default()
            };
            let out = finetune_cell(&bundle, &task, Method::LisaWor,
                                    &setup, OptFamily::AdamW)?;
            cells.push(format!("{:.2}", out.final_metric));
            csv.row_mixed(&[
                CsvCell::I(gamma as i64),
                CsvCell::I(period as i64),
                CsvCell::F(out.final_metric),
            ])?;
        }
        table.row(cells);
        println!("  finished γ={gamma}");
    }
    csv.flush()?;
    table.print("Table 6 — LISA-WOR ablation, accuracy (%) on CoLA-like");
    println!("rows written to {}", csv_path.display());
    Ok(())
}
