//! Table 5 regenerator: layerwise methods on image-classification
//! fine-tuning (ViT-base substitute = `mlp-img` bundle, AdamW).
//!
//! Paper shape: LISA-WOR ≥ LISA ≈ full-params ceiling, with GoLore and
//! SIFT close behind; the γ/K setting follows B.2 (γ=3, K=5 scaled).
//! Emits Fig. 3-style test-loss curves to `results/fig3_test_loss.csv`.

use omgd::bench::TablePrinter;
use omgd::config::{OptFamily, RunConfig};
use omgd::data::ClassTask;
use omgd::experiments::*;
use omgd::metrics::{CsvCell, CsvWriter};
use omgd::runtime::Runtime;
use omgd::train::train_classifier;

fn main() -> anyhow::Result<()> {
    if !artifacts_present("mlp-img") {
        eprintln!("mlp-img artifacts missing — run `make artifacts`");
        return Ok(());
    }
    let rt = Runtime::cpu()?;
    let bundle = load_bundle(&rt, "mlp-img")?;
    let epochs = scaled(15, 3);
    let datasets = [
        ("IMG-easy", 3.0, 6001u64),
        ("IMG-mid", 4.0, 6002),
        ("IMG-hard", 5.5, 6003),
    ];
    // Full roster minus tensorwise (those are Table 4's subject).
    let methods = adamw_method_roster();
    println!("Table 5: {} datasets × {} methods, {} epochs (AdamW, γ=3 K=5)",
             datasets.len(), methods.len(), epochs);

    let mut table = TablePrinter::new(&[
        "Algorithm", "IMG-easy", "IMG-mid", "IMG-hard",
    ]);
    let csv_path = results_dir().join("table5.csv");
    let mut csv =
        CsvWriter::create(&csv_path, &["method", "dataset", "acc"])?;
    let mut fig3 = CsvWriter::create(
        results_dir().join("fig3_test_loss.csv"),
        &["method", "step", "test_loss"],
    )?;

    for method in &methods {
        let mut cells = vec![method.name().to_string()];
        for (name, spread, seed) in &datasets {
            let task = ClassTask::gaussian_blobs(
                name, bundle.man.data.d_in, bundle.man.data.n_class,
                1000, 400, *spread, *seed,
            );
            let steps_per_epoch =
                task.n_train().div_ceil(bundle.man.data.batch);
            let mut cfg = RunConfig::default();
            cfg.method = *method;
            cfg.opt.family = OptFamily::AdamW;
            cfg.opt.lr = 1e-3;
            cfg.mask.gamma = 3;
            cfg.mask.period = 5.min(epochs);
            cfg.mask.rank = 8;
            cfg.steps = epochs * steps_per_epoch;
            cfg.eval_every = steps_per_epoch; // per-epoch test loss
            cfg.seed = 11;
            let out = train_classifier(&bundle, &cfg, &task)?;
            cells.push(format!("{:.2}", out.final_metric));
            csv.row_mixed(&[
                CsvCell::S(method.name().into()),
                CsvCell::S((*name).into()),
                CsvCell::F(out.final_metric),
            ])?;
            if *name == "IMG-mid" {
                for &(s, l, _) in &out.eval_series {
                    fig3.row_mixed(&[
                        CsvCell::S(method.name().into()),
                        CsvCell::I(s as i64),
                        CsvCell::F(l),
                    ])?;
                }
            }
        }
        table.row(cells);
        println!("  finished {}", method.name());
    }
    csv.flush()?;
    fig3.flush()?;
    table.print("Table 5 — fine-tuning accuracy (%), layerwise methods");
    println!("rows written to {}", csv_path.display());
    println!("test-loss curves (Fig. 3) in results/fig3_test_loss.csv");
    Ok(())
}
