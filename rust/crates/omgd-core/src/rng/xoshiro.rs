//! Xoshiro256++ core generator (Blackman & Vigna, 2019).

use super::splitmix64;

/// Xoshiro256++ — 256-bit state, period 2^256 − 1, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via splitmix64 expansion (the recommended seeding procedure).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is invalid; splitmix64 cannot produce 4 zero
        // outputs from any seed, but guard anyway.
        if s == [0, 0, 0, 0] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    /// The raw 256-bit state, for bitwise-exact checkpoint/resume.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from a checkpointed state. The all-zero state (invalid
    /// for xoshiro) gets the same fallback as seeding.
    pub fn from_state(s: [u64; 4]) -> Self {
        if s == [0, 0, 0, 0] {
            Self { s: [1, 2, 3, 4] }
        } else {
            Self { s }
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_reference_sequence() {
        // Reference values computed from the canonical C implementation
        // seeded with splitmix64(0): s = {e220a8397b1dcdaf, 6e789e6aa1b965f4,
        // 06c45d188009454f, f88bb8a8724c81ec}.
        let mut g = Xoshiro256pp::seed_from_u64(0);
        let first = g.next_u64();
        let mut g2 = Xoshiro256pp::seed_from_u64(0);
        assert_eq!(first, g2.next_u64());
        // state must evolve
        assert_ne!(g.next_u64(), first);
    }

    #[test]
    fn state_round_trip_is_bitwise() {
        let mut g = Xoshiro256pp::seed_from_u64(42);
        for _ in 0..17 {
            g.next_u64();
        }
        let mut h = Xoshiro256pp::from_state(g.state());
        for _ in 0..100 {
            assert_eq!(g.next_u64(), h.next_u64());
        }
        // all-zero state gets the seeding fallback, not a stuck stream
        let mut z = Xoshiro256pp::from_state([0, 0, 0, 0]);
        assert_ne!(z.next_u64(), z.next_u64());
    }

    #[test]
    fn no_short_cycles() {
        let mut g = Xoshiro256pp::seed_from_u64(123);
        let x0 = g.next_u64();
        for _ in 0..10_000 {
            assert_ne!(g.next_u64(), 0u64.wrapping_sub(1) ^ x0);
        }
    }
}
