//! Deterministic pseudo-random numbers (no external crates).
//!
//! `splitmix64` seeds `Xoshiro256++`, the generator used for every
//! stochastic choice in the library: data reshuffling, mask generation,
//! the `[M]×[N]` cycle permutation, synthetic datasets and Stiefel
//! sampling. Determinism given a seed is load-bearing — every experiment
//! in EXPERIMENTS.md records its seed.

mod xoshiro;

pub use xoshiro::Xoshiro256pp;

/// The library-wide RNG handle. Thin alias so call-sites stay agnostic of
/// the concrete generator.
pub type Rng = Xoshiro256pp;

/// splitmix64 step — used to expand a single `u64` seed into generator
/// state, and as a cheap standalone hash.
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Uniform `f64` in `[0, 1)` with 53 bits of entropy.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform `f32` in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` via Lemire's rejection method
    /// (unbiased).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        loop {
            let x = self.next_u64();
            let (hi, lo) = mul_u64(x, n);
            if lo >= n || lo >= x.wrapping_neg() % n {
                return hi;
            }
        }
    }

    /// Uniform `usize` index in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box–Muller (uses both outputs is unnecessary —
    /// simplicity beats a cached half here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Standard normal as f32.
    pub fn normal32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fresh random permutation of `0..n`.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    /// Sample `k` distinct indices from `0..n` without replacement
    /// (partial Fisher–Yates; O(n) memory, O(k) swaps).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }

    /// Derive an independent child generator (stream split) — hash the
    /// parent's next output with a stream tag through splitmix64.
    pub fn split(&mut self, tag: u64) -> Rng {
        let mut s = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let _ = splitmix64(&mut s);
        Rng::seed_from_u64(s)
    }
}

#[inline]
fn mul_u64(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from_u64(1);
        let mut b = Rng::seed_from_u64(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::seed_from_u64(3);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[r.below(10) as usize] += 1;
        }
        for c in counts {
            // each bucket expected 10_000; allow 5% deviation
            assert!((c as f64 - 10_000.0).abs() < 500.0, "count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed_from_u64(5);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Rng::seed_from_u64(11);
        let p = r.permutation(1000);
        let mut seen = vec![false; 1000];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn choose_k_distinct_and_in_range() {
        let mut r = Rng::seed_from_u64(13);
        for _ in 0..50 {
            let ks = r.choose_k(20, 7);
            assert_eq!(ks.len(), 7);
            let mut s = ks.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 7);
            assert!(ks.iter().all(|&i| i < 20));
        }
    }

    #[test]
    fn choose_k_full_is_permutation() {
        let mut r = Rng::seed_from_u64(17);
        let mut ks = r.choose_k(8, 8);
        ks.sort_unstable();
        assert_eq!(ks, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_are_independent_ish() {
        let mut root = Rng::seed_from_u64(99);
        let mut a = root.split(0);
        let mut b = root.split(1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn shuffle_preserves_multiset() {
        let mut r = Rng::seed_from_u64(23);
        let mut v: Vec<u32> = (0..100).map(|i| i % 10).collect();
        let mut sorted_before = v.clone();
        sorted_before.sort_unstable();
        r.shuffle(&mut v);
        v.sort_unstable();
        assert_eq!(v, sorted_before);
    }
}
