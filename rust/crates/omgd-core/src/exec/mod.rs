//! Data-parallel execution engine for the training core.
//!
//! A dependency-free, std-only persistent thread pool plus a
//! deterministic shard partitioner over [`MaskRuns`]. The pool is
//! spawned once per engine (`--threads N` / `OMGD_THREADS`, default =
//! available parallelism) and drives optimizer steps, moment-state
//! remaps at mask refresh, and the quadratic testbed's masked-gradient
//! fill shard-parallel.
//!
//! ## Determinism contract
//!
//! Shards own *disjoint* `(offset, len)` coordinate windows — and,
//! for compact-state optimizers, the matching disjoint slot windows of
//! the SoA moment arrays — so parallel execution is race-free by
//! construction. Every update in this codebase is elementwise (no
//! cross-coordinate accumulation), so the result is **bitwise
//! identical for every thread count**: the partition only decides who
//! computes a coordinate, never what arithmetic reaches it. Property
//! tests in `rust/crates/omgd/tests/proptests.rs` pin parallel ==
//! serial bitwise for all five optimizers across thread counts.
//!
//! ## Pool shape
//!
//! [`ExecEngine::run_indexed`] erases the caller's closure to a raw
//! pointer, enqueues one job handle per worker, and lets workers (and
//! the calling thread — the caller always participates) claim indices
//! with a relaxed `fetch_add`. The caller blocks until every index has
//! completed, so the erased closure provably outlives every use; task
//! panics are caught and re-raised on the caller.

use crate::coordinator::{MaskRuns, Run};
use omgd_util::lock_recover;
use omgd_util::obs;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Active-coordinate count below which the engine layer prefers the
/// serial step: dispatch costs a few µs of wakeups, so tiny masks stay
/// inline. The optimizers themselves shard whenever asked — this
/// threshold is policy for the hot loop, not a correctness guard.
pub const PAR_MIN_ACTIVE: usize = 1 << 14;

/// Thread count from the environment: `OMGD_THREADS` if set to a
/// positive integer, else the machine's available parallelism.
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("OMGD_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

// ---------------------------------------------------------------------
// Shard partitioner
// ---------------------------------------------------------------------

/// One shard of a runs walk: a slice of (possibly split) runs covering
/// a contiguous coordinate window `[start, end)` and the matching
/// contiguous compact-slot window `[start_slot, start_slot + active)`.
#[derive(Clone, Debug, PartialEq)]
pub struct Shard {
    /// The runs this shard walks (splits of the input runs).
    pub runs: Vec<Run>,
    /// First coordinate owned (inclusive).
    pub start: usize,
    /// One past the last coordinate owned.
    pub end: usize,
    /// Active coordinates in every shard before this one — the offset
    /// into prefix-indexed compact state (MaskedAdamW/Sgdm moments).
    pub start_slot: usize,
    /// Active coordinates owned by this shard.
    pub active: usize,
}

/// Partition a mask's runs into at most `shards` balanced shards.
/// See [`partition_runs`].
pub fn partition(runs: &MaskRuns, shards: usize) -> Vec<Shard> {
    partition_runs(runs.runs(), runs.active_count(), shards)
}

/// Partition sorted disjoint runs (with `active` total active
/// coordinates) into at most `shards` shards, balanced to within one
/// active coordinate. Runs are split where a shard boundary lands
/// inside them, so each shard covers a contiguous coordinate window
/// *and* a contiguous slot window; shard `i` precedes shard `i+1` in
/// coordinate order (stable, deterministic in `(runs, shards)` only).
pub fn partition_runs(rs: &[Run], active: usize, shards: usize) -> Vec<Shard> {
    debug_assert_eq!(active, rs.iter().map(|r| r.len).sum::<usize>());
    let shards = shards.max(1).min(active.max(1));
    let base = active / shards;
    let rem = active % shards;
    let mut out = Vec::with_capacity(shards);
    let mut it = rs.iter().copied();
    let mut cur = it.next();
    let mut slot = 0usize;
    for s in 0..shards {
        let mut want = base + usize::from(s < rem);
        let start_slot = slot;
        let mut sruns = Vec::new();
        while want > 0 {
            let r = cur.expect("active covers all runs");
            if r.len <= want {
                want -= r.len;
                slot += r.len;
                sruns.push(r);
                cur = it.next();
            } else {
                sruns.push(Run { offset: r.offset, len: want, scale: r.scale });
                slot += want;
                cur = Some(Run {
                    offset: r.offset + want,
                    len: r.len - want,
                    scale: r.scale,
                });
                want = 0;
            }
        }
        let (start, end) = match (sruns.first(), sruns.last()) {
            (Some(a), Some(b)) => (a.offset, b.end()),
            _ => (0, 0),
        };
        out.push(Shard { runs: sruns, start, end, start_slot, active: slot - start_slot });
    }
    out
}

/// Load imbalance of a partition: max shard active count over the mean
/// (1.0 = perfectly balanced). Empty partitions read as 1.0.
pub fn shard_imbalance(shards: &[Shard]) -> f64 {
    let total: usize = shards.iter().map(|s| s.active).sum();
    if shards.is_empty() || total == 0 {
        return 1.0;
    }
    let mean = total as f64 / shards.len() as f64;
    let max = shards.iter().map(|s| s.active).max().unwrap_or(0) as f64;
    max / mean
}

// ---------------------------------------------------------------------
// Persistent pool
// ---------------------------------------------------------------------

/// One enqueued parallel region. Workers claim indices in `[0, n)`
/// with a relaxed `fetch_add` on `next` and report completion through
/// `done`; the submitting thread blocks on `done_cv` until
/// `done == n`. `f` is a lifetime-erased pointer to the caller's
/// closure — valid until the caller observes completion, and only
/// dereferenced between a successful index claim and the matching
/// `done` increment, both of which happen before that observation.
struct Job {
    f: *const (dyn Fn(usize) + Sync),
    n: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panicked: AtomicBool,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: `f` points at a `Sync` closure that the submitting thread
// keeps alive until every index completes (it blocks in
// `run_indexed`); the raw pointer itself is never dereferenced after
// the job's last `done` increment, and may dangle harmlessly in
// queue residue afterwards (exhausted jobs return before touching it).
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Shared {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

/// Persistent scoped thread pool: `threads - 1` workers spawned once
/// (the caller participates in every region, so `threads == 1` means
/// a pure serial engine with no threads at all).
pub struct ExecEngine {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ExecEngine {
    /// Spawn an engine with the given concurrency (clamped to ≥ 1).
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (1..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("omgd-exec-{i}"))
                    .spawn(move || Self::worker_loop(&sh))
                    .expect("spawn exec worker")
            })
            .collect();
        Self { shared, handles, threads }
    }

    /// Engine from the environment ([`default_threads`]).
    pub fn from_env() -> Self {
        Self::new(default_threads())
    }

    /// Concurrency this engine runs at (including the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    fn worker_loop(shared: &Shared) {
        loop {
            let job = {
                let mut q = lock_recover(&shared.queue);
                loop {
                    if let Some(j) = q.pop_front() {
                        break Some(j);
                    }
                    if shared.shutdown.load(Ordering::Relaxed) {
                        break None;
                    }
                    q = shared
                        .cv
                        .wait(q)
                        .unwrap_or_else(|e| e.into_inner());
                }
            };
            match job {
                Some(j) => Self::work_on(&j),
                None => return,
            }
        }
    }

    /// Claim and run indices until the job is exhausted.
    fn work_on(job: &Job) {
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.n {
                return;
            }
            // SAFETY: a successful claim (i < n) implies done < n, so
            // the submitter is still blocked and the closure is alive.
            let f = unsafe { &*job.f };
            if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                job.panicked.store(true, Ordering::Relaxed);
            }
            // AcqRel: the submitter's Acquire read of the final count
            // synchronizes with every increment in the RMW chain, so
            // all task writes are visible when it unblocks.
            let d = job.done.fetch_add(1, Ordering::AcqRel) + 1;
            if d == job.n {
                let _g = lock_recover(&job.done_mx);
                job.done_cv.notify_all();
            }
        }
    }

    /// Run `f(0), f(1), …, f(n-1)` across the pool, blocking until all
    /// complete. Indices are claimed dynamically (no fixed chunking),
    /// each runs exactly once, and the caller participates. Serial and
    /// inline when `threads <= 1` or `n <= 1`. Panics (on the caller)
    /// if any task panicked.
    pub fn run_indexed<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if self.threads <= 1 || n <= 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let f_ref: &(dyn Fn(usize) + Sync) = &f;
        let job = Arc::new(Job {
            f: f_ref as *const (dyn Fn(usize) + Sync),
            n,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
            done_mx: Mutex::new(()),
            done_cv: Condvar::new(),
        });
        {
            let mut q = lock_recover(&self.shared.queue);
            // One handle per helper: each pops once and then claims
            // indices until exhaustion, so the queue never grows with n.
            for _ in 0..(self.threads - 1).min(n - 1) {
                q.push_back(job.clone());
            }
        }
        self.shared.cv.notify_all();
        Self::work_on(&job);
        let mut g = lock_recover(&job.done_mx);
        while job.done.load(Ordering::Acquire) < n {
            g = job.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
        drop(g);
        if job.panicked.load(Ordering::Relaxed) {
            panic!("exec: a parallel task panicked");
        }
    }

    /// Run `f(i, &mut tasks[i])` for every task, each on some pool
    /// thread, blocking until all complete. Per-shard wall time is
    /// recorded into `omgd_exec_shard_seconds`. The dynamic index
    /// claim hands each element to exactly one thread, so the `&mut`
    /// projections never alias.
    pub fn run_tasks<T, F>(&self, tasks: &mut [T], f: F)
    where
        T: Send,
        F: Fn(usize, &mut T) + Sync,
    {
        let base = tasks.as_mut_ptr() as usize;
        let n = tasks.len();
        self.run_indexed(n, move |i| {
            // SAFETY: each index is claimed exactly once (see
            // run_indexed), so this is the sole &mut to element i for
            // the duration of the call; T: Send permits the cross-
            // thread handoff, and `base` outlives the blocking call.
            let t = unsafe { &mut *(base as *mut T).add(i) };
            let t0 = Instant::now();
            f(i, t);
            obs::EXEC_SHARD_SECONDS.observe(t0.elapsed().as_secs_f64());
        });
    }
}

impl Drop for ExecEngine {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::Relaxed);
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mask;
    use std::sync::atomic::AtomicU64;

    fn mask_with_segments(n: usize, segs: &[(usize, usize, f32)]) -> Mask {
        let mut m = Mask::zeros(n);
        for &(off, len, scale) in segs {
            m.set_segment(off, len, scale).unwrap();
        }
        m
    }

    #[test]
    fn partition_is_balanced_disjoint_and_covering() {
        let mask = mask_with_segments(
            100,
            &[(0, 10, 1.0), (20, 5, 2.0), (40, 33, 1.0), (90, 7, 4.0)],
        );
        let runs = mask.runs();
        let active = runs.active_count();
        assert_eq!(active, 55);
        for shards in [1usize, 2, 3, 4, 7, 55, 200] {
            let parts = partition(runs, shards);
            let want = shards.min(active);
            assert_eq!(parts.len(), want, "shards={shards}");
            // balanced within one active coordinate
            let min = parts.iter().map(|s| s.active).min().unwrap();
            let max = parts.iter().map(|s| s.active).max().unwrap();
            assert!(max - min <= 1, "shards={shards}: {min}..{max}");
            // slot windows tile [0, active) in order
            let mut slot = 0usize;
            for s in &parts {
                assert_eq!(s.start_slot, slot);
                assert_eq!(s.active, s.runs.iter().map(|r| r.len).sum::<usize>());
                slot += s.active;
            }
            assert_eq!(slot, active);
            // coordinate windows are disjoint and increasing, and the
            // union of shard runs equals the active set exactly
            let mut covered = vec![0u32; 100];
            let mut prev_end = 0usize;
            for s in &parts {
                assert!(s.start >= prev_end, "shards={shards}");
                assert!(s.end > s.start);
                prev_end = s.end;
                for r in &s.runs {
                    assert!(r.offset >= s.start && r.end() <= s.end);
                    for i in r.offset..r.end() {
                        covered[i] += 1;
                        assert_eq!(mask.value(i), r.scale, "coord {i}");
                    }
                }
            }
            for i in 0..100 {
                let want = u32::from(mask.value(i) != 0.0);
                assert_eq!(covered[i], want, "coord {i} shards={shards}");
            }
            // stable: same inputs, same partition
            assert_eq!(parts, partition(runs, shards));
        }
    }

    #[test]
    fn partition_of_empty_mask_is_one_empty_shard() {
        let mask = Mask::zeros(16);
        let parts = partition(mask.runs(), 4);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].active, 0);
        assert!(parts[0].runs.is_empty());
        assert_eq!(shard_imbalance(&parts), 1.0);
    }

    #[test]
    fn shard_imbalance_is_max_over_mean() {
        let mask = mask_with_segments(40, &[(0, 30, 1.0)]);
        let parts = partition(mask.runs(), 3);
        // 30 split 3 ways exactly: perfectly balanced
        assert!((shard_imbalance(&parts) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pool_runs_every_index_exactly_once_and_is_reusable() {
        let exec = ExecEngine::new(4);
        for round in 0..3 {
            let n = 1000 + round;
            let hits: Vec<AtomicU64> =
                (0..n).map(|_| AtomicU64::new(0)).collect();
            exec.run_indexed(n, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "round {round}"
            );
        }
    }

    #[test]
    fn run_tasks_gives_each_element_to_one_thread() {
        let exec = ExecEngine::new(4);
        let mut tasks: Vec<u64> = (0..64).collect();
        exec.run_tasks(&mut tasks, |i, t| {
            *t += 1000 * (i as u64 + 1);
        });
        for (i, t) in tasks.iter().enumerate() {
            assert_eq!(*t, i as u64 + 1000 * (i as u64 + 1));
        }
    }

    #[test]
    fn serial_engine_runs_inline() {
        let exec = ExecEngine::new(1);
        assert_eq!(exec.threads(), 1);
        let mut sum = 0u64;
        // a non-Sync-unfriendly pattern that only works inline is not
        // expressible through the Fn bound; instead check effects
        let cell = AtomicU64::new(0);
        exec.run_indexed(10, |i| {
            cell.fetch_add(i as u64, Ordering::Relaxed);
        });
        sum += cell.load(Ordering::Relaxed);
        assert_eq!(sum, 45);
    }

    #[test]
    fn task_panic_propagates_to_caller() {
        let exec = ExecEngine::new(3);
        let out = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.run_indexed(16, |i| {
                if i == 7 {
                    panic!("boom");
                }
            });
        }));
        assert!(out.is_err(), "panic must surface on the caller");
        // the pool survives a panicked region
        let cell = AtomicU64::new(0);
        exec.run_indexed(8, |_| {
            cell.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(cell.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn default_threads_reads_env_then_parallelism() {
        // no env manipulation here (tests run multi-threaded); just
        // check the fallback is sane
        assert!(default_threads() >= 1);
    }
}
