//! Synthetic dataset generators.
//!
//! The paper's experiments run on CIFAR/ImageNet/GLUE/OpenWebText; on this
//! testbed we substitute parameterized synthetic equivalents (see
//! DESIGN.md §Substitutions). The theory under test only requires the ERM
//! structure `F(θ) = 1/N Σᵢ f(θ; zᵢ)` over a *fixed finite* sample set —
//! these generators produce exactly that, with enough task diversity to
//! exercise the method roster the way GLUE does.

pub mod corpus;
pub mod linreg;
pub mod tasks;

pub use corpus::{Corpus, CorpusConfig};
pub use linreg::LinRegData;
pub use tasks::{find_task, ClassTask, TaskSpec, GLUE_LIKE_TASKS};
