//! §5.1 illustrative-example dataset (Appendix B.1).
//!
//! `n` samples of dimension `d`: features `x⁽ⁱ⁾ ~ N(0, I_d)`, responses
//! `y⁽ⁱ⁾ | x⁽ⁱ⁾ ~ N((x⁽ⁱ⁾)ᵀ w_gen, 1)` with `w_gen ~ Uniform([0,1]^d)`.
//! Exposes the quadratic form `F(θ) = ½θᵀAθ − bᵀθ + c`, the optimum
//! `θ* = A⁻¹b`, and A's extreme eigenvalues (used to choose `c₀` so that
//! `c₀λ_min > 2`, the Theorem 5.3 regime).

use crate::linalg::{dot, Mat};
use crate::rng::Rng;

#[derive(Clone, Debug)]
pub struct LinRegData {
    pub d: usize,
    pub n: usize,
    pub xs: Vec<Vec<f64>>,
    pub ys: Vec<f64>,
    /// A = (2/n) Σ x xᵀ
    pub a: Mat,
    /// b = (2/n) Σ x y
    pub b: Vec<f64>,
    /// θ* = A⁻¹ b
    pub theta_star: Vec<f64>,
    pub lambda_min: f64,
    pub lambda_max: f64,
}

impl LinRegData {
    pub fn generate(d: usize, n: usize, seed: u64) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let w_gen: Vec<f64> = (0..d).map(|_| rng.f64()).collect();
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..n {
            let x: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
            let y = dot(&x, &w_gen) + rng.normal();
            xs.push(x);
            ys.push(y);
        }
        let mut a = Mat::zeros(d, d);
        let mut b = vec![0.0; d];
        let scale = 2.0 / n as f64;
        for (x, &y) in xs.iter().zip(&ys) {
            a.add_outer(scale, x, x);
            for (bi, &xi) in b.iter_mut().zip(x) {
                *bi += scale * xi * y;
            }
        }
        // θ* via eigen-decomposition (A is SPD for n >> d).
        let (vals, vecs) = a.sym_eig();
        let vt_b = vecs.transpose().matvec(&b);
        let scaled: Vec<f64> =
            vt_b.iter().zip(&vals).map(|(x, &l)| x / l).collect();
        let theta_star = vecs.matvec(&scaled);
        let lambda_min = *vals.last().unwrap();
        let lambda_max = vals[0];
        Self { d, n, xs, ys, a, b, theta_star, lambda_min, lambda_max }
    }

    /// Per-sample gradient `∇f(θ; xᵢ, yᵢ) = 2 xᵢ (xᵢᵀθ − yᵢ)`.
    pub fn grad_sample(&self, theta: &[f64], i: usize) -> Vec<f64> {
        let mut out = vec![0.0; theta.len()];
        self.grad_sample_into(theta, i, &mut out);
        out
    }

    /// [`LinRegData::grad_sample`] into a caller-owned buffer — the
    /// allocation-free form for step loops (`out.len()` must be `d`).
    pub fn grad_sample_into(&self, theta: &[f64], i: usize,
                            out: &mut [f64]) {
        let x = &self.xs[i];
        let r = 2.0 * (dot(x, theta) - self.ys[i]);
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = r * xi;
        }
    }

    /// Full gradient `∇F(θ) = Aθ − b`.
    pub fn grad_full(&self, theta: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; theta.len()];
        self.grad_full_into(theta, &mut out);
        out
    }

    /// [`LinRegData::grad_full`] into a caller-owned buffer.
    pub fn grad_full_into(&self, theta: &[f64], out: &mut [f64]) {
        self.a.matvec_into(theta, out);
        for (o, &b) in out.iter_mut().zip(&self.b) {
            *o -= b;
        }
    }

    /// `F(θ) − F(θ*)` (suboptimality; always ≥ 0 up to float error).
    pub fn subopt(&self, theta: &[f64]) -> f64 {
        let diff: Vec<f64> = theta
            .iter()
            .zip(&self.theta_star)
            .map(|(t, s)| t - s)
            .collect();
        0.5 * dot(&diff, &self.a.matvec(&diff))
    }

    /// ‖θ − θ*‖².
    pub fn err_sq(&self, theta: &[f64]) -> f64 {
        theta
            .iter()
            .zip(&self.theta_star)
            .map(|(t, s)| (t - s) * (t - s))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norm;

    #[test]
    fn generator_is_deterministic() {
        let a = LinRegData::generate(5, 50, 42);
        let b = LinRegData::generate(5, 50, 42);
        assert_eq!(a.ys, b.ys);
        assert_eq!(a.theta_star, b.theta_star);
    }

    #[test]
    fn full_gradient_vanishes_at_optimum() {
        let d = LinRegData::generate(8, 500, 1);
        let g = d.grad_full(&d.theta_star);
        assert!(norm(&g) < 1e-8, "grad norm {}", norm(&g));
    }

    #[test]
    fn sample_gradients_average_to_full() {
        let d = LinRegData::generate(6, 200, 2);
        let theta: Vec<f64> = (0..6).map(|i| 0.1 * i as f64).collect();
        let mut avg = vec![0.0; 6];
        for i in 0..d.n {
            let g = d.grad_sample(&theta, i);
            for (a, &gi) in avg.iter_mut().zip(&g) {
                *a += gi / d.n as f64;
            }
        }
        let full = d.grad_full(&theta);
        for (a, f) in avg.iter().zip(&full) {
            assert!((a - f).abs() < 1e-10, "{a} vs {f}");
        }
    }

    #[test]
    fn spd_spectrum() {
        let d = LinRegData::generate(10, 1000, 3);
        assert!(d.lambda_min > 0.0);
        assert!(d.lambda_max >= d.lambda_min);
        // For n=1000 standard normal features, A ≈ 2I.
        assert!((d.lambda_min - 2.0).abs() < 1.0);
        assert!((d.lambda_max - 2.0).abs() < 1.0);
    }

    #[test]
    fn suboptimality_nonnegative_and_zero_at_star() {
        let d = LinRegData::generate(5, 100, 4);
        assert!(d.subopt(&d.theta_star).abs() < 1e-10);
        let theta = vec![0.0; 5];
        assert!(d.subopt(&theta) >= 0.0);
        assert!(d.err_sq(&d.theta_star) < 1e-18);
    }
}
