//! Synthetic text corpus for LM pre-training (OpenWebText substitute).
//!
//! Token stream from a sparse first-order Markov chain with Zipfian
//! marginals: each token's successor distribution concentrates on a small
//! random set, giving the corpus learnable bigram structure (so the LM
//! loss curve has signal well below the unigram entropy) while the
//! Zipf marginal mimics natural-language token statistics.

use crate::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct CorpusConfig {
    pub vocab: usize,
    /// Total tokens generated.
    pub tokens: usize,
    /// Successors per token in the Markov chain.
    pub branching: usize,
    /// Zipf exponent for the stationary-ish marginal.
    pub zipf_s: f64,
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        Self { vocab: 512, tokens: 1 << 18, branching: 8, zipf_s: 1.1,
               seed: 0 }
    }
}

/// Generated corpus + windowed (x, y) sample view for next-token training.
#[derive(Clone, Debug)]
pub struct Corpus {
    pub cfg: CorpusConfig,
    pub tokens: Vec<u32>,
    /// Window length (= model seq len); windows are the ERM samples z⁽ⁱ⁾.
    pub seq: usize,
}

impl Corpus {
    pub fn generate(cfg: CorpusConfig, seq: usize) -> Self {
        assert!(cfg.vocab >= 2 && cfg.branching >= 1);
        let mut rng = Rng::seed_from_u64(cfg.seed);

        // Zipfian weights over candidate successors.
        let zipf: Vec<f64> = (1..=cfg.vocab)
            .map(|k| 1.0 / (k as f64).powf(cfg.zipf_s))
            .collect();
        let zsum: f64 = zipf.iter().sum();

        // Per-token successor table: `branching` successors sampled from
        // the Zipf marginal, with uniform mixing weights.
        let successors: Vec<Vec<u32>> = (0..cfg.vocab)
            .map(|_| {
                (0..cfg.branching)
                    .map(|_| sample_zipf(&zipf, zsum, &mut rng) as u32)
                    .collect()
            })
            .collect();

        let mut tokens = Vec::with_capacity(cfg.tokens);
        let mut cur = rng.index(cfg.vocab) as u32;
        for _ in 0..cfg.tokens {
            tokens.push(cur);
            let succ = &successors[cur as usize];
            // 10% chance of a "topic jump" to keep the chain mixing.
            cur = if rng.f64() < 0.1 {
                sample_zipf(&zipf, zsum, &mut rng) as u32
            } else {
                succ[rng.index(succ.len())]
            };
        }
        Self { cfg, tokens, seq }
    }

    /// Number of ERM samples: non-overlapping windows of `seq + 1` tokens
    /// (x = first seq, y = shifted by one).
    pub fn n_samples(&self) -> usize {
        self.tokens.len() / (self.seq + 1)
    }

    /// Materialize window `i` as (x, y) i32 pairs of length `seq`.
    pub fn window(&self, i: usize) -> (Vec<i32>, Vec<i32>) {
        let start = i * (self.seq + 1);
        let w = &self.tokens[start..start + self.seq + 1];
        let x = w[..self.seq].iter().map(|&t| t as i32).collect();
        let y = w[1..].iter().map(|&t| t as i32).collect();
        (x, y)
    }

    /// Pack a batch of window indices into contiguous `[B, S]` buffers.
    pub fn pack(&self, idx: &[usize], batch: usize)
                -> (Vec<i32>, Vec<i32>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        self.pack_into(idx, batch, &mut xs, &mut ys);
        (xs, ys)
    }

    /// [`Corpus::pack`] into caller-owned buffers — the allocation-free
    /// form for the step loop (the trainer hoists one `(x, y)` pair per
    /// run and reuses it every step). Reads the token windows directly,
    /// skipping [`Corpus::window`]'s per-sample intermediates.
    pub fn pack_into(&self, idx: &[usize], batch: usize,
                     xs: &mut Vec<i32>, ys: &mut Vec<i32>) {
        xs.clear();
        ys.clear();
        xs.reserve(batch * self.seq);
        ys.reserve(batch * self.seq);
        for b in 0..batch {
            let start = idx[b % idx.len()] * (self.seq + 1);
            let w = &self.tokens[start..start + self.seq + 1];
            xs.extend(w[..self.seq].iter().map(|&t| t as i32));
            ys.extend(w[1..].iter().map(|&t| t as i32));
        }
    }

    /// Empirical unigram entropy in nats — the loss floor a
    /// context-ignoring model can reach; the LM should go below it.
    pub fn unigram_entropy(&self) -> f64 {
        let mut counts = vec![0usize; self.cfg.vocab];
        for &t in &self.tokens {
            counts[t as usize] += 1;
        }
        let n = self.tokens.len() as f64;
        counts
            .iter()
            .filter(|&&c| c > 0)
            .map(|&c| {
                let p = c as f64 / n;
                -p * p.ln()
            })
            .sum()
    }

    /// Empirical bigram conditional entropy in nats — the floor for a
    /// one-step-context model; a healthy chain has bigram ≪ unigram.
    pub fn bigram_entropy(&self) -> f64 {
        let v = self.cfg.vocab;
        let mut pair = vec![0u32; v * v];
        let mut marg = vec![0u32; v];
        for w in self.tokens.windows(2) {
            pair[w[0] as usize * v + w[1] as usize] += 1;
            marg[w[0] as usize] += 1;
        }
        let n = (self.tokens.len() - 1) as f64;
        let mut h = 0.0;
        for a in 0..v {
            if marg[a] == 0 {
                continue;
            }
            for b in 0..v {
                let c = pair[a * v + b];
                if c > 0 {
                    let p_ab = c as f64 / n;
                    let p_cond = c as f64 / marg[a] as f64;
                    h -= p_ab * p_cond.ln();
                }
            }
        }
        h
    }
}

fn sample_zipf(weights: &[f64], sum: f64, rng: &mut Rng) -> usize {
    let mut u = rng.f64() * sum;
    for (i, &w) in weights.iter().enumerate() {
        u -= w;
        if u <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Corpus {
        Corpus::generate(
            CorpusConfig { vocab: 64, tokens: 1 << 14, branching: 4,
                           zipf_s: 1.1, seed: 3 },
            16,
        )
    }

    #[test]
    fn deterministic() {
        let a = small();
        let b = small();
        assert_eq!(a.tokens, b.tokens);
    }

    #[test]
    fn tokens_in_vocab() {
        let c = small();
        assert!(c.tokens.iter().all(|&t| (t as usize) < c.cfg.vocab));
        assert_eq!(c.tokens.len(), 1 << 14);
    }

    #[test]
    fn windows_shift_by_one() {
        let c = small();
        let (x, y) = c.window(3);
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        assert_eq!(&x[1..], &y[..15]);
    }

    #[test]
    fn pack_batches() {
        let c = small();
        let (x, y) = c.pack(&[0, 1, 2, 3], 4);
        assert_eq!(x.len(), 4 * 16);
        assert_eq!(y.len(), 4 * 16);
    }

    #[test]
    fn bigram_structure_exists() {
        let c = small();
        let uni = c.unigram_entropy();
        let bi = c.bigram_entropy();
        assert!(uni > 0.0);
        // The Markov chain must give a next-token model real signal.
        assert!(bi < uni - 0.3, "bigram {bi} vs unigram {uni}");
    }

    #[test]
    fn n_samples_counts_windows() {
        let c = small();
        assert_eq!(c.n_samples(), (1 << 14) / 17);
        // last window must be in range
        let _ = c.window(c.n_samples() - 1);
    }
}
