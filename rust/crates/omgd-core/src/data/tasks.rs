//! Synthetic classification tasks (GLUE-like and image-like).
//!
//! Each task plants a random teacher MLP, samples Gaussian features,
//! labels them by the teacher's argmax, then corrupts a `noise` fraction
//! of labels. This yields finite ERM problems of controllable difficulty
//! whose *fine-tuning dynamics* (which method converges better under a
//! fixed update budget) discriminate the paper's methods the way
//! GLUE/CIFAR do, while remaining CPU-sized.

use crate::rng::Rng;

/// Static description of one synthetic task (the "GLUE card").
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub name: &'static str,
    /// Dataset size (train split).
    pub n_train: usize,
    pub n_test: usize,
    /// Label-noise fraction (task difficulty).
    pub noise: f64,
    /// Teacher depth — deeper teachers make the decision boundary harder.
    pub teacher_depth: usize,
    /// Generator seed (fixed per task, like a dataset checksum).
    pub seed: u64,
}

/// The eight GLUE-like tasks mirrored from Table 3 (names kept for the
/// reproduced table; statistics are synthetic).
// `static`, not `const`: [`find_task`] hands out `&'static` borrows,
// which a const would only support via fragile rvalue promotion.
pub static GLUE_LIKE_TASKS: [TaskSpec; 8] = [
    TaskSpec { name: "CoLA", n_train: 512, n_test: 512, noise: 0.25,
               teacher_depth: 3, seed: 101 },
    TaskSpec { name: "STS-B", n_train: 512, n_test: 512, noise: 0.10,
               teacher_depth: 2, seed: 102 },
    TaskSpec { name: "MRPC", n_train: 384, n_test: 384, noise: 0.15,
               teacher_depth: 2, seed: 103 },
    TaskSpec { name: "RTE", n_train: 256, n_test: 384, noise: 0.30,
               teacher_depth: 3, seed: 104 },
    TaskSpec { name: "SST2", n_train: 768, n_test: 512, noise: 0.08,
               teacher_depth: 2, seed: 105 },
    TaskSpec { name: "MNLI", n_train: 1024, n_test: 512, noise: 0.18,
               teacher_depth: 3, seed: 106 },
    TaskSpec { name: "QNLI", n_train: 768, n_test: 512, noise: 0.12,
               teacher_depth: 2, seed: 107 },
    TaskSpec { name: "QQP", n_train: 1024, n_test: 512, noise: 0.15,
               teacher_depth: 2, seed: 108 },
];

/// Look up a GLUE-like task by name, tolerating case and `-`/`_`
/// differences (`"sst-2"` finds `SST2`, `"stsb"` finds `STS-B`) — the
/// paper and users spell these inconsistently. Every resolution site
/// (job specs, the CLI) must use this one helper so a name that hashes
/// as resolved also runs as resolved.
pub fn find_task(name: &str) -> Option<&'static TaskSpec> {
    let norm = |s: &str| -> String {
        s.chars()
            .filter(|c| *c != '-' && *c != '_')
            .map(|c| c.to_ascii_lowercase())
            .collect()
    };
    let want = norm(name);
    GLUE_LIKE_TASKS.iter().find(|t| norm(t.name) == want)
}

/// Materialized classification task.
#[derive(Clone, Debug)]
pub struct ClassTask {
    pub name: String,
    pub d_in: usize,
    pub n_class: usize,
    pub train_x: Vec<Vec<f32>>,
    pub train_y: Vec<u32>,
    pub test_x: Vec<Vec<f32>>,
    pub test_y: Vec<u32>,
}

impl ClassTask {
    /// Build a task from a spec for a model with `d_in` inputs and
    /// `n_class` classes.
    pub fn from_spec(spec: &TaskSpec, d_in: usize, n_class: usize) -> Self {
        let mut rng = Rng::seed_from_u64(spec.seed);
        let teacher = Teacher::random(d_in, n_class, spec.teacher_depth,
                                      &mut rng);
        let (train_x, train_y) =
            sample_split(&teacher, spec.n_train, spec.noise, n_class,
                         &mut rng);
        let (test_x, test_y) =
            sample_split(&teacher, spec.n_test, 0.0, n_class, &mut rng);
        Self {
            name: spec.name.to_string(),
            d_in,
            n_class,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    /// Image-like dataset: `n_class` Gaussian blobs with per-class means
    /// on a scaled hypercube, plus within-class covariance structure —
    /// the CIFAR substitute for Table 4.
    pub fn gaussian_blobs(
        name: &str,
        d_in: usize,
        n_class: usize,
        n_train: usize,
        n_test: usize,
        spread: f64,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let means: Vec<Vec<f64>> = (0..n_class)
            .map(|_| (0..d_in).map(|_| 2.0 * rng.normal()).collect())
            .collect();
        let gen = |n: usize, rng: &mut Rng| {
            let mut xs = Vec::with_capacity(n);
            let mut ys = Vec::with_capacity(n);
            for i in 0..n {
                let c = i % n_class; // balanced classes
                let x: Vec<f32> = means[c]
                    .iter()
                    .map(|&m| (m + spread * rng.normal()) as f32)
                    .collect();
                xs.push(x);
                ys.push(c as u32);
            }
            (xs, ys)
        };
        let (train_x, train_y) = gen(n_train, &mut rng);
        let (test_x, test_y) = gen(n_test, &mut rng);
        Self {
            name: name.to_string(),
            d_in,
            n_class,
            train_x,
            train_y,
            test_x,
            test_y,
        }
    }

    pub fn n_train(&self) -> usize {
        self.train_x.len()
    }

    /// Pack sample indices into contiguous batch buffers for the runtime:
    /// `x` as row-major f32 `[B, d_in]`, `y` as `i32[B]`. If `idx` is
    /// shorter than `batch`, the remainder wraps around (the trainer only
    /// does this on the final partial batch of an epoch).
    pub fn pack_train(&self, idx: &[usize], batch: usize)
                      -> (Vec<f32>, Vec<i32>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        self.pack_train_into(idx, batch, &mut x, &mut y);
        (x, y)
    }

    /// [`ClassTask::pack_train`] into caller-owned buffers — the
    /// allocation-free form for the step loop (the trainer hoists one
    /// `(x, y)` pair per run and reuses it every step).
    pub fn pack_train_into(&self, idx: &[usize], batch: usize,
                           x: &mut Vec<f32>, y: &mut Vec<i32>) {
        x.clear();
        y.clear();
        x.reserve(batch * self.d_in);
        y.reserve(batch);
        for b in 0..batch {
            let i = idx[b % idx.len()];
            x.extend_from_slice(&self.train_x[i]);
            y.push(self.train_y[i] as i32);
        }
    }

    pub fn pack_test(&self, start: usize, batch: usize)
                     -> (Vec<f32>, Vec<i32>) {
        let n = self.test_x.len();
        let mut x = Vec::with_capacity(batch * self.d_in);
        let mut y = Vec::with_capacity(batch);
        for b in 0..batch {
            let i = (start + b) % n;
            x.extend_from_slice(&self.test_x[i]);
            y.push(self.test_y[i] as i32);
        }
        (x, y)
    }
}

/// A fixed random MLP used as labelling teacher.
struct Teacher {
    weights: Vec<Vec<Vec<f64>>>, // layer -> out -> in
}

impl Teacher {
    fn random(d_in: usize, n_class: usize, depth: usize, rng: &mut Rng)
              -> Self {
        let hidden = 32;
        let mut dims = vec![d_in];
        dims.extend(std::iter::repeat(hidden).take(depth.saturating_sub(1)));
        dims.push(n_class);
        let weights = dims
            .windows(2)
            .map(|w| {
                let (i, o) = (w[0], w[1]);
                let std = 1.0 / (i as f64).sqrt();
                (0..o)
                    .map(|_| (0..i).map(|_| std * rng.normal()).collect())
                    .collect()
            })
            .collect();
        Self { weights }
    }

    fn label(&self, x: &[f64]) -> usize {
        let mut h: Vec<f64> = x.to_vec();
        for (li, layer) in self.weights.iter().enumerate() {
            let mut out: Vec<f64> = layer
                .iter()
                .map(|row| row.iter().zip(&h).map(|(w, x)| w * x).sum())
                .collect();
            if li + 1 < self.weights.len() {
                for v in out.iter_mut() {
                    *v = v.tanh();
                }
            }
            h = out;
        }
        h.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap()
    }
}

fn sample_split(
    teacher: &Teacher,
    n: usize,
    noise: f64,
    n_class: usize,
    rng: &mut Rng,
) -> (Vec<Vec<f32>>, Vec<u32>) {
    let d_in = teacher.weights[0][0].len();
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let x: Vec<f64> = (0..d_in).map(|_| rng.normal()).collect();
        let mut y = teacher.label(&x);
        if rng.f64() < noise {
            y = rng.index(n_class);
        }
        xs.push(x.iter().map(|&v| v as f32).collect());
        ys.push(y as u32);
    }
    (xs, ys)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_task_tolerates_case_and_separators() {
        assert_eq!(find_task("SST-2").unwrap().name, "SST2");
        assert_eq!(find_task("sst2").unwrap().name, "SST2");
        assert_eq!(find_task("stsb").unwrap().name, "STS-B");
        assert_eq!(find_task("CoLA").unwrap().name, "CoLA");
        assert!(find_task("nope").is_none());
    }

    #[test]
    fn tasks_are_deterministic() {
        let a = ClassTask::from_spec(&GLUE_LIKE_TASKS[0], 64, 4);
        let b = ClassTask::from_spec(&GLUE_LIKE_TASKS[0], 64, 4);
        assert_eq!(a.train_y, b.train_y);
        assert_eq!(a.train_x[0], b.train_x[0]);
    }

    #[test]
    fn sizes_match_spec() {
        for spec in &GLUE_LIKE_TASKS {
            let t = ClassTask::from_spec(spec, 64, 4);
            assert_eq!(t.n_train(), spec.n_train, "{}", spec.name);
            assert_eq!(t.test_x.len(), spec.n_test);
            assert!(t.train_y.iter().all(|&y| (y as usize) < 4));
        }
    }

    #[test]
    fn labels_cover_multiple_classes() {
        let t = ClassTask::from_spec(&GLUE_LIKE_TASKS[5], 64, 4);
        let mut seen = [false; 4];
        for &y in &t.train_y {
            seen[y as usize] = true;
        }
        assert!(seen.iter().filter(|&&b| b).count() >= 2,
                "degenerate task labels");
    }

    #[test]
    fn blobs_are_balanced_and_separable_ish() {
        let t = ClassTask::gaussian_blobs("img", 192, 10, 1000, 200, 0.5, 7);
        let mut counts = [0usize; 10];
        for &y in &t.train_y {
            counts[y as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
        // nearest-mean classification on test set should beat chance by a lot
        let mut means = vec![vec![0.0f64; 192]; 10];
        for (x, &y) in t.train_x.iter().zip(&t.train_y) {
            for (m, &v) in means[y as usize].iter_mut().zip(x) {
                *m += v as f64 / 100.0;
            }
        }
        let mut correct = 0;
        for (x, &y) in t.test_x.iter().zip(&t.test_y) {
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = means[a]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    let db: f64 = means[b]
                        .iter()
                        .zip(x)
                        .map(|(m, &v)| (m - v as f64).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == y as usize {
                correct += 1;
            }
        }
        assert!(correct > 150, "nearest-mean acc {correct}/200");
    }

    #[test]
    fn pack_shapes() {
        let t = ClassTask::from_spec(&GLUE_LIKE_TASKS[2], 64, 4);
        let (x, y) = t.pack_train(&[0, 1, 2], 8);
        assert_eq!(x.len(), 8 * 64);
        assert_eq!(y.len(), 8);
        // wrap-around repeats indices
        assert_eq!(y[0], y[3]);
        let (tx, ty) = t.pack_test(190, 8);
        assert_eq!(tx.len(), 8 * 64);
        assert_eq!(ty.len(), 8);
    }
}
