//! Algorithm 1's traversal engines.
//!
//! [`OmgdCycle`] is the literal Algorithm 1: at the start of each cycle,
//! draw `R_k ← RandomPermutation([M] × [N])` and walk it; every
//! `(mask, sample)` pair is visited exactly once per cycle.
//!
//! [`EpochwiseCycle`] is the Figure 1 implementation used in the deep
//! learning experiments: the outer loop walks the M masks sequentially
//! (one mask per epoch), the inner loop does a reshuffled full pass over
//! the N samples — a restricted but hardware-friendlier member of the
//! same family (each pair still visited exactly once per cycle).

use crate::rng::Rng;

/// One scheduled step: which mask and which sample to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pair {
    pub mask: usize,
    pub sample: usize,
}

/// Fully-random joint traversal of `[M] × [N]` (Algorithm 1 line 5).
#[derive(Clone, Debug)]
pub struct OmgdCycle {
    m: usize,
    n: usize,
    order: Vec<Pair>,
    pos: usize,
    /// Completed cycles (k in Algorithm 1).
    pub cycles: usize,
}

impl OmgdCycle {
    pub fn new(m: usize, n: usize) -> Self {
        assert!(m > 0 && n > 0);
        Self { m, n, order: Vec::new(), pos: 0, cycles: 0 }
    }

    pub fn cycle_len(&self) -> usize {
        self.m * self.n
    }

    /// Advance one step. Returns the pair and whether a *new cycle began*
    /// (so the caller regenerates the mask set, Algorithm 1 line 4).
    pub fn next(&mut self, rng: &mut Rng) -> (Pair, bool) {
        let mut fresh = false;
        if self.pos == self.order.len() {
            self.reshuffle(rng);
            fresh = true;
        }
        let p = self.order[self.pos];
        self.pos += 1;
        if self.pos == self.order.len() {
            self.cycles += 1;
        }
        (p, fresh)
    }

    fn reshuffle(&mut self, rng: &mut Rng) {
        self.order.clear();
        for j in 0..self.m {
            for i in 0..self.n {
                self.order.push(Pair { mask: j, sample: i });
            }
        }
        rng.shuffle(&mut self.order);
        self.pos = 0;
    }
}

/// Epochwise variant (Figure 1): mask j is applied for the whole j-th
/// epoch of the cycle; data is reshuffled every epoch.
#[derive(Clone, Debug)]
pub struct EpochwiseCycle {
    m: usize,
    n: usize,
    mask_order: Vec<usize>,
    data_order: Vec<usize>,
    epoch_in_cycle: usize,
    pos_in_epoch: usize,
    started: bool,
    pub cycles: usize,
}

impl EpochwiseCycle {
    pub fn new(m: usize, n: usize) -> Self {
        assert!(m > 0 && n > 0);
        Self {
            m,
            n,
            mask_order: Vec::new(),
            data_order: Vec::new(),
            epoch_in_cycle: 0,
            pos_in_epoch: 0,
            started: false,
            cycles: 0,
        }
    }

    pub fn cycle_len(&self) -> usize {
        self.m * self.n
    }

    /// Advance one step; returns `(pair, new_cycle, new_epoch)`.
    pub fn next(&mut self, rng: &mut Rng) -> (Pair, bool, bool) {
        let mut new_cycle = false;
        let mut new_epoch = false;
        if !self.started {
            self.start_cycle(rng);
            self.start_epoch(rng);
            self.started = true;
            new_cycle = true;
            new_epoch = true;
        } else if self.pos_in_epoch == self.n {
            self.epoch_in_cycle += 1;
            if self.epoch_in_cycle == self.m {
                self.cycles += 1;
                self.start_cycle(rng);
                new_cycle = true;
            }
            self.start_epoch(rng);
            new_epoch = true;
        }
        let p = Pair {
            mask: self.mask_order[self.epoch_in_cycle],
            sample: self.data_order[self.pos_in_epoch],
        };
        self.pos_in_epoch += 1;
        (p, new_cycle, new_epoch)
    }

    fn start_cycle(&mut self, rng: &mut Rng) {
        self.mask_order = rng.permutation(self.m);
        self.epoch_in_cycle = 0;
    }

    fn start_epoch(&mut self, rng: &mut Rng) {
        self.data_order = rng.permutation(self.n);
        self.pos_in_epoch = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn omgd_cycle_visits_every_pair_exactly_once() {
        let mut rng = Rng::seed_from_u64(1);
        let (m, n) = (4, 6);
        let mut cyc = OmgdCycle::new(m, n);
        for _cycle in 0..3 {
            let mut seen = HashSet::new();
            for _ in 0..m * n {
                let (p, _) = cyc.next(&mut rng);
                assert!(seen.insert((p.mask, p.sample)),
                        "duplicate pair {p:?}");
            }
            assert_eq!(seen.len(), m * n);
        }
        assert_eq!(cyc.cycles, 3);
    }

    #[test]
    fn omgd_cycle_signals_fresh_cycle() {
        let mut rng = Rng::seed_from_u64(2);
        let mut cyc = OmgdCycle::new(2, 3);
        let (_, fresh0) = cyc.next(&mut rng);
        assert!(fresh0);
        for _ in 1..6 {
            let (_, fresh) = cyc.next(&mut rng);
            assert!(!fresh);
        }
        let (_, fresh6) = cyc.next(&mut rng);
        assert!(fresh6, "cycle boundary must signal mask-set refresh");
    }

    #[test]
    fn omgd_cycle_orders_differ_across_cycles() {
        let mut rng = Rng::seed_from_u64(3);
        let mut cyc = OmgdCycle::new(3, 5);
        let c1: Vec<Pair> = (0..15).map(|_| cyc.next(&mut rng).0).collect();
        let c2: Vec<Pair> = (0..15).map(|_| cyc.next(&mut rng).0).collect();
        assert_ne!(c1, c2, "permutation must be re-drawn per cycle");
    }

    #[test]
    fn epochwise_uses_one_mask_per_epoch() {
        let mut rng = Rng::seed_from_u64(4);
        let (m, n) = (3, 4);
        let mut cyc = EpochwiseCycle::new(m, n);
        for _ in 0..m {
            let mut epoch_masks = HashSet::new();
            for _ in 0..n {
                let (p, _, _) = cyc.next(&mut rng);
                epoch_masks.insert(p.mask);
            }
            assert_eq!(epoch_masks.len(), 1, "mask changed mid-epoch");
        }
    }

    #[test]
    fn epochwise_cycle_covers_all_pairs() {
        let mut rng = Rng::seed_from_u64(5);
        let (m, n) = (4, 5);
        let mut cyc = EpochwiseCycle::new(m, n);
        let mut seen = HashSet::new();
        for _ in 0..m * n {
            let (p, _, _) = cyc.next(&mut rng);
            assert!(seen.insert((p.mask, p.sample)));
        }
        assert_eq!(seen.len(), m * n);
    }

    #[test]
    fn epochwise_reshuffles_data_every_epoch() {
        let mut rng = Rng::seed_from_u64(6);
        let n = 32;
        let mut cyc = EpochwiseCycle::new(2, n);
        let e1: Vec<usize> =
            (0..n).map(|_| cyc.next(&mut rng).0.sample).collect();
        let e2: Vec<usize> =
            (0..n).map(|_| cyc.next(&mut rng).0.sample).collect();
        assert_ne!(e1, e2);
        let s1: HashSet<_> = e1.iter().collect();
        assert_eq!(s1.len(), n, "epoch must be a permutation");
    }

    #[test]
    fn epochwise_flags() {
        let mut rng = Rng::seed_from_u64(7);
        let mut cyc = EpochwiseCycle::new(2, 3);
        let (_, nc, ne) = cyc.next(&mut rng);
        assert!(nc && ne);
        let (_, nc, ne) = cyc.next(&mut rng);
        assert!(!nc && !ne);
        cyc.next(&mut rng);
        let (_, nc, ne) = cyc.next(&mut rng); // step 4 = epoch 2 start
        assert!(!nc && ne);
        cyc.next(&mut rng);
        cyc.next(&mut rng);
        let (_, nc, ne) = cyc.next(&mut rng); // step 7 = cycle 2 start
        assert!(nc && ne);
        assert_eq!(cyc.cycles, 1);
    }
}
