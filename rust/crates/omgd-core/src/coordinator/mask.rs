//! Masks, their segment-run representation, and mask sets.
//!
//! A [`Mask`] selects coordinates of the flat parameter space and
//! carries the OMGD rescale factor on the selected ones. The canonical
//! (and only always-resident) representation is [`MaskRuns`]: sorted,
//! disjoint `(offset, len, scale)` segments over the active region
//! ([`Mask::runs`]). Construction, refresh ([`Mask::set_segment`]) and
//! every native consumer — optimizer steps, coverage verification,
//! residency accounting — operate on the runs, so masked work is
//! O(runs + active) instead of O(d).
//!
//! The dense `f32` vector the fused HLO kernels consume is *not* a
//! stored field. It is a lazily materialized `DenseBridge` cache:
//! [`Mask::dense_bridge`] builds it on first request (one O(d)
//! expansion), every later request is a cache hit, and
//! [`Mask::set_segment`] invalidates it — so a period's worth of device
//! steps shares one materialization, and masks that never cross the
//! device boundary never pay for one.
//!
//! The dense→runs direction ([`MaskRuns::from_dense`] /
//! [`Mask::from_dense`]) is cold-path-only: scattered-coordinate
//! constructions (coordinate partitions, i.i.d. masks, top-k
//! selections) and snapshot restore. Every scan increments the
//! `omgd_mask_densify_total` counter so a hot-loop densification
//! regression shows up in `/metrics`.
//!
//! A [`MaskSet`] is the per-cycle collection `{S⁽ʲ⁾}` required to
//! satisfy eq. (3): `Σⱼ S⁽ʲ⁾ = M·1_d` over the *maskable* region (the
//! paper's LISA instantiation keeps embed/head always active with scale 1
//! and splits only middle layers — the §5.2 worked example shows exactly
//! this shape: `S⁽¹⁾ = (1, 4, 0, 0, 0, 1)ᵀ`, ...).

use crate::manifest::Manifest;
use crate::rng::Rng;
use anyhow::{bail, ensure, Result};

/// One active segment of a mask: coordinates `offset .. offset+len`,
/// all carrying the same non-zero `scale`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Run {
    pub offset: usize,
    pub len: usize,
    pub scale: f32,
}

impl Run {
    /// One past the last coordinate of the run.
    pub fn end(&self) -> usize {
        self.offset + self.len
    }
}

/// Canonical run-length view of a mask over a flat space of `n`
/// coordinates.
///
/// Invariants (enforced by every constructor and mutator):
/// * runs are sorted by `offset` and pairwise disjoint;
/// * every run has `len > 0` and `scale != 0.0`;
/// * adjacent runs with equal scale are coalesced (no `[0,4)@2, [4,8)@2`
///   split — that is one run);
/// * `active` caches the total run length.
///
/// The canonical form makes support comparison ([`same_support`]) and
/// residency accounting O(runs), and lets consumers iterate exactly the
/// active coordinates.
///
/// [`same_support`]: MaskRuns::same_support
#[derive(Clone, Debug, PartialEq)]
pub struct MaskRuns {
    n: usize,
    runs: Vec<Run>,
    active: usize,
}

impl MaskRuns {
    /// All-frozen view over `n` coordinates.
    pub fn empty(n: usize) -> Self {
        Self { n, runs: Vec::new(), active: 0 }
    }

    /// Derive runs from a dense value vector (one O(d) scan). Values
    /// are grouped by bit pattern so a NaN entry (e.g. out of a
    /// degenerate config) forms its own run instead of stalling the
    /// scan — `NaN != NaN` would otherwise never advance it.
    ///
    /// Cold path by contract: counted in `omgd_mask_densify_total` and
    /// kept out of the steady-state step/refresh path (those splice
    /// runs instead). `#[cold]` keeps the optimizer from inlining it
    /// into hot callers.
    #[cold]
    pub fn from_dense(values: &[f32]) -> Self {
        crate::obs::MASK_DENSIFY.inc();
        let mut runs = Vec::new();
        let mut i = 0usize;
        while i < values.len() {
            let s = values[i];
            if s == 0.0 {
                // ±0.0 are both "frozen" (every consumer tests == 0.0).
                i += 1;
                continue;
            }
            let start = i;
            while i < values.len()
                && values[i].to_bits() == s.to_bits()
            {
                i += 1;
            }
            runs.push(Run { offset: start, len: i - start, scale: s });
        }
        let active = runs.iter().map(|r| r.len).sum();
        let out = Self { n: values.len(), runs, active };
        debug_assert!(
            out.runs.windows(2).all(|w| {
                w[0].end() < w[1].offset
                    || (w[0].end() == w[1].offset
                        && w[0].scale.to_bits() != w[1].scale.to_bits())
            }),
            "from_dense produced non-canonical runs"
        );
        out
    }

    /// Materialize the dense vector (the HLO bridge direction — used by
    /// the lazy [`Mask::dense_bridge`] cache and the reference mirrors).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut v = vec![0.0f32; self.n];
        for r in &self.runs {
            v[r.offset..r.end()].fill(r.scale);
        }
        v
    }

    /// Full (padded) coordinate-space length.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The canonical run list.
    pub fn runs(&self) -> &[Run] {
        &self.runs
    }

    /// Plain `(offset, len, scale)` descriptor triples — the wire form
    /// handed across the runtime boundary (`runtime` sits below this
    /// layer and cannot name [`Run`]). O(runs), never O(d).
    pub fn descriptors(&self) -> Vec<(usize, usize, f32)> {
        self.runs.iter().map(|r| (r.offset, r.len, r.scale)).collect()
    }

    /// [`MaskRuns::descriptors`] into a caller-owned buffer — the
    /// allocation-free form for per-step hot paths (the training
    /// engine caches one buffer per mask period).
    pub fn descriptors_into(&self, out: &mut Vec<(usize, usize, f32)>) {
        out.clear();
        out.extend(
            self.runs.iter().map(|r| (r.offset, r.len, r.scale)),
        );
    }

    /// Number of active coordinates (cached; O(1)).
    pub fn active_count(&self) -> usize {
        self.active
    }

    /// Active / total keep ratio.
    pub fn keep_ratio(&self) -> f64 {
        self.active as f64 / self.n.max(1) as f64
    }

    /// Scale at a single coordinate (binary search; 0.0 when frozen).
    pub fn scale_at(&self, i: usize) -> f32 {
        match self.runs.binary_search_by(|r| {
            if r.end() <= i {
                std::cmp::Ordering::Less
            } else if r.offset > i {
                std::cmp::Ordering::Greater
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(k) => self.runs[k].scale,
            Err(_) => 0.0,
        }
    }

    /// True when both views activate exactly the same coordinates
    /// (scales ignored) — the optimizer index map only depends on the
    /// support, not on the rescale factors.
    pub fn same_support(&self, other: &MaskRuns) -> bool {
        // Canonical form almost gives run-list equality, but two
        // adjacent runs with *different* scales coalesce into one when
        // scales are ignored — walk coordinate intervals instead.
        let mut a = support_iter(&self.runs);
        let mut b = support_iter(&other.runs);
        loop {
            match (a.next(), b.next()) {
                (None, None) => return true,
                (Some(x), Some(y)) if x == y => {}
                _ => return false,
            }
        }
    }

    /// Coordinates active in *both* views, keeping `self`'s scales —
    /// e.g. a caller mask restricted to SIFT's top-k selection.
    pub fn intersect_keep_scale(&self, sel: &MaskRuns) -> MaskRuns {
        let mut out = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.runs.len() && j < sel.runs.len() {
            let (a, b) = (&self.runs[i], &sel.runs[j]);
            let lo = a.offset.max(b.offset);
            let hi = a.end().min(b.end());
            if lo < hi {
                push_coalesced(&mut out, Run {
                    offset: lo,
                    len: hi - lo,
                    scale: a.scale,
                });
            }
            if a.end() <= b.end() {
                i += 1;
            } else {
                j += 1;
            }
        }
        let active = out.iter().map(|r| r.len).sum();
        MaskRuns { n: self.n, runs: out, active }
    }

    /// Replace the region `[offset, offset+len)` with `scale` (0 =
    /// freeze). Bounds are the caller's responsibility ([`Mask`] checks
    /// them); O(runs) via a vector splice, no dense scan.
    fn splice(&mut self, offset: usize, len: usize, scale: f32) {
        if len == 0 {
            return;
        }
        let end = offset + len;
        // First run ending after `offset`, first run starting at/after
        // `end`: the affected range.
        let lo = self.runs.partition_point(|r| r.end() <= offset);
        let hi = self.runs.partition_point(|r| r.offset < end);
        let mut repl = Vec::with_capacity(3);
        if lo < hi && self.runs[lo].offset < offset {
            let r = self.runs[lo];
            repl.push(Run {
                offset: r.offset,
                len: offset - r.offset,
                scale: r.scale,
            });
        }
        if scale != 0.0 {
            push_coalesced(&mut repl, Run { offset, len, scale });
        }
        if lo < hi && self.runs[hi - 1].end() > end {
            let r = self.runs[hi - 1];
            push_coalesced(&mut repl, Run {
                offset: end,
                len: r.end() - end,
                scale: r.scale,
            });
        }
        let repl_len = repl.len();
        self.runs.splice(lo..hi, repl);
        // The replacement pieces are internally coalesced; only the two
        // seams with the untouched neighbors can still need a merge.
        // Right seam first so the left index stays valid.
        if repl_len > 0 {
            self.try_merge_at(lo + repl_len - 1);
        }
        if lo > 0 {
            self.try_merge_at(lo - 1);
        }
        self.active = self.runs.iter().map(|r| r.len).sum();
    }

    /// Merge `runs[k]` into `runs[k+1]`'s place when they are adjacent
    /// and equal-scale (no-op otherwise or out of bounds).
    fn try_merge_at(&mut self, k: usize) {
        if k + 1 >= self.runs.len() {
            return;
        }
        let (a, b) = (self.runs[k], self.runs[k + 1]);
        if a.end() == b.offset && a.scale == b.scale {
            self.runs[k].len += b.len;
            self.runs.remove(k + 1);
        }
    }
}

/// Append a run, merging into the previous one when adjacent and
/// equal-scale (keeps builder output canonical).
fn push_coalesced(out: &mut Vec<Run>, r: Run) {
    if let Some(last) = out.last_mut() {
        if last.end() == r.offset && last.scale == r.scale {
            last.len += r.len;
            return;
        }
    }
    out.push(r);
}

/// Iterate maximal active coordinate intervals `(offset, end)`,
/// merging adjacent runs regardless of scale.
fn support_iter(runs: &[Run]) -> impl Iterator<Item = (usize, usize)> + '_ {
    let mut i = 0usize;
    std::iter::from_fn(move || {
        if i >= runs.len() {
            return None;
        }
        let start = runs[i].offset;
        let mut end = runs[i].end();
        i += 1;
        while i < runs.len() && runs[i].offset == end {
            end = runs[i].end();
            i += 1;
        }
        Some((start, end))
    })
}

/// Coordinate mask with scale values: canonical runs plus a lazy
/// `DenseBridge` cache for the fused HLO kernels.
///
/// The runs are the source of truth. The bridge is materialized by
/// [`Mask::dense_bridge`] on first request, reused until
/// [`Mask::set_segment`] invalidates it, and deliberately *not* carried
/// across [`Clone`] — clones happen at refresh boundaries where the
/// next device step re-materializes anyway, and a clone that never
/// crosses the device boundary should stay O(runs).
#[derive(Debug)]
pub struct Mask {
    runs: MaskRuns,
    bridge: std::cell::OnceCell<Vec<f32>>,
}

impl Clone for Mask {
    fn clone(&self) -> Self {
        Self { runs: self.runs.clone(), bridge: std::cell::OnceCell::new() }
    }
}

impl PartialEq for Mask {
    fn eq(&self, other: &Self) -> bool {
        // The runs are canonical, so run equality is mask equality; the
        // bridge is a cache and never part of the value.
        self.runs == other.runs
    }
}

impl Mask {
    pub fn zeros(n: usize) -> Self {
        Self::from_runs(MaskRuns::empty(n))
    }

    pub fn ones(n: usize) -> Self {
        let runs = if n == 0 {
            MaskRuns::empty(0)
        } else {
            MaskRuns {
                n,
                runs: vec![Run { offset: 0, len: n, scale: 1.0 }],
                active: n,
            }
        };
        Self::from_runs(runs)
    }

    fn from_runs(runs: MaskRuns) -> Self {
        Self { runs, bridge: std::cell::OnceCell::new() }
    }

    /// Build from a dense value vector (scattered-coordinate
    /// constructions: coordinate partitions, i.i.d. masks, top-k
    /// selections); one O(d) scan derives the runs. Cold path by
    /// contract — counted in `omgd_mask_densify_total`. The input
    /// vector seeds the bridge cache so an immediately following device
    /// step does not re-expand it.
    pub fn from_dense(values: Vec<f32>) -> Self {
        let runs = MaskRuns::from_dense(&values);
        let bridge = std::cell::OnceCell::new();
        let _ = bridge.set(values);
        Self { runs, bridge }
    }

    pub fn len(&self) -> usize {
        self.runs.n()
    }

    pub fn is_empty(&self) -> bool {
        self.runs.n() == 0
    }

    /// Dense view — the bridge the fused HLO kernels consume.
    /// Materialized lazily on first request (one O(d) expansion of the
    /// runs), cached until the next [`Mask::set_segment`], so a
    /// period's worth of device steps shares a single expansion.
    pub fn dense_bridge(&self) -> &[f32] {
        self.bridge.get_or_init(|| self.runs.to_dense())
    }

    /// Scale at one coordinate (binary search over the runs; 0.0 when
    /// frozen).
    pub fn value(&self, i: usize) -> f32 {
        assert!(i < self.runs.n(), "coord {i} out of mask bounds");
        self.runs.scale_at(i)
    }

    /// Canonical segment-run view (O(1); maintained incrementally).
    pub fn runs(&self) -> &MaskRuns {
        &self.runs
    }

    /// Number of active (non-zero) coordinates. Cached at
    /// construction/refresh — O(1), never a dense rescan.
    pub fn active_count(&self) -> usize {
        self.runs.active_count()
    }

    /// Keep ratio = active / total (O(1)).
    pub fn keep_ratio(&self) -> f64 {
        self.runs.active_count() as f64 / self.len().max(1) as f64
    }

    /// Set a contiguous segment to `scale` (0 freezes it). Errors on an
    /// out-of-bounds segment instead of panicking — a malformed
    /// manifest must surface as a job failure, not take down a worker
    /// thread.
    pub fn set_segment(
        &mut self,
        offset: usize,
        len: usize,
        scale: f32,
    ) -> Result<()> {
        let Some(end) = offset.checked_add(len) else {
            bail!("mask segment {offset}+{len} overflows");
        };
        ensure!(
            end <= self.runs.n(),
            "mask segment {offset}..{end} exceeds mask length {}",
            self.runs.n()
        );
        self.runs.splice(offset, len, scale);
        // The cached dense bridge (if any) is stale now.
        self.bridge.take();
        Ok(())
    }

    /// Set a single coordinate (run splice; prefer [`Mask::from_dense`]
    /// when writing many scattered coordinates).
    pub fn set_coord(&mut self, i: usize, scale: f32) -> Result<()> {
        self.set_segment(i, 1, scale)
    }

    /// Apply in place to a gradient: `g ← mask ⊙ g`. Walks the runs —
    /// frozen gaps are zeroed, active segments scaled — with no dense
    /// mask materialization. Errors on a length mismatch instead of
    /// panicking.
    pub fn apply(&self, grad: &mut [f32]) -> Result<()> {
        ensure!(
            grad.len() == self.runs.n(),
            "mask/gradient length mismatch: {} vs {}",
            self.runs.n(),
            grad.len()
        );
        let mut pos = 0usize;
        for r in self.runs.runs() {
            grad[pos..r.offset].fill(0.0);
            for g in &mut grad[r.offset..r.end()] {
                *g *= r.scale;
            }
            pos = r.end();
        }
        grad[pos..].fill(0.0);
        Ok(())
    }
}

/// A cycle's worth of masks satisfying the eq. (3) coverage condition.
#[derive(Clone, Debug)]
pub struct MaskSet {
    pub masks: Vec<Mask>,
}

impl MaskSet {
    pub fn m(&self) -> usize {
        self.masks.len()
    }

    /// Verify `Σⱼ S⁽ʲ⁾ = c·1` over `0..total` (padding excluded) for a
    /// *single* scalar c; returns c or None if violated. Runs entirely
    /// over the segment-run views: an event sweep over run boundaries,
    /// O(R log R) in the total run count instead of O(total·M).
    pub fn coverage_scalar(&self, total: usize) -> Option<f32> {
        if self.masks.is_empty() || total == 0 {
            return None;
        }
        // Difference events: +scale at run start, −scale at run end.
        let mut events: Vec<(usize, f64)> = Vec::new();
        for m in &self.masks {
            for r in m.runs().runs() {
                if r.offset >= total {
                    break; // runs are sorted; the rest is padding
                }
                events.push((r.offset, r.scale as f64));
                events.push((r.end().min(total), -(r.scale as f64)));
            }
        }
        events.sort_by_key(|&(pos, _)| pos);
        let mut c: Option<f64> = None;
        let mut sum = 0.0f64;
        let mut pos = 0usize;
        let mut k = 0usize;
        while pos < total {
            while k < events.len() && events[k].0 == pos {
                sum += events[k].1;
                k += 1;
            }
            // The sum is constant on [pos, next): one check covers it.
            match c {
                None => c = Some(sum),
                Some(prev) if (prev - sum).abs() > 1e-4 => return None,
                _ => {}
            }
            pos = if k < events.len() {
                events[k].0.min(total)
            } else {
                total
            };
        }
        c.map(|x| x as f32)
    }

    /// Remark 4.11 construction over raw coordinates: `M = ⌈1/r⌉` masks;
    /// masks 1..M−1 each own `⌊r·d⌋` random coordinates (scale M), the
    /// last mask owns the remainder. Coordinates in `total..n` (padding)
    /// stay zero in every mask.
    pub fn coordinate_partition(
        n: usize,
        total: usize,
        keep_ratio: f64,
        rng: &mut Rng,
    ) -> MaskSet {
        assert!(total <= n);
        let m = (1.0 / keep_ratio).ceil().max(1.0) as usize;
        let chunk = ((total as f64) * keep_ratio).floor() as usize;
        let perm = rng.permutation(total);
        let scale = m as f32;
        let mut dense = vec![vec![0.0f32; n]; m];
        for (rank, &coord) in perm.iter().enumerate() {
            let j = (rank / chunk.max(1)).min(m - 1);
            dense[j][coord] = scale;
        }
        MaskSet {
            masks: dense.into_iter().map(Mask::from_dense).collect(),
        }
    }

    /// Tensorwise partition (§5.2 SGDM-wor): randomly split the
    /// manifest's tensors into `M` groups of approximately equal
    /// parameter count; mask `j` activates group `j` with scale `M`.
    /// Errors (instead of panicking) when the manifest's tensor table
    /// points outside the padded parameter space.
    pub fn tensor_partition(
        man: &Manifest,
        keep_ratio: f64,
        rng: &mut Rng,
    ) -> Result<MaskSet> {
        let m = (1.0 / keep_ratio).ceil().max(1.0) as usize;
        let n = man.padded_len;
        let mut order: Vec<usize> = (0..man.params.len()).collect();
        rng.shuffle(&mut order);
        // Greedy balance: assign each tensor (in random order) to the
        // currently lightest group.
        let mut group_load = vec![0usize; m];
        let mut masks = vec![Mask::zeros(n); m];
        let scale = m as f32;
        for &pi in &order {
            let p = &man.params[pi];
            let j = (0..m).min_by_key(|&j| group_load[j]).unwrap();
            group_load[j] += p.len;
            masks[j].set_segment(p.offset, p.len, scale)?;
        }
        Ok(MaskSet { masks })
    }

    /// I.i.d. tensorwise baseline (§5.2 SGDM-iid): each tensor kept
    /// independently with probability `keep_ratio`, scale 1 (the naïve
    /// freeze scheme — no rescale, matching the paper's baseline).
    pub fn tensor_iid(
        man: &Manifest,
        keep_ratio: f64,
        rng: &mut Rng,
    ) -> Result<Mask> {
        let mut mask = Mask::zeros(man.padded_len);
        for p in &man.params {
            if rng.f64() < keep_ratio {
                mask.set_segment(p.offset, p.len, 1.0)?;
            }
        }
        Ok(mask)
    }

    /// I.i.d. coordinate mask (Remark 4.10): each coordinate kept with
    /// probability `r`, active entries scaled by `1/r` (unbiased).
    pub fn coordinate_iid(n: usize, total: usize, r: f64, rng: &mut Rng)
                          -> Mask {
        let mut dense = vec![0.0f32; n];
        let scale = (1.0 / r) as f32;
        for v in &mut dense[..total] {
            if rng.f64() < r {
                *v = scale;
            }
        }
        Mask::from_dense(dense)
    }

    /// Layerwise mask (LISA family): embed/head/final always active at
    /// scale 1; the given middle layers active at `mid_scale`; everything
    /// else frozen. Errors on a manifest whose tensor table points
    /// outside the padded space.
    pub fn layerwise(
        man: &Manifest,
        active_middle: &[String],
        mid_scale: f32,
    ) -> Result<Mask> {
        let mut mask = Mask::zeros(man.padded_len);
        for p in &man.params {
            let scale = if p.layer == "embed"
                || p.layer == "head"
                || p.layer == "final"
            {
                1.0
            } else if active_middle.iter().any(|l| *l == p.layer) {
                mid_scale
            } else {
                continue;
            };
            mask.set_segment(p.offset, p.len, scale)?;
        }
        Ok(mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn toy_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 4,
 "total_len": 20, "padded_len": 24,
 "params": [
  {"name": "in_w", "shape": [4], "layer": "embed", "offset": 0, "len": 4},
  {"name": "block_0.w", "shape": [4], "layer": "block_0", "offset": 4, "len": 4},
  {"name": "block_1.w", "shape": [4], "layer": "block_1", "offset": 8, "len": 4},
  {"name": "block_2.w", "shape": [4], "layer": "block_2", "offset": 12, "len": 4},
  {"name": "out_w", "shape": [4], "layer": "head", "offset": 16, "len": 4}
 ],
 "data": {"batch": 2},
 "artifacts": {"train": "t", "eval": "e", "init": "i",
               "update": {"adamw": "a", "sgdm": "s"}}
}"#,
        )
        .unwrap();
        Manifest::from_json(&j, Path::new("/tmp")).unwrap()
    }

    /// Dense scan ground truth for the cached count.
    fn dense_active(mask: &Mask) -> usize {
        mask.dense_bridge().iter().filter(|&&v| v != 0.0).count()
    }

    /// Runs must be canonical: sorted, disjoint, non-zero scale,
    /// coalesced, with a truthful cached count, and must round-trip
    /// through the dense bridge.
    fn assert_canonical(mask: &Mask) {
        let runs = mask.runs();
        let mut prev_end = 0usize;
        let mut prev_scale = f32::NAN;
        for r in runs.runs() {
            assert!(r.len > 0, "empty run {r:?}");
            assert!(r.scale != 0.0, "zero-scale run {r:?}");
            assert!(r.offset >= prev_end, "overlap at {r:?}");
            if r.offset == prev_end {
                assert!(r.scale != prev_scale, "uncoalesced {r:?}");
            }
            prev_end = r.end();
            prev_scale = r.scale;
        }
        assert!(prev_end <= mask.len());
        assert_eq!(runs.active_count(), dense_active(mask));
        assert_eq!(runs.to_dense(), mask.dense_bridge());
        assert_eq!(
            MaskRuns::from_dense(mask.dense_bridge()).runs(),
            runs.runs(),
            "splice-maintained runs differ from a fresh dense scan"
        );
    }

    #[test]
    fn coordinate_partition_satisfies_eq3() {
        let mut rng = Rng::seed_from_u64(1);
        for r in [0.5, 0.25, 0.34] {
            let set = MaskSet::coordinate_partition(128, 100, r, &mut rng);
            let m = (1.0f64 / r).ceil() as usize;
            assert_eq!(set.m(), m);
            let c = set.coverage_scalar(100).expect("coverage violated");
            assert!((c - m as f32).abs() < 1e-5, "c={c} m={m}");
            // padding untouched
            for mask in &set.masks {
                assert!(mask.dense_bridge()[100..].iter().all(|&v| v == 0.0));
                assert_canonical(mask);
            }
        }
    }

    #[test]
    fn coordinate_partition_disjoint() {
        let mut rng = Rng::seed_from_u64(2);
        let set = MaskSet::coordinate_partition(64, 64, 0.25, &mut rng);
        for i in 0..64 {
            let active =
                set.masks.iter().filter(|m| m.value(i) != 0.0).count();
            assert_eq!(active, 1, "coord {i} owned by {active} masks");
        }
    }

    #[test]
    fn coordinate_partition_keep_ratio() {
        let mut rng = Rng::seed_from_u64(3);
        let set = MaskSet::coordinate_partition(1024, 1000, 0.5, &mut rng);
        // first M-1 masks hold exactly floor(r d); last holds remainder
        assert_eq!(set.masks[0].active_count(), 500);
        assert_eq!(set.masks[1].active_count(), 500);
    }

    #[test]
    fn tensor_partition_satisfies_eq3() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(4);
        let set = MaskSet::tensor_partition(&man, 0.5, &mut rng).unwrap();
        assert_eq!(set.m(), 2);
        let c = set.coverage_scalar(man.total_len).unwrap();
        assert!((c - 2.0).abs() < 1e-6);
        // groups are tensor-aligned: a tensor is fully in or fully out
        for mask in &set.masks {
            assert_canonical(mask);
            for p in &man.params {
                let seg = &mask.dense_bridge()[p.offset..p.offset + p.len];
                let first = seg[0];
                assert!(seg.iter().all(|&v| v == first), "{} split", p.name);
            }
        }
    }

    #[test]
    fn tensor_partition_balances_load() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(5);
        let set = MaskSet::tensor_partition(&man, 0.5, &mut rng).unwrap();
        let loads: Vec<usize> =
            set.masks.iter().map(|m| m.active_count()).collect();
        // 5 tensors of 4 params in 2 groups → 12 vs 8
        assert_eq!(loads.iter().sum::<usize>(), 20);
        assert!(loads.iter().all(|&l| l >= 8), "{loads:?}");
    }

    #[test]
    fn tensor_iid_keeps_whole_tensors() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(6);
        let mask = MaskSet::tensor_iid(&man, 0.5, &mut rng).unwrap();
        assert_canonical(&mask);
        for p in &man.params {
            let seg = &mask.dense_bridge()[p.offset..p.offset + p.len];
            assert!(seg.iter().all(|&v| v == seg[0]));
        }
    }

    #[test]
    fn coordinate_iid_scale_unbiased() {
        let mut rng = Rng::seed_from_u64(7);
        let mask = MaskSet::coordinate_iid(4096, 4000, 0.25, &mut rng);
        let active = mask.dense_bridge()[..4000].iter()
            .filter(|&&v| v != 0.0).count();
        // ~1000 expected
        assert!((active as f64 - 1000.0).abs() < 150.0, "active {active}");
        assert!(mask.dense_bridge().iter().all(|&v| v == 0.0 || v == 4.0));
        assert!(mask.dense_bridge()[4000..].iter().all(|&v| v == 0.0));
        assert_canonical(&mask);
    }

    #[test]
    fn layerwise_mask_shape() {
        let man = toy_manifest();
        let mask =
            MaskSet::layerwise(&man, &["block_1".into()], 3.0).unwrap();
        // embed active at 1
        assert!(mask.dense_bridge()[0..4].iter().all(|&v| v == 1.0));
        // block_0 frozen
        assert!(mask.dense_bridge()[4..8].iter().all(|&v| v == 0.0));
        // block_1 active at 3 (= N_L/γ with N_L=3, γ=1)
        assert!(mask.dense_bridge()[8..12].iter().all(|&v| v == 3.0));
        // block_2 frozen
        assert!(mask.dense_bridge()[12..16].iter().all(|&v| v == 0.0));
        // head active at 1
        assert!(mask.dense_bridge()[16..20].iter().all(|&v| v == 1.0));
        // padding zero
        assert!(mask.dense_bridge()[20..].iter().all(|&v| v == 0.0));
        // runs view: embed@1, block_1@3, head@1 — three segments
        assert_canonical(&mask);
        assert_eq!(mask.runs().runs(), &[
            Run { offset: 0, len: 4, scale: 1.0 },
            Run { offset: 8, len: 4, scale: 3.0 },
            Run { offset: 16, len: 4, scale: 1.0 },
        ]);
    }

    #[test]
    fn lisa_wor_cycle_satisfies_eq3_on_middle_layers() {
        // Across a full WOR traversal (γ=1 over 3 middle layers) with
        // scale N_L/γ = 3, middle coordinates sum to 3 = M while
        // embed/head sum to 3·1 — i.e. Σ S⁽ʲ⁾ = M·1 exactly as in the
        // §5.2 worked example.
        let man = toy_manifest();
        let masks: Vec<Mask> = ["block_0", "block_1", "block_2"]
            .iter()
            .map(|l| {
                MaskSet::layerwise(&man, &[l.to_string()], 3.0).unwrap()
            })
            .collect();
        let set = MaskSet { masks };
        let c = set.coverage_scalar(man.total_len).unwrap();
        assert!((c - 3.0).abs() < 1e-6, "c={c}");
    }

    #[test]
    fn apply_masks_gradient() {
        let mut mask = Mask::zeros(4);
        mask.set_segment(1, 2, 2.0).unwrap();
        let mut g = vec![1.0f32, 1.0, 1.0, 1.0];
        mask.apply(&mut g).unwrap();
        assert_eq!(g, vec![0.0, 2.0, 2.0, 0.0]);
        assert_eq!(mask.active_count(), 2);
        assert!((mask.keep_ratio() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn apply_length_mismatch_is_an_error() {
        let mask = Mask::ones(4);
        let mut g = vec![1.0f32; 5];
        assert!(mask.apply(&mut g).is_err());
    }

    #[test]
    fn set_segment_out_of_bounds_is_an_error() {
        let mut mask = Mask::zeros(8);
        assert!(mask.set_segment(4, 8, 1.0).is_err());
        assert!(mask.set_segment(9, 0, 1.0).is_err());
        assert!(mask.set_segment(usize::MAX, 2, 1.0).is_err());
        // the failed writes left the mask untouched
        assert_eq!(mask.active_count(), 0);
        assert!(mask.dense_bridge().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn active_count_stays_consistent_after_overwrites() {
        // Regression guard for the cached count: overlapping
        // set_segment rewrites (activate, partially freeze, rescale,
        // re-activate) must keep the cache equal to a dense rescan.
        let mut mask = Mask::zeros(32);
        let script: &[(usize, usize, f32)] = &[
            (0, 16, 2.0),   // activate the front half
            (4, 8, 0.0),    // punch a hole
            (8, 20, 3.0),   // overwrite across the hole + beyond
            (0, 32, 1.0),   // full activate
            (30, 2, 0.0),   // trim the tail
            (10, 4, 1.0),   // same-scale overwrite (no-op net effect)
        ];
        for &(off, len, scale) in script {
            mask.set_segment(off, len, scale).unwrap();
            assert_eq!(
                mask.active_count(),
                mask.dense_bridge().iter().filter(|&&v| v != 0.0).count(),
                "cache diverged after set_segment({off}, {len}, {scale})"
            );
            assert_canonical(&mask);
        }
        assert_eq!(mask.active_count(), 30);
    }

    #[test]
    fn runs_splice_randomized_matches_dense_scan() {
        let mut rng = Rng::seed_from_u64(11);
        let mut mask = Mask::zeros(64);
        for _ in 0..200 {
            let off = rng.index(64);
            let len = rng.index(64 - off + 1);
            let scale = [0.0f32, 1.0, 2.0, 4.0][rng.index(4)];
            mask.set_segment(off, len, scale).unwrap();
            assert_canonical(&mask);
        }
    }

    #[test]
    fn dense_runs_bridge_round_trips() {
        let mut rng = Rng::seed_from_u64(12);
        for _ in 0..50 {
            let n = 1 + rng.index(100);
            let dense: Vec<f32> = (0..n)
                .map(|_| [0.0f32, 0.0, 1.0, 2.0][rng.index(4)])
                .collect();
            let runs = MaskRuns::from_dense(&dense);
            assert_eq!(runs.to_dense(), dense);
            assert_eq!(
                runs.active_count(),
                dense.iter().filter(|&&v| v != 0.0).count()
            );
        }
    }

    #[test]
    fn scale_at_matches_dense() {
        let mut mask = Mask::zeros(16);
        mask.set_segment(2, 3, 2.0).unwrap();
        mask.set_segment(9, 4, 0.5).unwrap();
        for i in 0..16 {
            assert_eq!(mask.runs().scale_at(i), mask.value(i), "coord {i}");
        }
    }

    #[test]
    fn same_support_ignores_scales_and_run_splits() {
        let mut a = Mask::zeros(10);
        a.set_segment(0, 4, 1.0).unwrap();
        a.set_segment(4, 2, 3.0).unwrap(); // adjacent, different scale
        let mut b = Mask::zeros(10);
        b.set_segment(0, 6, 2.0).unwrap(); // one run, same coords
        assert!(a.runs().same_support(b.runs()));
        b.set_segment(8, 1, 1.0).unwrap();
        assert!(!a.runs().same_support(b.runs()));
    }

    #[test]
    fn intersect_keeps_left_scales() {
        let mut a = Mask::zeros(12);
        a.set_segment(0, 8, 4.0).unwrap();
        let mut sel = Mask::zeros(12);
        sel.set_segment(2, 3, 1.0).unwrap();
        sel.set_segment(6, 4, 1.0).unwrap();
        let eff = a.runs().intersect_keep_scale(sel.runs());
        assert_eq!(eff.runs(), &[
            Run { offset: 2, len: 3, scale: 4.0 },
            Run { offset: 6, len: 2, scale: 4.0 },
        ]);
        assert_eq!(eff.active_count(), 5);
    }

    #[test]
    fn coverage_scalar_over_runs_matches_worked_example() {
        // §5.2 worked example, literally: d = 6 (embed, 4 middles,
        // head), M = 4 masks, S⁽ʲ⁾ = (1, …, 4 at middle j, …, 1)ᵀ.
        let mut masks = Vec::new();
        for j in 0..4 {
            let mut m = Mask::zeros(6);
            m.set_segment(0, 1, 1.0).unwrap();
            m.set_segment(1 + j, 1, 4.0).unwrap();
            m.set_segment(5, 1, 1.0).unwrap();
            masks.push(m);
        }
        let set = MaskSet { masks };
        let c = set.coverage_scalar(6).expect("eq. (3) holds");
        assert!((c - 4.0).abs() < 1e-6, "c={c}");
        // Breaking one entry breaks the scalar.
        let mut bad = set.clone();
        bad.masks[0].set_segment(2, 1, 1.0).unwrap();
        assert_eq!(bad.coverage_scalar(6), None);
    }

    #[test]
    fn dense_bridge_is_cached_and_invalidated_by_set_segment() {
        let mut mask = Mask::zeros(16);
        mask.set_segment(2, 6, 2.0).unwrap();
        // Two requests without an intervening splice hit the same
        // allocation — the bridge is materialized once.
        let p1 = mask.dense_bridge().as_ptr();
        let p2 = mask.dense_bridge().as_ptr();
        assert_eq!(p1, p2);
        assert_eq!(mask.dense_bridge(), mask.runs().to_dense());
        // A splice invalidates the cache; the next request reflects it.
        mask.set_segment(4, 2, 0.0).unwrap();
        let d = mask.dense_bridge();
        assert_eq!(&d[2..4], &[2.0, 2.0]);
        assert_eq!(&d[4..6], &[0.0, 0.0]);
        assert_eq!(&d[6..8], &[2.0, 2.0]);
        assert_eq!(d, mask.runs().to_dense());
    }

    #[test]
    fn from_dense_seeds_bridge_and_counts_one_densify() {
        let dense = vec![0.0f32, 3.0, 3.0, 0.0, 1.0];
        let ptr = dense.as_ptr();
        let before = crate::obs::MASK_DENSIFY.get();
        let mask = Mask::from_dense(dense);
        // exactly one dense scan happened for this mask (the counter is
        // global and monotonic, so other tests can only push it higher)
        assert!(crate::obs::MASK_DENSIFY.get() > before);
        // the input vector itself seeds the cache — no re-expansion
        assert_eq!(mask.dense_bridge().as_ptr(), ptr);
    }

    #[test]
    fn clone_drops_bridge_cache_but_preserves_equality() {
        let mut mask = Mask::zeros(8);
        mask.set_segment(1, 5, 2.0).unwrap();
        let _ = mask.dense_bridge();
        let copy = mask.clone();
        assert_eq!(copy, mask);
        assert_eq!(copy.dense_bridge(), mask.dense_bridge());
        // equality is over runs, not the cache state
        let fresh = copy.clone();
        assert_eq!(fresh, mask);
    }

    #[test]
    fn coverage_scalar_detects_uncovered_gap() {
        // Coords 0..4 covered at 2, coord 4 uncovered → not scalar.
        let mut m1 = Mask::zeros(5);
        m1.set_segment(0, 4, 2.0).unwrap();
        let set = MaskSet { masks: vec![m1] };
        assert_eq!(set.coverage_scalar(5), None);
        // But over total=4 it is.
        assert_eq!(set.coverage_scalar(4), Some(2.0));
    }
}
