//! The OMGD coordinator — the paper's algorithmic contribution at L3.
//!
//! * [`mask`] — mask representations and mask-*set* generation satisfying
//!   eq. (3): `Σⱼ S⁽ʲ⁾ = M·1_d` (coordinate, tensorwise and layerwise
//!   constructions, plus the i.i.d. baselines they are compared
//!   against). The canonical mask representation is the segment-run
//!   view ([`mask::MaskRuns`]); the dense vector is a lazy,
//!   explicitly requested cache ([`mask::Mask::dense_bridge`]), so
//!   every consumer — native steps, residency accounting, the HLO
//!   dispatch (via [`mask::MaskRuns::descriptors`]) — does O(active)
//!   work instead of O(d).
//! * [`cycle`] — Algorithm 1's traversal engine: per cycle, a fresh
//!   random permutation of `[M] × [N]` visited exactly once, plus the
//!   epochwise variant of Figure 1.
//! * [`lisa`] — Algorithm 2: LISA (i.i.d. layer sampling) and LISA-WOR
//!   (without-replacement pool + `N_L/γ` gradient scaling) and both
//!   ablations.
//! * [`sampler`] — data-order strategies (random reshuffling vs i.i.d.).

pub mod cycle;
pub mod lisa;
pub mod mask;
pub mod sampler;

pub use cycle::{EpochwiseCycle, OmgdCycle};
pub use lisa::{LisaScheduler, LisaVariant};
pub use mask::{Mask, MaskRuns, MaskSet, Run};
pub use sampler::DataSampler;
