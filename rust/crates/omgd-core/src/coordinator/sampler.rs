//! Data-order strategies: random reshuffling vs i.i.d. with replacement.
//!
//! RR is the default (and the regime the paper's theory addresses): at
//! each epoch boundary a fresh permutation of `0..n` is drawn and
//! consumed without replacement. The IID sampler is the with-replacement
//! baseline used by the §5.1/appendix comparisons.

use crate::rng::Rng;

#[derive(Clone, Debug)]
pub enum DataSampler {
    /// Random reshuffling: permute per epoch, consume sequentially.
    Rr { n: usize, order: Vec<usize>, pos: usize, epochs: usize },
    /// With-replacement uniform sampling.
    Iid { n: usize, draws: usize },
    /// Fixed sequential order (ablation / determinism tests).
    Sequential { n: usize, pos: usize },
}

impl DataSampler {
    pub fn rr(n: usize) -> Self {
        assert!(n > 0);
        DataSampler::Rr { n, order: Vec::new(), pos: 0, epochs: 0 }
    }

    pub fn iid(n: usize) -> Self {
        assert!(n > 0);
        DataSampler::Iid { n, draws: 0 }
    }

    pub fn sequential(n: usize) -> Self {
        assert!(n > 0);
        DataSampler::Sequential { n, pos: 0 }
    }

    pub fn n(&self) -> usize {
        match self {
            DataSampler::Rr { n, .. }
            | DataSampler::Iid { n, .. }
            | DataSampler::Sequential { n, .. } => *n,
        }
    }

    /// Next sample index; `bool` flags an epoch boundary (RR reshuffle).
    pub fn next(&mut self, rng: &mut Rng) -> (usize, bool) {
        match self {
            DataSampler::Rr { n, order, pos, epochs } => {
                let mut new_epoch = false;
                if *pos == order.len() {
                    *order = rng.permutation(*n);
                    *pos = 0;
                    new_epoch = true;
                    *epochs += 1;
                }
                let i = order[*pos];
                *pos += 1;
                (i, new_epoch)
            }
            DataSampler::Iid { n, draws } => {
                *draws += 1;
                (rng.index(*n), false)
            }
            DataSampler::Sequential { n, pos } => {
                let i = *pos % *n;
                let new_epoch = i == 0;
                *pos += 1;
                (i, new_epoch)
            }
        }
    }

    /// Draw a batch of indices (RR batches never straddle epochs unless
    /// the epoch ends mid-batch, in which case the next epoch continues
    /// filling — standard DataLoader semantics with drop_last=False).
    pub fn next_batch(&mut self, batch: usize, rng: &mut Rng)
                      -> Vec<usize> {
        (0..batch).map(|_| self.next(rng).0).collect()
    }

    /// Completed epochs (RR/Sequential; IID reports draws / n).
    pub fn epochs(&self) -> usize {
        match self {
            DataSampler::Rr { epochs, .. } => *epochs,
            DataSampler::Iid { n, draws } => draws / n,
            DataSampler::Sequential { n, pos } => pos / n,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn rr_epoch_is_permutation() {
        let mut rng = Rng::seed_from_u64(1);
        let mut s = DataSampler::rr(17);
        for _epoch in 0..4 {
            let mut seen = HashSet::new();
            for _ in 0..17 {
                let (i, _) = s.next(&mut rng);
                assert!(seen.insert(i), "index {i} repeated within epoch");
            }
            assert_eq!(seen.len(), 17);
        }
    }

    #[test]
    fn rr_orders_differ_between_epochs() {
        let mut rng = Rng::seed_from_u64(2);
        let mut s = DataSampler::rr(32);
        let e1: Vec<usize> = (0..32).map(|_| s.next(&mut rng).0).collect();
        let e2: Vec<usize> = (0..32).map(|_| s.next(&mut rng).0).collect();
        assert_ne!(e1, e2);
    }

    #[test]
    fn iid_can_repeat_within_window() {
        let mut rng = Rng::seed_from_u64(3);
        let mut s = DataSampler::iid(4);
        let draws: Vec<usize> =
            (0..16).map(|_| s.next(&mut rng).0).collect();
        let distinct: HashSet<_> = draws[..4].iter().collect();
        // with n=4, 4 i.i.d. draws are a permutation with prob 4!/4⁴ ≈ 9%;
        // over 4 windows of 4 the chance all are permutations is ~1e-4.
        let windows_all_perms = draws
            .chunks(4)
            .all(|w| w.iter().collect::<HashSet<_>>().len() == 4);
        assert!(!windows_all_perms || distinct.len() < 4 || true);
        // main check: all draws in range
        assert!(draws.iter().all(|&i| i < 4));
    }

    #[test]
    fn sequential_wraps() {
        let mut rng = Rng::seed_from_u64(4);
        let mut s = DataSampler::sequential(3);
        let xs: Vec<usize> = (0..7).map(|_| s.next(&mut rng).0).collect();
        assert_eq!(xs, vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(s.epochs(), 2);
    }

    #[test]
    fn batch_sizes() {
        let mut rng = Rng::seed_from_u64(5);
        let mut s = DataSampler::rr(10);
        let b = s.next_batch(7, &mut rng);
        assert_eq!(b.len(), 7);
        let b2 = s.next_batch(7, &mut rng);
        assert_eq!(b2.len(), 7);
        // first 10 across both batches form a permutation
        let first_epoch: HashSet<usize> =
            b.iter().chain(b2.iter().take(3)).cloned().collect();
        assert_eq!(first_epoch.len(), 10);
    }

    #[test]
    fn epoch_counting() {
        let mut rng = Rng::seed_from_u64(6);
        let mut s = DataSampler::rr(5);
        for _ in 0..12 {
            s.next(&mut rng);
        }
        assert_eq!(s.epochs(), 3); // 3 reshuffles happened (step 1, 6, 11)
    }
}
