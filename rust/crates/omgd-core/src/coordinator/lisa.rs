//! Algorithm 2: LISA and LISA-WOR layer schedulers.
//!
//! LISA (Pan et al., 2024) periodically unfreezes γ randomly chosen
//! middle layers (plus embed/head, always active). LISA-WOR adds the two
//! red lines of Algorithm 2: (1) layers are drawn from a
//! without-replacement pool that reshuffles only when exhausted, so a
//! cycle of ⌈N_L/γ⌉ periods covers every middle layer exactly once; and
//! (2) selected middle layers' gradients are rescaled by `N_L/γ`, which
//! is what makes the traversal satisfy eq. (3) and inherit Theorem 4.6.

use crate::rng::Rng;

/// Which of Algorithm 2's four variants (paper Table 3 ablation roster).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LisaVariant {
    /// i.i.d. sampling, no scaling (original LISA).
    Lisa,
    /// i.i.d. sampling + N_L/γ scaling ("LISA-scale").
    LisaScale,
    /// WOR sampling, no scaling ("LISA-wor-no-scale").
    LisaWorNoScale,
    /// WOR sampling + scaling (the paper's LISA-WOR).
    LisaWor,
}

impl LisaVariant {
    pub fn uses_wor(&self) -> bool {
        matches!(self, LisaVariant::LisaWorNoScale | LisaVariant::LisaWor)
    }

    pub fn uses_scale(&self) -> bool {
        matches!(self, LisaVariant::LisaScale | LisaVariant::LisaWor)
    }
}

/// The active set for one sampling period.
#[derive(Clone, Debug, PartialEq)]
pub struct ActiveSet {
    /// Names of the unfrozen middle layers.
    pub layers: Vec<String>,
    /// Gradient scale to apply to those layers (1.0 when no scaling).
    pub scale: f32,
    /// True if this period began a fresh WOR pool (cycle boundary).
    pub new_cycle: bool,
}

/// Stateful scheduler; call [`LisaScheduler::next_period`] every K steps.
#[derive(Clone, Debug)]
pub struct LisaScheduler {
    variant: LisaVariant,
    middle: Vec<String>,
    gamma: usize,
    /// Algorithm 2's UNSELECTED_LAYERS pool (indices into `middle`).
    pool: Vec<usize>,
    /// Completed full traversals of the pool.
    pub cycles: usize,
}

impl LisaScheduler {
    pub fn new(variant: LisaVariant, middle_layers: Vec<String>,
               gamma: usize) -> Self {
        assert!(gamma >= 1, "γ must be >= 1");
        assert!(!middle_layers.is_empty(), "no middle layers");
        let gamma = gamma.min(middle_layers.len());
        let pool = (0..middle_layers.len()).collect();
        Self { variant, middle: middle_layers, gamma, pool, cycles: 0 }
    }

    pub fn n_middle(&self) -> usize {
        self.middle.len()
    }

    /// The `N_L/γ` rescale factor used by the scaling variants.
    pub fn scale_factor(&self) -> f32 {
        self.middle.len() as f32 / self.gamma as f32
    }

    /// The current WOR pool (indices into the middle-layer list), for
    /// checkpointing — together with `cycles` this is the scheduler's
    /// whole mutable state.
    pub fn pool(&self) -> &[usize] {
        &self.pool
    }

    /// Restore checkpointed traversal state. Errors on out-of-range or
    /// duplicate pool indices (a corrupt checkpoint must not panic a
    /// worker later, inside `next_period`).
    pub fn set_state(
        &mut self,
        pool: Vec<usize>,
        cycles: usize,
    ) -> anyhow::Result<()> {
        let mut seen = vec![false; self.middle.len()];
        for &i in &pool {
            anyhow::ensure!(
                i < self.middle.len(),
                "pool index {i} out of range ({} middle layers)",
                self.middle.len()
            );
            anyhow::ensure!(!seen[i], "duplicate pool index {i}");
            seen[i] = true;
        }
        self.pool = pool;
        self.cycles = cycles;
        Ok(())
    }

    /// Draw the next period's active set (Algorithm 2 lines 4–9).
    pub fn next_period(&mut self, rng: &mut Rng) -> ActiveSet {
        let scale = if self.variant.uses_scale() {
            self.scale_factor()
        } else {
            1.0
        };
        if self.variant.uses_wor() {
            let mut new_cycle = false;
            // Line 4–6: reset the pool if it cannot supply γ layers.
            if self.pool.len() < self.gamma {
                if self.pool.len() < self.middle.len() {
                    self.cycles += 1;
                    new_cycle = true;
                }
                self.pool = (0..self.middle.len()).collect();
            }
            // Line 7–8: draw γ WITHOUT replacement from the pool.
            let mut chosen = Vec::with_capacity(self.gamma);
            for _ in 0..self.gamma {
                let k = rng.index(self.pool.len());
                chosen.push(self.pool.swap_remove(k));
            }
            chosen.sort_unstable();
            ActiveSet {
                layers: chosen.iter()
                    .map(|&i| self.middle[i].clone()).collect(),
                scale,
                new_cycle,
            }
        } else {
            // Original LISA: fresh i.i.d. γ-subset each period.
            let mut chosen = rng.choose_k(self.middle.len(), self.gamma);
            chosen.sort_unstable();
            ActiveSet {
                layers: chosen.iter()
                    .map(|&i| self.middle[i].clone()).collect(),
                scale,
                new_cycle: false,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    fn layers(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("block_{i}")).collect()
    }

    #[test]
    fn wor_covers_all_layers_per_cycle() {
        let mut rng = Rng::seed_from_u64(1);
        let mut sched =
            LisaScheduler::new(LisaVariant::LisaWor, layers(12), 3);
        for _cycle in 0..5 {
            let mut seen = HashSet::new();
            for _ in 0..4 {
                // 12/3 = 4 periods per cycle
                let act = sched.next_period(&mut rng);
                assert_eq!(act.layers.len(), 3);
                for l in &act.layers {
                    assert!(seen.insert(l.clone()),
                            "layer {l} repeated within cycle");
                }
            }
            assert_eq!(seen.len(), 12);
        }
    }

    #[test]
    fn wor_scale_is_nl_over_gamma() {
        let mut rng = Rng::seed_from_u64(2);
        let mut sched =
            LisaScheduler::new(LisaVariant::LisaWor, layers(12), 3);
        let act = sched.next_period(&mut rng);
        assert!((act.scale - 4.0).abs() < 1e-6);
    }

    #[test]
    fn no_scale_variants_scale_one() {
        let mut rng = Rng::seed_from_u64(3);
        for v in [LisaVariant::Lisa, LisaVariant::LisaWorNoScale] {
            let mut sched = LisaScheduler::new(v, layers(8), 2);
            let act = sched.next_period(&mut rng);
            assert_eq!(act.scale, 1.0);
        }
    }

    #[test]
    fn iid_lisa_can_repeat_layers_across_periods() {
        // Statistical: over many periods, i.i.d. sampling must produce at
        // least one immediate repeat that WOR provably cannot (γ=N_L/2).
        let mut rng = Rng::seed_from_u64(4);
        let mut sched = LisaScheduler::new(LisaVariant::Lisa, layers(4), 2);
        let mut repeat = false;
        let mut prev: HashSet<String> = HashSet::new();
        for _ in 0..50 {
            let act = sched.next_period(&mut rng);
            let cur: HashSet<String> = act.layers.iter().cloned().collect();
            if !prev.is_disjoint(&cur) {
                repeat = true;
            }
            prev = cur;
        }
        assert!(repeat, "i.i.d. LISA never repeated in 50 periods?");
    }

    #[test]
    fn wor_never_repeats_within_cycle_even_with_remainder() {
        // N_L = 5, γ = 2: periods get {2,2,1}-sized fresh draws; pool
        // resets mid-stream. Every cycle still covers all 5 exactly once.
        let mut rng = Rng::seed_from_u64(5);
        let mut sched =
            LisaScheduler::new(LisaVariant::LisaWor, layers(5), 2);
        let mut seen: HashSet<String> = HashSet::new();
        let mut count = 0usize;
        // run until the second cycle starts
        loop {
            let act = sched.next_period(&mut rng);
            if act.new_cycle {
                break;
            }
            for l in &act.layers {
                assert!(seen.insert(l.clone()));
                count += 1;
            }
        }
        // first cycle covered 4 (2+2); the 5th layer rolls into the
        // period that triggered the reset
        assert!(count == 4, "covered {count}");
    }

    #[test]
    fn gamma_clamped_to_pool() {
        let mut rng = Rng::seed_from_u64(6);
        let mut sched =
            LisaScheduler::new(LisaVariant::LisaWor, layers(3), 10);
        let act = sched.next_period(&mut rng);
        assert_eq!(act.layers.len(), 3);
    }

    #[test]
    fn cycles_counted() {
        let mut rng = Rng::seed_from_u64(7);
        let mut sched =
            LisaScheduler::new(LisaVariant::LisaWor, layers(6), 2);
        for _ in 0..9 {
            sched.next_period(&mut rng);
        }
        // 3 periods per cycle → after 9 periods, 2 completed resets
        assert_eq!(sched.cycles, 2);
    }

    #[test]
    fn pool_state_round_trips_bitwise() {
        let mut rng = Rng::seed_from_u64(8);
        let mut a =
            LisaScheduler::new(LisaVariant::LisaWor, layers(7), 2);
        for _ in 0..5 {
            a.next_period(&mut rng);
        }
        let mut b =
            LisaScheduler::new(LisaVariant::LisaWor, layers(7), 2);
        b.set_state(a.pool().to_vec(), a.cycles).unwrap();
        // identical RNG + identical pool → identical future draws
        let mut rng_a = Rng::seed_from_u64(99);
        let mut rng_b = Rng::seed_from_u64(99);
        for _ in 0..10 {
            assert_eq!(
                a.next_period(&mut rng_a),
                b.next_period(&mut rng_b)
            );
        }
    }

    #[test]
    fn set_state_rejects_corrupt_pools() {
        let mut s =
            LisaScheduler::new(LisaVariant::LisaWor, layers(3), 1);
        assert!(s.set_state(vec![0, 3], 0).is_err(), "out of range");
        assert!(s.set_state(vec![1, 1], 0).is_err(), "duplicate");
        assert!(s.set_state(vec![2, 0], 5).is_ok());
        assert_eq!(s.cycles, 5);
        assert_eq!(s.pool(), &[2, 0]);
    }

    #[test]
    fn variant_flags() {
        assert!(LisaVariant::LisaWor.uses_wor()
            && LisaVariant::LisaWor.uses_scale());
        assert!(!LisaVariant::Lisa.uses_wor()
            && !LisaVariant::Lisa.uses_scale());
        assert!(LisaVariant::LisaScale.uses_scale()
            && !LisaVariant::LisaScale.uses_wor());
        assert!(LisaVariant::LisaWorNoScale.uses_wor()
            && !LisaVariant::LisaWorNoScale.uses_scale());
    }
}
