//! Property-testing mini-framework (proptest replacement).
//!
//! Deterministic, seed-driven random-case runner: a property is a closure
//! over a [`Gen`] handle; `check` runs it across many derived seeds and
//! reports the failing seed so a regression can be pinned as an explicit
//! unit test. No shrinking — failing seeds are small, inspectable inputs
//! by construction (generators take explicit bounds).

use crate::rng::Rng;

/// Generation handle passed to each property case.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.rng.index(hi - lo + 1)
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.f64() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.f64() < 0.5
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| scale * self.rng.normal32()).collect()
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.index(xs.len())]
    }
}

/// Run `cases` random cases of `prop`; panic with the failing case/seed.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base = env_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen { rng: Rng::seed_from_u64(seed), case };
        let result = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| prop(&mut g)),
        );
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    err.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property {name:?} failed at case {case} \
                 (OMGD_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, mut prop: F) {
    let mut g = Gen { rng: Rng::seed_from_u64(seed), case: 0 };
    prop(&mut g);
}

fn env_seed() -> u64 {
    std::env::var("OMGD_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xD1CE_5EED)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut count = 0usize;
        check("counting", 25, |_| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 50, |g| {
            let n = g.usize_in(3, 9);
            assert!((3..=9).contains(&n));
            let x = g.f64_in(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&x));
            let v = g.vec_f32(n, 2.0);
            assert_eq!(v.len(), n);
            let item = *g.pick(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&item));
        });
    }

    #[test]
    #[should_panic(expected = "property \"fails\" failed")]
    fn failing_property_reports_seed() {
        check("fails", 10, |g| {
            let n = g.usize_in(0, 100);
            assert!(n < 101); // passes
            if g.case == 7 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut a = Vec::new();
        replay(42, |g| a.push(g.usize_in(0, 1000)));
        let mut b = Vec::new();
        replay(42, |g| b.push(g.usize_in(0, 1000)));
        assert_eq!(a, b);
    }
}
