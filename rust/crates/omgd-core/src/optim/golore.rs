//! GoLore-style low-rank *random* gradient projection (He et al., 2024).
//!
//! For every 2-D parameter tensor `W ∈ R^{m×n}` (with `min(m,n) > rank`)
//! the gradient matrix `G` is compressed to `Ĝ = Pᵀ G ∈ R^{r×n}` (or
//! `G P ∈ R^{m×r}` when n < m) where `P` is drawn *uniformly on the
//! Stiefel manifold* and refreshed every `refresh` steps. Adam moments
//! live in the projected space (that is the memory saving); the update is
//! projected back with the `1/r`-style unbiasing factor absorbed into P's
//! orthonormality. Small tensors (biases, norms) fall back to dense
//! AdamW.
//!
//! The same struct also implements GaLore when constructed with
//! [`ProjectionKind::TopSingular`]: P is then the top-r left-singular
//! block of G (computed by power iteration), refreshed on the same
//! schedule — the dominated-subspace scheme whose bias §1(i) discusses.

use crate::coordinator::{MaskRuns, Run};
use crate::exec::ExecEngine;
use crate::linalg::{stiefel, Mat};
use crate::manifest::ParamInfo;
use crate::optim::{dense_adamw_run, par_adamw_segments, Optimizer};
use crate::rng::Rng;

/// How the projection factor is chosen.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProjectionKind {
    /// Uniform random Stiefel factor (GoLore).
    RandomStiefel,
    /// Top-r singular subspace of the current gradient (GaLore).
    TopSingular,
}

/// Per-tensor projection state.
struct TensorState {
    offset: usize,
    rows: usize,
    cols: usize,
    /// Project on the left (P: rows×r, Ĝ = PᵀG) if rows >= cols,
    /// else on the right (P: cols×r, Ĝ = G P).
    left: bool,
    p: Mat,
    /// Adam moments in projected space (r×cols or rows×r, flattened).
    m: Vec<f32>,
    v: Vec<f32>,
}

/// Dense fallback state for non-projected coordinates.
struct DenseState {
    m: Vec<f32>,
    v: Vec<f32>,
    /// Flat indices covered (tensor too small to project).
    segments: Vec<(usize, usize)>,
}

pub struct GoloreOptimizer {
    kind: ProjectionKind,
    rank: usize,
    refresh: usize,
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    t: u64,
    tensors: Vec<TensorState>,
    dense: DenseState,
    rng: Rng,
    n: usize,
}

impl GoloreOptimizer {
    pub fn new(
        kind: ProjectionKind,
        params: &[ParamInfo],
        n: usize,
        rank: usize,
        refresh: usize,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::seed_from_u64(seed);
        let mut tensors = Vec::new();
        let mut segments = Vec::new();
        let mut dense_len = 0usize;
        for p in params {
            if p.shape.len() == 2
                && p.shape[0].min(p.shape[1]) > rank
            {
                let (rows, cols) = (p.shape[0], p.shape[1]);
                let left = rows >= cols;
                let pm = if left {
                    stiefel(rows, rank, &mut rng)
                } else {
                    stiefel(cols, rank, &mut rng)
                };
                let proj_len = if left { rank * cols } else { rows * rank };
                tensors.push(TensorState {
                    offset: p.offset,
                    rows,
                    cols,
                    left,
                    p: pm,
                    m: vec![0.0; proj_len],
                    v: vec![0.0; proj_len],
                });
            } else {
                segments.push((p.offset, p.len));
                dense_len += p.len;
            }
        }
        let _ = dense_len;
        // The run-aware step merge-walks runs against these; keep them
        // in flat-offset order regardless of manifest ordering.
        segments.sort_unstable();
        let dense = DenseState {
            m: vec![0.0; n],
            v: vec![0.0; n],
            segments,
        };
        Self {
            kind,
            rank,
            refresh: refresh.max(1),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            t: 0,
            tensors,
            dense,
            rng,
            n,
        }
    }

    fn refresh_projection(&mut self, g: &[f32]) {
        for ts in &mut self.tensors {
            let dim = if ts.left { ts.rows } else { ts.cols };
            ts.p = match self.kind {
                ProjectionKind::RandomStiefel => {
                    stiefel(dim, self.rank, &mut self.rng)
                }
                ProjectionKind::TopSingular => {
                    top_singular_block(g, ts, self.rank, &mut self.rng)
                }
            };
            // Paper practice: reset projected moments on refresh (the old
            // subspace's moments are meaningless in the new basis).
            ts.m.iter_mut().for_each(|x| *x = 0.0);
            ts.v.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    /// Number of projected (compressed-state) parameters.
    pub fn projected_params(&self) -> usize {
        self.tensors.iter().map(|t| t.m.len()).sum()
    }

    /// Overlaps of the mask runs with the (sorted) dense-fallback
    /// segments: a merge walk in O(active ∩ fallback), each overlap
    /// contiguous with a uniform scale. Both the serial and the
    /// sharded step walk exactly this list, so they cannot drift.
    fn fallback_overlaps(&self, runs: &MaskRuns) -> Vec<Run> {
        let rs = runs.runs();
        let mut out = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < rs.len() && j < self.dense.segments.len() {
            let r = rs[i];
            let (off, len) = self.dense.segments[j];
            let lo = r.offset.max(off);
            let hi = r.end().min(off + len);
            if lo < hi {
                out.push(Run { offset: lo, len: hi - lo, scale: r.scale });
            }
            if r.end() <= off + len {
                i += 1;
            } else {
                j += 1;
            }
        }
        out
    }
}

/// Top-r left/right singular block of the gradient matrix via subspace
/// (block power) iteration on G Gᵀ / Gᵀ G.
fn top_singular_block(g: &[f32], ts: &TensorState, rank: usize,
                      rng: &mut Rng) -> Mat {
    let (rows, cols) = (ts.rows, ts.cols);
    let gm = Mat {
        rows,
        cols,
        data: g[ts.offset..ts.offset + rows * cols]
            .iter()
            .map(|&x| x as f64)
            .collect(),
    };
    let dim = if ts.left { rows } else { cols };
    let mut q = stiefel(dim, rank, rng);
    for _ in 0..4 {
        let z = if ts.left {
            // (G Gᵀ) Q
            gm.matmul(&gm.transpose().matmul(&q))
        } else {
            gm.transpose().matmul(&gm.matmul(&q))
        };
        let (qq, _) = z.qr();
        q = qq;
    }
    q
}

impl GoloreOptimizer {
    /// Shared step prologue: projection refresh, step count, bias
    /// corrections.
    fn begin_step(&mut self, g: &[f32]) -> (f32, f32) {
        if self.t % self.refresh as u64 == 0 {
            self.refresh_projection(g);
        }
        self.t += 1;
        (
            1.0 - self.beta1.powi(self.t as i32),
            1.0 - self.beta2.powi(self.t as i32),
        )
    }

    /// The mask-independent part: project each large tensor's gradient,
    /// run Adam in the projected space, back-project the update.
    fn step_projected(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        lr: f32,
        bc1: f32,
        bc2: f32,
    ) {
        let (b1, b2) = (self.beta1, self.beta2);
        for ts in &mut self.tensors {
            let (rows, cols) = (ts.rows, ts.cols);
            let gm = Mat {
                rows,
                cols,
                data: g[ts.offset..ts.offset + rows * cols]
                    .iter()
                    .map(|&x| x as f64)
                    .collect(),
            };
            // Ĝ in projected space.
            let ghat = if ts.left {
                ts.p.transpose().matmul(&gm) // r×cols
            } else {
                gm.matmul(&ts.p) // rows×r
            };
            // Adam in projected space.
            let mut upd_hat = Mat::zeros(ghat.rows, ghat.cols);
            for i in 0..ghat.data.len() {
                let gi = ghat.data[i] as f32;
                let m = b1 * ts.m[i] + (1.0 - b1) * gi;
                let v = b2 * ts.v[i] + (1.0 - b2) * gi * gi;
                ts.m[i] = m;
                ts.v[i] = v;
                let mhat = m / bc1;
                let vhat = v / bc2;
                upd_hat.data[i] =
                    (mhat / (vhat.sqrt() + self.eps)) as f64;
            }
            // Back-project the update.
            let upd = if ts.left {
                ts.p.matmul(&upd_hat) // rows×cols
            } else {
                upd_hat.matmul(&ts.p.transpose())
            };
            let seg = &mut p[ts.offset..ts.offset + rows * cols];
            for (i, pi) in seg.iter_mut().enumerate() {
                *pi -= lr
                    * (upd.data[i] as f32 + self.weight_decay * *pi);
            }
        }
    }
}

impl Optimizer for GoloreOptimizer {
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    ) {
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        let (bc1, bc2) = self.begin_step(g);
        self.step_projected(p, g, lr, bc1, bc2);
        // Dense fallback tensors: each run∩segment overlap is
        // contiguous with a uniform scale, so the shared SoA per-run
        // kernel handles it whole.
        let hp = (self.beta1, self.beta2, bc1, bc2, self.eps,
                  self.weight_decay);
        for r in self.fallback_overlaps(runs) {
            dense_adamw_run(
                &mut self.dense.m, &mut self.dense.v, p, g, r.offset,
                r.len, r.scale, hp, lr,
            );
        }
    }

    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        let (bc1, bc2) = self.begin_step(g);
        // The projected update stays serial (dense matmuls over a few
        // small tensors); only the dense-fallback runs walk shards.
        self.step_projected(p, g, lr, bc1, bc2);
        let hp = (self.beta1, self.beta2, bc1, bc2, self.eps,
                  self.weight_decay);
        let segs = self.fallback_overlaps(runs);
        par_adamw_segments(exec, &segs, &mut self.dense.m,
                           &mut self.dense.v, p, g, hp, lr);
    }

    fn state_bytes(&self) -> usize {
        // Projected moments + projection factors + dense moments actually
        // used (only the dense segments count toward residency).
        let proj: usize = self
            .tensors
            .iter()
            .map(|t| (t.m.len() + t.v.len()) * 4 + t.p.data.len() * 8)
            .sum();
        let dense: usize = self
            .dense
            .segments
            .iter()
            .map(|&(_, len)| len * 8)
            .sum();
        proj + dense
    }

    fn name(&self) -> &'static str {
        match self.kind {
            ProjectionKind::RandomStiefel => "golore",
            ProjectionKind::TopSingular => "galore",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mask;

    fn params_2d() -> Vec<ParamInfo> {
        vec![
            ParamInfo {
                name: "w".into(),
                shape: vec![16, 12],
                layer: "block_0".into(),
                offset: 0,
                len: 192,
            },
            ParamInfo {
                name: "b".into(),
                shape: vec![12],
                layer: "block_0".into(),
                offset: 192,
                len: 12,
            },
        ]
    }

    #[test]
    fn projects_large_matrices_only() {
        let opt = GoloreOptimizer::new(
            ProjectionKind::RandomStiefel, &params_2d(), 204, 4, 10, 0,
        );
        assert_eq!(opt.tensors.len(), 1);
        assert_eq!(opt.dense.segments, vec![(192, 12)]);
        // projected moments are rank×cols = 4×12
        assert_eq!(opt.projected_params(), 48);
    }

    #[test]
    fn state_smaller_than_dense_adamw() {
        let opt = GoloreOptimizer::new(
            ProjectionKind::RandomStiefel, &params_2d(), 204, 4, 10, 0,
        );
        // dense AdamW would be 204*2*4 = 1632 bytes of moments
        assert!(opt.projected_params() * 8 < 192 * 8);
        assert!(opt.state_bytes() > 0);
    }

    #[test]
    fn descends_a_quadratic() {
        // minimize ½‖W‖² + ½‖b‖²: g = p. GoLore still makes progress
        // because random subspaces rotate over refreshes.
        let mut rng = Rng::seed_from_u64(1);
        let n = 204;
        let mut p: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut opt = GoloreOptimizer::new(
            ProjectionKind::RandomStiefel, &params_2d(), n, 4, 5, 0,
        );
        let mask = Mask::ones(n);
        let norm0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..300 {
            let g = p.clone();
            opt.step(&mut p, &g, mask.runs(), 0.05);
        }
        let norm1: f32 = p.iter().map(|x| x * x).sum();
        assert!(norm1 < 0.5 * norm0, "{norm1} vs {norm0}");
    }

    #[test]
    fn galore_top_subspace_captures_dominant_direction() {
        // Gradient of rank ~1 ⇒ GaLore's P should capture it: the
        // back-projected update must be nearly parallel to the gradient.
        let params = vec![ParamInfo {
            name: "w".into(),
            shape: vec![20, 16],
            layer: "b".into(),
            offset: 0,
            len: 320,
        }];
        let mut rng = Rng::seed_from_u64(2);
        let u: Vec<f32> = (0..20).map(|_| rng.normal32()).collect();
        let v: Vec<f32> = (0..16).map(|_| rng.normal32()).collect();
        let g: Vec<f32> = (0..320)
            .map(|i| u[i / 16] * v[i % 16])
            .collect();
        let mut p = vec![0.0f32; 320];
        let mut opt = GoloreOptimizer::new(
            ProjectionKind::TopSingular, &params, 320, 2, 100, 0,
        );
        opt.step(&mut p, &g, Mask::ones(320).runs(), 1.0);
        // update direction ≈ -sign pattern of g's rank-1 structure:
        // cosine between Δp and g should be large in magnitude.
        let dp: Vec<f32> = p.clone();
        let dot: f32 = dp.iter().zip(&g).map(|(a, b)| a * b).sum();
        let na: f32 = dp.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nb: f32 = g.iter().map(|x| x * x).sum::<f32>().sqrt();
        let cos = (dot / (na * nb)).abs();
        assert!(cos > 0.5, "cosine {cos}");
    }

    #[test]
    fn refresh_changes_projection() {
        let params = params_2d();
        let mut opt = GoloreOptimizer::new(
            ProjectionKind::RandomStiefel, &params, 204, 4, 1, 0,
        );
        let g = vec![0.1f32; 204];
        let mut p = vec![0.0f32; 204];
        let mask = Mask::ones(204);
        opt.step(&mut p, &g, mask.runs(), 0.01);
        let p1 = opt.tensors[0].p.clone();
        opt.step(&mut p, &g, mask.runs(), 0.01);
        let p2 = opt.tensors[0].p.clone();
        assert!(p1.sub(&p2).fro() > 1e-6, "projection did not refresh");
    }

    #[test]
    fn names() {
        let a = GoloreOptimizer::new(
            ProjectionKind::RandomStiefel, &params_2d(), 204, 4, 10, 0,
        );
        assert_eq!(a.name(), "golore");
        let b = GoloreOptimizer::new(
            ProjectionKind::TopSingular, &params_2d(), 204, 4, 10, 0,
        );
        assert_eq!(b.name(), "galore");
    }
}
