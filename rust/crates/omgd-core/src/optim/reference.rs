//! Dense reference optimizers — ground truth for the runs path.
//!
//! Plain O(d) loops over the dense mask vector, written independently
//! of the compact implementations in [`super`]. They mirror the L1
//! Pallas kernels' semantics exactly (hard-freeze masking, same
//! bias-correction convention) and keep full-length state, which is
//! precisely what the compact optimizers must reproduce elementwise on
//! the active region. This file is the **only** place outside
//! `coordinator/mask.rs` allowed to consume a dense mask slice (fed by
//! `Mask::dense_bridge()` — ci.sh greps for leaks elsewhere). Used by
//! `tests/proptests.rs` (bitwise runs-vs-dense property) and as the
//! dense-bridge arm of `omgd microbench`.

/// Dense AdamW with hard-freeze masking and full-length `m`/`v`.
pub struct DenseAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: u64,
}

impl DenseAdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32,
               weight_decay: f32) -> Self {
        Self {
            beta1,
            beta2,
            eps,
            weight_decay,
            m: vec![0.0; n],
            v: vec![0.0; n],
            t: 0,
        }
    }

    pub fn default_hp(n: usize) -> Self {
        Self::new(n, 0.9, 0.999, 1e-8, 0.01)
    }

    /// One dense masked step: `mask` is the dense scale vector.
    pub fn step(&mut self, p: &mut [f32], g: &[f32], mask: &[f32],
                lr: f32) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), mask.len());
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let (b1, b2) = (self.beta1, self.beta2);
        for i in 0..p.len() {
            let mk = mask[i];
            if mk == 0.0 {
                continue;
            }
            let gm = mk * g[i];
            let m = b1 * self.m[i] + (1.0 - b1) * gm;
            let v = b2 * self.v[i] + (1.0 - b2) * gm * gm;
            self.m[i] = m;
            self.v[i] = v;
            let mhat = m / bc1;
            let vhat = v / bc2;
            p[i] -= lr
                * (mhat / (vhat.sqrt() + self.eps)
                    + self.weight_decay * p[i]);
        }
    }
}

/// Dense SGDM with hard-freeze masking and a full-length buffer.
pub struct DenseSgdm {
    pub momentum: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
    pub buf: Vec<f32>,
}

impl DenseSgdm {
    pub fn new(n: usize, momentum: f32, weight_decay: f32,
               nesterov: bool) -> Self {
        Self { momentum, weight_decay, nesterov, buf: vec![0.0; n] }
    }

    pub fn step(&mut self, p: &mut [f32], g: &[f32], mask: &[f32],
                lr: f32) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), mask.len());
        let mu = self.momentum;
        for i in 0..p.len() {
            let mk = mask[i];
            if mk == 0.0 {
                continue;
            }
            let gm = mk * g[i] + self.weight_decay * p[i];
            let b = mu * self.buf[i] + gm;
            self.buf[i] = b;
            let upd = if self.nesterov { gm + mu * b } else { b };
            p[i] -= lr * upd;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mask;
    use crate::optim::{MaskedAdamW, MaskedSgdm, Optimizer};
    use crate::rng::Rng;

    #[test]
    fn compact_adamw_matches_dense_reference_bitwise() {
        let n = 96;
        let mut rng = Rng::seed_from_u64(10);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut mask = Mask::zeros(n);
        mask.set_segment(5, 30, 2.0).unwrap();
        mask.set_segment(60, 17, 0.5).unwrap();
        let (mut pd, mut pc) = (p0.clone(), p0);
        let mut dense = DenseAdamW::default_hp(n);
        let mut compact = MaskedAdamW::default_hp(n);
        for _ in 0..4 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
            dense.step(&mut pd, &g, mask.dense_bridge(), 1e-3);
            compact.step(&mut pc, &g, mask.runs(), 1e-3);
        }
        assert!(pd.iter().zip(&pc).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn compact_sgdm_matches_dense_reference_bitwise() {
        let n = 64;
        let mut rng = Rng::seed_from_u64(11);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, 20, 3.0).unwrap();
        mask.set_segment(40, 10, 1.0).unwrap();
        let (mut pd, mut pc) = (p0.clone(), p0);
        let mut dense = DenseSgdm::new(n, 0.9, 1e-4, true);
        let mut compact = MaskedSgdm::new(n, 0.9, 1e-4, true);
        for _ in 0..4 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
            dense.step(&mut pd, &g, mask.dense_bridge(), 0.05);
            compact.step(&mut pc, &g, mask.runs(), 0.05);
        }
        assert!(pd.iter().zip(&pc).all(|(a, b)| a.to_bits() == b.to_bits()));
    }
}
