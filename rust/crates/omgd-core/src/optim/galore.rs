//! GaLore baseline (Zhao et al., 2024) — thin constructor over the shared
//! low-rank projection machinery in [`super::golore`] with the top-r
//! singular-subspace projection (the deterministic, dominated-subspace
//! variant whose persistent bias §1(i) and §5.1 analyze).

use crate::manifest::ParamInfo;
use crate::optim::golore::{GoloreOptimizer, ProjectionKind};

/// GaLore = projection onto the gradient's top-r singular block.
pub type GaloreOptimizer = GoloreOptimizer;

/// Construct a GaLore optimizer (top-singular projection).
pub fn galore(
    params: &[ParamInfo],
    n: usize,
    rank: usize,
    refresh: usize,
    seed: u64,
) -> GaloreOptimizer {
    GoloreOptimizer::new(ProjectionKind::TopSingular, params, n, rank,
                         refresh, seed)
}

/// Construct a GoLore optimizer (random Stiefel projection).
pub fn golore(
    params: &[ParamInfo],
    n: usize,
    rank: usize,
    refresh: usize,
    seed: u64,
) -> GaloreOptimizer {
    GoloreOptimizer::new(ProjectionKind::RandomStiefel, params, n, rank,
                         refresh, seed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mask;
    use crate::optim::Optimizer;
    use crate::rng::Rng;

    #[test]
    fn constructors_pick_kind() {
        let params = vec![ParamInfo {
            name: "w".into(),
            shape: vec![16, 16],
            layer: "b".into(),
            offset: 0,
            len: 256,
        }];
        assert_eq!(galore(&params, 256, 4, 10, 0).name(), "galore");
        assert_eq!(golore(&params, 256, 4, 10, 0).name(), "golore");
    }

    #[test]
    fn galore_descends_quadratic() {
        let params = vec![ParamInfo {
            name: "w".into(),
            shape: vec![16, 16],
            layer: "b".into(),
            offset: 0,
            len: 256,
        }];
        let mut rng = Rng::seed_from_u64(3);
        let mut p: Vec<f32> = (0..256).map(|_| rng.normal32()).collect();
        let mut opt = galore(&params, 256, 4, 20, 0);
        let mask = Mask::ones(256);
        let n0: f32 = p.iter().map(|x| x * x).sum();
        for _ in 0..200 {
            let g = p.clone();
            opt.step(&mut p, &g, mask.runs(), 0.05);
        }
        let n1: f32 = p.iter().map(|x| x * x).sum();
        assert!(n1 < n0, "galore failed to descend: {n1} vs {n0}");
    }
}
