//! SIFT baseline (Song et al., 2023): gradient-magnitude-based sparse
//! fine-tuning. Each period the optimizer re-selects the top-k fraction
//! of coordinates by |g| and only updates those — "sparse is enough"
//! component sparsification.
//!
//! State stays dense (full-length `m`/`v`): the selection churns by
//! gradient magnitude every refresh and SIFT's semantics carry moments
//! across re-selections, so compacting would change the method. The
//! *iteration* is run-aware: the selection is held as a [`MaskRuns`]
//! view and [`Optimizer::step`] walks the caller's runs intersected
//! with it — O(active ∩ selected) per step, each intersection run
//! through the shared SoA per-run AdamW kernel. `state_bytes()`
//! reports the paper's residency model (moments for selected
//! coordinates only). Re-selection itself is a sanctioned cold
//! `Mask::from_dense` (top-k is inherently scattered).

use crate::coordinator::{Mask, MaskRuns};
use crate::exec::ExecEngine;
use crate::optim::{dense_adamw_run, par_adamw_segments, Optimizer};

pub struct SiftOptimizer {
    beta1: f32,
    beta2: f32,
    eps: f32,
    weight_decay: f32,
    /// Dense moments (carried across re-selections).
    m: Vec<f32>,
    v: Vec<f32>,
    /// Fraction of coordinates kept.
    pub topk: f64,
    /// Steps between re-selections.
    pub refresh: usize,
    /// Current selection (scale 1.0 on kept coords; runs view drives
    /// the intersection in `step`).
    sel: Mask,
    t: u64,
    /// Only the first `total` coords participate (padding excluded).
    total: usize,
}

impl SiftOptimizer {
    pub fn new(n: usize, total: usize, topk: f64, refresh: usize) -> Self {
        assert!(topk > 0.0 && topk <= 1.0);
        Self {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.01,
            m: vec![0.0; n],
            v: vec![0.0; n],
            topk,
            refresh: refresh.max(1),
            sel: Mask::zeros(n),
            t: 0,
            total,
        }
    }

    fn reselect(&mut self, g: &[f32]) {
        let k = ((self.total as f64) * self.topk).ceil() as usize;
        // Partial select: nth_element by |g|.
        let mut idx: Vec<usize> = (0..self.total).collect();
        let kk = k.min(self.total).max(1);
        idx.select_nth_unstable_by(kk - 1, |&a, &b| {
            g[b].abs().partial_cmp(&g[a].abs()).unwrap()
        });
        let mut dense = vec![0.0f32; self.sel.len()];
        for &i in &idx[..kk] {
            dense[i] = 1.0;
        }
        self.sel = Mask::from_dense(dense);
    }

    pub fn selected(&self) -> usize {
        self.sel.active_count()
    }

    /// Shared prologue: re-selection cadence, step count, corrections.
    fn begin_step(&mut self, g: &[f32]) -> (f32, f32) {
        if self.t % self.refresh as u64 == 0 {
            self.reselect(g);
        }
        self.t += 1;
        (
            1.0 - self.beta1.powi(self.t as i32),
            1.0 - self.beta2.powi(self.t as i32),
        )
    }

    /// Hyper-parameter tuple for [`dense_adamw_run`] — the one shared
    /// dense masked-AdamW per-run update (see optim/mod.rs), so SIFT's
    /// arithmetic can never drift from golore's fallback or the
    /// property-test contract.
    fn hp(&self, bc1: f32, bc2: f32) -> (f32, f32, f32, f32, f32, f32) {
        (self.beta1, self.beta2, bc1, bc2, self.eps, self.weight_decay)
    }
}

impl Optimizer for SiftOptimizer {
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    ) {
        assert_eq!(p.len(), g.len());
        assert_eq!(runs.n(), p.len());
        let (bc1, bc2) = self.begin_step(g);
        let hp = self.hp(bc1, bc2);
        let eff = runs.intersect_keep_scale(self.sel.runs());
        for r in eff.runs() {
            dense_adamw_run(&mut self.m, &mut self.v, p, g, r.offset,
                            r.len, r.scale, hp, lr);
        }
    }

    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        assert_eq!(p.len(), g.len());
        assert_eq!(runs.n(), p.len());
        let (bc1, bc2) = self.begin_step(g);
        let hp = self.hp(bc1, bc2);
        let eff = runs.intersect_keep_scale(self.sel.runs());
        par_adamw_segments(exec, eff.runs(), &mut self.m, &mut self.v,
                           p, g, hp, lr);
    }

    fn state_bytes(&self) -> usize {
        // Residency model: only selected coordinates need moments.
        self.sel.active_count() * 8
    }

    fn name(&self) -> &'static str {
        "sift"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn selects_topk_by_magnitude() {
        let n = 100;
        let mut opt = SiftOptimizer::new(n, n, 0.1, 1000);
        let mut g = vec![0.01f32; n];
        for i in 0..10 {
            g[i * 10] = 10.0 - i as f32; // 10 large coords
        }
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, Mask::ones(n).runs(), 0.1);
        assert_eq!(opt.selected(), 10);
        // only those ten moved
        let moved: Vec<usize> =
            (0..n).filter(|&i| p[i] != 0.0).collect();
        assert_eq!(moved.len(), 10);
        assert!(moved.iter().all(|&i| i % 10 == 0));
    }

    #[test]
    fn refresh_reselects() {
        let n = 32;
        let mut opt = SiftOptimizer::new(n, n, 0.25, 1);
        let mut p = vec![0.0f32; n];
        let mut g1 = vec![0.0f32; n];
        g1[0] = 1.0;
        g1[1] = 1.0;
        let mut g2 = vec![0.0f32; n];
        g2[30] = 1.0;
        g2[31] = 1.0;
        opt.step(&mut p, &g1, Mask::ones(n).runs(), 0.1);
        assert!(p[0] != 0.0);
        let p30_before = p[30];
        opt.step(&mut p, &g2, Mask::ones(n).runs(), 0.1);
        assert!(p[30] != p30_before, "reselection failed");
    }

    #[test]
    fn respects_outer_mask() {
        let n = 16;
        let mut opt = SiftOptimizer::new(n, n, 1.0, 1);
        let mut p = vec![0.0f32; n];
        let g = vec![1.0f32; n];
        let mut outer = Mask::zeros(n);
        outer.set_segment(0, 8, 1.0).unwrap();
        opt.step(&mut p, &g, outer.runs(), 0.1);
        assert!(p[..8].iter().all(|&x| x != 0.0));
        assert!(p[8..].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn padding_excluded_from_selection() {
        let n = 64;
        let total = 48;
        let mut opt = SiftOptimizer::new(n, total, 1.0, 1);
        let g = vec![1.0f32; n];
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, Mask::ones(n).runs(), 0.1);
        assert!(p[total..].iter().all(|&x| x == 0.0));
        assert_eq!(opt.selected(), total);
    }

    #[test]
    fn state_bytes_tracks_selection() {
        let n = 1000;
        let mut opt = SiftOptimizer::new(n, n, 0.1, 1);
        let mut rng = Rng::seed_from_u64(0);
        let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut p = vec![0.0f32; n];
        opt.step(&mut p, &g, Mask::ones(n).runs(), 0.01);
        assert_eq!(opt.state_bytes(), 100 * 8);
    }

    #[test]
    fn runs_step_is_deterministic_across_instances() {
        // Two independently constructed optimizers driven with the same
        // inputs must stay bitwise identical — the selection and the
        // intersection walk are both deterministic.
        let n = 200;
        let mut rng = Rng::seed_from_u64(1);
        let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut mask = Mask::zeros(n);
        mask.set_segment(10, 90, 2.0).unwrap();
        mask.set_segment(120, 60, 1.0).unwrap();
        let (mut pd, mut pr) = (p0.clone(), p0);
        let mut od = SiftOptimizer::new(n, n, 0.2, 2);
        let mut or = SiftOptimizer::new(n, n, 0.2, 2);
        for _ in 0..5 {
            let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
            od.step(&mut pd, &g, mask.runs(), 0.01);
            or.step(&mut pr, &g, mask.runs(), 0.01);
        }
        assert!(
            pd.iter().zip(&pr).all(|(a, b)| a.to_bits() == b.to_bits())
        );
        assert_eq!(od.selected(), or.selected());
    }

    #[test]
    fn moments_carry_across_reselection() {
        // SIFT keeps dense state: a coordinate that leaves and
        // re-enters the selection resumes from its old moments (unlike
        // the compact masked optimizers' reset semantics).
        let n = 8;
        let mut opt = SiftOptimizer::new(n, n, 0.25, 1);
        let mut p = vec![0.0f32; n];
        let mut g1 = vec![0.0f32; n];
        g1[0] = 1.0;
        g1[1] = 1.0;
        opt.step(&mut p, &g1, Mask::ones(n).runs(), 0.0); // lr 0: state only
        let m0 = opt.m[0];
        assert!(m0 != 0.0);
        let mut g2 = vec![0.0f32; n];
        g2[6] = 1.0;
        g2[7] = 1.0;
        opt.step(&mut p, &g2, Mask::ones(n).runs(), 0.0); // coord 0 deselected
        assert_eq!(opt.m[0], m0, "dense state must survive deselection");
    }
}
