//! Native optimizers over the flat parameter vector.
//!
//! The API is runs-first: [`Optimizer::step`] takes the mask's
//! canonical segment-run view ([`MaskRuns`]) and touches **only active
//! coordinates** — O(runs + active) time per step instead of O(d). No
//! trait entry point accepts (or materializes) a dense mask vector; the
//! only dense-slice steppers left in the crate are the ground-truth
//! mirrors in [`reference`], which the bitwise property tests and the
//! `omgd microbench` dense-bridge arm drive through
//! `Mask::dense_bridge()`.
//!
//! [`MaskedAdamW`] and [`MaskedSgdm`] additionally store their moment
//! state **only for the active region**: a compact index map (the
//! support runs; compact slot = prefix position within them) is rebuilt
//! at period boundaries with explicit carry/reset semantics —
//! coordinates active across the refresh carry their moments,
//! re-activated coordinates restart from zero, deactivated coordinates'
//! state is freed. `state_bytes()` therefore reports **true residency**
//! (≈ `keep_ratio · d · 8` bytes for AdamW), matching the paper's
//! analytic model in [`crate::memory`] instead of silently holding
//! 2·d·4 bytes. The update arithmetic per active coordinate is
//! bit-identical to the L1 Pallas kernels (same hard-freeze masking,
//! same bias-correction convention).
//!
//! [`galore`]/[`golore`] implement the low-rank gradient-projection
//! baselines, and [`sift`] the top-k magnitude-masking baseline. Those
//! keep dense state (their residency story is the projection /
//! selection, not the mask) but still step through runs; their shared
//! per-run AdamW update is the SoA [`dense_adamw_run`] helper, whose
//! fixed-lane chunked inner loop the compiler autovectorizes.
//!
//! Every optimizer also exposes [`Optimizer::step_sharded`]: the same
//! step driven shard-parallel over an [`ExecEngine`]. Shards own
//! disjoint coordinate windows (and, for compact state, the matching
//! slot windows), every update is elementwise, and the partition is a
//! pure function of `(runs, shards)` — so the sharded step is
//! **bitwise identical** to the serial one for every thread count.

pub mod galore;
pub mod golore;
pub mod reference;
pub mod sift;

pub use galore::GaloreOptimizer;
pub use golore::{GoloreOptimizer, ProjectionKind};
pub use sift::SiftOptimizer;

use crate::coordinator::{MaskRuns, Run};
use crate::exec::{partition, partition_runs, ExecEngine};

/// Common interface: one update step on the flat parameter vector.
/// The mask's segment runs carry both selection and scale (see
/// kernels/ref.py); `lr` is supplied per step so schedules stay outside
/// the optimizer.
pub trait Optimizer {
    /// Run-aware step: walk the mask's segment runs and touch only the
    /// active coordinates. Must produce parameters elementwise-identical
    /// to the dense reference mirrors driven with the same mask's
    /// `dense_bridge()` (the bitwise property contract).
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    );

    /// Period-boundary notification: rebuild any active-region index
    /// map for the new support (carry still-active state, reset
    /// re-activated coordinates, free the rest). Default: no-op for
    /// optimizers without compact state.
    fn on_mask_refresh(&mut self, _runs: &MaskRuns) {}

    /// Shard-parallel [`Optimizer::step`] over `exec`'s pool. Must be
    /// **bitwise identical** to the serial step for every thread
    /// count: the partition only decides which thread computes a
    /// coordinate, never what arithmetic reaches it (all updates are
    /// elementwise). The default runs the serial step; stateful
    /// implementations override it with disjoint-window sharding.
    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        let _ = exec;
        self.step(p, g, runs, lr);
    }

    /// Shard-parallel [`Optimizer::on_mask_refresh`]: state
    /// carry-copies may run on `exec`'s pool (the copy windows are
    /// disjoint). Same bitwise contract as [`Optimizer::step_sharded`].
    fn on_mask_refresh_sharded(
        &mut self,
        runs: &MaskRuns,
        exec: &ExecEngine,
    ) {
        let _ = exec;
        self.on_mask_refresh(runs);
    }

    /// Bytes of optimizer state currently held (memory accounting).
    fn state_bytes(&self) -> usize;

    fn name(&self) -> &'static str;
}

/// Compact active-region index map shared by the stateful masked
/// optimizers: the support runs of the current mask, in order. The
/// compact slot of coordinate `i` inside run `k` is
/// `prefix_len(k) + (i − offset_k)` — walking the runs in order yields
/// consecutive slots, so stepping needs no per-coordinate lookup table
/// (which would itself be O(d) memory).
#[derive(Clone, Debug, Default)]
struct ActiveMap {
    /// Support segments (scale is irrelevant to residency).
    segs: Vec<(usize, usize)>,
    active: usize,
}

impl ActiveMap {
    fn from_runs(runs: &MaskRuns) -> Self {
        let mut segs: Vec<(usize, usize)> = Vec::new();
        for r in runs.runs() {
            // Merge adjacent runs that differ only in scale: the map is
            // support-only, so `same…` comparisons stay canonical.
            if let Some(last) = segs.last_mut() {
                if last.0 + last.1 == r.offset {
                    last.1 += r.len;
                    continue;
                }
            }
            segs.push((r.offset, r.len));
        }
        Self { active: runs.active_count(), segs }
    }

    fn matches(&self, runs: &MaskRuns) -> bool {
        if self.active != runs.active_count() {
            return false;
        }
        let mut k = 0usize;
        let mut segs = self.segs.iter().copied();
        let mut cur: Option<(usize, usize)> = segs.next();
        for r in runs.runs() {
            // Consume run [r.offset, r.end()) from the current segment.
            match cur {
                Some((off, len)) if off + k == r.offset
                    && r.len <= len - k =>
                {
                    k += r.len;
                    if k == len {
                        cur = segs.next();
                        k = 0;
                    }
                }
                _ => return false,
            }
        }
        cur.is_none()
    }

    /// Compact slot of the first coordinate of each segment.
    fn prefix(&self) -> Vec<usize> {
        let mut p = Vec::with_capacity(self.segs.len());
        let mut acc = 0usize;
        for &(_, len) in &self.segs {
            p.push(acc);
            acc += len;
        }
        p
    }

    /// Compact slot for flat coordinate `i`, if active (no allocation:
    /// the prefix of segment `k` is summed directly).
    fn slot(&self, i: usize) -> Option<usize> {
        let k = self.segs.partition_point(|&(off, len)| off + len <= i);
        let (off, len) = *self.segs.get(k)?;
        if i >= off && i < off + len {
            let base: usize =
                self.segs[..k].iter().map(|&(_, l)| l).sum();
            Some(base + (i - off))
        } else {
            None
        }
    }

    /// Copy instructions `(new_pos, old_pos, len)` carrying state for
    /// every coordinate active in both maps (a merge walk over the two
    /// support lists).
    fn carry_copies(&self, new: &ActiveMap) -> Vec<(usize, usize, usize)> {
        let (old_pre, new_pre) = (self.prefix(), new.prefix());
        let mut out = Vec::new();
        let (mut i, mut j) = (0usize, 0usize);
        while i < self.segs.len() && j < new.segs.len() {
            let (ao, al) = self.segs[i];
            let (bo, bl) = new.segs[j];
            let lo = ao.max(bo);
            let hi = (ao + al).min(bo + bl);
            if lo < hi {
                out.push((
                    new_pre[j] + (lo - bo),
                    old_pre[i] + (lo - ao),
                    hi - lo,
                ));
            }
            if ao + al <= bo + bl {
                i += 1;
            } else {
                j += 1;
            }
        }
        out
    }
}

/// Fixed lane width of the chunked inner bodies below: the hot loops
/// walk `LANES`-wide blocks of equal-length subslices (bounds checks
/// hoisted once per block, whole block eligible for vector registers)
/// with a scalar remainder loop. Chunking never changes results —
/// every update is elementwise, so block boundaries are invisible to
/// the arithmetic.
const LANES: usize = 8;

/// Chunked masked-AdamW inner body over equal-length slices — the one
/// SoA hot loop every AdamW-family path shares (compact-state
/// [`MaskedAdamW`], golore's dense fallback, SIFT's intersection walk,
/// the HLO-bridge mirrors), so the arithmetic can never drift between
/// them. The per-coordinate update (order of operations included) is
/// exactly the scalar update the reference mirrors perform.
/// `hp = (beta1, beta2, bc1, bc2, eps, weight_decay)`.
#[inline]
pub(crate) fn adamw_lanes(
    m: &mut [f32],
    v: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    scale: f32,
    hp: (f32, f32, f32, f32, f32, f32),
    lr: f32,
) {
    let (b1, b2, bc1, bc2, eps, wd) = hp;
    let n = m.len();
    debug_assert!(v.len() == n && p.len() == n && g.len() == n);
    let mut i = 0usize;
    while i + LANES <= n {
        let mb = &mut m[i..i + LANES];
        let vb = &mut v[i..i + LANES];
        let pb = &mut p[i..i + LANES];
        let gb = &g[i..i + LANES];
        for l in 0..LANES {
            let gm = scale * gb[l];
            let mn = b1 * mb[l] + (1.0 - b1) * gm;
            let vn = b2 * vb[l] + (1.0 - b2) * gm * gm;
            mb[l] = mn;
            vb[l] = vn;
            let mhat = mn / bc1;
            let vhat = vn / bc2;
            pb[l] -= lr * (mhat / (vhat.sqrt() + eps) + wd * pb[l]);
        }
        i += LANES;
    }
    for l in i..n {
        let gm = scale * g[l];
        let mn = b1 * m[l] + (1.0 - b1) * gm;
        let vn = b2 * v[l] + (1.0 - b2) * gm * gm;
        m[l] = mn;
        v[l] = vn;
        let mhat = mn / bc1;
        let vhat = vn / bc2;
        p[l] -= lr * (mhat / (vhat.sqrt() + eps) + wd * p[l]);
    }
}

/// Chunked masked-SGDM inner body (same lane structure as
/// [`adamw_lanes`]); `buf` is the momentum buffer slice.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn sgdm_lanes(
    buf: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    scale: f32,
    mu: f32,
    wd: f32,
    nesterov: bool,
    lr: f32,
) {
    let n = buf.len();
    debug_assert!(p.len() == n && g.len() == n);
    let mut i = 0usize;
    while i + LANES <= n {
        let bb = &mut buf[i..i + LANES];
        let pb = &mut p[i..i + LANES];
        let gb = &g[i..i + LANES];
        for l in 0..LANES {
            let gm = scale * gb[l] + wd * pb[l];
            let b = mu * bb[l] + gm;
            bb[l] = b;
            let upd = if nesterov { gm + mu * b } else { b };
            pb[l] -= lr * upd;
        }
        i += LANES;
    }
    for l in i..n {
        let gm = scale * g[l] + wd * p[l];
        let b = mu * buf[l] + gm;
        buf[l] = b;
        let upd = if nesterov { gm + mu * b } else { b };
        p[l] -= lr * upd;
    }
}

/// Dense-state masked-AdamW update over one contiguous run
/// `[offset, offset+len)` at a uniform `scale` — a slice-then-call
/// wrapper over [`adamw_lanes`], kept for the segment walkers that
/// index full-length state by flat coordinate (golore's fallback,
/// SIFT). `hp = (beta1, beta2, bc1, bc2, eps, weight_decay)`.
#[allow(clippy::too_many_arguments)]
#[inline]
pub(crate) fn dense_adamw_run(
    m: &mut [f32],
    v: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    offset: usize,
    len: usize,
    scale: f32,
    hp: (f32, f32, f32, f32, f32, f32),
    lr: f32,
) {
    let end = offset + len;
    adamw_lanes(
        &mut m[offset..end],
        &mut v[offset..end],
        &mut p[offset..end],
        &g[offset..end],
        scale,
        hp,
        lr,
    );
}

/// Shard-parallel masked-AdamW over dense (coordinate-indexed) state:
/// the segment list is partitioned into balanced shards of disjoint
/// coordinate windows and each shard drives its own `m`/`v`/`p`
/// windows through [`adamw_lanes`]. Falls back to the serial segment
/// walk on a single-threaded engine; either way the per-coordinate
/// arithmetic is identical, so results are bitwise equal for every
/// thread count. Shared by golore's dense fallback, SIFT, and the
/// HLO-bridge mirrors in the training engine.
#[allow(clippy::too_many_arguments)]
pub fn par_adamw_segments(
    exec: &ExecEngine,
    segs: &[Run],
    m: &mut [f32],
    v: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    hp: (f32, f32, f32, f32, f32, f32),
    lr: f32,
) {
    let active: usize = segs.iter().map(|r| r.len).sum();
    if active == 0 {
        return;
    }
    if exec.threads() <= 1 {
        for r in segs {
            dense_adamw_run(m, v, p, g, r.offset, r.len, r.scale, hp, lr);
        }
        return;
    }
    let mut shards = partition_runs(segs, active, exec.threads());
    let bm = m.as_mut_ptr() as usize;
    let bv = v.as_mut_ptr() as usize;
    let bp = p.as_mut_ptr() as usize;
    exec.run_tasks(&mut shards, |_, sh| {
        for r in &sh.runs {
            // SAFETY: shards own disjoint coordinate windows
            // (partition_runs contract), so these are the only live
            // references to those elements for the duration of the
            // region; the caller blocks inside run_tasks, keeping the
            // backing buffers alive.
            let (ms, vs, ps) = unsafe {
                (
                    std::slice::from_raw_parts_mut(
                        (bm as *mut f32).add(r.offset), r.len),
                    std::slice::from_raw_parts_mut(
                        (bv as *mut f32).add(r.offset), r.len),
                    std::slice::from_raw_parts_mut(
                        (bp as *mut f32).add(r.offset), r.len),
                )
            };
            adamw_lanes(ms, vs, ps, &g[r.offset..r.offset + r.len],
                        r.scale, hp, lr);
        }
    });
}

/// Shard-parallel masked-SGDM over dense state — see
/// [`par_adamw_segments`]. `hp = (momentum, weight_decay, nesterov)`.
pub fn par_sgdm_segments(
    exec: &ExecEngine,
    segs: &[Run],
    buf: &mut [f32],
    p: &mut [f32],
    g: &[f32],
    hp: (f32, f32, bool),
    lr: f32,
) {
    let (mu, wd, nesterov) = hp;
    let active: usize = segs.iter().map(|r| r.len).sum();
    if active == 0 {
        return;
    }
    if exec.threads() <= 1 {
        for r in segs {
            let end = r.offset + r.len;
            sgdm_lanes(&mut buf[r.offset..end], &mut p[r.offset..end],
                       &g[r.offset..end], r.scale, mu, wd, nesterov, lr);
        }
        return;
    }
    let mut shards = partition_runs(segs, active, exec.threads());
    let bb = buf.as_mut_ptr() as usize;
    let bp = p.as_mut_ptr() as usize;
    exec.run_tasks(&mut shards, |_, sh| {
        for r in &sh.runs {
            // SAFETY: disjoint coordinate windows — see
            // par_adamw_segments.
            let (bs, ps) = unsafe {
                (
                    std::slice::from_raw_parts_mut(
                        (bb as *mut f32).add(r.offset), r.len),
                    std::slice::from_raw_parts_mut(
                        (bp as *mut f32).add(r.offset), r.len),
                )
            };
            sgdm_lanes(bs, ps, &g[r.offset..r.offset + r.len], r.scale,
                       mu, wd, nesterov, lr);
        }
    });
}

/// Remap one compact state vector onto a new support: carried where the
/// coordinate stays active, zero where (re-)activated.
fn remap_state(
    old_map: &ActiveMap,
    new_map: &ActiveMap,
    state: &mut Vec<f32>,
) {
    let mut fresh = vec![0.0f32; new_map.active];
    for (np, op, len) in old_map.carry_copies(new_map) {
        fresh[np..np + len].copy_from_slice(&state[op..op + len]);
    }
    *state = fresh;
}

/// Parallel [`remap_state`]: the carry copies target disjoint
/// destination windows (merge-walk output in slot order), so they can
/// run on the pool. Copies are moves of identical bytes — thread count
/// cannot change the result.
fn remap_state_par(
    old_map: &ActiveMap,
    new_map: &ActiveMap,
    state: &mut Vec<f32>,
    exec: &ExecEngine,
) {
    let copies = old_map.carry_copies(new_map);
    let mut fresh = vec![0.0f32; new_map.active];
    if exec.threads() <= 1 || copies.len() <= 1 {
        for &(np, op, len) in &copies {
            fresh[np..np + len].copy_from_slice(&state[op..op + len]);
        }
    } else {
        let base = fresh.as_mut_ptr() as usize;
        let src: &[f32] = state;
        exec.run_indexed(copies.len(), |i| {
            let (np, op, len) = copies[i];
            // SAFETY: carry_copies emits disjoint destination windows
            // in slot order, so no two indices overlap in `fresh`,
            // which the caller keeps alive across the region.
            let dst = unsafe {
                std::slice::from_raw_parts_mut(
                    (base as *mut f32).add(np), len)
            };
            dst.copy_from_slice(&src[op..op + len]);
        });
    }
    *state = fresh;
}

/// AdamW with hard-freeze masking (matches the `masked_adamw` kernel
/// per active coordinate) and **active-region-only** moment state.
pub struct MaskedAdamW {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    /// Flat parameter-space length (contract check only).
    n: usize,
    /// Compact first/second moments, one slot per active coordinate.
    m: Vec<f32>,
    v: Vec<f32>,
    map: ActiveMap,
    /// Global step count (bias correction).
    pub t: u64,
}

impl MaskedAdamW {
    pub fn new(n: usize, beta1: f32, beta2: f32, eps: f32,
               weight_decay: f32) -> Self {
        Self {
            beta1,
            beta2,
            eps,
            weight_decay,
            n,
            m: Vec::new(),
            v: Vec::new(),
            map: ActiveMap::default(),
            t: 0,
        }
    }

    pub fn default_hp(n: usize) -> Self {
        Self::new(n, 0.9, 0.999, 1e-8, 0.01)
    }

    /// Bias corrections for the *next* step (what the HLO kernel receives
    /// as `hp[5]`, `hp[6]`).
    pub fn next_bias_corrections(&self) -> (f32, f32) {
        let t = (self.t + 1) as i32;
        (1.0 - self.beta1.powi(t), 1.0 - self.beta2.powi(t))
    }

    /// Moments held for flat coordinate `i`, or `None` when the
    /// coordinate is outside the active region (no state resident).
    pub fn moment_at(&self, i: usize) -> Option<(f32, f32)> {
        self.map.slot(i).map(|s| (self.m[s], self.v[s]))
    }

    /// Number of coordinates state is resident for.
    pub fn resident(&self) -> usize {
        self.map.active
    }

    fn ensure_support(&mut self, runs: &MaskRuns) {
        if self.map.matches(runs) {
            return;
        }
        let new_map = ActiveMap::from_runs(runs);
        remap_state(&self.map, &new_map, &mut self.m);
        remap_state(&self.map, &new_map, &mut self.v);
        self.map = new_map;
    }
}

impl Optimizer for MaskedAdamW {
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    ) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        self.ensure_support(runs);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let hp = (self.beta1, self.beta2, bc1, bc2, self.eps,
                  self.weight_decay);
        let mut slot = 0usize;
        for r in runs.runs() {
            adamw_lanes(
                &mut self.m[slot..slot + r.len],
                &mut self.v[slot..slot + r.len],
                &mut p[r.offset..r.end()],
                &g[r.offset..r.end()],
                r.scale,
                hp,
                lr,
            );
            slot += r.len;
        }
    }

    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        if exec.threads() <= 1 {
            self.step(p, g, runs, lr);
            return;
        }
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        self.ensure_support(runs);
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        let hp = (self.beta1, self.beta2, bc1, bc2, self.eps,
                  self.weight_decay);
        let mut shards = partition(runs, exec.threads());
        let bm = self.m.as_mut_ptr() as usize;
        let bv = self.v.as_mut_ptr() as usize;
        let bp = p.as_mut_ptr() as usize;
        exec.run_tasks(&mut shards, |_, sh| {
            let mut slot = sh.start_slot;
            for r in &sh.runs {
                // SAFETY: shards own disjoint slot windows of the
                // compact moments and disjoint coordinate windows of
                // `p` (partition contract) — no element is reachable
                // from two shards, and the caller blocks inside
                // run_tasks keeping the buffers alive.
                let (ms, vs, ps) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(
                            (bm as *mut f32).add(slot), r.len),
                        std::slice::from_raw_parts_mut(
                            (bv as *mut f32).add(slot), r.len),
                        std::slice::from_raw_parts_mut(
                            (bp as *mut f32).add(r.offset), r.len),
                    )
                };
                adamw_lanes(ms, vs, ps, &g[r.offset..r.end()], r.scale,
                            hp, lr);
                slot += r.len;
            }
        });
    }

    fn on_mask_refresh(&mut self, runs: &MaskRuns) {
        self.ensure_support(runs);
    }

    fn on_mask_refresh_sharded(
        &mut self,
        runs: &MaskRuns,
        exec: &ExecEngine,
    ) {
        if self.map.matches(runs) {
            return;
        }
        let new_map = ActiveMap::from_runs(runs);
        remap_state_par(&self.map, &new_map, &mut self.m, exec);
        remap_state_par(&self.map, &new_map, &mut self.v, exec);
        self.map = new_map;
    }

    fn state_bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }

    fn name(&self) -> &'static str {
        "adamw"
    }
}

/// SGD with momentum, hard-freeze masking (matches `masked_sgdm` per
/// active coordinate) and active-region-only momentum state.
pub struct MaskedSgdm {
    pub momentum: f32,
    pub weight_decay: f32,
    pub nesterov: bool,
    n: usize,
    buf: Vec<f32>,
    map: ActiveMap,
}

impl MaskedSgdm {
    pub fn new(n: usize, momentum: f32, weight_decay: f32,
               nesterov: bool) -> Self {
        Self {
            momentum,
            weight_decay,
            nesterov,
            n,
            buf: Vec::new(),
            map: ActiveMap::default(),
        }
    }

    /// Momentum held for flat coordinate `i` (`None` = not resident).
    pub fn momentum_at(&self, i: usize) -> Option<f32> {
        self.map.slot(i).map(|s| self.buf[s])
    }

    /// Compact momentum buffer (test introspection).
    pub fn buf(&self) -> &[f32] {
        &self.buf
    }

    fn ensure_support(&mut self, runs: &MaskRuns) {
        if self.map.matches(runs) {
            return;
        }
        let new_map = ActiveMap::from_runs(runs);
        remap_state(&self.map, &new_map, &mut self.buf);
        self.map = new_map;
    }
}

impl Optimizer for MaskedSgdm {
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    ) {
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        self.ensure_support(runs);
        let (mu, wd, nv) =
            (self.momentum, self.weight_decay, self.nesterov);
        let mut slot = 0usize;
        for r in runs.runs() {
            sgdm_lanes(
                &mut self.buf[slot..slot + r.len],
                &mut p[r.offset..r.end()],
                &g[r.offset..r.end()],
                r.scale,
                mu,
                wd,
                nv,
                lr,
            );
            slot += r.len;
        }
    }

    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        if exec.threads() <= 1 {
            self.step(p, g, runs, lr);
            return;
        }
        assert_eq!(p.len(), g.len());
        assert_eq!(p.len(), self.n);
        assert_eq!(runs.n(), self.n);
        self.ensure_support(runs);
        let (mu, wd, nv) =
            (self.momentum, self.weight_decay, self.nesterov);
        let mut shards = partition(runs, exec.threads());
        let bb = self.buf.as_mut_ptr() as usize;
        let bp = p.as_mut_ptr() as usize;
        exec.run_tasks(&mut shards, |_, sh| {
            let mut slot = sh.start_slot;
            for r in &sh.runs {
                // SAFETY: disjoint slot/coordinate windows — see
                // MaskedAdamW::step_sharded.
                let (bs, ps) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(
                            (bb as *mut f32).add(slot), r.len),
                        std::slice::from_raw_parts_mut(
                            (bp as *mut f32).add(r.offset), r.len),
                    )
                };
                sgdm_lanes(bs, ps, &g[r.offset..r.end()], r.scale, mu,
                           wd, nv, lr);
                slot += r.len;
            }
        });
    }

    fn on_mask_refresh(&mut self, runs: &MaskRuns) {
        self.ensure_support(runs);
    }

    fn on_mask_refresh_sharded(
        &mut self,
        runs: &MaskRuns,
        exec: &ExecEngine,
    ) {
        if self.map.matches(runs) {
            return;
        }
        let new_map = ActiveMap::from_runs(runs);
        remap_state_par(&self.map, &new_map, &mut self.buf, exec);
        self.map = new_map;
    }

    fn state_bytes(&self) -> usize {
        self.buf.len() * 4
    }

    fn name(&self) -> &'static str {
        "sgdm"
    }
}

/// Plain SGD (no state) — the Algorithm 1 reference instantiation.
pub struct MaskedSgd;

impl Optimizer for MaskedSgd {
    fn step(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
    ) {
        for r in runs.runs() {
            // (lr * scale) * g[i] matches the left-associative scalar
            // form bit for bit; zipped equal-length subslices let the
            // loop autovectorize.
            let c = lr * r.scale;
            let ps = &mut p[r.offset..r.end()];
            let gs = &g[r.offset..r.end()];
            for (pi, gi) in ps.iter_mut().zip(gs) {
                *pi -= c * *gi;
            }
        }
    }

    fn step_sharded(
        &mut self,
        p: &mut [f32],
        g: &[f32],
        runs: &MaskRuns,
        lr: f32,
        exec: &ExecEngine,
    ) {
        if exec.threads() <= 1 {
            self.step(p, g, runs, lr);
            return;
        }
        let mut shards = partition(runs, exec.threads());
        let bp = p.as_mut_ptr() as usize;
        exec.run_tasks(&mut shards, |_, sh| {
            for r in &sh.runs {
                let c = lr * r.scale;
                // SAFETY: disjoint coordinate windows (partition
                // contract); caller blocks inside run_tasks.
                let ps = unsafe {
                    std::slice::from_raw_parts_mut(
                        (bp as *mut f32).add(r.offset), r.len)
                };
                for (pi, gi) in
                    ps.iter_mut().zip(&g[r.offset..r.end()])
                {
                    *pi -= c * *gi;
                }
            }
        });
    }

    fn state_bytes(&self) -> usize {
        0
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Mask;
    use crate::rng::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal32()).collect()
    }

    #[test]
    fn adamw_full_mask_first_step_closed_form() {
        let n = 64;
        let mut rng = Rng::seed_from_u64(1);
        let p0 = randv(n, &mut rng);
        let g = randv(n, &mut rng);
        let mut p = p0.clone();
        let mut opt = MaskedAdamW::new(n, 0.9, 0.999, 1e-8, 0.01);
        opt.step(&mut p, &g, Mask::ones(n).runs(), 1e-3);
        for i in 0..n {
            // step 1: mhat = g, vhat = g² → update = lr*(sign-ish + wd p)
            let want = p0[i]
                - 1e-3
                    * (g[i] / (g[i].abs() + 1e-8) + 0.01 * p0[i]);
            assert!((p[i] - want).abs() < 1e-6, "{} vs {}", p[i], want);
        }
    }

    #[test]
    fn adamw_zero_mask_is_identity() {
        let n = 32;
        let mut rng = Rng::seed_from_u64(2);
        let p0 = randv(n, &mut rng);
        let g = randv(n, &mut rng);
        let mut p = p0.clone();
        let mut opt = MaskedAdamW::default_hp(n);
        opt.step(&mut p, &g, Mask::zeros(n).runs(), 1e-3);
        assert_eq!(p, p0);
        // no state is resident at all for an empty support
        assert_eq!(opt.resident(), 0);
        assert_eq!(opt.state_bytes(), 0);
    }

    #[test]
    fn adamw_frozen_coords_hold_no_state() {
        let n = 8;
        let mut rng = Rng::seed_from_u64(3);
        let g = randv(n, &mut rng);
        let mut p = randv(n, &mut rng);
        let mut opt = MaskedAdamW::default_hp(n);
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, 4, 2.0).unwrap();
        opt.step(&mut p, &g, mask.runs(), 1e-3);
        // active half has state; frozen half has NO resident slots
        for i in 0..4 {
            let (m, _) = opt.moment_at(i).expect("active coord has state");
            assert!(m != 0.0);
        }
        for i in 4..8 {
            assert!(opt.moment_at(i).is_none(), "frozen coord {i}");
        }
        assert_eq!(opt.resident(), 4);
    }

    #[test]
    fn adamw_support_change_carries_and_resets() {
        // Support A = [0,8): step twice. Support B = [4,12): coords
        // 4..8 carry their moments, 8..12 start from zero, 0..4 are
        // freed. Re-activating 0..4 later finds zeros again (explicit
        // reset semantics for re-activated coordinates).
        let n = 16;
        let mut rng = Rng::seed_from_u64(4);
        let g = randv(n, &mut rng);
        let mut p = randv(n, &mut rng);
        let mut opt = MaskedAdamW::default_hp(n);
        let mut a = Mask::zeros(n);
        a.set_segment(0, 8, 1.0).unwrap();
        opt.step(&mut p, &g, a.runs(), 1e-3);
        opt.step(&mut p, &g, a.runs(), 1e-3);
        let carried: Vec<(f32, f32)> =
            (4..8).map(|i| opt.moment_at(i).unwrap()).collect();
        let mut b = Mask::zeros(n);
        b.set_segment(4, 8, 1.0).unwrap();
        opt.on_mask_refresh(b.runs());
        assert_eq!(opt.resident(), 8);
        for (k, i) in (4..8).enumerate() {
            assert_eq!(opt.moment_at(i).unwrap(), carried[k],
                       "coord {i} did not carry");
        }
        for i in 8..12 {
            assert_eq!(opt.moment_at(i).unwrap(), (0.0, 0.0),
                       "newly-active coord {i} must reset");
        }
        for i in 0..4 {
            assert!(opt.moment_at(i).is_none(), "coord {i} must be freed");
        }
        // back to A: previously-freed coords restart from zero
        opt.on_mask_refresh(a.runs());
        for i in 0..4 {
            assert_eq!(opt.moment_at(i).unwrap(), (0.0, 0.0));
        }
    }

    #[test]
    fn sgdm_matches_manual_two_steps() {
        let n = 4;
        let mut p = vec![0.0f32; n];
        let g = vec![1.0f32; n];
        let mut opt = MaskedSgdm::new(n, 0.9, 0.0, false);
        opt.step(&mut p, &g, Mask::ones(n).runs(), 0.1);
        // buf = 1, p = -0.1
        assert!((p[0] + 0.1).abs() < 1e-7);
        opt.step(&mut p, &g, Mask::ones(n).runs(), 0.1);
        // buf = 1.9, p = -0.1 - 0.19 = -0.29
        assert!((p[0] + 0.29).abs() < 1e-6);
    }

    #[test]
    fn sgdm_nesterov_differs() {
        let n = 4;
        let g = vec![1.0f32; n];
        let mut p1 = vec![0.0f32; n];
        let mut p2 = vec![0.0f32; n];
        let mut o1 = MaskedSgdm::new(n, 0.9, 0.0, false);
        let mut o2 = MaskedSgdm::new(n, 0.9, 0.0, true);
        o1.step(&mut p1, &g, Mask::ones(n).runs(), 0.1);
        o2.step(&mut p2, &g, Mask::ones(n).runs(), 0.1);
        assert!((p1[0] + 0.1).abs() < 1e-7);
        assert!((p2[0] + 0.19).abs() < 1e-7); // g + mu*buf = 1.9
    }

    #[test]
    fn sgd_descends_quadratic() {
        // minimize ½‖p‖²: g = p
        let n = 16;
        let mut rng = Rng::seed_from_u64(4);
        let mut p = randv(n, &mut rng);
        let mut opt = MaskedSgd;
        for _ in 0..100 {
            let g = p.clone();
            opt.step(&mut p, &g, Mask::ones(n).runs(), 0.1);
        }
        let norm: f32 = p.iter().map(|x| x * x).sum();
        assert!(norm < 1e-4, "norm {norm}");
    }

    #[test]
    fn state_bytes_scale_with_the_active_region() {
        // Acceptance criterion: at keep ratios {1.0, 0.25, 0.05} over
        // d = 4000, AdamW residency ≈ keep·d·8 bytes (m+v, f32) and
        // SGDM ≈ keep·d·4 — never the dense 2·d·4 / d·4.
        let d = 4000usize;
        for keep in [1.0f64, 0.25, 0.05] {
            let active = (d as f64 * keep) as usize;
            let mut mask = Mask::zeros(d);
            mask.set_segment(0, active, 1.0).unwrap();
            let g = vec![0.1f32; d];
            let mut p = vec![0.0f32; d];
            let mut a = MaskedAdamW::default_hp(d);
            a.step(&mut p, &g, mask.runs(), 1e-3);
            assert_eq!(a.state_bytes(), active * 8, "adamw keep={keep}");
            let mut s = MaskedSgdm::new(d, 0.9, 0.0, false);
            s.step(&mut p, &g, mask.runs(), 1e-3);
            assert_eq!(s.state_bytes(), active * 4, "sgdm keep={keep}");
        }
        assert_eq!(MaskedSgd.state_bytes(), 0);
    }

    #[test]
    fn soa_run_helper_matches_reference_scalar_update() {
        // `dense_adamw_run` (the SoA per-run inner loop golore/SIFT
        // share) must stay bitwise identical to the scalar reference
        // mirror driven with the same mask's dense bridge.
        let n = 128;
        let mut rng = Rng::seed_from_u64(5);
        let g = randv(n, &mut rng);
        let p0 = randv(n, &mut rng);
        let mut mask = Mask::zeros(n);
        mask.set_segment(3, 40, 2.0).unwrap();
        mask.set_segment(70, 21, 4.0).unwrap();
        let mut pa = p0.clone();
        let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
        let mut mirror = reference::DenseAdamW::default_hp(n);
        let mut pb = p0;
        for t in 1..=3i32 {
            let hp = (
                0.9f32,
                0.999f32,
                1.0 - 0.9f32.powi(t),
                1.0 - 0.999f32.powi(t),
                1e-8f32,
                0.01f32,
            );
            for r in mask.runs().runs() {
                dense_adamw_run(
                    &mut m, &mut v, &mut pa, &g, r.offset, r.len,
                    r.scale, hp, 1e-3,
                );
            }
            mirror.step(&mut pb, &g, mask.dense_bridge(), 1e-3);
        }
        assert!(pa.iter().zip(&pb).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn mask_scale_equals_prescaled_gradient() {
        let n = 32;
        let mut rng = Rng::seed_from_u64(5);
        let g = randv(n, &mut rng);
        let p0 = randv(n, &mut rng);

        let mut pa = p0.clone();
        let mut oa = MaskedAdamW::default_hp(n);
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, n, 4.0).unwrap();
        oa.step(&mut pa, &g, mask.runs(), 1e-3);

        let mut pb = p0.clone();
        let mut ob = MaskedAdamW::default_hp(n);
        let g4: Vec<f32> = g.iter().map(|x| 4.0 * x).collect();
        ob.step(&mut pb, &g4, Mask::ones(n).runs(), 1e-3);

        for (a, b) in pa.iter().zip(&pb) {
            assert!((a - b).abs() < 1e-7);
        }
    }

    #[test]
    fn sgdm_support_change_carries_momentum() {
        let n = 8;
        let g = vec![1.0f32; n];
        let mut p = vec![0.0f32; n];
        let mut opt = MaskedSgdm::new(n, 0.9, 0.0, false);
        let mut a = Mask::zeros(n);
        a.set_segment(0, 4, 1.0).unwrap();
        opt.step(&mut p, &g, a.runs(), 0.1); // buf = 1 on 0..4
        let mut b = Mask::zeros(n);
        b.set_segment(2, 4, 1.0).unwrap();
        opt.step(&mut p, &g, b.runs(), 0.1);
        // carried coords: buf = 0.9·1 + 1 = 1.9; fresh coords: buf = 1
        assert!((opt.momentum_at(2).unwrap() - 1.9).abs() < 1e-6);
        assert!((opt.momentum_at(3).unwrap() - 1.9).abs() < 1e-6);
        assert!((opt.momentum_at(4).unwrap() - 1.0).abs() < 1e-6);
        assert!(opt.momentum_at(0).is_none());
    }

    #[test]
    fn active_map_slots_and_copies() {
        let mut a = Mask::zeros(20);
        a.set_segment(2, 4, 1.0).unwrap();
        a.set_segment(10, 5, 2.0).unwrap();
        let map = ActiveMap::from_runs(a.runs());
        assert_eq!(map.active, 9);
        assert_eq!(map.slot(2), Some(0));
        assert_eq!(map.slot(5), Some(3));
        assert_eq!(map.slot(6), None);
        assert_eq!(map.slot(10), Some(4));
        assert_eq!(map.slot(14), Some(8));
        assert_eq!(map.slot(15), None);
        let mut b = Mask::zeros(20);
        b.set_segment(4, 8, 1.0).unwrap();
        let nmap = ActiveMap::from_runs(b.runs());
        // overlap: coords 4..6 (old slots 2..4 → new slots 0..2) and
        // 10..12 (old slots 4..6 → new slots 6..8)
        assert_eq!(map.carry_copies(&nmap), vec![(0, 2, 2), (6, 4, 2)]);
    }

    #[test]
    fn sharded_step_is_bitwise_identical_to_serial() {
        // The core determinism contract, at unit scale: adamw and sgdm
        // compact-state steps driven through a 4-thread engine must be
        // bitwise equal to the serial walk, including state.
        let n = 512;
        let mut rng = Rng::seed_from_u64(9);
        let g = randv(n, &mut rng);
        let p0 = randv(n, &mut rng);
        let mut mask = Mask::zeros(n);
        mask.set_segment(3, 100, 2.0).unwrap();
        mask.set_segment(200, 57, 1.0).unwrap();
        mask.set_segment(400, 90, 4.0).unwrap();
        let exec = crate::exec::ExecEngine::new(4);
        let (mut ps, mut pp) = (p0.clone(), p0.clone());
        let mut os = MaskedAdamW::default_hp(n);
        let mut op = MaskedAdamW::default_hp(n);
        for _ in 0..3 {
            os.step(&mut ps, &g, mask.runs(), 1e-3);
            op.step_sharded(&mut pp, &g, mask.runs(), 1e-3, &exec);
        }
        assert!(ps.iter().zip(&pp).all(|(a, b)| a.to_bits() == b.to_bits()));
        for i in 0..n {
            assert_eq!(os.moment_at(i), op.moment_at(i), "coord {i}");
        }
        let (mut ps, mut pp) = (p0.clone(), p0);
        let mut ss = MaskedSgdm::new(n, 0.9, 0.01, true);
        let mut sp = MaskedSgdm::new(n, 0.9, 0.01, true);
        for _ in 0..3 {
            ss.step(&mut ps, &g, mask.runs(), 1e-2);
            sp.step_sharded(&mut pp, &g, mask.runs(), 1e-2, &exec);
        }
        assert!(ps.iter().zip(&pp).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(ss.buf(), sp.buf());
    }

    #[test]
    fn sharded_refresh_matches_serial_remap() {
        let n = 64;
        let mut rng = Rng::seed_from_u64(10);
        let g = randv(n, &mut rng);
        let mut p1 = vec![0.0f32; n];
        let mut p2 = vec![0.0f32; n];
        let exec = crate::exec::ExecEngine::new(4);
        let mut a = Mask::zeros(n);
        a.set_segment(0, 40, 1.0).unwrap();
        let mut b = Mask::zeros(n);
        b.set_segment(8, 16, 1.0).unwrap();
        b.set_segment(30, 20, 2.0).unwrap();
        let mut serial = MaskedAdamW::default_hp(n);
        let mut shard = MaskedAdamW::default_hp(n);
        serial.step(&mut p1, &g, a.runs(), 1e-3);
        shard.step_sharded(&mut p2, &g, a.runs(), 1e-3, &exec);
        serial.on_mask_refresh(b.runs());
        shard.on_mask_refresh_sharded(b.runs(), &exec);
        for i in 0..n {
            assert_eq!(serial.moment_at(i), shard.moment_at(i));
        }
    }

    #[test]
    fn par_segments_match_the_serial_dense_walk() {
        // The shared dense-state helpers (golore fallback / SIFT / HLO
        // mirrors) must be bitwise identical serial vs parallel.
        let n = 300;
        let mut rng = Rng::seed_from_u64(11);
        let g = randv(n, &mut rng);
        let p0 = randv(n, &mut rng);
        let segs = [
            crate::coordinator::Run { offset: 5, len: 90, scale: 2.0 },
            crate::coordinator::Run { offset: 120, len: 33, scale: 1.0 },
            crate::coordinator::Run { offset: 200, len: 77, scale: 4.0 },
        ];
        let hp = (0.9f32, 0.999, 0.1, 0.001999, 1e-8, 0.01);
        let exec = crate::exec::ExecEngine::new(4);
        let mut pa = p0.clone();
        let (mut ma, mut va) = (vec![0.0f32; n], vec![0.0f32; n]);
        for r in &segs {
            dense_adamw_run(&mut ma, &mut va, &mut pa, &g, r.offset,
                            r.len, r.scale, hp, 1e-3);
        }
        let mut pb = p0.clone();
        let (mut mb, mut vb) = (vec![0.0f32; n], vec![0.0f32; n]);
        par_adamw_segments(&exec, &segs, &mut mb, &mut vb, &mut pb, &g,
                           hp, 1e-3);
        assert!(pa.iter().zip(&pb).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(ma, mb);
        assert_eq!(va, vb);
        let mut pa = p0.clone();
        let mut bufa = vec![0.0f32; n];
        for r in &segs {
            let end = r.offset + r.len;
            sgdm_lanes(&mut bufa[r.offset..end], &mut pa[r.offset..end],
                       &g[r.offset..end], r.scale, 0.9, 0.01, true, 1e-2);
        }
        let mut pb = p0;
        let mut bufb = vec![0.0f32; n];
        par_sgdm_segments(&exec, &segs, &mut bufb, &mut pb, &g,
                          (0.9, 0.01, true), 1e-2);
        assert!(pa.iter().zip(&pb).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(bufa, bufb);
    }

    #[test]
    fn active_map_matches_is_support_only() {
        let mut a = Mask::zeros(10);
        a.set_segment(0, 3, 2.0).unwrap();
        a.set_segment(3, 3, 5.0).unwrap(); // adjacent, different scale
        let map = ActiveMap::from_runs(a.runs());
        let mut b = Mask::zeros(10);
        b.set_segment(0, 6, 1.0).unwrap();
        assert!(map.matches(b.runs()), "scale change must not rebuild");
        let mut c = Mask::zeros(10);
        c.set_segment(0, 5, 1.0).unwrap();
        assert!(!map.matches(c.runs()));
    }
}
