//! # omgd-core — OMGD numerics
//!
//! The paper's algorithms with no orchestration attached: Algorithm 1's
//! `[M]×[N]` without-replacement mask traversal ([`coordinator`]),
//! runs-first native optimizers with active-region-only moment state
//! ([`optim`]), the shard-parallel execution engine ([`exec`]),
//! dense linear algebra and Stiefel sampling ([`linalg`]),
//! deterministic RNG ([`rng`]), the analytic memory model ([`memory`]),
//! data pipelines ([`data`]), the PJRT runtime bridge ([`runtime`]),
//! and the in-repo property-testing harness ([`prop`]).
//!
//! Layering contract (enforced by ci.sh's core-dependency guard):
//! omgd-core depends only on `omgd-util` and must never depend on
//! `omgd-jobs` or touch network code. Job orchestration builds on the
//! numerics, never the reverse.

pub mod coordinator;
pub mod data;
pub mod exec;
pub mod linalg;
pub mod memory;
pub mod optim;
pub mod prop;
pub mod rng;
pub mod runtime;

// Path-compatibility aliases: files moved here from the monolithic
// crate keep referring to `crate::util::json`, `crate::manifest`,
// `crate::obs`, ... — resolve those through the util layer.
pub use omgd_util::{bench, cli, config, manifest, metrics, obs, util};
