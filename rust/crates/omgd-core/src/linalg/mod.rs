//! Small dense linear algebra, built in-repo (no external crates).
//!
//! Used by the §5.1 quadratic testbed (eigenvalues of `A`, product-matrix
//! recursions), the GaLore/GoLore baselines (QR → Stiefel factors,
//! power-iteration top-r subspace), and tests.

use crate::rng::Rng;

/// Row-major dense matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    /// Gaussian random matrix.
    pub fn randn(rows: usize, cols: usize, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * other` — naive triple loop with the inner loop over
    /// contiguous memory (ikj order).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row =
                    &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| dot(self.row(i), v))
            .collect()
    }

    /// [`Mat::matvec`] into a caller-owned buffer — the allocation-free
    /// form for step loops (`out.len()` must be `rows`).
    pub fn matvec_into(&self, v: &[f64], out: &mut [f64]) {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        assert_eq!(self.rows, out.len(), "matvec_into output length");
        for (i, o) in out.iter_mut().enumerate() {
            *o = dot(self.row(i), v);
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        let data = self.data.iter().map(|x| x * s).collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a + b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| a - b)
            .collect();
        Mat { rows: self.rows, cols: self.cols, data }
    }

    /// Frobenius norm.
    pub fn fro(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Outer product accumulate: `self += s * u vᵀ`.
    pub fn add_outer(&mut self, s: f64, u: &[f64], v: &[f64]) {
        assert_eq!(u.len(), self.rows);
        assert_eq!(v.len(), self.cols);
        for i in 0..self.rows {
            let su = s * u[i];
            let row = &mut self.data[i * self.cols..(i + 1) * self.cols];
            for (r, &x) in row.iter_mut().zip(v) {
                *r += su * x;
            }
        }
    }

    /// Thin QR via modified Gram–Schmidt (columns of Q orthonormal).
    /// Returns `(Q: rows×cols, R: cols×cols)`; requires `rows >= cols`.
    pub fn qr(&self) -> (Mat, Mat) {
        let (m, n) = (self.rows, self.cols);
        assert!(m >= n, "thin QR needs rows >= cols");
        // Work in column-major scratch for cache-friendly column ops.
        let mut cols: Vec<Vec<f64>> = (0..n)
            .map(|j| (0..m).map(|i| self[(i, j)]).collect())
            .collect();
        let mut r = Mat::zeros(n, n);
        for j in 0..n {
            for k in 0..j {
                let (head, tail) = cols.split_at_mut(j);
                let rkj = dot(&head[k], &tail[0]);
                r[(k, j)] = rkj;
                for (x, &qk) in tail[0].iter_mut().zip(&head[k]) {
                    *x -= rkj * qk;
                }
            }
            let nrm = dot(&cols[j], &cols[j]).sqrt();
            r[(j, j)] = nrm;
            if nrm > 1e-300 {
                for x in cols[j].iter_mut() {
                    *x /= nrm;
                }
            }
        }
        let mut q = Mat::zeros(m, n);
        for j in 0..n {
            for i in 0..m {
                q[(i, j)] = cols[j][i];
            }
        }
        (q, r)
    }

    /// Eigen-decomposition of a symmetric matrix via cyclic Jacobi.
    /// Returns `(eigenvalues desc, eigenvectors as columns)`.
    pub fn sym_eig(&self) -> (Vec<f64>, Mat) {
        assert_eq!(self.rows, self.cols, "sym_eig needs square");
        let n = self.rows;
        let mut a = self.clone();
        let mut v = Mat::eye(n);
        for _sweep in 0..100 {
            let mut off = 0.0;
            for i in 0..n {
                for j in (i + 1)..n {
                    off += a[(i, j)] * a[(i, j)];
                }
            }
            if off.sqrt() < 1e-12 * (1.0 + a.fro()) {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = a[(p, q)];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let theta = (a[(q, q)] - a[(p, p)]) / (2.0 * apq);
                    let t = theta.signum()
                        / (theta.abs() + (theta * theta + 1.0).sqrt());
                    let c = 1.0 / (t * t + 1.0).sqrt();
                    let s = t * c;
                    for k in 0..n {
                        let akp = a[(k, p)];
                        let akq = a[(k, q)];
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[(p, k)];
                        let aqk = a[(q, k)];
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                        let vkp = v[(k, p)];
                        let vkq = v[(k, q)];
                        v[(k, p)] = c * vkp - s * vkq;
                        v[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
        let mut idx: Vec<usize> = (0..n).collect();
        let diag: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
        idx.sort_by(|&i, &j| diag[j].partial_cmp(&diag[i]).unwrap());
        let vals: Vec<f64> = idx.iter().map(|&i| diag[i]).collect();
        let mut vecs = Mat::zeros(n, n);
        for (newj, &oldj) in idx.iter().enumerate() {
            for i in 0..n {
                vecs[(i, newj)] = v[(i, oldj)];
            }
        }
        (vals, vecs)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
#[inline]
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// `y += s * x` (axpy).
#[inline]
pub fn axpy(s: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

/// Sample a uniformly distributed element of the Stiefel manifold
/// `St(m, k)` = {P ∈ R^{m×k} : PᵀP = I} via QR of a Gaussian matrix
/// (Chikuse 2012 / Remark 5.2 of the paper), with the sign fix that makes
/// the distribution exactly Haar (R's diagonal forced positive).
pub fn stiefel(m: usize, k: usize, rng: &mut Rng) -> Mat {
    assert!(m >= k, "St(m,k) needs m >= k");
    let z = Mat::randn(m, k, rng);
    let (mut q, r) = z.qr();
    for j in 0..k {
        if r[(j, j)] < 0.0 {
            for i in 0..m {
                q[(i, j)] = -q[(i, j)];
            }
        }
    }
    q
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::seed_from_u64(1234)
    }

    #[test]
    fn matmul_identity() {
        let mut r = rng();
        let a = Mat::randn(5, 7, &mut r);
        let i7 = Mat::eye(7);
        assert!(a.matmul(&i7).sub(&a).fro() < 1e-12);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut r = rng();
        let a = Mat::randn(6, 4, &mut r);
        let v: Vec<f64> = (0..4).map(|_| r.normal()).collect();
        let mv = a.matvec(&v);
        let vm = Mat { rows: 4, cols: 1, data: v.clone() };
        let want = a.matmul(&vm);
        for i in 0..6 {
            assert!((mv[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut r = rng();
        let a = Mat::randn(3, 8, &mut r);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn qr_reconstructs_and_orthonormal() {
        let mut r = rng();
        let a = Mat::randn(10, 4, &mut r);
        let (q, rr) = a.qr();
        assert!(q.matmul(&rr).sub(&a).fro() < 1e-10);
        let qtq = q.transpose().matmul(&q);
        assert!(qtq.sub(&Mat::eye(4)).fro() < 1e-10);
    }

    #[test]
    fn sym_eig_reconstructs() {
        let mut r = rng();
        let b = Mat::randn(6, 6, &mut r);
        let a = b.matmul(&b.transpose()); // SPD
        let (vals, vecs) = a.sym_eig();
        // A = V Λ Vᵀ
        let mut lam = Mat::zeros(6, 6);
        for i in 0..6 {
            lam[(i, i)] = vals[i];
        }
        let rec = vecs.matmul(&lam).matmul(&vecs.transpose());
        assert!(rec.sub(&a).fro() < 1e-8, "fro {}", rec.sub(&a).fro());
        // eigenvalues of BBᵀ are nonnegative and sorted desc
        for w in vals.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(vals.iter().all(|&v| v > -1e-10));
    }

    #[test]
    fn sym_eig_known_2x2() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, _) = a.sym_eig();
        assert!((vals[0] - 3.0).abs() < 1e-10);
        assert!((vals[1] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn stiefel_is_orthonormal() {
        let mut r = rng();
        for _ in 0..5 {
            let p = stiefel(10, 5, &mut r);
            let ptp = p.transpose().matmul(&p);
            assert!(ptp.sub(&Mat::eye(5)).fro() < 1e-10);
        }
    }

    #[test]
    fn stiefel_projection_is_idempotent_scaled() {
        // PPᵀ is a rank-k orthogonal projection: (PPᵀ)² = PPᵀ.
        let mut r = rng();
        let p = stiefel(8, 4, &mut r);
        let proj = p.matmul(&p.transpose());
        assert!(proj.matmul(&proj).sub(&proj).fro() < 1e-10);
    }

    #[test]
    fn axpy_and_dot() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert_eq!(dot(&x, &x), 14.0);
        assert!((norm(&x) - 14.0f64.sqrt()).abs() < 1e-15);
    }

    #[test]
    fn add_outer() {
        let mut m = Mat::zeros(2, 3);
        m.add_outer(2.0, &[1.0, 2.0], &[1.0, 0.0, 1.0]);
        assert_eq!(m.data, vec![2.0, 0.0, 2.0, 4.0, 0.0, 4.0]);
    }
}
