//! Analytic GPU-memory model (Fig. 6 / Table 8).
//!
//! Training-time memory decomposes into model weights, gradients,
//! optimizer states, and "others" (activations, caches, allocator
//! overhead). The first three are exact arithmetic over the
//! architecture's tensor inventory and the method's residency policy —
//! no training needed — which is how we reproduce the paper's LLaMA-7B
//! breakdown on a CPU-only testbed. "Others" is modelled as
//! activation-dominated and scaled by the fraction of layers requiring
//! backward state, calibrated to the paper's full-parameter figure.
//!
//! Residency policies (paper §5.4):
//! * Full: grads for all params; Adam m+v for all params.
//! * GaLore/GoLore(rank r): **full gradients** (the paper stresses this
//!   remains their bottleneck), moments in the projected space plus the
//!   projection factors.
//! * LISA/LISA-WOR(γ): grads and moments only for embed + head + the γ
//!   active middle layers.

use crate::manifest::Manifest;

/// Bytes per parameter for weights/grads/states (bf16 training).
pub const BYTES_PER_EL: usize = 2;

/// One tensor in an architecture inventory.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    /// `"embed"`, `"block_<i>"`, `"final"`, `"head"`.
    pub layer: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn is_matrix(&self) -> bool {
        self.shape.len() == 2
    }
}

/// Architecture = named tensor inventory.
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: String,
    pub tensors: Vec<TensorSpec>,
    pub n_middle: usize,
}

impl ArchSpec {
    pub fn total_params(&self) -> usize {
        self.tensors.iter().map(|t| t.numel()).sum()
    }

    /// LLaMA-7B inventory (Touvron et al., 2023): 32 layers, d=4096,
    /// ffn=11008, vocab=32000 → ≈ 6.74 B params.
    pub fn llama_7b() -> Self {
        let (d, ffn, vocab, layers) = (4096usize, 11008usize, 32000usize,
                                       32usize);
        let mut tensors = vec![TensorSpec {
            name: "tok_embeddings".into(),
            shape: vec![vocab, d],
            layer: "embed".into(),
        }];
        for i in 0..layers {
            let blk = format!("block_{i}");
            for (n, shape) in [
                ("attn_q", vec![d, d]),
                ("attn_k", vec![d, d]),
                ("attn_v", vec![d, d]),
                ("attn_o", vec![d, d]),
                ("ffn_gate", vec![d, ffn]),
                ("ffn_up", vec![d, ffn]),
                ("ffn_down", vec![ffn, d]),
                ("attn_norm", vec![d]),
                ("ffn_norm", vec![d]),
            ] {
                tensors.push(TensorSpec {
                    name: format!("{blk}.{n}"),
                    shape,
                    layer: blk.clone(),
                });
            }
        }
        tensors.push(TensorSpec {
            name: "norm".into(),
            shape: vec![d],
            layer: "final".into(),
        });
        tensors.push(TensorSpec {
            name: "output".into(),
            shape: vec![d, vocab],
            layer: "head".into(),
        });
        Self { name: "llama-7b".into(), tensors, n_middle: layers }
    }

    /// GPT-2-124M inventory (12 layers, d=768, vocab 50257, seq 1024).
    pub fn gpt2_124m() -> Self {
        let (d, vocab, seq, layers) = (768usize, 50257usize, 1024usize,
                                       12usize);
        let mut tensors = vec![
            TensorSpec { name: "wte".into(), shape: vec![vocab, d],
                         layer: "embed".into() },
            TensorSpec { name: "wpe".into(), shape: vec![seq, d],
                         layer: "embed".into() },
        ];
        for i in 0..layers {
            let blk = format!("block_{i}");
            for (n, shape) in [
                ("attn_qkv", vec![d, 3 * d]),
                ("attn_proj", vec![d, d]),
                ("mlp_fc", vec![d, 4 * d]),
                ("mlp_proj", vec![4 * d, d]),
                ("ln1", vec![2 * d]),
                ("ln2", vec![2 * d]),
            ] {
                tensors.push(TensorSpec {
                    name: format!("{blk}.{n}"),
                    shape,
                    layer: blk.clone(),
                });
            }
        }
        tensors.push(TensorSpec {
            name: "lnf".into(), shape: vec![2 * d], layer: "final".into(),
        });
        // tied head (no extra tensor)
        Self { name: "gpt2-124m".into(), tensors, n_middle: layers }
    }

    /// Build from an AOT manifest (so the memory report matches exactly
    /// what the rust trainer holds for our own configs).
    pub fn from_manifest(man: &Manifest) -> Self {
        let tensors = man
            .params
            .iter()
            .map(|p| TensorSpec {
                name: p.name.clone(),
                shape: p.shape.clone(),
                layer: p.layer.clone(),
            })
            .collect();
        Self {
            name: man.name.clone(),
            tensors,
            n_middle: man.middle_layers().len(),
        }
    }
}

/// Method residency policy for the breakdown.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum MemPolicy {
    Full,
    /// rank
    Galore(usize),
    /// rank (same residency as GaLore)
    Golore(usize),
    /// γ active middle layers (LISA and LISA-WOR are identical here)
    Lisa(usize),
}

/// Component breakdown in bytes.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MemBreakdown {
    pub model: usize,
    pub gradients: usize,
    pub optimizer: usize,
    pub others: usize,
}

impl MemBreakdown {
    pub fn total(&self) -> usize {
        self.model + self.gradients + self.optimizer + self.others
    }

    pub fn gb(bytes: usize) -> f64 {
        bytes as f64 / (1u64 << 30) as f64
    }
}

/// "Others" (activations/caches) scales with model size; the paper's
/// full-parameter LLaMA-7B run reports 14.66 GiB against a 12.55 GiB
/// model — ratio ≈ 1.168 under their batch/checkpointing setting. We
/// carry that ratio to other architectures (batch-proportional detail is
/// out of scope for the residency comparison).
const OTHERS_TO_MODEL_RATIO: f64 = 1.168;

/// Compute the breakdown for an architecture and policy.
pub fn breakdown(arch: &ArchSpec, policy: MemPolicy) -> MemBreakdown {
    let total = arch.total_params();
    let model = total * BYTES_PER_EL;

    let (gradients, optimizer) = match policy {
        MemPolicy::Full => {
            (total * BYTES_PER_EL, 2 * total * BYTES_PER_EL)
        }
        MemPolicy::Galore(r) | MemPolicy::Golore(r) => {
            // Full grads (their backward-time bottleneck); projected
            // moments (2 ×) + one projection factor per matrix.
            let mut proj_state = 0usize;
            let mut proj_factors = 0usize;
            let mut small = 0usize;
            for t in &arch.tensors {
                if t.is_matrix() && t.shape[0].min(t.shape[1]) > r {
                    let (m, n) = (t.shape[0], t.shape[1]);
                    let (pf, ps) = if m >= n {
                        (m * r, r * n)
                    } else {
                        (n * r, m * r)
                    };
                    proj_factors += pf;
                    proj_state += ps;
                } else {
                    small += t.numel();
                }
            }
            let opt = (2 * proj_state + proj_factors + 2 * small)
                * BYTES_PER_EL;
            (total * BYTES_PER_EL, opt)
        }
        MemPolicy::Lisa(gamma) => {
            // Active set: embed + head + final + γ middle layers.
            let gamma = gamma.min(arch.n_middle);
            let mut per_middle = 0usize;
            let mut always = 0usize;
            for t in &arch.tensors {
                if t.layer.starts_with("block_") {
                    // all middle layers are identical; count layer 0
                    if t.layer == "block_0" {
                        per_middle += t.numel();
                    }
                } else {
                    always += t.numel();
                }
            }
            let active = always + gamma * per_middle;
            (active * BYTES_PER_EL, 2 * active * BYTES_PER_EL)
        }
    };

    // Others: activation/workspace-dominated. All memory-efficient
    // methods free backward buffers eagerly (GaLore projects per layer
    // during backprop; LISA never materializes frozen-layer state), so
    // "others" empirically tracks *optimizer residency*: base 15%
    // (allocator, workspace) plus 85% scaled by the optimizer-state
    // fraction relative to full Adam. Calibrated to the paper's
    // full-parameter 14.66 GB.
    let opt_frac = optimizer as f64 / (2 * total * BYTES_PER_EL) as f64;
    let others_full = OTHERS_TO_MODEL_RATIO * model as f64;
    let others =
        (others_full * (0.15 + 0.85 * opt_frac.min(1.0))) as usize;

    MemBreakdown { model, gradients, optimizer, others }
}

/// Active-region element count implied by an AdamW-family `optimizer`
/// entry of a [`breakdown`] (m + v per element, [`BYTES_PER_EL`] each).
///
/// This is the bridge for cross-checking the analytic model against the
/// *live* residency the native stack now reports: the compact
/// [`crate::optim::MaskedAdamW`] holds f32 m + v for exactly the active
/// region, so its `state_bytes()` must equal `8 ×` this count for the
/// matching mask (bf16 analytic model vs f32 native state — element
/// counts agree, byte widths differ by the dtype).
pub fn adamw_state_elems(optimizer_bytes: usize) -> usize {
    optimizer_bytes / (2 * BYTES_PER_EL)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close_gb(got: f64, want: f64, tol: f64) -> bool {
        (got - want).abs() <= tol
    }

    #[test]
    fn llama7b_param_count() {
        let arch = ArchSpec::llama_7b();
        let p = arch.total_params();
        // 6.74 B ± 1%
        assert!((p as f64 - 6.74e9).abs() < 6.74e7, "params {p}");
    }

    #[test]
    fn table8_full_row() {
        let arch = ArchSpec::llama_7b();
        let b = breakdown(&arch, MemPolicy::Full);
        assert!(close_gb(MemBreakdown::gb(b.model), 12.55, 0.15),
                "model {}", MemBreakdown::gb(b.model));
        assert!(close_gb(MemBreakdown::gb(b.gradients), 12.55, 0.15));
        assert!(close_gb(MemBreakdown::gb(b.optimizer), 25.10, 0.3));
        assert!(close_gb(MemBreakdown::gb(b.others), 14.66, 0.5));
        assert!(close_gb(MemBreakdown::gb(b.total()), 64.86, 1.0),
                "total {}", MemBreakdown::gb(b.total()));
    }

    #[test]
    fn table8_lisa_row() {
        let arch = ArchSpec::llama_7b();
        let b = breakdown(&arch, MemPolicy::Lisa(2));
        assert!(close_gb(MemBreakdown::gb(b.gradients), 1.24, 0.2),
                "grads {}", MemBreakdown::gb(b.gradients));
        assert!(close_gb(MemBreakdown::gb(b.optimizer), 2.48, 0.4),
                "opt {}", MemBreakdown::gb(b.optimizer));
        // headline: ≈ 70% total reduction vs full
        let full = breakdown(&arch, MemPolicy::Full);
        let red = 1.0 - b.total() as f64 / full.total() as f64;
        assert!(red > 0.6 && red < 0.8, "reduction {red}");
    }

    #[test]
    fn table8_galore_row_shape() {
        let arch = ArchSpec::llama_7b();
        let b = breakdown(&arch, MemPolicy::Galore(128));
        // grads stay full — the paper's point
        assert!(close_gb(MemBreakdown::gb(b.gradients), 12.55, 0.15));
        // optimizer collapses to ~1.7 GB
        assert!(MemBreakdown::gb(b.optimizer) < 3.0,
                "opt {}", MemBreakdown::gb(b.optimizer));
        // ≈ 52% total reduction
        let full = breakdown(&arch, MemPolicy::Full);
        let red = 1.0 - b.total() as f64 / full.total() as f64;
        assert!(red > 0.4 && red < 0.62, "reduction {red}");
    }

    #[test]
    fn ordering_lisa_beats_galore_beats_full() {
        let arch = ArchSpec::llama_7b();
        let full = breakdown(&arch, MemPolicy::Full).total();
        let gal = breakdown(&arch, MemPolicy::Galore(128)).total();
        let lisa = breakdown(&arch, MemPolicy::Lisa(2)).total();
        assert!(lisa < gal && gal < full, "{lisa} {gal} {full}");
    }

    #[test]
    fn golore_equals_galore_residency() {
        let arch = ArchSpec::llama_7b();
        assert_eq!(
            breakdown(&arch, MemPolicy::Galore(128)),
            breakdown(&arch, MemPolicy::Golore(128))
        );
    }

    #[test]
    fn gpt2_param_count() {
        let arch = ArchSpec::gpt2_124m();
        let p = arch.total_params();
        // 124M family (weights only, tied head): 124M ± 5%
        assert!((p as f64 - 1.24e8).abs() < 6.2e6, "params {p}");
    }

    #[test]
    fn analytic_residency_matches_live_state_bytes() {
        // The paper's residency model and the compact optimizer must
        // agree on *element counts*: build the LISA mask the analytic
        // Lisa(γ) policy describes, drive the native AdamW through it,
        // and compare its live state_bytes() to the breakdown.
        use crate::coordinator::{Mask, MaskSet};
        use crate::optim::{MaskedAdamW, Optimizer};
        use crate::util::json::Json;
        use std::path::Path;

        let j = Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 4,
 "total_len": 20, "padded_len": 24,
 "params": [
  {"name": "in_w", "shape": [4], "layer": "embed", "offset": 0, "len": 4},
  {"name": "block_0.w", "shape": [4], "layer": "block_0", "offset": 4, "len": 4},
  {"name": "block_1.w", "shape": [4], "layer": "block_1", "offset": 8, "len": 4},
  {"name": "block_2.w", "shape": [4], "layer": "block_2", "offset": 12, "len": 4},
  {"name": "out_w", "shape": [4], "layer": "head", "offset": 16, "len": 4}
 ],
 "data": {"batch": 2},
 "artifacts": {"train": "t", "eval": "e", "init": "i",
               "update": {"adamw": "a", "sgdm": "s"}}
}"#,
        )
        .unwrap();
        let man =
            crate::manifest::Manifest::from_json(&j, Path::new("/tmp"))
                .unwrap();
        let arch = ArchSpec::from_manifest(&man);
        for gamma in [1usize, 2, 3] {
            let b = breakdown(&arch, MemPolicy::Lisa(gamma));
            let elems = adamw_state_elems(b.optimizer);
            // the mask the policy describes: embed+head + γ middles
            let active: Vec<String> = (0..gamma)
                .map(|i| format!("block_{i}"))
                .collect();
            let mask = MaskSet::layerwise(&man, &active, 1.0).unwrap();
            assert_eq!(elems, mask.active_count(), "γ={gamma}");
            let mut opt = MaskedAdamW::default_hp(man.padded_len);
            let g = vec![0.1f32; man.padded_len];
            let mut p = vec![0.0f32; man.padded_len];
            opt.step(&mut p, &g, mask.runs(), 1e-3);
            assert_eq!(opt.state_bytes(), elems * 8, "γ={gamma}");
        }
        // Full policy: every real parameter resident.
        let full = breakdown(&arch, MemPolicy::Full);
        assert_eq!(adamw_state_elems(full.optimizer), man.total_len);
        let mut opt = MaskedAdamW::default_hp(man.padded_len);
        let mut full_mask = Mask::zeros(man.padded_len);
        full_mask.set_segment(0, man.total_len, 1.0).unwrap();
        let g = vec![0.1f32; man.padded_len];
        let mut p = vec![0.0f32; man.padded_len];
        opt.step(&mut p, &g, full_mask.runs(), 1e-3);
        assert_eq!(opt.state_bytes(), man.total_len * 8);
    }

    #[test]
    fn lisa_gamma_monotone() {
        let arch = ArchSpec::llama_7b();
        let mut prev = 0usize;
        for gamma in [1usize, 2, 4, 8, 16, 32] {
            let t = breakdown(&arch, MemPolicy::Lisa(gamma)).total();
            assert!(t > prev, "γ={gamma}");
            prev = t;
        }
        // γ = 32 (all layers) grads+opt equal full
        let full = breakdown(&arch, MemPolicy::Full);
        let all = breakdown(&arch, MemPolicy::Lisa(32));
        assert_eq!(all.gradients, full.gradients);
        assert_eq!(all.optimizer, full.optimizer);
    }
}
