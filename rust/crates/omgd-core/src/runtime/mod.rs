//! PJRT runtime: load AOT-compiled HLO text and execute it from rust.
//!
//! Wraps the `xla` crate (xla_extension 0.5.1, CPU PJRT plugin):
//! `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `client.compile` → `execute`. All artifacts were lowered with
//! `return_tuple=True`, so every executable returns one tuple literal
//! which [`Executable::run`] decomposes into its elements.
//!
//! [`ModelBundle`] packages the manifest plus the compiled train / eval /
//! update executables for one AOT config — the unit the trainer works
//! with.

pub mod bundle;

pub use bundle::{ModelBundle, RunsScratch};

use anyhow::{anyhow, Context, Result};
use std::path::Path;
use std::time::Instant;

/// Handle to the PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path) -> Result<Executable> {
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e}"))?;
        Ok(Executable {
            exe,
            name: path
                .file_name()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            compile_secs: t0.elapsed().as_secs_f64(),
        })
    }
}

/// A borrowed view of one executable argument (host data + dims).
///
/// Arguments are uploaded with `buffer_from_host_buffer` and executed via
/// `execute_b` so the input device buffers are owned by rust and freed on
/// drop. (The `xla` crate's literal-based `execute` leaks every input
/// buffer — `buffer.release()` with no matching free in xla_rs.cc — which
/// at ~58 MB/step OOM-killed long training runs; see EXPERIMENTS.md
/// §Perf.)
#[derive(Clone, Copy, Debug)]
pub enum Arg<'a> {
    F32(&'a [f32], &'a [usize]),
    I32(&'a [i32], &'a [usize]),
}

/// A compiled entry point.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with host-slice inputs; returns the decomposed output
    /// tuple as literals.
    pub fn run_args(&self, args: &[Arg<'_>]) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let mut bufs = Vec::with_capacity(args.len());
        for (i, a) in args.iter().enumerate() {
            let buf = match a {
                Arg::F32(data, dims) => {
                    client.buffer_from_host_buffer(data, dims, None)
                }
                Arg::I32(data, dims) => {
                    client.buffer_from_host_buffer(data, dims, None)
                }
            }
            .map_err(|e| anyhow!("{}: upload arg {i}: {e}", self.name))?;
            bufs.push(buf);
        }
        let out = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        drop(bufs); // input device buffers freed here (rust-owned)
        let buf = out
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let mut lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.name))?;
        match lit.shape().map_err(|e| anyhow!("shape: {e}"))? {
            xla::Shape::Tuple(_) => lit
                .decompose_tuple()
                .map_err(|e| anyhow!("{}: decompose: {e}", self.name)),
            _ => Ok(vec![lit]),
        }
    }

    /// Execute with literal inputs (convenience for tests / small calls).
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let client = self.exe.client();
        let device = client.devices().into_iter().next();
        let mut bufs = Vec::with_capacity(inputs.len());
        for (i, lit) in inputs.iter().enumerate() {
            bufs.push(
                client
                    .buffer_from_host_literal(device.as_ref(), lit)
                    .map_err(|e| {
                        anyhow!("{}: upload literal {i}: {e}", self.name)
                    })?,
            );
        }
        let out = self
            .exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("execute {}: {e}", self.name))?;
        drop(bufs);
        let buf = out
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("{}: no output buffer", self.name))?;
        let mut lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("{}: to_literal: {e}", self.name))?;
        match lit.shape().map_err(|e| anyhow!("shape: {e}"))? {
            xla::Shape::Tuple(_) => lit
                .decompose_tuple()
                .map_err(|e| anyhow!("{}: decompose: {e}", self.name)),
            _ => Ok(vec![lit]),
        }
    }
}

/// Build an `f32` literal with the given dimensions.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(),
                    "lit_f32: {} elements for dims {dims:?}", data.len());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    v.reshape(dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an `i32` literal with the given dimensions.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let numel: i64 = dims.iter().product();
    anyhow::ensure!(numel as usize == data.len(),
                    "lit_i32: {} elements for dims {dims:?}", data.len());
    let v = xla::Literal::vec1(data);
    if dims.len() == 1 {
        return Ok(v);
    }
    v.reshape(dims).map_err(|e| anyhow!("reshape: {e}"))
}

/// Build an `f32` scalar literal.
pub fn lit_scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a `Vec<f32>` from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e}"))
}

/// Extract the single `f32` value of a scalar literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar f32: {e}"))
}

/// Locate the artifacts directory: explicit argument, `OMGD_ARTIFACTS`
/// env var, or `./artifacts` (in that order).
pub fn artifacts_dir(explicit: Option<&str>) -> std::path::PathBuf {
    if let Some(p) = explicit {
        return p.into();
    }
    if let Ok(p) = std::env::var("OMGD_ARTIFACTS") {
        return p.into();
    }
    // Try CWD, then the crate root (useful under `cargo test`).
    let cwd = Path::new("artifacts");
    if cwd.exists() {
        return cwd.into();
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

impl Runtime {
    /// Helper used by integration tests: load the §5.1 linreg gradient
    /// artifact and evaluate it.
    pub fn linreg_grad(
        &self,
        exe: &Executable,
        theta: &[f32],
        x: &[f32],
        y: f32,
    ) -> Result<Vec<f32>> {
        let d = theta.len() as i64;
        let out = exe.run(&[
            lit_f32(theta, &[d])?,
            lit_f32(x, &[d])?,
            lit_scalar_f32(y),
        ])?;
        to_vec_f32(out.first().context("no grad output")?)
    }
}
