//! Model bundle: manifest + compiled executables for one AOT config.
//!
//! All hot-path calls go through [`Executable::run_args`] (host slices →
//! rust-owned device buffers → `execute_b`), which avoids both the
//! literal-intermediate copy and the input-buffer leak of the crate's
//! literal `execute` (see runtime/mod.rs).
//!
//! The fused masked-update entry points are runs-first:
//! [`ModelBundle::adamw_update_runs`] / [`ModelBundle::sgdm_update_runs`]
//! take the mask's `(offset, len, scale)` segment descriptors as plain
//! triples (this layer sits below `coordinator` and must not import its
//! types). The AOT Pallas kernels' ABI is fixed dense full-length
//! operands (dense tiles through VMEM — there is no descriptor-indexed
//! artifact), so the descriptors are expanded into a cached dense
//! multiplier *once per distinct mask* (exact descriptor comparison
//! guards reuse) and every subsequent step with the same mask is an
//! O(runs) compare plus the kernel dispatch. The dense-slice entry
//! points survive as the fallback behind the same signature discipline —
//! callers holding only a dense vector (the reference mirrors' domain)
//! can still dispatch.

use super::{to_scalar_f32, to_vec_f32, Arg, Executable, Runtime};
use crate::manifest::Manifest;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Which optimizer-update artifact to load.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum UpdateKind {
    AdamW,
    Sgdm,
}

/// One mask segment descriptor: `(offset, len, scale)` over the flat
/// padded parameter space (the wire form of `coordinator::Run`).
pub type RunDesc = (usize, usize, f32);

/// Cached dense-multiplier expansion for the runs-descriptor update
/// entry points: the descriptor list it was built from (the exact reuse
/// key) and the expanded vector. Steady state is an O(runs) key
/// compare; the O(d) expansion happens only when the mask actually
/// changed (period boundaries).
///
/// Owned **per engine** (each `MethodEngine` holds one and threads it
/// into every update call), not globally behind a lock: the old
/// `Mutex<RunsScratch>` inside `ModelBundle` serialized every
/// HLO-bridge step across engines sharing a bundle. ci.sh greps this
/// file to keep the mutex from reappearing.
#[derive(Default)]
pub struct RunsScratch {
    key: Vec<RunDesc>,
    mask: Vec<f32>,
}

impl RunsScratch {
    pub fn new() -> Self {
        Self::default()
    }

    fn dense_multiplier(&mut self, n: usize, runs: &[RunDesc]) -> &[f32] {
        if self.mask.len() != n || self.key != runs {
            self.key.clear();
            self.key.extend_from_slice(runs);
            self.mask.clear();
            self.mask.resize(n, 0.0);
            for &(off, len, scale) in runs {
                self.mask[off..off + len].fill(scale);
            }
        }
        &self.mask
    }
}

/// A loaded model: train / eval / fused-update executables + layout.
pub struct ModelBundle {
    pub man: Manifest,
    pub train: Executable,
    pub eval: Executable,
    pub update: Executable,
    pub update_kind: UpdateKind,
}

impl ModelBundle {
    pub fn load(
        rt: &Runtime,
        artifacts_dir: &Path,
        config: &str,
        update_kind: UpdateKind,
    ) -> Result<Self> {
        let man = Manifest::load(artifacts_dir, config)?;
        let train = rt.load(&man.hlo_path(&man.train_hlo))?;
        let eval = rt.load(&man.hlo_path(&man.eval_hlo))?;
        let upd_file = match update_kind {
            UpdateKind::AdamW => &man.update_adamw_hlo,
            UpdateKind::Sgdm => &man.update_sgdm_hlo,
        };
        let update = rt.load(&man.hlo_path(upd_file))?;
        Ok(Self { man, train, eval, update, update_kind })
    }

    pub fn padded_len(&self) -> usize {
        self.man.padded_len
    }

    /// Initial flat parameters from the AOT init dump.
    pub fn init_params(&self) -> Result<Vec<f32>> {
        self.man.load_init()
    }

    /// One LM forward/backward step: `(loss, grad)`. `x`/`y` are packed
    /// row-major `i32[B, S]`.
    pub fn train_step_lm(&self, flat: &[f32], x: &[i32], y: &[i32])
                         -> Result<(f32, Vec<f32>)> {
        ensure!(self.man.kind == "gpt", "train_step_lm on {}", self.man.kind);
        let (b, s) = (self.man.data.batch, self.man.data.seq);
        ensure!(x.len() == b * s && y.len() == b * s, "bad batch shape");
        let out = self.train.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::I32(x, &[b, s]),
            Arg::I32(y, &[b, s]),
        ])?;
        ensure!(out.len() == 2, "train returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// One classifier step: `(loss, grad)`. `x` is packed `f32[B, d_in]`.
    pub fn train_step_clf(&self, flat: &[f32], x: &[f32], y: &[i32])
                          -> Result<(f32, Vec<f32>)> {
        ensure!(self.man.kind == "mlp", "train_step_clf on {}",
                self.man.kind);
        let (b, d) = (self.man.data.batch, self.man.data.d_in);
        ensure!(x.len() == b * d && y.len() == b, "bad batch shape");
        let out = self.train.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::F32(x, &[b, d]),
            Arg::I32(y, &[b]),
        ])?;
        ensure!(out.len() == 2, "train returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_vec_f32(&out[1])?))
    }

    /// Held-out LM eval loss.
    pub fn eval_step_lm(&self, flat: &[f32], x: &[i32], y: &[i32])
                        -> Result<f32> {
        let (b, s) = (self.man.data.batch, self.man.data.seq);
        let out = self.eval.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::I32(x, &[b, s]),
            Arg::I32(y, &[b, s]),
        ])?;
        to_scalar_f32(out.first().context("no eval output")?)
    }

    /// Classifier eval: `(loss, n_correct)`.
    pub fn eval_step_clf(&self, flat: &[f32], x: &[f32], y: &[i32])
                         -> Result<(f32, f32)> {
        let (b, d) = (self.man.data.batch, self.man.data.d_in);
        let out = self.eval.run_args(&[
            Arg::F32(flat, &[flat.len()]),
            Arg::F32(x, &[b, d]),
            Arg::I32(y, &[b]),
        ])?;
        ensure!(out.len() == 2, "eval returned {} outputs", out.len());
        Ok((to_scalar_f32(&out[0])?, to_scalar_f32(&out[1])?))
    }

    /// Every descriptor must fit inside the flat space of length `n`.
    fn check_descriptors(n: usize, runs: &[RunDesc]) -> Result<()> {
        for &(off, len, scale) in runs {
            let end = off.checked_add(len);
            ensure!(
                end.is_some_and(|e| e <= n) && scale != 0.0,
                "bad mask descriptor ({off}, {len}, {scale}) over {n}"
            );
        }
        Ok(())
    }

    /// Fused masked-AdamW update from `(offset, len, scale)` segment
    /// descriptors: they are expanded into the caller's [`RunsScratch`]
    /// dense multiplier (only when the mask changed since the last
    /// call) and dispatched to the same AOT kernel as
    /// [`ModelBundle::adamw_update`]. The scratch is per caller — no
    /// lock on the hot path.
    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update_runs(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        runs: &[RunDesc],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        hp: &[f32; 8],
        scratch: &mut RunsScratch,
    ) -> Result<()> {
        Self::check_descriptors(p.len(), runs)?;
        let mask = scratch.dense_multiplier(p.len(), runs);
        self.adamw_update(p, g, mask, m, v, hp)
    }

    /// Fused masked-SGDM update from segment descriptors (see
    /// [`ModelBundle::adamw_update_runs`]).
    pub fn sgdm_update_runs(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        runs: &[RunDesc],
        buf: &mut Vec<f32>,
        hp: &[f32; 4],
        scratch: &mut RunsScratch,
    ) -> Result<()> {
        Self::check_descriptors(p.len(), runs)?;
        let mask = scratch.dense_multiplier(p.len(), runs);
        self.sgdm_update(p, g, mask, buf, hp)
    }

    /// Fused masked-AdamW update (the L1 Pallas kernel, AOT-compiled):
    /// `(p, m, v) ← kernel(hp, p, g, mask, m, v)`. Dense-multiplier
    /// fallback — prefer [`ModelBundle::adamw_update_runs`]; callers
    /// holding a [`crate::coordinator::Mask`] should feed this from
    /// `dense_bridge()`.
    #[allow(clippy::too_many_arguments)]
    pub fn adamw_update(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        mask: &[f32],
        m: &mut Vec<f32>,
        v: &mut Vec<f32>,
        hp: &[f32; 8],
    ) -> Result<()> {
        ensure!(self.update_kind == UpdateKind::AdamW, "not an adamw bundle");
        let n = p.len();
        let out = self.update.run_args(&[
            Arg::F32(hp, &[8]),
            Arg::F32(p, &[n]),
            Arg::F32(g, &[n]),
            Arg::F32(mask, &[n]),
            Arg::F32(m, &[n]),
            Arg::F32(v, &[n]),
        ])?;
        ensure!(out.len() == 3, "update returned {} outputs", out.len());
        *p = to_vec_f32(&out[0])?;
        *m = to_vec_f32(&out[1])?;
        *v = to_vec_f32(&out[2])?;
        Ok(())
    }

    /// Fused masked-SGDM update: `(p, buf) ← kernel(hp, p, g, mask, buf)`.
    /// Dense-multiplier fallback — prefer
    /// [`ModelBundle::sgdm_update_runs`].
    pub fn sgdm_update(
        &self,
        p: &mut Vec<f32>,
        g: &[f32],
        mask: &[f32],
        buf: &mut Vec<f32>,
        hp: &[f32; 4],
    ) -> Result<()> {
        ensure!(self.update_kind == UpdateKind::Sgdm, "not an sgdm bundle");
        let n = p.len();
        let out = self.update.run_args(&[
            Arg::F32(hp, &[4]),
            Arg::F32(p, &[n]),
            Arg::F32(g, &[n]),
            Arg::F32(mask, &[n]),
            Arg::F32(buf, &[n]),
        ])?;
        ensure!(out.len() == 2, "update returned {} outputs", out.len());
        *p = to_vec_f32(&out[0])?;
        *buf = to_vec_f32(&out[1])?;
        Ok(())
    }
}
