//! Bounded MPMC work queue with priorities and cancellation.
//!
//! `Mutex<BinaryHeap> + Condvar` — no external crates. Producers block
//! when the queue is at capacity; consumers block when it is empty.
//! Higher priority pops first; within one priority, FIFO by submission
//! order (so a grid with uniform priority is a plain work queue whose
//! drain order is deterministic up to worker interleaving).
//!
//! Lifecycle: [`JobQueue::close`] seals the producer side and lets
//! workers drain what remains; [`JobQueue::cancel`] additionally drops
//! all pending jobs so workers exit at the next pop.

use super::spec::JobSpec;
use crate::obs;
use anyhow::{bail, Result};
use omgd_util::lock_recover;
use std::collections::BinaryHeap;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// A queued job: the spec plus its queue identity.
#[derive(Clone, Debug)]
pub struct Job {
    /// Submission sequence number (unique per queue, starts at 0).
    pub seq: u64,
    pub priority: i32,
    pub spec: JobSpec,
    /// When this job entered the queue (reset on requeue) — consumers
    /// observe `enqueued.elapsed()` as the queue-wait span.
    pub enqueued: Instant,
}

struct Entry {
    priority: i32,
    seq: u64,
    spec: JobSpec,
    /// Times a window scan chose a deeper match over this entry while
    /// it sat at the head (see [`JobQueue::pop_scan_timeout`]).
    skips: u32,
    enqueued: Instant,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.priority == other.priority && self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Max-heap: larger priority wins; ties broken by *smaller* seq.
        self.priority
            .cmp(&other.priority)
            .then(other.seq.cmp(&self.seq))
    }
}

struct State {
    heap: BinaryHeap<Entry>,
    next_seq: u64,
    capacity: usize,
    closed: bool,
    cancelled: bool,
}

/// Outcome of a timed [`JobQueue::pop_timeout`].
#[derive(Debug)]
pub enum PopTimeout {
    /// A job was available (or arrived) within the timeout.
    Job(Job),
    /// The timeout elapsed with the queue open but empty — the caller
    /// (a long-polling lease, typically) should answer "idle".
    Empty,
    /// The queue is closed and drained, or cancelled: no job will ever
    /// arrive again.
    Closed,
}

impl PopTimeout {
    /// The extracted job, if any — handy when draining a queue whose
    /// open/closed distinction does not matter to the caller.
    pub fn job(self) -> Option<Job> {
        match self {
            PopTimeout::Job(j) => Some(j),
            _ => None,
        }
    }
}

/// Outcome of a windowed [`JobQueue::pop_scan_timeout`].
#[derive(Debug)]
pub enum PopScan {
    /// A window entry matched the predicate and was extracted.
    Match(Job),
    /// Nothing in the window matched (or the head has been passed over
    /// [`MAX_SCAN_SKIPS`] times): the queue head — oldest seq of the
    /// highest pending priority — was extracted instead.
    Head(Job),
    /// The timeout elapsed with the queue open but empty.
    Empty,
    /// The queue is closed and drained, or cancelled.
    Closed,
}

/// How many times the queue head may be passed over by scan matches
/// before a scan is forced to take it regardless — the anti-starvation
/// bound of [`JobQueue::pop_scan_timeout`].
pub const MAX_SCAN_SKIPS: u32 = 8;

/// Outcome of a non-blocking [`JobQueue::try_push`].
#[derive(Debug)]
pub enum TryPush {
    /// Enqueued with this sequence number.
    Pushed(u64),
    /// Queue at capacity; the spec is handed back for a retry.
    Full(JobSpec),
    /// Queue closed or cancelled; the spec is handed back.
    Closed(JobSpec),
}

/// Bounded multi-producer multi-consumer priority queue of [`JobSpec`]s.
pub struct JobQueue {
    state: Mutex<State>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl JobQueue {
    /// Create a queue holding at most `capacity` pending jobs (min 1).
    pub fn bounded(capacity: usize) -> Self {
        Self {
            state: Mutex::new(State {
                heap: BinaryHeap::new(),
                next_seq: 0,
                capacity: capacity.max(1),
                closed: false,
                cancelled: false,
            }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Submit a job; blocks while the queue is full. Returns the job's
    /// sequence number, or an error if the queue is closed/cancelled.
    pub fn push(&self, spec: JobSpec, priority: i32) -> Result<u64> {
        let mut st = lock_recover(&self.state);
        while st.heap.len() >= st.capacity && !st.closed && !st.cancelled {
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        if st.closed || st.cancelled {
            bail!("job queue is closed");
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.heap.push(Entry {
            priority,
            seq,
            spec,
            skips: 0,
            enqueued: Instant::now(),
        });
        obs::JOBS_SUBMITTED.inc();
        obs::QUEUE_DEPTH.set(st.heap.len() as f64);
        drop(st);
        self.not_empty.notify_one();
        Ok(seq)
    }

    /// Non-blocking push: never waits, hands the spec back when it
    /// cannot be enqueued. Lets a caller keep its own critical section
    /// short — retry with [`Self::wait_not_full`] between attempts.
    pub fn try_push(&self, spec: JobSpec, priority: i32) -> TryPush {
        let mut st = lock_recover(&self.state);
        if st.closed || st.cancelled {
            return TryPush::Closed(spec);
        }
        if st.heap.len() >= st.capacity {
            return TryPush::Full(spec);
        }
        let seq = st.next_seq;
        st.next_seq += 1;
        st.heap.push(Entry {
            priority,
            seq,
            spec,
            skips: 0,
            enqueued: Instant::now(),
        });
        obs::JOBS_SUBMITTED.inc();
        obs::QUEUE_DEPTH.set(st.heap.len() as f64);
        drop(st);
        self.not_empty.notify_one();
        TryPush::Pushed(seq)
    }

    /// Block until the queue has room for a push — or is closed or
    /// cancelled, after which push attempts fail fast.
    pub fn wait_not_full(&self) {
        let mut st = lock_recover(&self.state);
        while st.heap.len() >= st.capacity && !st.closed && !st.cancelled {
            st = self.not_full.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Take the highest-priority pending job; blocks while the queue is
    /// empty and open. Returns `None` once the queue is closed and
    /// drained, or immediately after cancellation.
    pub fn pop(&self) -> Option<Job> {
        let mut st = lock_recover(&self.state);
        loop {
            if st.cancelled {
                return None;
            }
            if let Some(e) = st.heap.pop() {
                obs::QUEUE_DEPTH.set(st.heap.len() as f64);
                drop(st);
                self.not_full.notify_one();
                return Some(Job {
                    seq: e.seq,
                    priority: e.priority,
                    spec: e.spec,
                    enqueued: e.enqueued,
                });
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Timed [`Self::pop`]: wait at most `timeout` for a job. Unlike
    /// `pop`, the closed-and-drained and still-open-but-empty cases are
    /// distinguished, so a long-polling remote lease can answer "idle,
    /// retry" vs "no more work ever".
    pub fn pop_timeout(&self, timeout: Duration) -> PopTimeout {
        let deadline = Instant::now() + timeout;
        let mut st = lock_recover(&self.state);
        loop {
            if st.cancelled {
                return PopTimeout::Closed;
            }
            if let Some(e) = st.heap.pop() {
                obs::QUEUE_DEPTH.set(st.heap.len() as f64);
                drop(st);
                self.not_full.notify_one();
                return PopTimeout::Job(Job {
                    seq: e.seq,
                    priority: e.priority,
                    spec: e.spec,
                    enqueued: e.enqueued,
                });
            }
            if st.closed {
                return PopTimeout::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopTimeout::Empty;
            }
            let (guard, _timed_out) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// Windowed [`Self::pop_timeout`]: scan up to `window` pending
    /// entries — in exact pop order — for one whose spec satisfies
    /// `pred`, extract the first match, and hand every passed-over
    /// entry back unchanged (same seq, same priority, so ordering
    /// guarantees and result routing survive the scan). With no match,
    /// the queue head is extracted instead — the oldest-first fallback
    /// that keeps any job from starving.
    ///
    /// Two deliberate bounds on the reordering this allows:
    ///
    /// * The scan never crosses a priority boundary: only entries of
    ///   the head's priority are candidates, so "higher priority pops
    ///   first" still holds exactly.
    /// * A head passed over [`MAX_SCAN_SKIPS`] times is forced out on
    ///   the next scan even when a deeper match exists, so a steady
    ///   stream of affinity matches cannot park one job forever.
    ///
    /// `pred` runs under the queue lock — keep it cheap (the affinity
    /// scheduler memoizes its per-(dir, model) fingerprint lookups for
    /// exactly this reason). `window <= 1` never reorders anything —
    /// the head is always extracted, reported as `Match` when it
    /// happens to satisfy `pred`.
    pub fn pop_scan_timeout(
        &self,
        timeout: Duration,
        window: usize,
        pred: &mut dyn FnMut(&JobSpec) -> bool,
    ) -> PopScan {
        let deadline = Instant::now() + timeout;
        let mut st = lock_recover(&self.state);
        loop {
            if st.cancelled {
                return PopScan::Closed;
            }
            if !st.heap.is_empty() {
                let picked =
                    Self::scan_extract(&mut st, window, &mut *pred);
                obs::QUEUE_DEPTH.set(st.heap.len() as f64);
                drop(st);
                self.not_full.notify_one();
                return picked;
            }
            if st.closed {
                return PopScan::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return PopScan::Empty;
            }
            let (guard, _timed_out) = self
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(|e| e.into_inner());
            st = guard;
        }
    }

    /// The scan-and-extract core of [`Self::pop_scan_timeout`], run
    /// with the state lock held and a non-empty heap.
    fn scan_extract(
        st: &mut State,
        window: usize,
        pred: &mut dyn FnMut(&JobSpec) -> bool,
    ) -> PopScan {
        let job = |e: Entry| Job {
            seq: e.seq,
            priority: e.priority,
            spec: e.spec,
            enqueued: e.enqueued,
        };
        let head = st.heap.pop().expect("scan_extract needs a non-empty heap");
        if pred(&head.spec) {
            return PopScan::Match(job(head));
        }
        if window <= 1 || head.skips >= MAX_SCAN_SKIPS {
            return PopScan::Head(job(head));
        }
        // Pull up to window-1 more entries of the head's priority,
        // looking for a match; everything not chosen goes back intact.
        let mut passed: Vec<Entry> = Vec::new();
        let mut matched: Option<Entry> = None;
        while passed.len() + 1 < window {
            match st.heap.pop() {
                Some(e) if e.priority == head.priority => {
                    if pred(&e.spec) {
                        matched = Some(e);
                        break;
                    }
                    passed.push(e);
                }
                Some(e) => {
                    // Crossed into a lower priority band: scan over.
                    st.heap.push(e);
                    break;
                }
                None => break,
            }
        }
        match matched {
            Some(e) => {
                let mut head = head;
                head.skips += 1;
                st.heap.push(head);
                st.heap.extend(passed);
                PopScan::Match(job(e))
            }
            None => {
                st.heap.extend(passed);
                PopScan::Head(job(head))
            }
        }
    }

    /// Re-admit a job that was popped but not completed (an expired
    /// remote lease). The original `seq`/`priority` are preserved so
    /// result routing — keyed by the seq the submitter was acked with
    /// — still works after re-dispatch.
    ///
    /// Re-admission deliberately ignores the capacity bound (the job
    /// was already accounted for when first pushed) and is allowed on a
    /// *closed* queue (drain re-dispatch: consumers are still
    /// draining). Only a cancelled queue refuses, since its consumers
    /// are already gone.
    pub fn requeue(&self, job: Job) -> Result<()> {
        let mut st = lock_recover(&self.state);
        if st.cancelled {
            bail!("job queue is cancelled");
        }
        st.heap.push(Entry {
            priority: job.priority,
            seq: job.seq,
            spec: job.spec,
            skips: 0,
            // A requeued job starts a fresh wait span: queue-wait
            // measures time since the *last* (re-)admission.
            enqueued: Instant::now(),
        });
        obs::QUEUE_DEPTH.set(st.heap.len() as f64);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Raise the seq counter to at least `next_seq` — journal replay
    /// installs the pre-crash counter here so re-admitted jobs keep
    /// their original seqs and *new* submissions can never collide
    /// with them. Never lowers the counter.
    pub fn resume_from(&self, next_seq: u64) {
        let mut st = lock_recover(&self.state);
        st.next_seq = st.next_seq.max(next_seq);
    }

    /// Seal the producer side: further pushes fail, consumers drain the
    /// remaining jobs and then see `None`.
    pub fn close(&self) {
        let mut st = lock_recover(&self.state);
        st.closed = true;
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Drop all pending jobs and wake everyone; pops return `None` from
    /// now on. Implies `close`.
    pub fn cancel(&self) {
        let mut st = lock_recover(&self.state);
        st.cancelled = true;
        st.closed = true;
        st.heap.clear();
        obs::QUEUE_DEPTH.set(0.0);
        drop(st);
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Number of pending (not yet popped) jobs.
    pub fn len(&self) -> usize {
        lock_recover(&self.state).heap.len()
    }

    /// Maximum number of pending jobs (the bound given to
    /// [`Self::bounded`], clamped to ≥ 1). `len() >= capacity()` is the
    /// saturation signal the HTTP gateway turns into `429`.
    pub fn capacity(&self) -> usize {
        lock_recover(&self.state).capacity
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_cancelled(&self) -> bool {
        lock_recover(&self.state).cancelled
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::spec::ExperimentKind;

    fn spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        JobSpec { kind: ExperimentKind::Pretrain, cfg }
    }

    #[test]
    fn fifo_within_one_priority() {
        let q = JobQueue::bounded(16);
        for i in 0..5 {
            q.push(spec(i), 0).unwrap();
        }
        q.close();
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop()).map(|j| j.spec.cfg.seed).collect();
        assert_eq!(order, vec![0, 1, 2, 3, 4]);
        assert!(q.pop().is_none());
    }

    #[test]
    fn higher_priority_pops_first() {
        let q = JobQueue::bounded(16);
        q.push(spec(0), 0).unwrap();
        q.push(spec(1), 5).unwrap();
        q.push(spec(2), 1).unwrap();
        q.push(spec(3), 5).unwrap(); // same prio as seed 1 → after it
        q.close();
        let order: Vec<u64> =
            std::iter::from_fn(|| q.pop()).map(|j| j.spec.cfg.seed).collect();
        assert_eq!(order, vec![1, 3, 2, 0]);
    }

    #[test]
    fn close_rejects_pushes_but_drains() {
        let q = JobQueue::bounded(4);
        q.push(spec(0), 0).unwrap();
        q.close();
        assert!(q.push(spec(1), 0).is_err());
        assert!(q.pop().is_some());
        assert!(q.pop().is_none());
    }

    #[test]
    fn capacity_is_reported_and_clamped() {
        assert_eq!(JobQueue::bounded(4).capacity(), 4);
        assert_eq!(JobQueue::bounded(0).capacity(), 1);
    }

    #[test]
    fn resume_from_raises_but_never_lowers_the_seq_counter() {
        let q = JobQueue::bounded(16);
        q.resume_from(7);
        assert_eq!(q.push(spec(0), 0).unwrap(), 7);
        // A lower resume point is ignored: seqs stay monotone.
        q.resume_from(3);
        assert_eq!(q.push(spec(1), 0).unwrap(), 8);
    }

    #[test]
    fn try_push_never_blocks_and_hands_the_spec_back() {
        let q = JobQueue::bounded(1);
        let seq = match q.try_push(spec(0), 0) {
            TryPush::Pushed(seq) => seq,
            other => panic!("expected Pushed, got {other:?}"),
        };
        assert_eq!(seq, 0);
        match q.try_push(spec(1), 0) {
            TryPush::Full(s) => assert_eq!(s.cfg.seed, 1),
            other => panic!("expected Full, got {other:?}"),
        }
        assert!(q.pop().is_some());
        q.wait_not_full(); // room available: returns immediately
        q.close();
        match q.try_push(spec(2), 0) {
            TryPush::Closed(s) => assert_eq!(s.cfg.seed, 2),
            other => panic!("expected Closed, got {other:?}"),
        }
        q.wait_not_full(); // closed: returns immediately
    }

    #[test]
    fn cancel_drops_pending() {
        let q = JobQueue::bounded(4);
        q.push(spec(0), 0).unwrap();
        q.push(spec(1), 0).unwrap();
        q.cancel();
        assert!(q.is_cancelled());
        assert!(q.pop().is_none());
        assert_eq!(q.len(), 0);
        assert!(q.push(spec(2), 0).is_err());
    }

    #[test]
    fn bounded_capacity_blocks_until_popped() {
        use std::sync::Arc;
        let q = Arc::new(JobQueue::bounded(1));
        q.push(spec(0), 0).unwrap();
        let q2 = Arc::clone(&q);
        let producer = std::thread::spawn(move || {
            // Blocks until the consumer below pops the first job.
            q2.push(spec(1), 0).unwrap();
        });
        // Give the producer a moment to hit the full queue.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.pop().unwrap().spec.cfg.seed, 0);
        producer.join().unwrap();
        q.close();
        assert_eq!(q.pop().unwrap().spec.cfg.seed, 1);
        assert!(q.pop().is_none());
    }

    #[test]
    fn pop_timeout_distinguishes_empty_from_closed() {
        let q = JobQueue::bounded(4);
        match q.pop_timeout(Duration::from_millis(10)) {
            PopTimeout::Empty => {}
            other => panic!("open+empty should time out, got {other:?}"),
        }
        q.push(spec(0), 0).unwrap();
        match q.pop_timeout(Duration::from_millis(10)) {
            PopTimeout::Job(j) => assert_eq!(j.spec.cfg.seed, 0),
            other => panic!("expected Job, got {other:?}"),
        }
        q.close();
        match q.pop_timeout(Duration::from_millis(10)) {
            PopTimeout::Closed => {}
            other => panic!("closed+drained is Closed, got {other:?}"),
        }
    }

    #[test]
    fn pop_timeout_still_drains_a_closed_queue() {
        let q = JobQueue::bounded(4);
        q.push(spec(7), 0).unwrap();
        q.close();
        match q.pop_timeout(Duration::from_millis(10)) {
            PopTimeout::Job(j) => assert_eq!(j.spec.cfg.seed, 7),
            other => panic!("expected Job, got {other:?}"),
        }
    }

    #[test]
    fn requeue_preserves_seq_and_ignores_capacity() {
        let q = JobQueue::bounded(1);
        let seq = q.push(spec(0), 3).unwrap();
        let job = q.pop().unwrap();
        assert_eq!(job.seq, seq);
        // Fill the queue again, then requeue on top of a full queue.
        q.push(spec(1), 0).unwrap();
        q.requeue(job).unwrap();
        assert_eq!(q.len(), 2, "requeue bypasses the capacity bound");
        // Higher priority (3) pops first, with its original seq.
        let back = q.pop().unwrap();
        assert_eq!((back.seq, back.priority), (seq, 3));
        assert_eq!(back.spec.cfg.seed, 0);
        // Requeue after close still works (drain re-dispatch)...
        q.close();
        let j2 = q.pop().unwrap();
        q.requeue(j2).unwrap();
        assert_eq!(q.pop().unwrap().spec.cfg.seed, 1);
        // ...but not after cancel.
        let q2 = JobQueue::bounded(1);
        let s = q2.push(spec(9), 0).unwrap();
        let job = q2.pop().unwrap();
        assert_eq!(job.seq, s);
        q2.cancel();
        assert!(q2.requeue(job).is_err());
    }

    fn scan(q: &JobQueue, window: usize, want: &[u64]) -> PopScan {
        let mut pred = |s: &JobSpec| want.contains(&s.cfg.seed);
        q.pop_scan_timeout(Duration::from_millis(10), window, &mut pred)
    }

    #[test]
    fn scan_extracts_a_deeper_match_and_preserves_order() {
        let q = JobQueue::bounded(16);
        let seqs: Vec<u64> =
            (0..4).map(|i| q.push(spec(i), 0).unwrap()).collect();
        // Seed 2 sits third in line; a window of 4 finds it.
        let j = match scan(&q, 4, &[2]) {
            PopScan::Match(j) => j,
            other => panic!("expected Match, got {other:?}"),
        };
        assert_eq!(j.spec.cfg.seed, 2);
        assert_eq!(j.seq, seqs[2], "extraction keeps the original seq");
        // The passed-over entries drain in their original FIFO order.
        let rest: Vec<u64> =
            std::iter::from_fn(|| q.pop_timeout(Duration::ZERO).job())
                .map(|j| j.spec.cfg.seed)
                .collect();
        assert_eq!(rest, vec![0, 1, 3]);
    }

    #[test]
    fn scan_without_match_falls_back_to_the_head() {
        let q = JobQueue::bounded(16);
        for i in 0..3 {
            q.push(spec(i), 0).unwrap();
        }
        match scan(&q, 8, &[99]) {
            PopScan::Head(j) => assert_eq!(j.spec.cfg.seed, 0),
            other => panic!("expected Head, got {other:?}"),
        }
        // A window larger than the queue is fine; matching head is a
        // Match without any scan.
        match scan(&q, 8, &[1]) {
            PopScan::Match(j) => assert_eq!(j.spec.cfg.seed, 1),
            other => panic!("expected Match, got {other:?}"),
        }
        // Empty and closed are distinguished exactly like pop_timeout.
        assert!(matches!(scan(&q, 8, &[99]), PopScan::Head(_)));
        assert!(matches!(scan(&q, 8, &[99]), PopScan::Empty));
        q.close();
        assert!(matches!(scan(&q, 8, &[99]), PopScan::Closed));
    }

    #[test]
    fn scan_never_crosses_a_priority_boundary() {
        let q = JobQueue::bounded(16);
        q.push(spec(0), 5).unwrap(); // head: high priority, no match
        q.push(spec(1), 0).unwrap(); // deeper match, but lower priority
        match scan(&q, 8, &[1]) {
            PopScan::Head(j) => {
                assert_eq!(j.spec.cfg.seed, 0, "priority still wins")
            }
            other => panic!("expected Head, got {other:?}"),
        }
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn scan_head_skip_cap_prevents_starvation() {
        let q = JobQueue::bounded(64);
        q.push(spec(0), 0).unwrap(); // never matches
        q.push(spec(1), 0).unwrap(); // always matches
        for _ in 0..MAX_SCAN_SKIPS {
            let j = match scan(&q, 4, &[1]) {
                PopScan::Match(j) => j,
                other => panic!("expected Match, got {other:?}"),
            };
            assert_eq!(j.spec.cfg.seed, 1);
            q.requeue(j).unwrap(); // keep a matching sibling available
        }
        // The head has now been skipped MAX_SCAN_SKIPS times: the next
        // scan must take it even though a match is still waiting.
        match scan(&q, 4, &[1]) {
            PopScan::Head(j) => assert_eq!(j.spec.cfg.seed, 0),
            other => panic!("expected forced Head, got {other:?}"),
        }
    }

    #[test]
    fn mpmc_drains_exactly_once() {
        use std::sync::Arc;
        let q = Arc::new(JobQueue::bounded(64));
        for i in 0..40 {
            q.push(spec(i), 0).unwrap();
        }
        q.close();
        let mut handles = Vec::new();
        for _ in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(j) = q.pop() {
                    seen.push(j.seq);
                }
                seen
            }));
        }
        let mut all: Vec<u64> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        assert_eq!(all, (0..40).collect::<Vec<u64>>());
    }
}
