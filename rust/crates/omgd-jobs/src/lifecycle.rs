//! The lifecycle transition authority: one totalized state machine
//! for every job, lease, session-quota, and gateway-phase mutation in
//! this crate.
//!
//! Before this module existed, job state was implicit in the union of
//! five maps (`routes`, `live`, `leases`, `orphans`, `completed`)
//! mutated from many lock sites across `serve.rs` and `net.rs`; an
//! illegal transition was whatever the scattered code happened not to
//! represent. Now the legal automaton is written down **once**, in
//! [`next_state`]:
//!
//! ```text
//!            Admit          Enqueue           Lease(w)
//!   (none) ───────► Admitted ───────► Queued ─────────► Leased(w)
//!                                       ▲                 │  │ │
//!                                       │ (requeue)       │  │ └─ Renew(w) ↺
//!                                       │                 │  │
//!                              Requeued ◄───── Expire ────┘  └─ Report(w)
//!                                  │                               │
//!                                  │ Lease(w')                     ▼
//!                                  └──────────► Leased(w')     Reported
//!                                                                  │
//!   Admitted | Queued | Requeued ── Cancel ──► Cancelled           │ Finalize
//!   Queued | Requeued | Reported ── Finalize ──► Done ◄────────────┘
//! ```
//!
//! plus the journal-replay entry points (`ReplayPending` admits a
//! journaled job straight to `Queued`, `ReplayDone` straight to
//! `Done`). Everything else is a typed [`TransitionError`] — the
//! `match` in [`next_state`] is totalized over `(state, event)`, so a
//! new state or event fails to compile until every pairing is
//! classified.
//!
//! Discipline: **transition first, then mutate.** A caller applies the
//! event to the [`Lifecycle`] table and only touches its data maps
//! (routes, lease table, completed log) after the transition
//! succeeded; a failed transition means skip the mutation and surface
//! the typed error. The table's mutex is a *leaf* lock — [`Lifecycle`]
//! never takes another lock while holding it — so sites may apply
//! transitions while holding their own map locks without ordering
//! hazards (renew vs. expire serialize on the hub's lease-table lock,
//! report vs. expire likewise). See `docs/lifecycle.md` for the
//! invariant list this module enforces.
//!
//! The same discipline covers the two non-job machines the gateway
//! needs: [`GatewayPhase`] (serving → draining → stopped, lock-free
//! via [`PhaseCell`]) and the per-client in-flight quota
//! ([`ClientLedger`]). The worker side mirrors the lease half with
//! [`WorkerLeases`].

use omgd_util::lock_recover;
use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Job state machine
// ---------------------------------------------------------------------------

/// Where a job is in its life. One value per seq, owned by
/// [`Lifecycle`]; the hub's data maps (routes, lease table, result
/// log) are projections of this, never the source of truth.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Accepted by `submit` (or journal `Admit`); not yet in the queue.
    Admitted,
    /// In the job queue, waiting for a local worker or a remote lease.
    Queued,
    /// Held by the named remote worker under a TTL.
    Leased(String),
    /// Lease expired; back in the queue with its original seq.
    Requeued,
    /// A remote worker reported a result; dispatch is in flight.
    Reported,
    /// Withdrawn before execution. Terminal.
    Cancelled,
    /// Result dispatched (done, failed, or cached). Terminal.
    Done,
}

impl JobState {
    /// Terminal states never transition again (enforced by
    /// [`next_state`], asserted by the transition-table test).
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Cancelled | JobState::Done)
    }
}

impl fmt::Display for JobState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JobState::Admitted => write!(f, "admitted"),
            JobState::Queued => write!(f, "queued"),
            JobState::Leased(w) => write!(f, "leased({w})"),
            JobState::Requeued => write!(f, "requeued"),
            JobState::Reported => write!(f, "reported"),
            JobState::Cancelled => write!(f, "cancelled"),
            JobState::Done => write!(f, "done"),
        }
    }
}

/// Everything that can happen to a job.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobEvent {
    /// `submit` accepted the spec (journal `Admit` record).
    Admit,
    /// The spec landed in the job queue.
    Enqueue,
    /// A remote worker took a lease (journal `Lease` record).
    Lease(String),
    /// The leasing worker extended its TTL (journal `Renew` record).
    Renew(String),
    /// A worker reported a result. `None` means a local (in-process)
    /// worker, which never held a lease.
    Report(Option<String>),
    /// The requeue sweep found the lease TTL elapsed.
    Expire,
    /// The job was withdrawn before execution (journal `Cancel`).
    Cancel,
    /// The result was dispatched to its submitter (journal `Done`).
    Finalize,
    /// Journal replay: a pending job goes straight to the queue.
    ReplayPending,
    /// Journal replay: a completed job goes straight to `Done`.
    ReplayDone,
}

impl JobEvent {
    fn name(&self) -> &'static str {
        match self {
            JobEvent::Admit => "admit",
            JobEvent::Enqueue => "enqueue",
            JobEvent::Lease(_) => "lease",
            JobEvent::Renew(_) => "renew",
            JobEvent::Report(_) => "report",
            JobEvent::Expire => "expire",
            JobEvent::Cancel => "cancel",
            JobEvent::Finalize => "finalize",
            JobEvent::ReplayPending => "replay-pending",
            JobEvent::ReplayDone => "replay-done",
        }
    }
}

/// Why a transition was refused. Every illegal `(state, event)`
/// pairing maps to exactly one of these — there is no silent drop.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransitionError {
    /// Event for a seq the authority has never admitted.
    UnknownJob { event: &'static str },
    /// `Admit`/replay events for a seq that already has a state.
    DuplicateAdmit { state: JobState },
    /// Renew/report by a worker that does not hold the lease. The
    /// gateway surfaces this as HTTP 409.
    WrongWorker { held_by: String, claimed: String },
    /// Any other pairing the automaton does not allow.
    Invalid { state: JobState, event: &'static str },
}

impl fmt::Display for TransitionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransitionError::UnknownJob { event } => {
                write!(f, "event '{event}' for a job the lifecycle never admitted")
            }
            TransitionError::DuplicateAdmit { state } => {
                write!(f, "admit of a job already {state}")
            }
            TransitionError::WrongWorker { held_by, claimed } => {
                write!(f, "lease held by {held_by:?}, claimed by {claimed:?}")
            }
            TransitionError::Invalid { state, event } => {
                write!(f, "event '{event}' is illegal in state {state}")
            }
        }
    }
}

impl std::error::Error for TransitionError {}

/// The totalized transition function. Pure: no locks, no clocks, no
/// side effects — this is the single place the legal automaton is
/// defined, and the only function the transition-table test needs.
///
/// `state` is `None` for a seq the authority has not seen. The outer
/// match is over the event, the inner over the state; together they
/// cover every `(state, event)` pairing explicitly, so extending
/// either enum forces this function through the compiler.
pub fn next_state(
    state: Option<&JobState>,
    event: &JobEvent,
) -> Result<JobState, TransitionError> {
    use JobEvent as E;
    use JobState as S;
    let unknown = || TransitionError::UnknownJob { event: event.name() };
    let invalid = |s: &S| TransitionError::Invalid {
        state: s.clone(),
        event: event.name(),
    };
    match event {
        // Birth events: legal only for an unseen seq.
        E::Admit => match state {
            None => Ok(S::Admitted),
            Some(s) => Err(TransitionError::DuplicateAdmit { state: s.clone() }),
        },
        E::ReplayPending => match state {
            None => Ok(S::Queued),
            Some(s) => Err(TransitionError::DuplicateAdmit { state: s.clone() }),
        },
        E::ReplayDone => match state {
            None => Ok(S::Done),
            Some(s) => Err(TransitionError::DuplicateAdmit { state: s.clone() }),
        },

        E::Enqueue => match state {
            Some(S::Admitted) => Ok(S::Queued),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },

        E::Lease(w) => match state {
            Some(S::Queued) | Some(S::Requeued) => Ok(S::Leased(w.clone())),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },

        E::Renew(w) => match state {
            Some(S::Leased(held)) if held == w => Ok(S::Leased(held.clone())),
            Some(S::Leased(held)) => Err(TransitionError::WrongWorker {
                held_by: held.clone(),
                claimed: w.clone(),
            }),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },

        E::Report(claimed) => match (state, claimed) {
            // Remote report: must name the worker holding the lease.
            // A report that arrives after the lease expired finds the
            // job `Requeued` (or re-`Leased`) and is refused — the
            // typed error is what the gateway surfaces as a 409
            // conflict, preserving exactly-once dispatch.
            (Some(S::Leased(held)), Some(w)) if held == w => Ok(S::Reported),
            (Some(S::Leased(held)), Some(w)) => Err(TransitionError::WrongWorker {
                held_by: held.clone(),
                claimed: w.clone(),
            }),
            (Some(S::Leased(held)), None) => Err(TransitionError::WrongWorker {
                held_by: held.clone(),
                claimed: String::from("<local>"),
            }),
            // Local report: an in-process worker popped the queue
            // directly; no lease was ever granted.
            (Some(S::Queued), None) | (Some(S::Requeued), None) => Ok(S::Reported),
            (Some(s), _) => Err(invalid(s)),
            (None, _) => Err(unknown()),
        },

        E::Expire => match state {
            Some(S::Leased(_)) => Ok(S::Requeued),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },

        E::Cancel => match state {
            Some(S::Admitted) | Some(S::Queued) | Some(S::Requeued) => Ok(S::Cancelled),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },

        E::Finalize => match state {
            Some(S::Reported) => Ok(S::Done),
            // A queued job can finalize directly: cache fast-path hits
            // and requeue-failure dispatches skip the report step.
            Some(S::Queued) | Some(S::Requeued) => Ok(S::Done),
            Some(s) => Err(invalid(s)),
            None => Err(unknown()),
        },
    }
}

/// The shared transition table: seq → [`JobState`], every mutation
/// funneled through [`next_state`].
///
/// Lock ordering: sites that mutate both the lifecycle and a data map
/// take this lock **first**, apply the transition, and only touch the
/// data map after the transition succeeded. Concurrent writers
/// therefore serialize on the automaton, and the loser of any race
/// observes a typed error instead of clobbering state.
#[derive(Debug, Default)]
pub struct Lifecycle {
    table: Mutex<HashMap<u64, JobState>>,
}

impl Lifecycle {
    pub fn new() -> Self {
        Self::default()
    }

    /// Apply `event` to `seq`. On success the table is updated and the
    /// new state returned; on failure the table is untouched.
    pub fn apply(&self, seq: u64, event: &JobEvent) -> Result<JobState, TransitionError> {
        let mut table = lock_recover(&self.table);
        let next = next_state(table.get(&seq), event)?;
        table.insert(seq, next.clone());
        Ok(next)
    }

    /// Apply `event` only if the seq is already known; an unknown seq
    /// is first admitted through `first`. Used by the lease path,
    /// where the queue is also a public surface (`hub.queue.push`)
    /// and a job may reach the authority only at lease time.
    pub fn apply_or_register(
        &self,
        seq: u64,
        first: &[JobEvent],
        event: &JobEvent,
    ) -> Result<JobState, TransitionError> {
        let mut table = lock_recover(&self.table);
        if !table.contains_key(&seq) {
            let mut st: Option<JobState> = None;
            for ev in first {
                st = Some(next_state(st.as_ref(), ev)?);
            }
            if let Some(st) = st {
                table.insert(seq, st);
            }
        }
        let next = next_state(table.get(&seq), event)?;
        table.insert(seq, next.clone());
        Ok(next)
    }

    /// Current state of `seq`, if the authority has seen it.
    pub fn state(&self, seq: u64) -> Option<JobState> {
        lock_recover(&self.table).get(&seq).cloned()
    }

    /// Drop a terminal seq from the table. The authority bounds its
    /// own growth by forgetting jobs once their terminal state has
    /// been externalized (result dispatched and, when a journal is
    /// attached, retained in the completed log). Forgetting a
    /// non-terminal seq is a logic error and panics in debug builds.
    pub fn forget(&self, seq: u64) {
        let mut table = lock_recover(&self.table);
        if let Some(st) = table.remove(&seq) {
            debug_assert!(st.is_terminal(), "forgetting live job {seq} in state {st}");
        }
    }

    /// Number of tracked (non-forgotten) jobs.
    pub fn len(&self) -> usize {
        lock_recover(&self.table).len()
    }

    pub fn is_empty(&self) -> bool {
        lock_recover(&self.table).is_empty()
    }

    /// Seqs currently in a terminal state (test/diagnostic surface).
    pub fn terminal_seqs(&self) -> Vec<u64> {
        let table = lock_recover(&self.table);
        let mut v: Vec<u64> = table
            .iter()
            .filter(|(_, s)| s.is_terminal())
            .map(|(&k, _)| k)
            .collect();
        v.sort_unstable();
        v
    }
}

// ---------------------------------------------------------------------------
// Gateway phase machine
// ---------------------------------------------------------------------------

/// The gateway's connection-level lifecycle: accepting new work,
/// draining (finish what's in flight, refuse new jobs), stopped.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum GatewayPhase {
    /// Accepting connections and job submissions.
    Serving = 0,
    /// `/shutdown` received: existing sessions finish, new submissions
    /// get 503, the accept loop exits once the queue and leases drain.
    Draining = 1,
    /// Accept loop exited; no connection threads remain.
    Stopped = 2,
}

/// Lock-free holder for the current [`GatewayPhase`]. Replaces the old
/// `stop: AtomicBool`, which conflated "start draining" with "fully
/// stopped" and let any site flip it. Phases only move forward:
/// `Serving → Draining → Stopped`; a regression attempt is refused and
/// repeated `/shutdown`s are idempotent.
#[derive(Debug)]
pub struct PhaseCell(AtomicU8);

impl Default for PhaseCell {
    fn default() -> Self {
        Self::new()
    }
}

impl PhaseCell {
    pub fn new() -> Self {
        PhaseCell(AtomicU8::new(GatewayPhase::Serving as u8))
    }

    pub fn get(&self) -> GatewayPhase {
        match self.0.load(Ordering::SeqCst) {
            0 => GatewayPhase::Serving,
            1 => GatewayPhase::Draining,
            _ => GatewayPhase::Stopped,
        }
    }

    /// True once draining has begun (draining or stopped).
    pub fn draining(&self) -> bool {
        self.get() != GatewayPhase::Serving
    }

    /// Request `Serving → Draining`. Returns `true` if this call made
    /// the transition, `false` if the gateway was already past it
    /// (idempotent repeat — not an error).
    pub fn request_drain(&self) -> bool {
        self.0
            .compare_exchange(
                GatewayPhase::Serving as u8,
                GatewayPhase::Draining as u8,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
    }

    /// Mark the drain complete (`Draining → Stopped`). Refused (with
    /// `false`) unless the gateway was draining: the accept loop may
    /// not skip the draining phase.
    pub fn mark_stopped(&self) -> bool {
        self.0
            .compare_exchange(
                GatewayPhase::Draining as u8,
                GatewayPhase::Stopped as u8,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
    }
}

// ---------------------------------------------------------------------------
// Client quota ledger
// ---------------------------------------------------------------------------

/// Per-client in-flight accounting for `--client-quota`: the session
/// half of the lifecycle authority. Owns the map, the quota, and the
/// condvar; callers can no longer reach into the raw map, so the
/// increment/decrement discipline (acquire blocks, release notifies,
/// zero entries are removed) lives in exactly one place.
#[derive(Debug, Default)]
pub struct ClientLedger {
    in_flight: Mutex<HashMap<String, usize>>,
    cv: Condvar,
    quota: AtomicUsize,
}

impl ClientLedger {
    pub fn new() -> Self {
        Self::default()
    }

    /// Set the per-client cap (0 = unlimited) and wake waiters so a
    /// raised quota is observed immediately.
    pub fn set_quota(&self, quota: usize) {
        self.quota.store(quota, Ordering::SeqCst);
        self.cv.notify_all();
    }

    pub fn quota(&self) -> usize {
        self.quota.load(Ordering::SeqCst)
    }

    /// In-flight count for one client.
    pub fn in_flight(&self, client: &str) -> usize {
        lock_recover(&self.in_flight).get(client).copied().unwrap_or(0)
    }

    /// Snapshot of all clients with in-flight jobs, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> = lock_recover(&self.in_flight)
            .iter()
            .map(|(k, &n)| (k.clone(), n))
            .collect();
        v.sort();
        v
    }

    /// True if `client` is at its quota right now (advisory — the
    /// authoritative check is the blocking wait in [`Self::acquire`]).
    pub fn at_quota(&self, client: &str) -> bool {
        let quota = self.quota();
        quota > 0 && self.in_flight(client) >= quota
    }

    /// Take one in-flight slot for `client`, blocking while the client
    /// is at quota. `client = None` is exempt from quotas.
    pub fn acquire(&self, client: Option<&str>) {
        let Some(client) = client else { return };
        let mut map = lock_recover(&self.in_flight);
        loop {
            let quota = self.quota();
            let n = map.get(client).copied().unwrap_or(0);
            if quota == 0 || n < quota {
                *map.entry(client.to_string()).or_insert(0) += 1;
                return;
            }
            map = self
                .cv
                .wait(map)
                .unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Rebuild one slot during journal replay, bypassing the quota
    /// wait: the slot was legally acquired before the crash, and
    /// replay must not deadlock when a client's pending backlog
    /// exceeds a (possibly lowered) quota.
    pub fn restore(&self, client: Option<&str>) {
        let Some(client) = client else { return };
        *lock_recover(&self.in_flight)
            .entry(client.to_string())
            .or_insert(0) += 1;
    }

    /// Test seam: run `f` while holding the ledger lock, so crate
    /// tests can poison it the way a panicking session thread would
    /// and assert the recovery path.
    #[cfg(test)]
    pub(crate) fn with_lock<R>(&self, f: impl FnOnce() -> R) -> R {
        let _g = self.in_flight.lock().unwrap();
        f()
    }

    /// Release one slot. Saturating; a zeroed entry is removed so the
    /// snapshot only lists clients with live work.
    pub fn release(&self, client: Option<&str>) {
        let Some(client) = client else { return };
        let mut map = lock_recover(&self.in_flight);
        if let Some(n) = map.get_mut(client) {
            *n = n.saturating_sub(1);
            if *n == 0 {
                map.remove(client);
            }
        }
        drop(map);
        self.cv.notify_all();
    }
}

// ---------------------------------------------------------------------------
// Worker-side lease mirror
// ---------------------------------------------------------------------------

/// One lease as seen by the worker that holds it.
#[derive(Clone, Debug)]
pub struct HeldLease {
    /// TTL the gateway granted; renewals target half this interval.
    pub ttl_secs: u64,
    /// Next heartbeat due time.
    pub next_renew: Instant,
    /// Monotone token distinguishing re-leases of the same seq; a
    /// heartbeat outcome only applies if the token still matches.
    pub token: u64,
}

/// The worker-side mirror of the gateway's lease table: seq → lease
/// being executed right now. The heartbeat thread and the worker
/// threads share it; all mutation goes through these methods so the
/// token discipline (a stale heartbeat must not clobber a re-leased
/// seq) is enforced in one place.
#[derive(Debug, Default)]
pub struct WorkerLeases {
    map: Mutex<HashMap<u64, HeldLease>>,
    next_token: AtomicUsize,
}

impl WorkerLeases {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a newly granted lease; returns its token.
    pub fn start(&self, seq: u64, ttl_secs: u64, next_renew: Instant) -> u64 {
        let token = self.next_token.fetch_add(1, Ordering::SeqCst) as u64;
        lock_recover(&self.map).insert(
            seq,
            HeldLease {
                ttl_secs,
                next_renew,
                token,
            },
        );
        token
    }

    /// The job finished (reported or abandoned): drop the mirror entry.
    pub fn finish(&self, seq: u64) {
        lock_recover(&self.map).remove(&seq);
    }

    /// Leases whose heartbeat is due at `now`: `(seq, ttl, token)`.
    pub fn due(&self, now: Instant) -> Vec<(u64, u64, u64)> {
        lock_recover(&self.map)
            .iter()
            .filter(|(_, l)| l.next_renew <= now)
            .map(|(&seq, l)| (seq, l.ttl_secs, l.token))
            .collect()
    }

    /// A renew round-tripped: push the next heartbeat out. Ignored if
    /// the lease was dropped or re-issued (token mismatch) meanwhile.
    pub fn renewed(&self, seq: u64, token: u64, next_renew: Instant) {
        if let Some(l) = lock_recover(&self.map).get_mut(&seq) {
            if l.token == token {
                l.next_renew = next_renew;
            }
        }
    }

    /// The gateway answered 409 (lease gone): drop the mirror entry,
    /// token-guarded for the same reason as [`Self::renewed`].
    pub fn lease_gone(&self, seq: u64, token: u64) {
        let mut map = lock_recover(&self.map);
        if map.get(&seq).is_some_and(|l| l.token == token) {
            map.remove(&seq);
        }
    }

    pub fn len(&self) -> usize {
        lock_recover(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        lock_recover(&self.map).is_empty()
    }
}

// ---------------------------------------------------------------------------
// Transition-table test: every (state, event) pairing, legal and not
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn all_states() -> Vec<Option<JobState>> {
        vec![
            None,
            Some(JobState::Admitted),
            Some(JobState::Queued),
            Some(JobState::Leased("w1".into())),
            Some(JobState::Requeued),
            Some(JobState::Reported),
            Some(JobState::Cancelled),
            Some(JobState::Done),
        ]
    }

    fn all_events() -> Vec<JobEvent> {
        vec![
            JobEvent::Admit,
            JobEvent::Enqueue,
            JobEvent::Lease("w1".into()),
            JobEvent::Lease("w2".into()),
            JobEvent::Renew("w1".into()),
            JobEvent::Renew("w2".into()),
            JobEvent::Report(Some("w1".into())),
            JobEvent::Report(Some("w2".into())),
            JobEvent::Report(None),
            JobEvent::Expire,
            JobEvent::Cancel,
            JobEvent::Finalize,
            JobEvent::ReplayPending,
            JobEvent::ReplayDone,
        ]
    }

    /// The full legal transition table, written out by hand. Every
    /// (state, event) pairing not listed here must yield an error —
    /// the test below checks both directions exhaustively, so this
    /// table IS the spec of the automaton.
    fn legal(state: &Option<JobState>, event: &JobEvent) -> Option<JobState> {
        use JobEvent as E;
        use JobState as S;
        let w1 = || "w1".to_string();
        match (state, event) {
            (None, E::Admit) => Some(S::Admitted),
            (None, E::ReplayPending) => Some(S::Queued),
            (None, E::ReplayDone) => Some(S::Done),
            (Some(S::Admitted), E::Enqueue) => Some(S::Queued),
            (Some(S::Admitted), E::Cancel) => Some(S::Cancelled),
            (Some(S::Queued), E::Lease(w)) => Some(S::Leased(w.clone())),
            (Some(S::Queued), E::Report(None)) => Some(S::Reported),
            (Some(S::Queued), E::Cancel) => Some(S::Cancelled),
            (Some(S::Queued), E::Finalize) => Some(S::Done),
            (Some(S::Leased(h)), E::Renew(w)) if h == w => Some(S::Leased(w1())),
            (Some(S::Leased(h)), E::Report(Some(w))) if h == w => Some(S::Reported),
            (Some(S::Leased(_)), E::Expire) => Some(S::Requeued),
            (Some(S::Requeued), E::Lease(w)) => Some(S::Leased(w.clone())),
            (Some(S::Requeued), E::Report(None)) => Some(S::Reported),
            (Some(S::Requeued), E::Cancel) => Some(S::Cancelled),
            (Some(S::Requeued), E::Finalize) => Some(S::Done),
            (Some(S::Reported), E::Finalize) => Some(S::Done),
            _ => None,
        }
    }

    #[test]
    fn transition_table_is_exhaustive_and_matches_spec() {
        let mut legal_n = 0;
        let mut illegal_n = 0;
        for state in all_states() {
            for event in all_events() {
                let got = next_state(state.as_ref(), &event);
                match legal(&state, &event) {
                    Some(want) => {
                        legal_n += 1;
                        assert_eq!(
                            got.as_ref(),
                            Ok(&want),
                            "({state:?}, {event:?}) should be legal"
                        );
                    }
                    None => {
                        illegal_n += 1;
                        assert!(
                            got.is_err(),
                            "({state:?}, {event:?}) should be illegal, got {got:?}"
                        );
                    }
                }
            }
        }
        // 8 states × 14 events, all visited; the split below is the
        // hand-counted size of the legal table: 3 births + 2 from
        // Admitted + 5 from Queued + 3 from Leased + 5 from Requeued
        // + 1 from Reported = 19 legal pairings.
        assert_eq!(legal_n + illegal_n, 8 * 14);
        assert_eq!(legal_n, 19, "legal transition count drifted");
    }

    #[test]
    fn illegal_transitions_carry_typed_errors() {
        use JobEvent as E;
        use JobState as S;
        // Unknown seq.
        assert_eq!(
            next_state(None, &E::Lease("w".into())),
            Err(TransitionError::UnknownJob { event: "lease" })
        );
        // Double admit.
        assert_eq!(
            next_state(Some(&S::Queued), &E::Admit),
            Err(TransitionError::DuplicateAdmit { state: S::Queued })
        );
        // Wrong worker renew + report.
        assert_eq!(
            next_state(Some(&S::Leased("a".into())), &E::Renew("b".into())),
            Err(TransitionError::WrongWorker {
                held_by: "a".into(),
                claimed: "b".into()
            })
        );
        assert_eq!(
            next_state(Some(&S::Leased("a".into())), &E::Report(Some("b".into()))),
            Err(TransitionError::WrongWorker {
                held_by: "a".into(),
                claimed: "b".into()
            })
        );
        // Terminal states refuse everything.
        for ev in all_events() {
            assert!(next_state(Some(&S::Done), &ev).is_err());
            assert!(next_state(Some(&S::Cancelled), &ev).is_err());
        }
    }

    #[test]
    fn table_apply_and_forget() {
        let lc = Lifecycle::new();
        lc.apply(7, &JobEvent::Admit).unwrap();
        lc.apply(7, &JobEvent::Enqueue).unwrap();
        assert_eq!(lc.state(7), Some(JobState::Queued));
        // Failed transition leaves the table untouched.
        assert!(lc.apply(7, &JobEvent::Renew("w".into())).is_err());
        assert_eq!(lc.state(7), Some(JobState::Queued));
        lc.apply(7, &JobEvent::Lease("w".into())).unwrap();
        lc.apply(7, &JobEvent::Report(Some("w".into()))).unwrap();
        lc.apply(7, &JobEvent::Finalize).unwrap();
        assert_eq!(lc.state(7), Some(JobState::Done));
        assert_eq!(lc.terminal_seqs(), vec![7]);
        lc.forget(7);
        assert!(lc.is_empty());
    }

    #[test]
    fn apply_or_register_admits_queue_pushed_jobs() {
        let lc = Lifecycle::new();
        // A job pushed straight into hub.queue (public surface) first
        // meets the authority at lease time.
        let st = lc
            .apply_or_register(
                3,
                &[JobEvent::Admit, JobEvent::Enqueue],
                &JobEvent::Lease("w".into()),
            )
            .unwrap();
        assert_eq!(st, JobState::Leased("w".into()));
        // Second lease of the same seq is refused, not re-registered.
        assert!(lc
            .apply_or_register(
                3,
                &[JobEvent::Admit, JobEvent::Enqueue],
                &JobEvent::Lease("x".into()),
            )
            .is_err());
    }

    #[test]
    fn phase_cell_moves_forward_only() {
        let p = PhaseCell::new();
        assert_eq!(p.get(), GatewayPhase::Serving);
        assert!(!p.draining());
        assert!(!p.mark_stopped(), "cannot skip draining");
        assert!(p.request_drain());
        assert!(!p.request_drain(), "second drain request is a no-op");
        assert!(p.draining());
        assert_eq!(p.get(), GatewayPhase::Draining);
        assert!(p.mark_stopped());
        assert!(!p.mark_stopped());
        assert_eq!(p.get(), GatewayPhase::Stopped);
        assert!(p.draining(), "stopped still reads as draining");
    }

    #[test]
    fn client_ledger_counts_and_releases() {
        let l = ClientLedger::new();
        l.acquire(Some("a"));
        l.acquire(Some("a"));
        l.acquire(Some("b"));
        l.acquire(None); // exempt
        assert_eq!(l.in_flight("a"), 2);
        assert_eq!(l.in_flight("b"), 1);
        assert_eq!(
            l.snapshot(),
            vec![("a".to_string(), 2), ("b".to_string(), 1)]
        );
        l.release(Some("a"));
        l.release(Some("b"));
        l.release(Some("b")); // saturating
        assert_eq!(l.in_flight("a"), 1);
        assert_eq!(l.in_flight("b"), 0);
        assert_eq!(l.snapshot(), vec![("a".to_string(), 1)]);
    }

    #[test]
    fn client_ledger_quota_blocks_until_release() {
        use std::sync::Arc;
        let l = Arc::new(ClientLedger::new());
        l.set_quota(1);
        l.acquire(Some("c"));
        assert!(l.at_quota("c"));
        let l2 = l.clone();
        let waiter = std::thread::spawn(move || {
            l2.acquire(Some("c")); // blocks until main releases
            l2.release(Some("c"));
        });
        std::thread::sleep(std::time::Duration::from_millis(30));
        l.release(Some("c"));
        waiter.join().unwrap();
        assert_eq!(l.in_flight("c"), 0);
    }

    #[test]
    fn worker_leases_token_guard() {
        let wl = WorkerLeases::new();
        let now = Instant::now();
        let t1 = wl.start(5, 60, now);
        assert_eq!(wl.len(), 1);
        assert_eq!(wl.due(now), vec![(5, 60, t1)]);
        // Re-lease of the same seq invalidates the old token.
        wl.finish(5);
        let t2 = wl.start(5, 30, now);
        assert_ne!(t1, t2);
        wl.lease_gone(5, t1); // stale: ignored
        assert_eq!(wl.len(), 1);
        wl.renewed(5, t1, now + std::time::Duration::from_secs(9)); // stale: ignored
        assert_eq!(wl.due(now), vec![(5, 30, t2)]);
        wl.lease_gone(5, t2); // current: applies
        assert!(wl.is_empty());
    }
}
