//! Distributed execution over the HTTP gateway: the `omgd worker`
//! pull agent and the `omgd grid --remote` submission client.
//!
//! ## Worker agent (`omgd worker --connect <addr>`)
//!
//! N worker threads long-poll the gateway for leases
//! (`POST /work/lease`), each carrying this worker's identity and the
//! artifact fingerprints its local [`ArtifactStore`] already holds. A
//! granted lease delivers the full-fidelity wire spec
//! ([`JobSpec::to_wire`]); the agent verifies the spec's content hash,
//! syncs the referenced artifact set on a store miss
//! (`GET /artifacts/<fp>`, verified frame), consults its local result
//! cache (keyed by the *gateway's* fingerprint, so both ends agree),
//! runs the job panic-isolated, and reports via
//! `POST /work/<seq>/result`. A heartbeat thread renews in-flight
//! leases at a third of the TTL, so only a genuinely crashed,
//! partitioned, or wedged worker lets its lease expire — at which point
//! the gateway requeues the job for someone else.
//!
//! The agent exits when the gateway reports it is draining (or its
//! queue closed), or — once it has ever successfully connected — after
//! [`WorkerOptions::max_failures`] consecutive connection failures
//! (gateway gone). A gateway that was *never* reachable is an error.
//!
//! ## Remote grids (`omgd grid --remote <addr>`)
//!
//! [`run_grid_remote`] submits every cell of a grid to a gateway as one
//! `POST /jobs` session, using `{"spec":<wire>}` request lines so no
//! `RunConfig` field is lost in transit, verifies each ack's spec hash
//! against the locally-built cell, and reassembles the streamed results
//! into a [`GridReport`] whose CSV aggregate is byte-identical to the
//! same grid run on a local pool (deterministic columns only).
//!
//! Everything here is dependency-free `std::net` HTTP/1.1, matching
//! the gateway's deliberately minimal framing (`Content-Length`
//! request bodies on the worker protocol; the grid submission itself
//! streams `Transfer-Encoding: chunked`, one chunk per spec line, so
//! a grid's total size is never announced up front). Both clients
//! speak `Connection: keep-alive`: each
//! worker thread (and the heartbeat) holds ONE persistent connection
//! across lease/renew/result/artifact rounds (`GatewayConn`), and
//! `run_grid_remote` reuses its socket across `429` retry rounds, with
//! the `200` session stream arriving chunked so its end is visible
//! without a close. A connection the gateway idle-closed between
//! rounds is retried once on a fresh socket.

use super::cache::{self, ResultCache};
use super::lifecycle::WorkerLeases;
use super::pool::{panic_message, JobOutcome, JobResult, JobStatus};
use super::report::GridReport;
use super::serve::PhaseSecs;
use super::spec::JobSpec;
use super::sync::ArtifactStore;
use crate::metrics::Timer;
use crate::obs;
use crate::util::json::{escape_str as esc, ser_f64 as ser_f, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::time::{Duration, Instant};

/// Knobs for one `omgd worker` agent.
#[derive(Clone, Debug)]
pub struct WorkerOptions {
    /// Gateway address, `host:port`.
    pub connect: String,
    /// Concurrent jobs (worker threads); each owns its own runtime.
    pub workers: usize,
    /// Identity sent with every lease/renew/result — lease ownership is
    /// checked against it, so it should be unique per agent.
    pub worker_id: String,
    /// Local result-cache directory (default [`super::DEFAULT_CACHE_DIR`]).
    pub cache_dir: Option<String>,
    /// Local artifact-store root (default [`super::DEFAULT_STORE_DIR`]).
    pub store_dir: Option<String>,
    /// Recompute locally-cached cells instead of replaying them.
    pub force: bool,
    /// Consecutive connection failures tolerated (after the first
    /// successful round trip) before the agent concludes the gateway is
    /// gone and exits.
    pub max_failures: usize,
    /// Lifecycle: total leases this agent will run before exiting
    /// cleanly (`--max-jobs`; shared budget across its threads). `0` =
    /// unlimited. For autoscaled fleets that recycle agents.
    pub max_jobs: usize,
    /// Lifecycle: exit once a thread has gone this many seconds
    /// without being granted work (`--idle-exit`; granularity is the
    /// gateway's long-poll window). `0` = keep polling forever. For
    /// autoscaled fleets that scale to zero on an idle gateway.
    pub idle_exit_secs: u64,
    /// Park a training checkpoint in the local cache dir every this
    /// many steps (`--ckpt-period`; 0 = off). A job whose lease is
    /// lost mid-run keeps its newest checkpoint on disk, and the next
    /// lease of the same spec — on a worker sharing this cache dir —
    /// resumes from it bitwise-identically (`docs/durability.md`).
    pub ckpt_period: usize,
    /// Bearer token (`--token`) sent as `Authorization: Bearer <t>` on
    /// every gateway request, for gateways running `--auth-token`.
    /// `None` = no header (an open gateway).
    pub token: Option<String>,
    /// Shard-parallel step-pool width per job (`--step-threads`),
    /// exported as `OMGD_THREADS` before any engine spawns its pool.
    /// `0` = inherit the environment (unset = available parallelism).
    /// Useful on a many-core box running several job threads: cap each
    /// job's pool so `workers × step_threads` matches the machine.
    pub step_threads: usize,
}

impl Default for WorkerOptions {
    fn default() -> Self {
        Self {
            connect: String::new(),
            workers: 1,
            worker_id: default_worker_id(),
            cache_dir: None,
            store_dir: None,
            force: false,
            max_failures: 5,
            max_jobs: 0,
            idle_exit_secs: 0,
            ckpt_period: 0,
            token: None,
            step_threads: 0,
        }
    }
}

/// `<hostname>-<pid>`, unique enough for lease ownership on a fleet.
pub fn default_worker_id() -> String {
    let host = std::env::var("HOSTNAME")
        .ok()
        .filter(|h| !h.is_empty())
        .unwrap_or_else(|| "worker".to_string());
    format!("{host}-{}", std::process::id())
}

/// What one agent did over its lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerStats {
    /// Leases received and executed (including cache replays).
    pub leased: usize,
    /// Jobs that ran and reported `done`.
    pub done: usize,
    /// Jobs reported `failed` or `panicked`.
    pub failed: usize,
    /// Jobs answered from the local result cache.
    pub cached: usize,
    /// Artifact sets downloaded into the local store.
    pub synced: usize,
    /// Results the gateway refused (`409`: lease expired mid-run and
    /// the job was re-dispatched).
    pub conflicts: usize,
}

#[derive(Default)]
struct StatCounters {
    leased: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    cached: AtomicUsize,
    synced: AtomicUsize,
    conflicts: AtomicUsize,
}

impl StatCounters {
    fn snapshot(&self) -> WorkerStats {
        WorkerStats {
            leased: self.leased.load(Ordering::Relaxed),
            done: self.done.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            cached: self.cached.load(Ordering::Relaxed),
            synced: self.synced.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
        }
    }
}

/// Run a worker agent with an injectable per-thread runner (tests use
/// stubs, exactly like [`super::run_pool`] / [`super::run_gateway`];
/// the production trainer-backed `run_worker` lives in `omgd-train`).
/// The agent wraps the runner with artifact sync, the local result
/// cache, and panic isolation.
pub fn run_worker_with<M, F>(
    opts: &WorkerOptions,
    make_runner: M,
) -> Result<WorkerStats>
where
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<JobOutcome>,
{
    if opts.step_threads > 0 {
        // Before any job thread builds an engine (pools read the env
        // once at construction), and while this process is still
        // single-threaded enough for set_var to be unremarkable.
        std::env::set_var("OMGD_THREADS", opts.step_threads.to_string());
    }
    let cache = ResultCache::open(opts.cache_dir.as_deref())?;
    let store = ArtifactStore::open(opts.store_dir.as_deref())?;
    let stats = StatCounters::default();
    // Every job this agent is currently running, for the heartbeat
    // thread to renew — the worker-side lifecycle mirror.
    let in_flight = WorkerLeases::new();
    let hb_stop = AtomicBool::new(false);
    // `--max-jobs` ledger, shared by every thread: a slot is claimed
    // before each lease poll and kept only when a job is granted.
    let claimed = AtomicUsize::new(0);
    eprintln!(
        "omgd worker {}: {} thread(s), gateway {}",
        opts.worker_id,
        opts.workers.max(1),
        opts.connect,
    );
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let heartbeat = s.spawn(|| {
            heartbeat_loop(opts, &in_flight, &hb_stop);
        });
        let handles: Vec<_> = (0..opts.workers.max(1))
            .map(|wid| {
                let (make, cache, store, stats, in_flight, claimed) = (
                    &make_runner,
                    &cache,
                    &store,
                    &stats,
                    &in_flight,
                    &claimed,
                );
                s.spawn(move || {
                    let mut runner = make(wid);
                    worker_thread(
                        opts, cache, store, stats, in_flight, claimed,
                        &mut runner,
                    )
                })
            })
            .collect();
        let out: Vec<Result<()>> = handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(p) => Err(anyhow!(
                    "worker thread panicked: {}",
                    panic_message(p.as_ref())
                )),
            })
            .collect();
        hb_stop.store(true, Ordering::SeqCst);
        let _ = heartbeat.join();
        out
    });
    for r in results {
        r?;
    }
    Ok(stats.snapshot())
}

/// A claimed `--max-jobs` budget slot: refunded on drop unless the
/// claim turned into a granted lease ([`Self::keep`]).
struct BudgetClaim<'a> {
    counter: &'a AtomicUsize,
    armed: bool,
}

impl BudgetClaim<'_> {
    fn keep(mut self) {
        self.armed = false;
    }
}

impl Drop for BudgetClaim<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.counter.fetch_sub(1, Ordering::SeqCst);
        }
    }
}

/// One lease-pull thread: poll → (sync, cache, run) → report over one
/// persistent keep-alive connection, until the gateway drains or
/// disappears — or the agent's `--max-jobs`/`--idle-exit` lifecycle
/// bounds are reached.
#[allow(clippy::too_many_arguments)]
fn worker_thread<F>(
    opts: &WorkerOptions,
    cache: &ResultCache,
    store: &ArtifactStore,
    stats: &StatCounters,
    in_flight: &WorkerLeases,
    claimed: &AtomicUsize,
    runner: &mut F,
) -> Result<()>
where
    F: FnMut(&JobSpec) -> Result<JobOutcome>,
{
    let mut conn = GatewayConn::new(&opts.connect, opts.token.as_deref());
    let mut failures = 0usize;
    let mut ever_connected = false;
    let mut last_work = Instant::now();
    loop {
        // `--max-jobs`: claim a budget slot up front (exact accounting
        // across threads — no overshoot); the claim is dropped back
        // unless this poll actually wins a lease.
        let budget = if opts.max_jobs > 0 {
            if claimed.fetch_add(1, Ordering::SeqCst) >= opts.max_jobs {
                claimed.fetch_sub(1, Ordering::SeqCst);
                eprintln!(
                    "omgd worker: --max-jobs {} reached; exiting",
                    opts.max_jobs
                );
                return Ok(());
            }
            Some(BudgetClaim { counter: claimed, armed: true })
        } else {
            None
        };
        let fps = store.fingerprints();
        let fps_json: Vec<String> =
            fps.iter().map(|f| format!("\"{}\"", esc(f))).collect();
        let body = format!(
            "{{\"worker\":\"{}\",\"artifacts\":[{}]}}",
            esc(&opts.worker_id),
            fps_json.join(",")
        );
        // The gateway long-polls ~20s by default; allow slack on top.
        let reply = conn.request_json(
            "POST",
            "/work/lease",
            body.as_bytes(),
            Duration::from_secs(120),
        );
        let (status, j) = match reply {
            Ok(r) => r,
            Err(_) if !ever_connected => {
                failures += 1;
                if failures > opts.max_failures {
                    bail!(
                        "gateway {} unreachable after {} attempts",
                        opts.connect,
                        failures
                    );
                }
                std::thread::sleep(backoff(failures));
                continue;
            }
            Err(e) => {
                failures += 1;
                if failures > opts.max_failures {
                    eprintln!(
                        "omgd worker: gateway {} gone ({e:#}); exiting",
                        opts.connect
                    );
                    return Ok(());
                }
                std::thread::sleep(backoff(failures));
                continue;
            }
        };
        ever_connected = true;
        failures = 0;
        match status {
            200 => {}
            503 => {
                // Connection cap; retry politely.
                std::thread::sleep(Duration::from_secs(1));
                continue;
            }
            other => {
                bail!("lease request rejected with HTTP {other}: {j:?}")
            }
        }
        if j.get("closed").and_then(Json::as_bool) == Some(true) {
            return Ok(());
        }
        if j.get("idle").and_then(Json::as_bool) == Some(true) {
            if j.get("draining").and_then(Json::as_bool) == Some(true) {
                return Ok(());
            }
            if opts.idle_exit_secs > 0
                && last_work.elapsed()
                    >= Duration::from_secs(opts.idle_exit_secs)
            {
                eprintln!(
                    "omgd worker: no work for {}s; exiting (--idle-exit)",
                    last_work.elapsed().as_secs()
                );
                return Ok(());
            }
            continue;
        }
        let Some(lease) = j.get("lease") else {
            bail!("lease response has neither lease/idle/closed: {j:?}")
        };
        if let Some(b) = budget {
            b.keep();
        }
        last_work = Instant::now();
        stats.leased.fetch_add(1, Ordering::Relaxed);
        run_lease(
            opts, &mut conn, cache, store, stats, in_flight, runner,
            lease,
        );
    }
}

/// Execute one granted lease end to end. Never returns an error — every
/// failure mode becomes a reported `failed` result (or, if even the
/// report fails, an expired lease the gateway requeues).
#[allow(clippy::too_many_arguments)]
fn run_lease<F>(
    opts: &WorkerOptions,
    conn: &mut GatewayConn,
    cache: &ResultCache,
    store: &ArtifactStore,
    stats: &StatCounters,
    in_flight: &WorkerLeases,
    runner: &mut F,
    lease: &Json,
) where
    F: FnMut(&JobSpec) -> Result<JobOutcome>,
{
    let seq = lease
        .get("seq")
        .and_then(Json::as_usize)
        .map(|s| s as u64)
        .unwrap_or(u64::MAX);
    let ttl = Duration::from_secs(
        lease
            .get("lease_secs")
            .and_then(Json::as_usize)
            .unwrap_or(60)
            .max(1) as u64,
    );
    let afp = lease
        .get("afp")
        .and_then(Json::as_str)
        .unwrap_or("absent")
        .to_string();
    // Renew at a third of the TTL; register before any slow work
    // (artifact sync included) so a long download cannot expire the
    // lease. The token ties the registration to THIS run: if this
    // lease expires and the same seq is re-leased to a sibling thread,
    // neither this run's epilogue nor its heartbeat 409 may unregister
    // the newer run's renewals — [`WorkerLeases`] enforces that.
    let token =
        in_flight.start(seq, ttl.as_secs(), Instant::now() + ttl / 3);
    let t = Timer::start();
    let (status, from_cache, phases) =
        execute_lease(opts, conn, cache, store, stats, runner, lease, &afp);
    // This run is over: drop only our own registration (token-guarded).
    in_flight.lease_gone(seq, token);
    match &status {
        JobStatus::Done(_) if from_cache => {
            stats.cached.fetch_add(1, Ordering::Relaxed);
            stats.done.fetch_add(1, Ordering::Relaxed);
        }
        JobStatus::Done(_) => {
            stats.done.fetch_add(1, Ordering::Relaxed);
        }
        _ => {
            stats.failed.fetch_add(1, Ordering::Relaxed);
        }
    }
    // Agent-side journal: one "run" span per lease, mirroring what the
    // gateway reconstructs from the wire-reported phase timings.
    let mut ev = obs::Event::new("run", seq);
    ev.worker = opts.worker_id.clone();
    ev.sync_secs = phases.sync;
    ev.run_secs = phases.run;
    ev.secs = t.total();
    obs::journal().push(ev);
    let reported =
        post_result(opts, conn, seq, &status, from_cache, t.total(), phases);
    if !reported {
        stats.conflicts.fetch_add(1, Ordering::Relaxed);
    }
    // Checkpoint lifecycle (docs/durability.md): a successfully
    // reported Done retires this spec's parked checkpoints; a dropped
    // report (lease conflict / unreachable gateway) keeps the newest
    // one parked so the next lease of the same spec resumes from it
    // instead of restarting.
    if opts.ckpt_period > 0 {
        let hash = lease.get("hash").and_then(Json::as_str).unwrap_or("");
        if reported && matches!(status, JobStatus::Done(_)) {
            if !hash.is_empty() {
                cache.clear_checkpoints(hash);
            }
        } else if !hash.is_empty()
            && cache.latest_checkpoint(hash).is_some()
        {
            obs::CKPT_PARKED.inc();
            eprintln!(
                "omgd worker: checkpoint for job {seq} parked \
                 ({hash}); its next lease resumes from it"
            );
        }
    }
}

/// The sync → cache → run core of one lease; returns the job status,
/// whether it came from the local cache, and the measured per-phase
/// durations (artifact sync / fresh run) that [`post_result`] reports
/// back to the gateway for fleet-wide aggregation.
#[allow(clippy::too_many_arguments)]
fn execute_lease<F>(
    opts: &WorkerOptions,
    conn: &mut GatewayConn,
    cache: &ResultCache,
    store: &ArtifactStore,
    stats: &StatCounters,
    runner: &mut F,
    lease: &Json,
    afp: &str,
) -> (JobStatus, bool, PhaseSecs)
where
    F: FnMut(&JobSpec) -> Result<JobOutcome>,
{
    let mut phases = PhaseSecs::default();
    let Some(wire) = lease.get("spec") else {
        return (
            JobStatus::Failed("lease carries no spec".into()),
            false,
            phases,
        );
    };
    let mut spec = match JobSpec::from_wire(wire) {
        Ok(s) => s,
        Err(e) => {
            return (
                JobStatus::Failed(format!("bad wire spec: {e:#}")),
                false,
                phases,
            )
        }
    };
    // End-to-end fidelity check: the reconstructed spec must hash to
    // exactly what the gateway leased, else the two sides would run —
    // and cache — different cells under one seq.
    let want_hash = lease.get("hash").and_then(Json::as_str).unwrap_or("");
    if spec.hash_hex() != want_hash {
        return (
            JobStatus::Failed(format!(
                "wire spec hash mismatch (got {}, lease says {want_hash}; \
                 gateway/worker version skew?)",
                spec.hash_hex()
            )),
            false,
            phases,
        );
    }
    // Artifact sync: on a gateway fingerprint, run against the synced
    // copy; `"absent"` means the gateway itself had no artifacts and
    // this worker falls back to its own local resolution.
    let cache_afp = if afp == "absent" {
        super::artifact_fingerprint(&spec.cfg)
    } else {
        let had_it = store.contains(afp);
        let sync_t = Timer::start();
        let dir = store.ensure(afp, || fetch_artifacts(conn, afp));
        match dir {
            Ok(d) => {
                if !had_it {
                    stats.synced.fetch_add(1, Ordering::Relaxed);
                    // Only a real fetch+unpack counts as sync time; a
                    // store hit is a hash lookup and reports zero.
                    phases.sync = sync_t.total();
                }
                spec.cfg.artifacts_dir = d.to_string_lossy().into_owned();
                afp.to_string()
            }
            Err(e) => {
                return (
                    JobStatus::Failed(format!(
                        "artifact sync of {afp} failed: {e:#}"
                    )),
                    false,
                    phases,
                )
            }
        }
    };
    // The gateway's `--force` travels with the lease: a recompute
    // request must defeat the worker's local cache too.
    let force = opts.force
        || lease.get("force").and_then(Json::as_bool) == Some(true);
    if force {
        cache.invalidate(&spec);
    } else if let Some(out) = cache.get(&spec, &cache_afp) {
        return (JobStatus::Done(out), true, phases);
    }
    let run_t = Timer::start();
    let run = catch_unwind(AssertUnwindSafe(|| runner(&spec)));
    phases.run = run_t.total();
    match run {
        Ok(Ok(out)) => {
            // Fault-injection seam: a worker killed here has finished
            // the run but published nothing — the gateway re-dispatches
            // on lease expiry and the rerun resumes from the newest
            // parked checkpoint.
            obs::faultpoint("artifact.publish");
            if let Err(e) = cache.put(&spec, &cache_afp, &out) {
                eprintln!(
                    "warning: cache write failed for {} ({}): {e:#}",
                    spec.label(),
                    spec.hash_hex()
                );
            }
            (JobStatus::Done(out), false, phases)
        }
        Ok(Err(e)) => (JobStatus::Failed(format!("{e:#}")), false, phases),
        Err(p) => {
            (JobStatus::Panicked(panic_message(p.as_ref())), false, phases)
        }
    }
}

/// Report one result; retried briefly because losing a finished
/// training run to a transient network blip is expensive. `false` when
/// the gateway rejected the result (lease conflict) or never took it.
/// The body carries the worker-measured per-phase durations
/// (`sync_secs` / `run_secs`) so the gateway can fold them into its
/// fleet-wide histograms; a gateway predating those fields ignores
/// them.
#[allow(clippy::too_many_arguments)]
fn post_result(
    opts: &WorkerOptions,
    conn: &mut GatewayConn,
    seq: u64,
    status: &JobStatus,
    from_cache: bool,
    secs: f64,
    phases: PhaseSecs,
) -> bool {
    // Fault-injection seam: a worker killed here has published its
    // result locally but never told the gateway — the classic
    // "crashed between checkpoint write and report" window that
    // `tests/remote.rs` drives.
    obs::faultpoint("lease.report");
    let body = match status {
        JobStatus::Done(out) => format!(
            "{{\"worker\":\"{}\",\"status\":\"done\",\"cached\":{},\
             \"secs\":{},\"sync_secs\":{},\"run_secs\":{},\
             \"outcome\":{}}}",
            esc(&opts.worker_id),
            from_cache,
            ser_f(secs),
            ser_f(phases.sync),
            ser_f(phases.run),
            cache::ser_outcome(out),
        ),
        JobStatus::Failed(e) | JobStatus::Panicked(e) => format!(
            "{{\"worker\":\"{}\",\"status\":\"{}\",\"secs\":{},\
             \"sync_secs\":{},\"run_secs\":{},\"error\":\"{}\"}}",
            esc(&opts.worker_id),
            status.tag(),
            ser_f(secs),
            ser_f(phases.sync),
            ser_f(phases.run),
            esc(e),
        ),
    };
    let path = format!("/work/{seq}/result");
    for attempt in 0..3 {
        match conn.request_json(
            "POST",
            &path,
            body.as_bytes(),
            Duration::from_secs(30),
        ) {
            Ok((200, _)) => return true,
            Ok((409, _)) => {
                eprintln!(
                    "omgd worker: result for job {seq} dropped \
                     (lease expired; job was re-dispatched)"
                );
                return false;
            }
            Ok((code, j)) => {
                eprintln!(
                    "omgd worker: result for job {seq} rejected \
                     (HTTP {code}): {j:?}"
                );
                return false;
            }
            Err(_) if attempt + 1 < 3 => {
                std::thread::sleep(Duration::from_millis(500));
            }
            Err(e) => {
                eprintln!(
                    "omgd worker: could not report job {seq} ({e:#}); \
                     the gateway will re-dispatch it on lease expiry"
                );
                return false;
            }
        }
    }
    false
}

/// One-shot `GET` against a gateway, body returned as text. Backs
/// `omgd stats --connect`, which fetches `/stats`, `/metrics`, and
/// `/events` for a fleet snapshot without holding a connection open.
pub fn gateway_get(
    addr: &str,
    path: &str,
    timeout: Duration,
) -> Result<(u16, String)> {
    let mut conn = GatewayConn::new(addr, None);
    let (status, bytes) = conn.request_bytes("GET", path, &[], timeout)?;
    Ok((status, String::from_utf8_lossy(&bytes).into_owned()))
}

fn fetch_artifacts(conn: &mut GatewayConn, fp: &str) -> Result<Vec<u8>> {
    let (status, body) = conn.request_bytes(
        "GET",
        &format!("/artifacts/{fp}"),
        &[],
        Duration::from_secs(120),
    )?;
    if status != 200 {
        bail!(
            "GET /artifacts/{fp} returned HTTP {status}: {}",
            String::from_utf8_lossy(&body)
        );
    }
    Ok(body)
}

/// Renew every in-flight lease that is due. Renewal failures are
/// tolerated silently (the job keeps running; at worst the gateway
/// re-dispatches and this worker's result is dropped as a conflict) —
/// except a `409`, which means the lease is already lost, so renewing
/// stops.
fn heartbeat_loop(
    opts: &WorkerOptions,
    in_flight: &WorkerLeases,
    stop: &AtomicBool,
) {
    let mut conn = GatewayConn::new(&opts.connect, opts.token.as_deref());
    let body = format!("{{\"worker\":\"{}\"}}", esc(&opts.worker_id));
    while !stop.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(200));
        for (seq, ttl_secs, token) in in_flight.due(Instant::now()) {
            // Only a definitive 409 means the lease is gone. Transport
            // errors and transient rejections (503 connection cap, …)
            // keep the renewal scheduled — dropping it on a blip would
            // let a healthy long job's lease expire mid-run. Either
            // outcome is applied token-guarded: it must never touch a
            // successor run's registration of the same seq.
            let lease_gone = matches!(
                conn.request_json(
                    "POST",
                    &format!("/work/{seq}/renew"),
                    body.as_bytes(),
                    Duration::from_secs(10),
                ),
                Ok((409, _))
            );
            if lease_gone {
                // Stop renewing, let the run finish — its result will
                // be dropped as stale.
                in_flight.lease_gone(seq, token);
            } else {
                in_flight.renewed(
                    seq,
                    token,
                    Instant::now() + Duration::from_secs(ttl_secs) / 3,
                );
            }
        }
    }
}

fn backoff(failures: usize) -> Duration {
    Duration::from_millis(250 * failures.min(8) as u64)
}

// ---------------------------------------------------------------------
// Remote grid submission
// ---------------------------------------------------------------------

/// Submit `specs` to a gateway as one `POST /jobs` session and collect
/// the results into a [`GridReport`] ordered like the input — the same
/// shape the local grid runner returns, so callers print/CSV
/// identically.
///
/// Each request line is `{"spec":<wire>}` (full fidelity) and each
/// ack's hash is checked against the locally-built cell, so a gateway
/// running skewed code fails loudly instead of aggregating the wrong
/// sweep. A saturated gateway (`429`) is retried with backoff over one
/// reused keep-alive connection. `client` is presented as the
/// `X-OMGD-Client` fairness token (`--client`), subjecting this grid
/// to the gateway's per-client quota.
pub fn run_grid_remote(
    addr: &str,
    specs: Vec<JobSpec>,
    client: Option<&str>,
) -> Result<GridReport> {
    run_grid_remote_auth(addr, specs, client, None)
}

/// [`run_grid_remote`] against an auth-enabled gateway: `token`
/// (`grid --remote --token`) rides every request as
/// `Authorization: Bearer <token>` — the session submission, the
/// by-seq re-polls after a broken stream, everything.
pub fn run_grid_remote_auth(
    addr: &str,
    specs: Vec<JobSpec>,
    client: Option<&str>,
    token: Option<&str>,
) -> Result<GridReport> {
    if specs.is_empty() {
        return Ok(GridReport::new(Vec::new()));
    }
    let n = specs.len();
    let mut statuses: Vec<Option<(JobStatus, bool, f64)>> = vec![None; n];
    // Gateway seq for each acked cell — the durable handle this client
    // re-polls (`GET /jobs/<seq>/result`) after a broken stream or a
    // gateway restart; the journal preserves seqs across crashes
    // (docs/durability.md).
    let mut seqs: Vec<Option<u64>> = vec![None; n];
    const SESSION_ATTEMPTS: usize = 3;
    for attempt in 0..SESSION_ATTEMPTS {
        // Submit everything never acked (first round: all cells; later
        // rounds: cells whose seq the gateway disowned with a 404).
        let todo: Vec<usize> = (0..n)
            .filter(|&i| statuses[i].is_none() && seqs[i].is_none())
            .collect();
        if !todo.is_empty() {
            match stream_session(
                addr, &specs, &todo, client, token, &mut statuses,
                &mut seqs,
            ) {
                Ok(()) => {}
                Err(e) if attempt + 1 < SESSION_ATTEMPTS => {
                    eprintln!(
                        "omgd grid: session attempt {} failed ({e:#}); \
                         reconnecting",
                        attempt + 1
                    );
                    std::thread::sleep(Duration::from_secs(1));
                }
                Err(e) => return Err(e),
            }
        }
        // Acked but unresolved (stream broke mid-results, or the
        // gateway restarted and replayed its journal): re-poll by seq.
        // A 404 clears the seq so the next round resubmits the spec.
        let pending: Vec<usize> = (0..n)
            .filter(|&i| statuses[i].is_none() && seqs[i].is_some())
            .collect();
        if !pending.is_empty() {
            poll_by_seq(addr, token, &pending, &mut statuses, &mut seqs);
        }
        if statuses.iter().all(Option::is_some) {
            break;
        }
        if attempt + 1 < SESSION_ATTEMPTS {
            let left = statuses.iter().filter(|s| s.is_none()).count();
            eprintln!(
                "omgd grid: {left} cell(s) unresolved after attempt {}; \
                 reconnecting",
                attempt + 1
            );
            std::thread::sleep(Duration::from_secs(1));
        }
    }

    let results: Vec<JobResult> = specs
        .into_iter()
        .enumerate()
        .map(|(i, spec)| {
            let (status, from_cache, secs) =
                statuses[i].take().unwrap_or((
                    JobStatus::Failed(
                        "gateway closed the stream before this cell's \
                         result arrived"
                            .into(),
                    ),
                    false,
                    0.0,
                ));
            JobResult { seq: i as u64, spec, status, from_cache, secs }
        })
        .collect();
    Ok(GridReport::new(results))
}

/// One `POST /jobs` session over the subset `todo` of `specs`, filling
/// `statuses`/`seqs` in place. Protocol violations (hash mismatch,
/// malformed lines) are hard errors; a transport break mid-stream
/// returns `Ok(())` with whatever arrived — the caller re-polls the
/// rest by seq.
fn stream_session(
    addr: &str,
    specs: &[JobSpec],
    todo: &[usize],
    client: Option<&str>,
    token: Option<&str>,
    statuses: &mut [Option<(JobStatus, bool, f64)>],
    seqs: &mut [Option<u64>],
) -> Result<()> {
    let body: String = todo
        .iter()
        .map(|&i| format!("{{\"spec\":{}}}\n", specs[i].to_wire()))
        .collect();
    // The returned reader is already positioned at the NDJSON body.
    let mut reader =
        post_jobs_with_retry(addr, body.as_bytes(), client, token)?;

    // seq (gateway) → index (ours). Acks and rejects arrive in request
    // order, so the n-th ack-or-reject line belongs to todo[n].
    let mut seq_to_idx: HashMap<u64, usize> = HashMap::new();
    let mut next = 0usize;
    let mut line = String::new();
    loop {
        line.clear();
        let read = match reader.read_line(&mut line) {
            Ok(0) => return Ok(()), // gateway closed the stream
            Ok(read) => read,
            // Mid-stream transport loss (gateway killed, connection
            // reset): keep the partial session; acked seqs survive in
            // the gateway's journal and are re-polled.
            Err(e) => {
                eprintln!("omgd grid: result stream broke ({e})");
                return Ok(());
            }
        };
        let _ = read;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let j = Json::parse(text).map_err(|e| {
            anyhow!("gateway sent a non-JSON line {text:?}: {e}")
        })?;
        if let Some(seq) = j.get("accepted").and_then(Json::as_usize) {
            if next >= todo.len() {
                bail!("gateway acked more jobs than were submitted");
            }
            let idx = todo[next];
            let want = specs[idx].hash_hex();
            let got = j.get("hash").and_then(Json::as_str).unwrap_or("");
            if got != want {
                bail!(
                    "spec hash mismatch on cell {idx} \
                     ({}): ours {want}, gateway {got} — version skew?",
                    specs[idx].label()
                );
            }
            seqs[idx] = Some(seq as u64);
            seq_to_idx.insert(seq as u64, idx);
            next += 1;
        } else if j.get("status").and_then(Json::as_str).is_some() {
            let seq = j
                .get("seq")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("result line without seq"))? as u64;
            let idx = *seq_to_idx
                .get(&seq)
                .ok_or_else(|| anyhow!("result for unknown seq {seq}"))?;
            statuses[idx] = Some(parse_result_json(&j)?);
        } else if let Some(msg) = j.get("error").and_then(Json::as_str) {
            // Reject line: consumes the next request slot.
            if next >= todo.len() {
                bail!("gateway rejected more lines than were submitted");
            }
            statuses[todo[next]] =
                Some((JobStatus::Failed(msg.to_string()), false, 0.0));
            next += 1;
        } else {
            bail!("unrecognized stream line {text:?}");
        }
    }
}

/// Re-poll unresolved-but-acked cells via `GET /jobs/<seq>/result`.
/// `200` records the result, `404` forgets the seq (the caller
/// resubmits the spec), `202` means the replayed job is still queued or
/// running — poll until the budget runs out. Best-effort by design:
/// transport errors burn budget instead of failing the grid.
fn poll_by_seq(
    addr: &str,
    token: Option<&str>,
    pending: &[usize],
    statuses: &mut [Option<(JobStatus, bool, f64)>],
    seqs: &mut [Option<u64>],
) {
    // Generous budget: a recovered job may still be *running* after a
    // gateway restart and a long train step takes real time.
    const POLL_BUDGET: usize = 600;
    const ERR_BUDGET: usize = 30;
    let mut conn = GatewayConn::new(addr, token);
    for &i in pending {
        let Some(seq) = seqs[i] else { continue };
        let path = format!("/jobs/{seq}/result");
        let mut errs = 0usize;
        for _ in 0..POLL_BUDGET {
            match conn.request_json(
                "GET",
                &path,
                &[],
                Duration::from_secs(10),
            ) {
                Ok((200, j)) => {
                    match parse_result_json(&j) {
                        Ok(r) => statuses[i] = Some(r),
                        Err(_) => seqs[i] = None,
                    }
                    break;
                }
                Ok((404, _)) => {
                    // The gateway (or its journal) no longer knows this
                    // seq: resubmit the spec from scratch.
                    seqs[i] = None;
                    break;
                }
                Ok((202, _)) => {
                    std::thread::sleep(Duration::from_millis(500));
                }
                Ok((_, _)) => {
                    errs += 1;
                    if errs >= ERR_BUDGET {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(500));
                }
                Err(_) => {
                    errs += 1;
                    if errs >= ERR_BUDGET {
                        break;
                    }
                    std::thread::sleep(Duration::from_secs(1));
                }
            }
        }
    }
}

/// Decode one result JSON (a session result line or a
/// `GET /jobs/<seq>/result` body — same shape) into the
/// `(status, cached, secs)` triple the grid report stores.
fn parse_result_json(j: &Json) -> Result<(JobStatus, bool, f64)> {
    let tag = j
        .get("status")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("result without status"))?;
    let err = || {
        j.get("error")
            .and_then(Json::as_str)
            .unwrap_or("remote failure")
            .to_string()
    };
    let status = match tag {
        "done" => JobStatus::Done(outcome_from_result(j)),
        "failed" => JobStatus::Failed(err()),
        "panicked" => JobStatus::Panicked(err()),
        other => bail!("unknown result status {other:?}"),
    };
    let cached = j.get("cached").and_then(Json::as_bool).unwrap_or(false);
    let secs = j.get("secs").and_then(Json::as_f64).unwrap_or(0.0);
    Ok((status, cached, secs))
}

/// The deterministic outcome slice carried by a result line. Loss/eval
/// series are not streamed (they live in the gateway-side cache), so
/// curve CSVs require a local run; the aggregate CSV needs only these.
fn outcome_from_result(j: &Json) -> JobOutcome {
    let f = |k: &str| match j.get(k) {
        Some(Json::Null) => f64::NAN,
        Some(v) => v.as_f64().unwrap_or(f64::NAN),
        None => f64::NAN,
    };
    JobOutcome {
        final_metric: f("final_metric"),
        tail_loss: f("tail_loss"),
        steps: j.get("steps").and_then(Json::as_usize).unwrap_or(0),
        train_secs: f("secs"),
        loss_series: Vec::new(),
        eval_series: Vec::new(),
    }
}

/// POST the session body, honoring `429 Retry-After` with bounded
/// retries on ONE reused keep-alive connection; on `200` returns a
/// reader positioned at the start of the NDJSON body (chunked streams
/// are transparently decoded, close-delimited streams read to EOF).
fn post_jobs_with_retry(
    addr: &str,
    body: &[u8],
    client: Option<&str>,
    token: Option<&str>,
) -> Result<Box<dyn BufRead>> {
    const MAX_RETRIES: usize = 30;
    let client_hdr = client
        .map(|c| format!("X-OMGD-Client: {c}\r\n"))
        .unwrap_or_default();
    let auth_hdr = bearer_header(token);
    let mut conn: Option<BufReader<TcpStream>> = None;
    let mut attempt = 0usize;
    let mut stale_retries = 0usize;
    loop {
        let reused = conn.is_some();
        let mut reader = match conn.take() {
            Some(r) => r,
            None => {
                let stream = connect(addr)?;
                // Results can be minutes apart mid-grid: no read
                // timeout on the session stream (a dead gateway still
                // EOFs via TCP).
                stream
                    .set_write_timeout(Some(Duration::from_secs(60)))
                    .ok();
                BufReader::new(stream)
            }
        };
        let round =
            submit_jobs_round(&mut reader, body, &client_hdr, &auth_hdr);
        let (status, headers) = match round {
            Ok(x) => x,
            // A reused connection the gateway idle-closed between
            // retry rounds is expected — one fresh reconnect; a fresh
            // connection's failure is real.
            Err(_) if reused && stale_retries < 3 => {
                stale_retries += 1;
                continue;
            }
            Err(e) => return Err(e).context("submitting the grid"),
        };
        let chunked = headers
            .get("transfer-encoding")
            .map(|v| v.to_ascii_lowercase().contains("chunked"))
            .unwrap_or(false);
        let keep = headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false);
        match status {
            200 if chunked => {
                return Ok(Box::new(BufReader::new(ChunkedReader::new(
                    reader,
                ))))
            }
            200 => return Ok(Box::new(reader)),
            // Retry only transient rejections, which carry Retry-After
            // (queue saturation / client quota 429, connection-cap
            // 503). The gateway's drain-mode 503 has no Retry-After
            // and never reverts — fail it immediately instead of
            // resubmitting for ~30s.
            429 | 503 if headers.contains_key("retry-after") => {
                if attempt >= MAX_RETRIES {
                    bail!(
                        "gateway stayed saturated after {MAX_RETRIES} \
                         retries (HTTP {status})"
                    );
                }
                attempt += 1;
                let secs = headers
                    .get("retry-after")
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(1);
                eprintln!(
                    "gateway busy (HTTP {status}); retrying in {secs}s \
                     [{attempt}/{MAX_RETRIES}]"
                );
                // Keep the connection across the retry round when the
                // gateway kept it: drain the (Content-Length-framed)
                // error body so the next response starts cleanly.
                let len = headers
                    .get("content-length")
                    .and_then(|v| v.parse::<usize>().ok());
                if keep {
                    if let Some(len) = len {
                        let mut buf = vec![0u8; len];
                        if reader.read_exact(&mut buf).is_ok() {
                            conn = Some(reader);
                        }
                    }
                }
                std::thread::sleep(Duration::from_secs(secs.clamp(1, 30)));
            }
            other => {
                let mut body = String::new();
                if let Some(len) = headers
                    .get("content-length")
                    .and_then(|v| v.parse::<usize>().ok())
                {
                    let mut buf = vec![0u8; len.min(64 << 10)];
                    let _ = reader.read_exact(&mut buf);
                    body = String::from_utf8_lossy(&buf).into_owned();
                }
                bail!("gateway rejected the grid (HTTP {other}): {body}");
            }
        }
    }
}

/// One submission round of [`post_jobs_with_retry`]: write the
/// `POST /jobs` request on the (possibly reused) connection and parse
/// the response head. The request body goes out with
/// `Transfer-Encoding: chunked`, one chunk per NDJSON line — the
/// submitter never announces a total size, so an open-ended spec
/// stream could ride the same wire shape.
fn submit_jobs_round(
    reader: &mut BufReader<TcpStream>,
    body: &[u8],
    client_hdr: &str,
    auth_hdr: &str,
) -> Result<(u16, HashMap<String, String>)> {
    {
        // One chunk per spec line is the wire shape; the chunk framing
        // is written into a BufWriter so the whole submission still
        // goes out in large writes instead of three small syscalls per
        // line.
        let mut sw = std::io::BufWriter::new(reader.get_ref());
        write!(
            sw,
            "POST /jobs HTTP/1.1\r\nHost: omgd\r\nContent-Type: \
             application/x-ndjson\r\nTransfer-Encoding: chunked\r\n\
             {client_hdr}{auth_hdr}Connection: keep-alive\r\n\r\n",
        )?;
        for line in body.split_inclusive(|&b| b == b'\n') {
            write!(sw, "{:x}\r\n", line.len())?;
            sw.write_all(line)?;
            sw.write_all(b"\r\n")?;
        }
        sw.write_all(b"0\r\n\r\n")?; // terminal chunk
        sw.flush()?;
    }
    let mut status_line = String::new();
    if reader.read_line(&mut status_line)? == 0 {
        bail!("gateway closed the connection before responding");
    }
    let status = parse_status_line(&status_line)?;
    let headers = read_headers(reader)?;
    Ok((status, headers))
}

// ---------------------------------------------------------------------
// Minimal HTTP/1.1 client (std::net only)
// ---------------------------------------------------------------------

use super::net::ChunkedReader;

fn connect(addr: &str) -> Result<TcpStream> {
    TcpStream::connect(addr)
        .with_context(|| format!("connecting to gateway {addr}"))
}

/// `Authorization: Bearer <token>\r\n` as a ready-to-splice header
/// line, or empty when no token is configured — the same shape the
/// `X-OMGD-Client` header uses.
fn bearer_header(token: Option<&str>) -> String {
    token
        .map(|t| format!("Authorization: Bearer {t}\r\n"))
        .unwrap_or_default()
}

/// One persistent keep-alive connection to the gateway for the
/// worker-protocol endpoints. Every request announces
/// `Connection: keep-alive`; as long as the gateway answers in kind
/// with a `Content-Length`-framed body, the socket is reused for the
/// next round — lease, renew, result, and artifact fetches all ride
/// one connection per thread instead of a TCP handshake per request.
/// A cached connection that died between rounds (gateway idle timeout,
/// network blip) is retried once on a fresh socket.
struct GatewayConn {
    addr: String,
    /// Pre-rendered `Authorization` header line ([`bearer_header`]);
    /// empty for an open gateway.
    auth_hdr: String,
    stream: Option<BufReader<TcpStream>>,
}

impl GatewayConn {
    fn new(addr: &str, token: Option<&str>) -> Self {
        Self {
            addr: addr.to_string(),
            auth_hdr: bearer_header(token),
            stream: None,
        }
    }

    /// One request/response round trip; the response body is read
    /// fully (via `Content-Length`, else to EOF, which also retires
    /// the connection).
    fn request_bytes(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        timeout: Duration,
    ) -> Result<(u16, Vec<u8>)> {
        loop {
            let reused = self.stream.is_some();
            if self.stream.is_none() {
                self.stream = Some(BufReader::new(connect(&self.addr)?));
            }
            match self.round_trip(method, path, body, timeout) {
                Ok(out) => return Ok(out),
                Err(e) => {
                    self.stream = None;
                    if !reused {
                        return Err(e);
                    }
                    // Stale keep-alive connection: fresh socket, one
                    // more try.
                }
            }
        }
    }

    /// [`Self::request_bytes`] with the response parsed as JSON.
    fn request_json(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        timeout: Duration,
    ) -> Result<(u16, Json)> {
        let (status, bytes) =
            self.request_bytes(method, path, body, timeout)?;
        let text = String::from_utf8_lossy(&bytes);
        let j = Json::parse(text.trim()).map_err(|e| {
            anyhow!("gateway sent non-JSON ({e}): {:?}", text.trim())
        })?;
        Ok((status, j))
    }

    fn round_trip(
        &mut self,
        method: &str,
        path: &str,
        body: &[u8],
        timeout: Duration,
    ) -> Result<(u16, Vec<u8>)> {
        let auth_hdr = self.auth_hdr.clone();
        let reader =
            self.stream.as_mut().expect("round_trip needs a connection");
        reader.get_ref().set_read_timeout(Some(timeout)).ok();
        reader.get_ref().set_write_timeout(Some(timeout)).ok();
        {
            let mut sw = reader.get_ref();
            write!(
                sw,
                "{method} {path} HTTP/1.1\r\nHost: omgd\r\n\
                 Content-Type: application/json\r\nContent-Length: {}\
                 \r\n{auth_hdr}Connection: keep-alive\r\n\r\n",
                body.len()
            )?;
            sw.write_all(body)?;
            sw.flush()?;
        }
        let mut status_line = String::new();
        if reader
            .read_line(&mut status_line)
            .context("reading status")?
            == 0
        {
            bail!("gateway closed the connection");
        }
        let status = parse_status_line(&status_line)?;
        let headers = read_headers(reader)?;
        let keep = headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("keep-alive"))
            .unwrap_or(false);
        let body = match headers
            .get("content-length")
            .and_then(|v| v.parse::<usize>().ok())
        {
            Some(len) => {
                let mut buf = vec![0u8; len];
                reader
                    .read_exact(&mut buf)
                    .context("reading response body")?;
                buf
            }
            None => {
                let mut buf = Vec::new();
                reader
                    .read_to_end(&mut buf)
                    .context("reading response body")?;
                self.stream = None; // EOF-delimited: socket is spent
                return Ok((status, buf));
            }
        };
        if !keep {
            self.stream = None;
        }
        Ok((status, body))
    }
}

fn parse_status_line(line: &str) -> Result<u16> {
    let code = line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse::<u16>().ok());
    code.ok_or_else(|| anyhow!("malformed HTTP status line {line:?}"))
}

/// Read response headers up to the blank line; names lowercased.
fn read_headers<R: BufRead>(
    reader: &mut R,
) -> Result<HashMap<String, String>> {
    let mut headers = HashMap::new();
    for _ in 0..100 {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            bail!("eof inside response headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            return Ok(headers);
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(
                k.trim().to_ascii_lowercase(),
                v.trim().to_string(),
            );
        }
    }
    bail!("too many response headers")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::spec::ExperimentKind;

    #[test]
    fn status_lines_parse() {
        assert_eq!(parse_status_line("HTTP/1.1 200 OK\r\n").unwrap(), 200);
        assert_eq!(
            parse_status_line("HTTP/1.1 429 Too Many Requests").unwrap(),
            429
        );
        assert!(parse_status_line("garbage").is_err());
        assert!(parse_status_line("").is_err());
    }

    #[test]
    fn response_headers_parse_and_lowercase() {
        let raw = "Content-Length: 12\r\nRetry-After: 1\r\n\r\nBODY";
        let mut r = raw.as_bytes();
        let h = read_headers(&mut r).unwrap();
        assert_eq!(h.get("content-length").map(String::as_str), Some("12"));
        assert_eq!(h.get("retry-after").map(String::as_str), Some("1"));
        assert!(read_headers(&mut "no terminator".as_bytes()).is_err());
    }

    #[test]
    fn result_outcomes_tolerate_null_metrics() {
        let j = Json::parse(
            "{\"seq\":0,\"status\":\"done\",\"final_metric\":null,\
             \"tail_loss\":0.5,\"steps\":7,\"secs\":1.25}",
        )
        .unwrap();
        let o = outcome_from_result(&j);
        assert!(o.final_metric.is_nan());
        assert_eq!(o.tail_loss, 0.5);
        assert_eq!(o.steps, 7);
    }

    #[test]
    fn bearer_headers_render_as_splice_ready_lines() {
        assert_eq!(bearer_header(None), "");
        assert_eq!(
            bearer_header(Some("s3cret")),
            "Authorization: Bearer s3cret\r\n"
        );
    }

    #[test]
    fn worker_ids_are_process_unique() {
        let id = default_worker_id();
        assert!(id.ends_with(&format!("-{}", std::process::id())));
    }

    #[test]
    fn empty_remote_grid_short_circuits() {
        // No gateway needed: zero cells is a complete report.
        let report =
            run_grid_remote("127.0.0.1:1", Vec::new(), None).unwrap();
        assert_eq!(report.n_jobs(), 0);
    }

    #[test]
    fn unreachable_gateway_is_an_error_not_a_hang() {
        let spec = JobSpec {
            kind: ExperimentKind::Pretrain,
            cfg: RunConfig::default(),
        };
        // Port 1 is essentially never listening; connect must fail
        // fast with a contextual error.
        let err = run_grid_remote("127.0.0.1:1", vec![spec], None)
            .unwrap_err();
        assert!(format!("{err:#}").contains("connecting to gateway"));
    }
}
