//! Crash-safe job journal: the write-ahead record log behind a
//! durable gateway.
//!
//! One append-only, fsynced text file (`journal.log`, under the cache
//! dir) records every job-lifecycle transition the [`JobHub`] makes:
//! admission, lease grant/renewal, completion, cancellation. At
//! startup `omgd serve` replays the log — tolerating a torn final
//! record, the only kind of damage an fsynced append can leave — to
//! rebuild the seq counter, the pending queue, and the completed-result
//! table, so a reconnecting `grid --remote` client can re-poll results
//! by seq across a coordinator crash.
//!
//! Record grammar (one record per line, documented in
//! `docs/durability.md`):
//!
//! ```text
//! <fnv1a64-hex-16> <json>\n
//! ```
//!
//! The checksum is FNV-1a over the JSON text, so replay detects a torn
//! tail byte-exactly. The JSON object carries a `"rec"` discriminator:
//!
//! * `meta`   — `{"rec":"meta","next_seq":N}` (compaction header)
//! * `admit`  — seq, priority, optional client token, full wire spec
//! * `lease`  — seq + worker id (replayed for the seq counter only:
//!   leases die with the process, the job re-dispatches)
//! * `renew`  — seq + worker id (ditto)
//! * `done`   — seq, status tag, cached flag, secs, wire spec, and the
//!   outcome (or error), self-contained so a re-poll needs no cache
//! * `cancel` — seq
//!
//! Compaction (clean shutdown and post-replay startup) rewrites the
//! log as one `meta` header plus the still-live `admit`s and retained
//! `done`s, via temp-file + durable rename, then truncating history.
//!
//! [`JobHub`]: super::serve::JobHub

use super::pool::{JobResult, JobStatus};
use omgd_util::lock_recover;
use super::spec::{fnv1a64, JobSpec};
use crate::obs;
use crate::util::json::{escape_str as esc, ser_f64 as ser_f, Json};
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

/// Journal file name inside the cache directory.
pub const JOURNAL_FILE: &str = "journal.log";

/// One journal record (see the module docs for the line grammar).
#[derive(Clone, Debug)]
pub enum Record {
    /// Compaction header: the seq counter to resume from.
    Meta { next_seq: u64 },
    /// A job entered the queue.
    Admit {
        seq: u64,
        priority: i32,
        client: Option<String>,
        spec: JobSpec,
    },
    /// A remote worker was granted the lease on `seq`.
    Lease { seq: u64, worker: String },
    /// The lease on `seq` was renewed.
    Renew { seq: u64, worker: String },
    /// The job completed (any status) and was dispatched.
    Done {
        seq: u64,
        status: JobStatus,
        from_cache: bool,
        secs: f64,
        spec: JobSpec,
    },
    /// The job was cancelled before completion.
    Cancel { seq: u64 },
}

impl Record {
    /// The JSON payload (no checksum, no newline).
    pub fn encode_json(&self) -> String {
        match self {
            Record::Meta { next_seq } => {
                format!("{{\"rec\":\"meta\",\"next_seq\":{next_seq}}}")
            }
            Record::Admit { seq, priority, client, spec } => {
                let client_part = client
                    .as_deref()
                    .map(|c| format!("\"client\":\"{}\",", esc(c)))
                    .unwrap_or_default();
                format!(
                    "{{\"rec\":\"admit\",\"seq\":{seq},\
                     \"pri\":{priority},{client_part}\"spec\":{}}}",
                    spec.to_wire()
                )
            }
            Record::Lease { seq, worker } => format!(
                "{{\"rec\":\"lease\",\"seq\":{seq},\"worker\":\"{}\"}}",
                esc(worker)
            ),
            Record::Renew { seq, worker } => format!(
                "{{\"rec\":\"renew\",\"seq\":{seq},\"worker\":\"{}\"}}",
                esc(worker)
            ),
            Record::Done { seq, status, from_cache, secs, spec } => {
                let payload = match status {
                    JobStatus::Done(o) => format!(
                        "\"outcome\":{}",
                        super::cache::ser_outcome(o)
                    ),
                    JobStatus::Failed(e) | JobStatus::Panicked(e) => {
                        format!("\"error\":\"{}\"", esc(e))
                    }
                };
                format!(
                    "{{\"rec\":\"done\",\"seq\":{seq},\
                     \"status\":\"{}\",\"cached\":{from_cache},\
                     \"secs\":{},\"spec\":{},{payload}}}",
                    status.tag(),
                    ser_f(*secs),
                    spec.to_wire(),
                )
            }
            Record::Cancel { seq } => {
                format!("{{\"rec\":\"cancel\",\"seq\":{seq}}}")
            }
        }
    }

    /// The full checksummed journal line, newline included.
    pub fn encode_line(&self) -> String {
        let json = self.encode_json();
        format!("{:016x} {json}\n", fnv1a64(json.as_bytes()))
    }

    /// Decode one journal line (without trailing newline). `None` on a
    /// short/torn line, checksum mismatch, or malformed record — the
    /// caller treats any of those as the torn tail and stops.
    pub fn decode_line(line: &str) -> Option<Record> {
        let (sum, json) = line.split_once(' ')?;
        if sum.len() != 16 {
            return None;
        }
        let sum = u64::from_str_radix(sum, 16).ok()?;
        if sum != fnv1a64(json.as_bytes()) {
            return None;
        }
        Self::decode_json(&Json::parse(json).ok()?)
    }

    fn decode_json(j: &Json) -> Option<Record> {
        let seq_of = |j: &Json| -> Option<u64> {
            Some(j.get("seq")?.as_f64()? as u64)
        };
        match j.get("rec")?.as_str()? {
            "meta" => Some(Record::Meta {
                next_seq: j.get("next_seq")?.as_f64()? as u64,
            }),
            "admit" => Some(Record::Admit {
                seq: seq_of(j)?,
                priority: j.get("pri")?.as_f64()? as i32,
                client: j
                    .get("client")
                    .and_then(Json::as_str)
                    .map(String::from),
                spec: JobSpec::from_wire(j.get("spec")?).ok()?,
            }),
            "lease" => Some(Record::Lease {
                seq: seq_of(j)?,
                worker: j.get("worker")?.as_str()?.to_string(),
            }),
            "renew" => Some(Record::Renew {
                seq: seq_of(j)?,
                worker: j.get("worker")?.as_str()?.to_string(),
            }),
            "done" => {
                let status = match j.get("status")?.as_str()? {
                    "done" => JobStatus::Done(
                        super::cache::parse_outcome(j.get("outcome")?)?,
                    ),
                    "failed" => JobStatus::Failed(
                        j.get("error")?.as_str()?.to_string(),
                    ),
                    "panicked" => JobStatus::Panicked(
                        j.get("error")?.as_str()?.to_string(),
                    ),
                    _ => return None,
                };
                Some(Record::Done {
                    seq: seq_of(j)?,
                    status,
                    from_cache: j.get("cached")?.as_bool()?,
                    secs: match j.get("secs")? {
                        Json::Null => f64::NAN,
                        v => v.as_f64()?,
                    },
                    spec: JobSpec::from_wire(j.get("spec")?).ok()?,
                })
            }
            "cancel" => Some(Record::Cancel { seq: seq_of(j)? }),
            _ => None,
        }
    }
}

/// One still-pending admission out of a replay.
#[derive(Clone, Debug)]
pub struct PendingJob {
    pub seq: u64,
    pub priority: i32,
    pub client: Option<String>,
    pub spec: JobSpec,
}

/// Rebuilt hub state after replaying a journal.
#[derive(Debug, Default)]
pub struct Replay {
    /// Seq counter to resume from: strictly above every seq the log
    /// ever mentioned.
    pub next_seq: u64,
    /// Admitted jobs with no `done`/`cancel` yet, in seq order.
    pub pending: Vec<PendingJob>,
    /// Completed jobs retained for by-seq re-polls, in seq order.
    pub completed: Vec<JobResult>,
    /// Records successfully replayed.
    pub replayed: usize,
    /// 1 when a torn/corrupt tail record was dropped, else 0.
    pub torn: usize,
}

/// Replay the journal at `path`. A missing file is an empty replay. A
/// torn or corrupt record ends the replay there (everything before it
/// is kept; it and anything after are dropped) — with fsynced appends
/// only the final record can be torn, so this loses at most one
/// unacknowledged transition.
pub fn replay(path: &Path) -> Result<Replay> {
    let mut out = Replay::default();
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(out)
        }
        Err(e) => {
            return Err(e).context(format!("reading journal {path:?}"))
        }
    };
    // A torn tail may not be valid UTF-8; lossy decoding mangles only
    // bytes the checksum then rejects anyway.
    let text = String::from_utf8_lossy(&bytes);
    let mut pending: BTreeMap<u64, PendingJob> = BTreeMap::new();
    let mut completed: BTreeMap<u64, JobResult> = BTreeMap::new();
    for line in text.split('\n') {
        if line.is_empty() {
            continue;
        }
        let Some(rec) = Record::decode_line(line) else {
            out.torn = 1;
            obs::JOURNAL_TORN.inc();
            break;
        };
        out.replayed += 1;
        obs::JOURNAL_REPLAYED.inc();
        match rec {
            Record::Meta { next_seq } => {
                out.next_seq = out.next_seq.max(next_seq);
            }
            Record::Admit { seq, priority, client, spec } => {
                out.next_seq = out.next_seq.max(seq + 1);
                // Admits are fsynced outside the hub's dispatch path, so
                // an ultra-fast (cached) job can land its `done` record
                // first; the seq is finished either way.
                if !completed.contains_key(&seq) {
                    pending.insert(
                        seq,
                        PendingJob { seq, priority, client, spec },
                    );
                }
            }
            Record::Lease { seq, .. } | Record::Renew { seq, .. } => {
                // Leases die with the process; the admit stays pending
                // and re-dispatches. Only the counter survives.
                out.next_seq = out.next_seq.max(seq + 1);
            }
            Record::Done { seq, status, from_cache, secs, spec } => {
                out.next_seq = out.next_seq.max(seq + 1);
                pending.remove(&seq);
                // First completion wins — exactly-once dispatch means a
                // duplicate can only be a replayed compaction artifact.
                completed.entry(seq).or_insert(JobResult {
                    seq,
                    spec,
                    status,
                    from_cache,
                    secs,
                });
            }
            Record::Cancel { seq } => {
                out.next_seq = out.next_seq.max(seq + 1);
                pending.remove(&seq);
            }
        }
    }
    out.pending = pending.into_values().collect();
    out.completed = completed.into_values().collect();
    Ok(out)
}

/// Spec hashes of every job a replay still considers live (admitted,
/// not done/cancelled) — the set whose parked checkpoints
/// [`ResultCache::gc`] must not evict.
///
/// [`ResultCache::gc`]: super::cache::ResultCache::gc
pub fn live_hashes(rep: &Replay) -> std::collections::HashSet<String> {
    rep.pending.iter().map(|p| p.spec.hash_hex()).collect()
}

/// Handle to one open journal file. Appends are serialized by a mutex
/// and fsynced before returning, so an acknowledged record survives
/// SIGKILL.
pub struct JobJournal {
    path: PathBuf,
    file: Mutex<File>,
}

impl JobJournal {
    /// Where the journal lives for a given cache dir.
    pub fn path_in(cache_dir: &Path) -> PathBuf {
        cache_dir.join(JOURNAL_FILE)
    }

    /// Open (creating if needed) the journal under `cache_dir`.
    pub fn open(cache_dir: &Path) -> Result<JobJournal> {
        std::fs::create_dir_all(cache_dir).with_context(|| {
            format!("creating journal dir {cache_dir:?}")
        })?;
        let path = Self::path_in(cache_dir);
        let file = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .with_context(|| format!("opening journal {path:?}"))?;
        Ok(JobJournal { path, file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Append one record and fsync it. The `journal.append` faultpoint
    /// fires *before* the write: a killed-here admission is simply
    /// absent after restart — the client was never acked, so it
    /// resubmits.
    pub fn append(&self, rec: &Record) -> Result<()> {
        obs::faultpoint("journal.append");
        let line = rec.encode_line();
        let mut f = lock_recover(&self.file);
        f.write_all(line.as_bytes())
            .with_context(|| format!("appending to {:?}", self.path))?;
        f.sync_data()
            .with_context(|| format!("fsyncing {:?}", self.path))?;
        obs::JOURNAL_RECORDS.inc();
        Ok(())
    }

    /// Rewrite the log as a snapshot of live state — one `meta` header,
    /// the pending `admit`s, the retained `done`s — truncating all
    /// replayed history. Runs at startup (right after replay) and on
    /// clean shutdown. Atomic: temp file + fsync + rename; a crash
    /// mid-compaction leaves the old log intact.
    pub fn compact(
        &self,
        next_seq: u64,
        pending: &[PendingJob],
        completed: &[JobResult],
    ) -> Result<()> {
        let mut guard = lock_recover(&self.file);
        let tmp = self.path.with_extension("log.compact");
        {
            let mut w = std::io::BufWriter::new(
                File::create(&tmp).with_context(|| {
                    format!("creating compaction temp {tmp:?}")
                })?,
            );
            w.write_all(
                Record::Meta { next_seq }.encode_line().as_bytes(),
            )?;
            for p in pending {
                let rec = Record::Admit {
                    seq: p.seq,
                    priority: p.priority,
                    client: p.client.clone(),
                    spec: p.spec.clone(),
                };
                w.write_all(rec.encode_line().as_bytes())?;
            }
            for r in completed {
                let rec = Record::Done {
                    seq: r.seq,
                    status: r.status.clone(),
                    from_cache: r.from_cache,
                    secs: r.secs,
                    spec: r.spec.clone(),
                };
                w.write_all(rec.encode_line().as_bytes())?;
            }
            w.flush()?;
            w.get_ref().sync_data()?;
        }
        std::fs::rename(&tmp, &self.path)
            .with_context(|| format!("publishing {:?}", self.path))?;
        *guard = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("reopening {:?}", self.path))?;
        obs::JOURNAL_COMPACTIONS.inc();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::pool::JobOutcome;
    use crate::spec::ExperimentKind;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "omgd-journal-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        JobSpec {
            kind: ExperimentKind::Finetune {
                task: "CoLA".into(),
                epochs: 2,
            },
            cfg,
        }
    }

    fn done(seq: u64, seed: u64) -> Record {
        Record::Done {
            seq,
            status: JobStatus::Done(JobOutcome {
                final_metric: seed as f64 + 0.5,
                tail_loss: 0.25,
                steps: 3,
                train_secs: 1.0,
                loss_series: vec![(0, 2.0)],
                eval_series: vec![(1, 1.0, 50.0)],
            }),
            from_cache: false,
            secs: 0.75,
            spec: spec(seed),
        }
    }

    fn admit(seq: u64, seed: u64, client: Option<&str>) -> Record {
        Record::Admit {
            seq,
            priority: 2,
            client: client.map(String::from),
            spec: spec(seed),
        }
    }

    #[test]
    fn every_record_kind_round_trips_through_a_line() {
        let recs = vec![
            Record::Meta { next_seq: 42 },
            admit(1, 7, Some("grid-a")),
            admit(2, 8, None),
            Record::Lease { seq: 1, worker: "w-1".into() },
            Record::Renew { seq: 1, worker: "w-1".into() },
            done(1, 7),
            Record::Done {
                seq: 2,
                status: JobStatus::Failed("boom \"quoted\"".into()),
                from_cache: false,
                secs: 0.0,
                spec: spec(8),
            },
            Record::Cancel { seq: 3 },
        ];
        for r in recs {
            let line = r.encode_line();
            let back = Record::decode_line(line.trim_end())
                .unwrap_or_else(|| panic!("decode failed: {line}"));
            assert_eq!(
                back.encode_json(),
                r.encode_json(),
                "round trip changed the record"
            );
        }
    }

    #[test]
    fn corrupt_lines_are_rejected() {
        let line = done(1, 7).encode_line();
        let line = line.trim_end();
        assert!(Record::decode_line(line).is_some());
        // flip one payload byte → checksum mismatch
        let mut bad = line.to_string();
        let i = bad.len() - 3;
        bad.replace_range(i..i + 1, "X");
        assert!(Record::decode_line(&bad).is_none());
        // short/garbage lines
        assert!(Record::decode_line("").is_none());
        assert!(Record::decode_line("nonsense").is_none());
        assert!(Record::decode_line("0123 {\"rec\":\"meta\"}").is_none());
        // valid checksum over a non-record payload
        let json = "{\"rec\":\"wat\"}";
        let l = format!("{:016x} {json}", fnv1a64(json.as_bytes()));
        assert!(Record::decode_line(&l).is_none());
    }

    #[test]
    fn append_replay_rebuilds_pending_and_completed() {
        let dir = tmp_dir("replay");
        let j = JobJournal::open(&dir).unwrap();
        j.append(&admit(0, 10, Some("a"))).unwrap();
        j.append(&admit(1, 11, None)).unwrap();
        j.append(&Record::Lease { seq: 0, worker: "w".into() })
            .unwrap();
        j.append(&Record::Renew { seq: 0, worker: "w".into() })
            .unwrap();
        j.append(&done(0, 10)).unwrap();
        j.append(&admit(2, 12, Some("a"))).unwrap();
        j.append(&Record::Cancel { seq: 2 }).unwrap();
        let rep = replay(j.path()).unwrap();
        assert_eq!(rep.next_seq, 3);
        assert_eq!(rep.replayed, 7);
        assert_eq!(rep.torn, 0);
        // seq 0 done, seq 2 cancelled → only seq 1 pending
        assert_eq!(rep.pending.len(), 1);
        assert_eq!(rep.pending[0].seq, 1);
        assert_eq!(rep.pending[0].priority, 2);
        assert_eq!(rep.pending[0].spec.cfg.seed, 11);
        assert_eq!(rep.completed.len(), 1);
        assert_eq!(rep.completed[0].seq, 0);
        assert!(rep.completed[0].is_ok());
        assert_eq!(
            live_hashes(&rep)
                .into_iter()
                .collect::<Vec<_>>(),
            vec![spec(11).hash_hex()]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_journal_is_an_empty_replay() {
        let dir = tmp_dir("missing");
        let rep = replay(&JobJournal::path_in(&dir)).unwrap();
        assert_eq!(rep.next_seq, 0);
        assert!(rep.pending.is_empty());
        assert!(rep.completed.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_dropped_at_every_byte_boundary() {
        let dir = tmp_dir("torn");
        let j = JobJournal::open(&dir).unwrap();
        j.append(&admit(0, 20, None)).unwrap();
        j.append(&done(0, 20)).unwrap();
        j.append(&admit(1, 21, None)).unwrap();
        let full = std::fs::read(j.path()).unwrap();
        let tail_len = admit(1, 21, None).encode_line().len();
        let keep = full.len() - tail_len;
        // Truncate at every byte boundary inside the final record: the
        // first two records always survive, the tail never half-applies.
        for cut in keep..full.len() {
            std::fs::write(j.path(), &full[..cut]).unwrap();
            let rep = replay(j.path()).unwrap();
            assert_eq!(rep.replayed, 2, "cut at {cut}");
            assert_eq!(rep.torn, if cut == keep { 0 } else { 1 });
            assert!(rep.pending.is_empty(), "cut at {cut}");
            assert_eq!(rep.completed.len(), 1, "cut at {cut}");
            assert_eq!(rep.next_seq, 1, "cut at {cut}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_snapshots_live_state_and_truncates() {
        let dir = tmp_dir("compact");
        let j = JobJournal::open(&dir).unwrap();
        for s in 0..6u64 {
            j.append(&admit(s, s, Some("c"))).unwrap();
        }
        for s in 0..4u64 {
            j.append(&Record::Lease { seq: s, worker: "w".into() })
                .unwrap();
            j.append(&done(s, s)).unwrap();
        }
        let before = std::fs::metadata(j.path()).unwrap().len();
        let rep = replay(j.path()).unwrap();
        j.compact(rep.next_seq, &rep.pending, &rep.completed)
            .unwrap();
        let after = std::fs::metadata(j.path()).unwrap().len();
        assert!(after < before, "compaction must shrink the log");
        // The compacted log replays to the same state.
        let rep2 = replay(j.path()).unwrap();
        assert_eq!(rep2.next_seq, rep.next_seq);
        assert_eq!(
            rep2.pending.iter().map(|p| p.seq).collect::<Vec<_>>(),
            vec![4, 5]
        );
        assert_eq!(rep2.completed.len(), 4);
        // ...and appends still work on the reopened handle.
        j.append(&admit(6, 6, None)).unwrap();
        let rep3 = replay(j.path()).unwrap();
        assert_eq!(rep3.next_seq, 7);
        assert_eq!(rep3.pending.len(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn meta_floor_survives_when_all_jobs_complete() {
        // After compaction with no pending and no retained dones, the
        // meta record alone must keep the seq counter monotone.
        let dir = tmp_dir("meta");
        let j = JobJournal::open(&dir).unwrap();
        j.compact(17, &[], &[]).unwrap();
        let rep = replay(j.path()).unwrap();
        assert_eq!(rep.next_seq, 17);
        assert!(rep.pending.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
