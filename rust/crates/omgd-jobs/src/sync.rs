//! Content-addressed artifact sync between a gateway and its remote
//! workers.
//!
//! A [`crate::JobSpec`] names a model; running it needs the
//! model's on-disk artifact set (`<model>.json` manifest, `*.hlo.txt`
//! kernel texts, init dumps — every file `<model>.*` in the artifacts
//! dir). The gateway identifies one concrete artifact set by its
//! [`super::artifact_fingerprint`]; a worker whose local store lacks
//! that fingerprint downloads the set (`GET /artifacts/<fp>`),
//! verifies it, and runs against the synced copy — so a worker can
//! never silently compute against *older* weights than the gateway
//! leased the job for, and the fingerprint is the result-cache key on
//! both ends.
//!
//! The transfer format is a minimal tar-like frame (no external
//! crates):
//!
//! ```text
//! OMGD-ART1\n
//! <n files>\n
//! then, per file (sorted by name):
//! <name-byte-len> <content-byte-len> <fnv1a64-of-content hex>\n
//! <name bytes><content bytes>
//! ```
//!
//! Every file carries its own FNV-1a 64 content hash; [`unpack`]
//! rejects a frame whose bytes do not match (a truncated download or a
//! corrupting proxy degrades to a failed sync, never to silently wrong
//! artifacts). File names must be bare (no path separators), matching
//! how artifact sets are laid out.
//!
//! [`ArtifactStore`] is the worker-side cache: one subdirectory per
//! fingerprint, populated atomically (unpack into a temp dir, fsync
//! marker, rename), so concurrent worker threads — or a crash mid-sync
//! — can never leave a half-synced set that later runs.

use super::spec::fnv1a64;
use crate::obs;
use anyhow::{bail, Context, Result};
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Magic line opening every artifact frame; bump the digit on any
/// format change so skewed builds fail loudly.
const MAGIC: &str = "OMGD-ART1";

/// Hard cap on files per frame and bytes per file: artifact sets are a
/// handful of manifests/HLO texts/init dumps, so anything bigger is a
/// protocol error, not a workload.
const MAX_FILES: usize = 256;
const MAX_FILE_BYTES: usize = 1 << 30;

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Serialize every file of `dir` whose name starts with `<model>.` into
/// one artifact frame, sorted by name so identical sets produce
/// identical frames.
pub fn pack(dir: &Path, model: &str) -> Result<Vec<u8>> {
    let prefix = format!("{model}.");
    let mut names: Vec<String> = fs::read_dir(dir)
        .with_context(|| format!("reading artifacts dir {dir:?}"))?
        .filter_map(|e| e.ok())
        .filter(|e| e.file_type().map(|t| t.is_file()).unwrap_or(false))
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .filter(|n| n.starts_with(&prefix))
        .collect();
    if names.is_empty() {
        bail!("no artifact files for model {model:?} under {dir:?}");
    }
    if names.len() > MAX_FILES {
        bail!("artifact set for {model:?} exceeds {MAX_FILES} files");
    }
    names.sort();
    let mut out = Vec::new();
    out.extend_from_slice(format!("{MAGIC}\n{}\n", names.len()).as_bytes());
    for name in &names {
        let bytes = fs::read(dir.join(name))
            .with_context(|| format!("reading artifact {name:?}"))?;
        out.extend_from_slice(
            format!(
                "{} {} {:016x}\n",
                name.len(),
                bytes.len(),
                fnv1a64(&bytes)
            )
            .as_bytes(),
        );
        out.extend_from_slice(name.as_bytes());
        out.extend_from_slice(&bytes);
    }
    Ok(out)
}

/// One file parsed out of a frame.
pub struct ArtifactFile {
    pub name: String,
    pub bytes: Vec<u8>,
}

/// Parse and verify an artifact frame. Errors on a bad magic/shape, a
/// per-file hash mismatch, or an unsafe file name.
pub fn unpack(frame: &[u8]) -> Result<Vec<ArtifactFile>> {
    let mut pos = 0usize;
    let magic = read_line(frame, &mut pos)?;
    if magic != MAGIC {
        bail!("bad artifact frame magic {magic:?}");
    }
    let n: usize = read_line(frame, &mut pos)?
        .parse()
        .map_err(|_| anyhow::anyhow!("bad artifact frame file count"))?;
    if n == 0 || n > MAX_FILES {
        bail!("artifact frame file count {n} out of range");
    }
    let mut files = Vec::with_capacity(n);
    for _ in 0..n {
        let head = read_line(frame, &mut pos)?;
        let mut parts = head.split_whitespace();
        let name_len: usize = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad frame entry head {head:?}"))?;
        let byte_len: usize = parts
            .next()
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("bad frame entry head {head:?}"))?;
        let want_hash = parts
            .next()
            .and_then(|s| u64::from_str_radix(s, 16).ok())
            .ok_or_else(|| anyhow::anyhow!("bad frame entry head {head:?}"))?;
        if parts.next().is_some() || byte_len > MAX_FILE_BYTES {
            bail!("bad frame entry head {head:?}");
        }
        let name_bytes = take(frame, &mut pos, name_len)?;
        let name = std::str::from_utf8(name_bytes)
            .context("artifact name is not UTF-8")?
            .to_string();
        if name.is_empty()
            || name.contains('/')
            || name.contains('\\')
            || name.contains("..")
            || name.starts_with('.')
        {
            bail!("unsafe artifact file name {name:?}");
        }
        let bytes = take(frame, &mut pos, byte_len)?.to_vec();
        let got = fnv1a64(&bytes);
        if got != want_hash {
            bail!(
                "artifact {name:?} failed verification \
                 (got {got:016x}, want {want_hash:016x})"
            );
        }
        files.push(ArtifactFile { name, bytes });
    }
    if pos != frame.len() {
        bail!("trailing bytes after artifact frame");
    }
    Ok(files)
}

/// Write + fsync one file (the durable half of the atomic publish).
fn write_durable(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    let mut f = fs::File::create(path)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    Ok(())
}

fn read_line<'a>(frame: &'a [u8], pos: &mut usize) -> Result<&'a str> {
    let rest = &frame[*pos..];
    let nl = rest
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| anyhow::anyhow!("truncated artifact frame"))?;
    let line = std::str::from_utf8(&rest[..nl])
        .context("artifact frame header is not UTF-8")?;
    *pos += nl + 1;
    Ok(line)
}

fn take<'a>(frame: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if frame.len() - *pos < n {
        bail!("truncated artifact frame");
    }
    let out = &frame[*pos..*pos + n];
    *pos += n;
    Ok(out)
}

/// Default worker-side store location, relative to the working dir.
pub const DEFAULT_STORE_DIR: &str = "target/omgd-artifacts";

/// Worker-side artifact store: one immutable directory per gateway
/// fingerprint. `ensure` is the only write path and it is atomic, so a
/// fingerprint directory either exists completely (with its `.ok`
/// marker) or not at all.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if needed) the store at `dir`, or the default.
    pub fn open(dir: Option<&str>) -> Result<Self> {
        let root = PathBuf::from(dir.unwrap_or(DEFAULT_STORE_DIR));
        fs::create_dir_all(&root)
            .with_context(|| format!("creating artifact store {root:?}"))?;
        Ok(Self { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn fp_dir(&self, fp: &str) -> Result<PathBuf> {
        // Fingerprints are 16-hex strings (see `artifact_fingerprint`);
        // refuse anything that could walk out of the store.
        if fp.is_empty()
            || fp.len() > 64
            || !fp.chars().all(|c| c.is_ascii_alphanumeric())
        {
            bail!("invalid artifact fingerprint {fp:?}");
        }
        Ok(self.root.join(fp))
    }

    /// True when the store already holds a verified copy of `fp`.
    pub fn contains(&self, fp: &str) -> bool {
        self.fp_dir(fp)
            .map(|d| d.join(".ok").exists())
            .unwrap_or(false)
    }

    /// Directory for a fingerprint already in the store.
    pub fn dir_of(&self, fp: &str) -> Result<PathBuf> {
        let d = self.fp_dir(fp)?;
        if !d.join(".ok").exists() {
            bail!("artifact fingerprint {fp:?} not in store");
        }
        Ok(d)
    }

    /// Every fingerprint currently in the store (sorted) — sent along
    /// with lease requests so the gateway knows what a worker already
    /// holds.
    pub fn fingerprints(&self) -> Vec<String> {
        let mut fps: Vec<String> = fs::read_dir(&self.root)
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.file_name().to_string_lossy().into_owned())
            // Skip in-flight `.tmp-*` sync dirs (they contain a `.ok`
            // marker of their own just before the rename).
            .filter(|n| !n.starts_with('.'))
            .filter(|n| self.root.join(n).join(".ok").exists())
            .collect();
        fps.sort();
        fps
    }

    /// Return the directory holding fingerprint `fp`, downloading via
    /// `fetch` on a store miss. The unpack-verify-rename sequence is
    /// atomic: a failed or concurrent sync never publishes a partial
    /// set, and a lost rename race simply reuses the winner's copy.
    pub fn ensure(
        &self,
        fp: &str,
        fetch: impl FnOnce() -> Result<Vec<u8>>,
    ) -> Result<PathBuf> {
        let dest = self.fp_dir(fp)?;
        if dest.join(".ok").exists() {
            return Ok(dest);
        }
        let frame = fetch()?;
        let files = unpack(&frame)
            .with_context(|| format!("verifying artifact frame {fp}"))?;
        let tmp = self.root.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::create_dir_all(&tmp)?;
        // fsync every file (and the `.ok` marker) before the rename
        // publishes the set: a crash after publication must never
        // leave a `.ok` beside unflushed data — `contains` trusts the
        // marker without re-hashing.
        for f in &files {
            write_durable(&tmp.join(&f.name), &f.bytes)
                .with_context(|| format!("writing synced {:?}", f.name))?;
        }
        write_durable(&tmp.join(".ok"), fp.as_bytes())?;
        // Flush the directory entries themselves, best-effort (not
        // every platform supports fsync on a directory handle).
        if let Ok(d) = fs::File::open(&tmp) {
            let _ = d.sync_all();
        }
        // The nastiest instant: every byte fsynced but nothing
        // published. A kill here must leave only a `.tmp-*` dir that
        // the next sync ignores and GC sweeps (docs/durability.md).
        obs::faultpoint("store.publish");
        match fs::rename(&tmp, &dest) {
            Ok(()) => {}
            Err(e) => {
                // Lost a race with a concurrent sync of the same fp?
                // Their verified copy is as good as ours.
                let _ = fs::remove_dir_all(&tmp);
                if !dest.join(".ok").exists() {
                    return Err(e).with_context(|| {
                        format!("publishing synced artifacts {dest:?}")
                    });
                }
            }
        }
        Ok(dest)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("omgd-sync-test-{tag}-{}", std::process::id()));
        fs::remove_dir_all(&d).ok();
        fs::create_dir_all(&d).unwrap();
        d
    }

    fn fake_artifacts(dir: &Path, model: &str) {
        fs::write(dir.join(format!("{model}.json")), b"{\"m\":1}").unwrap();
        fs::write(
            dir.join(format!("{model}.train.hlo.txt")),
            b"HloModule train",
        )
        .unwrap();
        // Binary content with embedded newlines and NULs.
        fs::write(
            dir.join(format!("{model}.init.bin")),
            [0u8, 10, 13, 255, 0, 42],
        )
        .unwrap();
        // A different model's file must not be packed.
        fs::write(dir.join("other.json"), b"{}").unwrap();
    }

    #[test]
    fn pack_unpack_round_trips_bytes_exactly() {
        let dir = tmp_dir("roundtrip");
        fake_artifacts(&dir, "m1");
        let frame = pack(&dir, "m1").unwrap();
        let files = unpack(&frame).unwrap();
        assert_eq!(files.len(), 3, "only m1.* files are packed");
        let names: Vec<&str> =
            files.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["m1.init.bin", "m1.json", "m1.train.hlo.txt"],
            "sorted by name"
        );
        for f in &files {
            assert_eq!(f.bytes, fs::read(dir.join(&f.name)).unwrap());
        }
        // Identical input → identical frame (content-addressable).
        assert_eq!(frame, pack(&dir, "m1").unwrap());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unpack_rejects_corruption_and_unsafe_names() {
        let dir = tmp_dir("corrupt");
        fake_artifacts(&dir, "m1");
        let frame = pack(&dir, "m1").unwrap();
        // Flip one content byte near the end: hash check must fire.
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        let err = unpack(&bad).unwrap_err().to_string();
        assert!(err.contains("verification"), "got: {err}");
        // Truncation.
        assert!(unpack(&frame[..frame.len() - 3]).is_err());
        // Trailing garbage.
        let mut long = frame.clone();
        long.extend_from_slice(b"extra");
        assert!(unpack(&long).is_err());
        // Bad magic.
        assert!(unpack(b"NOPE\n0\n").is_err());
        // Path traversal in a name.
        let evil = format!(
            "{MAGIC}\n1\n{} {} {:016x}\n../evilhi",
            "../evil".len(),
            2,
            fnv1a64(b"hi")
        );
        let err = unpack(evil.as_bytes()).unwrap_err().to_string();
        assert!(err.contains("unsafe"), "got: {err}");
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn store_ensure_fetches_once_and_verifies() {
        let src = tmp_dir("store-src");
        fake_artifacts(&src, "m1");
        let frame = pack(&src, "m1").unwrap();
        let root = tmp_dir("store");
        let store =
            ArtifactStore::open(Some(root.to_str().unwrap())).unwrap();
        assert!(!store.contains("00ff00ff00ff00ff"));
        assert!(store.fingerprints().is_empty());

        let mut fetches = 0;
        let dir = store
            .ensure("00ff00ff00ff00ff", || {
                fetches += 1;
                Ok(frame.clone())
            })
            .unwrap();
        assert_eq!(fetches, 1);
        assert!(store.contains("00ff00ff00ff00ff"));
        assert_eq!(
            fs::read(dir.join("m1.json")).unwrap(),
            fs::read(src.join("m1.json")).unwrap()
        );
        // Second ensure is a pure store hit.
        let again = store
            .ensure("00ff00ff00ff00ff", || {
                panic!("must not refetch a stored fingerprint")
            })
            .unwrap();
        assert_eq!(again, dir);
        assert_eq!(store.fingerprints(), vec!["00ff00ff00ff00ff"]);
        assert_eq!(store.dir_of("00ff00ff00ff00ff").unwrap(), dir);

        // A corrupt fetch never publishes anything.
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert!(store.ensure("1111222233334444", || Ok(bad)).is_err());
        assert!(!store.contains("1111222233334444"));

        // Fingerprints that could escape the store are refused.
        assert!(store.ensure("../../etc", || Ok(vec![])).is_err());
        assert!(store.ensure("", || Ok(vec![])).is_err());
        fs::remove_dir_all(&src).ok();
        fs::remove_dir_all(&root).ok();
    }
}
