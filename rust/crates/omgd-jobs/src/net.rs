//! `omgd serve --listen`: HTTP/1.1 gateway over the shared [`JobHub`].
//!
//! A `TcpListener` accept loop hands each connection to its own thread;
//! every connection multiplexes into ONE hub — one bounded queue, one
//! worker pool, one result cache — so N clients share the same compute
//! budget. The HTTP layer is a thin, dependency-free HTTP/1.1 framing
//! helper (request line + headers + body in, status + headers + body
//! out), not a general web server: request bodies are read up front —
//! `Content-Length`-framed everywhere, with `Transfer-Encoding:
//! chunked` additionally accepted on `POST /jobs` so a submitter can
//! stream a session of unknown total size (`omgd grid --remote` does).
//! A client that sends `Connection: keep-alive` gets a
//! per-connection request loop — every `Content-Length`-framed
//! response keeps the socket open (bounded idle timeout), and the
//! streamed `POST /jobs` body switches to chunked transfer encoding so
//! the session's end is visible without closing. Without the header,
//! every response is `Connection: close` exactly as before.
//!
//! Endpoints (full spec with examples: `docs/serve-protocol.md`):
//!
//! * `POST /jobs` — body is JSONL job requests (the [`super::serve`]
//!   protocol); the response streams acks/rejects/results as NDJSON in
//!   completion order. When the shared queue is saturated the gateway
//!   answers `429 Too Many Requests` + `Retry-After` instead of
//!   queueing the connection.
//! * `GET /healthz` — liveness, queue depth, drain state.
//! * `GET /stats` — hub-lifetime job counters plus gateway counters
//!   (connections, 429/503 responses, remote leases) and per-phase
//!   latency summaries (queue wait / artifact sync / run / cache hit).
//! * `GET /metrics` — fleet-wide Prometheus text exposition
//!   ([`crate::obs`]); `GET /events?n=K` — the newest K job-lifecycle
//!   journal events as NDJSON. Both gated by `--metrics`.
//! * `GET /cache` — result-cache directory, entry count, byte size.
//! * `POST /work/lease` — remote-worker pull: long-poll for one queued
//!   job, leased with a TTL ([`super::remote`] is the client).
//! * `POST /work/<seq>/renew`, `POST /work/<seq>/result` — keep a
//!   lease alive / report its outcome (`409` once the lease is lost).
//! * `GET /artifacts/<fp>` — content-addressed artifact sync: the
//!   framed artifact set for a fingerprint a lease referenced
//!   ([`super::sync`] owns the frame format).
//! * `POST /shutdown` — stop accepting new job sessions, keep serving
//!   `/work/*` until every open session, queued job, and outstanding
//!   lease drains, then return.
//!
//! Backpressure is two-level: per connection (at most
//! [`ListenOptions::max_in_flight`] unfinished jobs per session — the
//! session reader throttles until results drain) and global (the
//! bounded queue; saturated → `429` for new `POST /jobs`).

use super::cache::{self, ResultCache};
use super::journal::{self, JobJournal};
use super::lifecycle::PhaseCell;
use super::pool::{JobOutcome, JobStatus};
use super::serve::{
    run_session, with_hub, JobHub, LeaseReply, PhaseSecs, RemoteDone,
    RemoteStats, ResultLookup, ServeStats, SessionOptions,
};
use super::spec::JobSpec;
use super::{cached_runner_with, open_cache, sync, GridOptions, JobExecutor};
use crate::obs::{self, MetricsLevel};
use crate::util::json::{escape_str as esc, Json};
use anyhow::{bail, Context, Result};
use omgd_util::{ct_eq, lock_recover};
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Largest accepted `POST /jobs` body (16 MiB ≈ 10⁵ job lines).
const MAX_BODY_BYTES: usize = 16 << 20;
/// Cap on the request head (request line + headers).
const MAX_HEAD_BYTES: u64 = 16 << 10;
/// Cap on the number of header lines.
const MAX_HEADERS: usize = 100;
/// How much of an over-limit or throttled request body gets drained
/// before responding, so the error reaches the client instead of a
/// connection reset (closing with unread bytes provokes an RST).
const MAX_DRAIN_BYTES: u64 = 64 << 20;
/// Interval between cache-GC passes in a long-lived gateway.
const GC_INTERVAL: Duration = Duration::from_secs(15 * 60);

/// Gateway knobs (`omgd serve --listen`).
#[derive(Clone, Debug)]
pub struct ListenOptions {
    /// Concurrent-connection cap; beyond it the gateway answers `503`.
    pub max_conns: usize,
    /// Per-connection cap on unfinished jobs (see module docs).
    pub max_in_flight: usize,
    /// Shared queue capacity; `0` = auto (`(2·workers).max(8)`).
    pub queue_capacity: usize,
    /// Socket read *and* write timeout, so a stalled client — silent,
    /// or refusing to read its result stream — cannot wedge graceful
    /// drain forever.
    pub io_timeout: Duration,
    /// Worker-lease TTL: a leased job whose worker neither renews nor
    /// reports within this window is requeued (crash/partition
    /// re-dispatch). Workers renew at a fraction of this.
    pub lease_secs: u64,
    /// Long-poll budget of `POST /work/lease`: how long the gateway
    /// holds an idle lease request open waiting for work before
    /// answering `idle`.
    pub poll_secs: u64,
    /// Mirror of [`GridOptions::force`] for remotely-leased jobs: skip
    /// (and invalidate) the gateway cache's fast-path when leasing.
    pub force: bool,
    /// Per-client in-flight quota (`--client-quota`): a token presented
    /// via `X-OMGD-Client` may have at most this many unfinished jobs
    /// across all of its sessions. New `POST /jobs` from an over-quota
    /// token answer `429` + `Retry-After`; inside an accepted stream
    /// the quota throttles submission instead. `0` = off.
    pub client_quota: usize,
    /// Affinity-scan bound (`--affinity-window`): how many queued jobs
    /// a worker lease may scan for one whose artifact fingerprint the
    /// worker already caches. `0`/`1` = plain oldest-first leasing.
    pub affinity_window: usize,
    /// How long an idle keep-alive connection may sit between requests
    /// before the gateway closes it (`--keepalive-idle-secs`; `0` = no
    /// idle limit, matching the other knobs' `0 = off` convention).
    /// While draining the bound drops to ~1s so parked connections
    /// cannot stall shutdown.
    pub keepalive_idle: Duration,
    /// Telemetry verbosity (`--metrics off|summary|full`): `off`
    /// disables `GET /metrics` and `GET /events` (404), `summary`
    /// serves `/metrics` but turns the event journal off, `full` (the
    /// default) serves both.
    pub metrics: MetricsLevel,
    /// Directory holding the crash-safe job journal (`journal.log`).
    /// When set, the gateway replays it at startup (rebuilding the
    /// queue, seq counter, and client ledger), appends every job
    /// transition durably, serves `GET /jobs/<seq>/result` re-polls,
    /// and compacts on clean shutdown. `None` = in-memory only (the
    /// pre-durability behavior). `serve_listen` points this at the
    /// cache dir.
    pub journal_dir: Option<PathBuf>,
    /// Shared bearer token (`--auth-token` / `OMGD_AUTH_TOKEN`). When
    /// set, every state-touching endpoint — `POST /jobs`,
    /// `GET /jobs/<seq>/result`, `/work/*`, `/artifacts/*`,
    /// `POST /shutdown` — requires `Authorization: Bearer <token>`
    /// (compared in constant time) and answers `401` +
    /// `WWW-Authenticate: Bearer` otherwise. Read-only probes
    /// (`/healthz`, `/stats`, `/metrics`, `/events`, `/cache`) stay
    /// open so dashboards and load balancers need no secret. `None` =
    /// no auth (the default).
    pub auth_token: Option<String>,
}

impl Default for ListenOptions {
    fn default() -> Self {
        Self {
            max_conns: 64,
            max_in_flight: 32,
            queue_capacity: 0,
            io_timeout: Duration::from_secs(300),
            lease_secs: 60,
            poll_secs: 20,
            force: false,
            client_quota: 0,
            affinity_window: 16,
            keepalive_idle: Duration::from_secs(60),
            metrics: MetricsLevel::Full,
            journal_dir: None,
            auth_token: None,
        }
    }
}

/// Gateway-lifetime counters, reported once the gateway drains.
#[derive(Clone, Copy, Debug, Default)]
pub struct GatewayStats {
    /// Connections handled (excluding ones refused with `503`).
    pub connections: usize,
    /// Parsed HTTP requests across all connections.
    pub requests: usize,
    /// `429 Too Many Requests` responses (queue saturated).
    pub throttled: usize,
    /// `429` responses to clients over their `--client-quota`.
    pub quota_throttled: usize,
    /// `503 Service Unavailable` responses (connection cap).
    pub refused: usize,
    /// Job counters aggregated across all `POST /jobs` sessions.
    pub jobs: ServeStats,
    /// Remote-worker lease counters (leases granted, expiries
    /// requeued, stale completions rejected).
    pub remote: RemoteStats,
}

#[derive(Default)]
struct Counters {
    connections: AtomicUsize,
    active: AtomicUsize,
    requests: AtomicUsize,
    throttled: AtomicUsize,
    quota_throttled: AtomicUsize,
    refused: AtomicUsize,
}

/// Bind `addr` and run the gateway until `POST /shutdown`, with local
/// workers built from `make_exec` and wrapped in the cache-aware
/// runner. `--listen 127.0.0.1:0` binds a free port; the actual
/// address is printed to stderr. The trainer-backed `serve_listen`
/// (in `omgd-train`) is this with the production [`JobExecutor`].
pub fn serve_listen_with<E, M>(
    addr: &str,
    opts: &GridOptions,
    lopts: &ListenOptions,
    make_exec: M,
) -> Result<GatewayStats>
where
    E: JobExecutor,
    M: Fn(usize) -> E + Sync,
{
    let cache = open_cache(opts)?;
    let listener =
        TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    eprintln!(
        "omgd serve: listening on http://{} ({} local worker(s); \
         POST /jobs, GET /healthz /stats /cache, POST /work/lease \
         (remote workers), POST /shutdown)",
        listener.local_addr()?,
        opts.workers,
    );
    // A long-lived gateway re-enforces its GC caps periodically, not
    // just at open; the thread owns its own cache handle (same dir)
    // and stops when the gateway drains. Entries written during a pass
    // are never candidates, so racing workers lose nothing. Each pass
    // re-reads the job journal to protect parked checkpoints of jobs
    // with a live (admitted, unfinished) journal entry from eviction.
    let (gc_stop_tx, gc_stop_rx) = std::sync::mpsc::channel::<()>();
    let gc_thread = (!opts.gc.is_noop()).then(|| {
        let policy = opts.gc;
        let dir = opts.cache_dir.clone();
        std::thread::spawn(move || {
            let Ok(cache) = ResultCache::open(dir.as_deref()) else {
                return;
            };
            let jpath = JobJournal::path_in(cache.dir());
            loop {
                match gc_stop_rx.recv_timeout(GC_INTERVAL) {
                    Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                        let protected = journal::replay(&jpath)
                            .map(|r| journal::live_hashes(&r))
                            .unwrap_or_default();
                        if let Ok(st) =
                            cache.gc_protected(&policy, &protected)
                        {
                            super::report_gc(&st);
                        }
                    }
                    _ => return, // drained (or sender gone): stop
                }
            }
        })
    });
    let lopts = ListenOptions {
        force: opts.force,
        journal_dir: Some(cache.dir().to_path_buf()),
        ..lopts.clone()
    };
    let out =
        run_gateway(listener, opts.workers, &lopts, Some(&cache), |wid| {
            cached_runner_with(&cache, opts.force, make_exec(wid))
        });
    let _ = gc_stop_tx.send(());
    if let Some(h) = gc_thread {
        let _ = h.join();
    }
    out
}

/// Shared, read-mostly context every connection thread gets a
/// reference to.
#[derive(Clone, Copy)]
struct GwCtx<'a> {
    hub: &'a JobHub,
    c: &'a Counters,
    /// Gateway lifecycle phase (`Serving → Draining → Stopped`); the
    /// `/shutdown` handler requests the drain, the accept loop marks
    /// the stop, and every drain check reads it. Forward-only by
    /// construction — see [`PhaseCell`].
    phase: &'a PhaseCell,
    lopts: &'a ListenOptions,
    cache: Option<&'a ResultCache>,
    local: SocketAddr,
    /// Artifact index: gateway fingerprint → (artifacts dir, model),
    /// registered when a job is leased and served by
    /// `GET /artifacts/<fp>`.
    artifacts: &'a ArtifactIndex,
}

/// Fingerprint → (artifacts dir, model) registry behind
/// `GET /artifacts/<fp>`: leases register the artifact set they
/// referenced *before* the lease reply is written, so a worker's fetch
/// can never race the index. Typed (instead of a bare map under a
/// mutex) so registration and lookup are the only operations — nothing
/// else can hold the lock across IO.
#[derive(Default)]
struct ArtifactIndex {
    map: Mutex<HashMap<String, (PathBuf, String)>>,
}

impl ArtifactIndex {
    fn register(&self, fp: String, dir: PathBuf, model: String) {
        lock_recover(&self.map).insert(fp, (dir, model));
    }

    fn lookup(&self, fp: &str) -> Option<(PathBuf, String)> {
        lock_recover(&self.map).get(fp).cloned()
    }
}

/// Run the accept loop + worker pool + router on `listener` until a
/// `POST /shutdown` arrives, then drain. Tests inject stub workers
/// (and `None` for the cache) the same way [`super::pool::run_pool`]
/// does. `workers == 0` runs a coordinator-only gateway whose jobs are
/// drained exclusively by remote `omgd worker` agents.
///
/// Drain is remote-worker-aware: after `POST /shutdown` the gateway
/// stops taking new `POST /jobs` (they get `503`) but **keeps serving
/// `/work/*` and `/artifacts/*`**, because open job sessions may be
/// waiting on results that only a remote worker can deliver. The loop
/// exits once no connection is open, the queue is empty, and no lease
/// is outstanding — at which point `with_hub` seals the queue and the
/// local pool drains.
///
/// Corollary: a coordinator-only gateway (`workers == 0`) whose last
/// remote worker died with jobs still queued waits — deliberately —
/// for a worker to (re)attach and drain them; the accept loop stays
/// live through the whole drain, so attaching one resolves it. Kill
/// the process to abandon the queued work instead.
pub fn run_gateway<M, F>(
    listener: TcpListener,
    workers: usize,
    lopts: &ListenOptions,
    cache: Option<&ResultCache>,
    make_worker: M,
) -> Result<GatewayStats>
where
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let queue_capacity = if lopts.queue_capacity == 0 {
        (2 * workers).max(8)
    } else {
        lopts.queue_capacity
    };
    let phase = PhaseCell::new();
    let loop_done = AtomicBool::new(false);
    let c = Counters::default();
    // Below `full`, the journal is a no-op for the gateway's lifetime;
    // metric counters/histograms stay live (they cost one atomic op
    // and are cheap enough to never gate).
    if lopts.metrics != MetricsLevel::Full {
        obs::journal().set_capacity(0);
    }
    let local = listener.local_addr().context("gateway local_addr")?;
    let artifacts = ArtifactIndex::default();

    // `with_hub` owns the worker pool + router + drain discipline; this
    // body is only the accept loop. Connection threads live in their
    // own scope and are joined before the body returns, so every open
    // session finishes before the hub seals its queue.
    let ((accepted, rejected, done, failed, cached), remote) =
        with_hub(workers, queue_capacity, make_worker, |hub| {
            hub.set_client_quota(lopts.client_quota);
            // Durable mode: replay the crash-safe journal (rebuilding
            // queued work, the seq counter, retained results, and the
            // client ledger), then compact the replayed history down to
            // a fresh snapshot before taking traffic.
            if let Some(dir) = &lopts.journal_dir {
                match JobJournal::open(dir) {
                    Ok(j) => match journal::replay(j.path()) {
                        Ok(rep) => {
                            let torn = rep.torn;
                            hub.attach_journal(j);
                            let (requeued, completed) = hub.recover(rep);
                            if requeued + completed + torn > 0 {
                                eprintln!(
                                    "omgd serve: journal replay requeued \
                                     {requeued} job(s), retained \
                                     {completed} result(s){}",
                                    if torn > 0 {
                                        " (dropped a torn tail record)"
                                    } else {
                                        ""
                                    }
                                );
                            }
                            if let Err(e) = hub.compact_journal() {
                                eprintln!(
                                    "warning: startup journal \
                                     compaction failed: {e:#}"
                                );
                            }
                        }
                        Err(e) => eprintln!(
                            "warning: journal replay failed ({e:#}); \
                             starting with an empty queue"
                        ),
                    },
                    Err(e) => eprintln!(
                        "warning: cannot open job journal in \
                         {dir:?} ({e:#}); running without durability"
                    ),
                }
            }
            let ctx = GwCtx {
                hub,
                c: &c,
                phase: &phase,
                lopts,
                cache,
                local,
                artifacts: &artifacts,
            };
            std::thread::scope(|s| {
                // Lease-expiry sweeper: re-dispatch jobs whose worker
                // went silent even when no one is polling `/work/lease`
                // (every lease call also sweeps opportunistically).
                let loop_done = &loop_done;
                let sweeper = s.spawn(move || {
                    while !loop_done.load(Ordering::SeqCst) {
                        hub.requeue_expired();
                        std::thread::sleep(Duration::from_millis(200));
                    }
                });
                let mut handles = Vec::new();
                let mut draining = false;
                loop {
                    if !draining && phase.draining() {
                        // Enter drain mode: from here on the accept
                        // call must not block forever, because the exit
                        // condition below needs re-checking even when
                        // no one connects.
                        draining = true;
                        let _ = listener.set_nonblocking(true);
                    }
                    let stream = match listener.accept() {
                        Ok((stream, _peer)) => {
                            // A drain-mode accept delivered a
                            // nonblocking socket; connection handling
                            // assumes blocking IO.
                            let _ = stream.set_nonblocking(false);
                            Some(stream)
                        }
                        Err(_) => None,
                    };
                    if draining && stream.is_none() {
                        let idle = c.active.load(Ordering::SeqCst) == 0
                            && ctx.hub.queue.is_empty()
                            && ctx.hub.n_leased() == 0;
                        if idle {
                            break;
                        }
                    }
                    let Some(stream) = stream else {
                        // Transient accept failure (fd exhaustion, …)
                        // or drain-mode WouldBlock: back off instead of
                        // spinning.
                        std::thread::sleep(Duration::from_millis(10));
                        continue;
                    };
                    let full =
                        c.active.load(Ordering::SeqCst) >= lopts.max_conns;
                    if full {
                        c.refused.fetch_add(1, Ordering::Relaxed);
                        obs::HTTP_REFUSED.inc();
                        let _ = respond_json(
                            &mut &stream,
                            503,
                            "Service Unavailable",
                            &[("Retry-After", "1")],
                            false,
                            "{\"error\":\"connection limit reached\"}",
                        );
                        continue;
                    }
                    c.active.fetch_add(1, Ordering::SeqCst);
                    c.connections.fetch_add(1, Ordering::Relaxed);
                    obs::HTTP_CONNECTIONS.inc();
                    let ctx_ref = &ctx;
                    let handle = s.spawn(move || {
                        handle_conn(ctx_ref, stream);
                        ctx_ref.c.active.fetch_sub(1, Ordering::SeqCst);
                    });
                    handles.push(handle);
                    // Bound the handle list over a long gateway
                    // lifetime; the scope still joins any thread whose
                    // handle is dropped.
                    handles.retain(|h| !h.is_finished());
                }
                // Graceful drain: open connections finish before
                // `with_hub` closes the queue behind this body.
                for h in handles {
                    let _ = h.join();
                }
                loop_done.store(true, Ordering::SeqCst);
                // Draining → Stopped: the accept loop has exited and
                // every connection thread is joined; nothing else can
                // mutate the hub from the network side.
                phase.mark_stopped();
                let _ = sweeper.join();
            });
            // Clean shutdown: snapshot live state and truncate the
            // journal's history. A crash before (or during) this leaves
            // the append-only log, which replays to the same state.
            if let Err(e) = hub.compact_journal() {
                eprintln!(
                    "warning: shutdown journal compaction failed: {e:#}"
                );
            }
            (hub.counters(), hub.remote_counters())
        });

    Ok(GatewayStats {
        connections: c.connections.load(Ordering::Relaxed),
        requests: c.requests.load(Ordering::Relaxed),
        throttled: c.throttled.load(Ordering::Relaxed),
        quota_throttled: c.quota_throttled.load(Ordering::Relaxed),
        refused: c.refused.load(Ordering::Relaxed),
        jobs: ServeStats { accepted, rejected, done, failed, cached },
        remote,
    })
}

/// Serve one connection as a request loop: parse a request head,
/// dispatch the endpoint, respond — then, if the client asked for
/// `Connection: keep-alive` and the exchange left the stream cleanly
/// framed, wait (bounded) for the next request on the same socket.
/// Never panics — every IO failure is a dropped client.
fn handle_conn(ctx: &GwCtx<'_>, stream: TcpStream) {
    let lopts = ctx.lopts;
    let _ = stream.set_read_timeout(Some(lopts.io_timeout));
    let _ = stream.set_write_timeout(Some(lopts.io_timeout));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut w = &stream;
    let mut first = true;
    loop {
        // Between keep-alive requests, park on the socket without
        // consuming anything (an idle timeout must never tear a
        // half-read request head) until the next request's first byte
        // arrives or the idle budget runs out. The first request rides
        // the plain io_timeout, exactly as before keep-alive existed.
        if !first && !wait_readable(&mut reader, &stream, ctx) {
            return;
        }
        first = false;
        let head = match read_head(&mut reader) {
            Ok(Some(h)) => h,
            Ok(None) => return, // clean EOF between requests
            Err(e) => {
                // The stream's framing is unknowable from here on:
                // answer 400 and close regardless of keep-alive.
                let _ = respond_json(
                    &mut w,
                    400,
                    "Bad Request",
                    &[],
                    false,
                    &err_body(&e.to_string()),
                );
                return;
            }
        };
        ctx.c.requests.fetch_add(1, Ordering::Relaxed);
        obs::HTTP_REQUESTS.inc();
        let keep = route_request(ctx, &mut reader, &mut w, &head);
        let _ = w.flush();
        if !keep {
            return;
        }
    }
}

/// Wait for the next keep-alive request's first byte without consuming
/// it: poll `fill_buf` in ~1s slices so a draining gateway closes
/// parked connections promptly instead of after the full idle budget.
/// `true` = data is buffered and the io timeout is restored; `false` =
/// EOF, idle expiry, drain, or a socket error — close the connection.
fn wait_readable(
    reader: &mut BufReader<TcpStream>,
    stream: &TcpStream,
    ctx: &GwCtx<'_>,
) -> bool {
    let restore = |ok: bool| -> bool {
        let _ = stream.set_read_timeout(Some(ctx.lopts.io_timeout));
        ok
    };
    if !reader.buffer().is_empty() {
        return true; // the client pipelined: next head already here
    }
    // `keepalive_idle == 0` means no idle limit (`0 = off`, like every
    // other knob); the ~1s poll slices still shed the connection
    // promptly on drain.
    let idle = ctx.lopts.keepalive_idle;
    let deadline =
        (!idle.is_zero()).then(|| Instant::now() + idle);
    let slice = Duration::from_secs(1);
    loop {
        let now = Instant::now();
        if deadline.is_some_and(|d| now >= d) {
            return restore(false);
        }
        let wait = match deadline {
            Some(d) => slice.min(d - now),
            None => slice,
        };
        let _ = stream.set_read_timeout(Some(wait));
        match reader.fill_buf() {
            Ok([]) => return restore(false), // clean EOF
            Ok(_) => return restore(true),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                ) =>
            {
                if ctx.phase.draining() {
                    // Draining: idle keep-alive connections step aside
                    // so the gateway can exit.
                    return restore(false);
                }
            }
            Err(_) => return restore(false),
        }
    }
}

/// Dispatch one parsed request. The returned flag is "this connection
/// may carry another request": the client asked for keep-alive, the
/// request body was fully consumed, and the response was
/// self-delimited (`Content-Length` or chunked).
fn route_request(
    ctx: &GwCtx<'_>,
    reader: &mut BufReader<TcpStream>,
    w: &mut &TcpStream,
    head: &HttpHead,
) -> bool {
    let GwCtx { hub, c, phase, lopts, cache, local, .. } = *ctx;
    // POST /jobs and the worker-protocol POSTs consume their bodies;
    // every other endpoint ignores its body — drain it (bounded) up
    // front so responding can't RST the reply away. Skipped under
    // Expect: 100-continue — the client has not sent the body yet and
    // is waiting on our verdict.
    let wants_body = head.method == "POST"
        && (head.path == "/jobs"
            || head.path == "/work/lease"
            || parse_work_path(&head.path).is_some());
    let mut keep = head.keep_alive;
    // Auth gate: before any endpoint logic, a state-touching request
    // must present the bearer token. The body (if any) is drained
    // first so the 401 reaches the client instead of an RST; under
    // Expect: 100-continue nothing was sent, so close after answering
    // (the stream would desynchronize if the client sent it anyway).
    if let Some(expected) = lopts.auth_token.as_deref() {
        if path_needs_auth(&head.path)
            && !token_matches(head.authorization.as_deref(), expected)
        {
            let drained = !head.expect_continue
                && if head.chunked {
                    drain_chunked(reader)
                } else {
                    drain_body(reader, head.content_length)
                };
            let _ = respond_json(
                w,
                401,
                "Unauthorized",
                &[("WWW-Authenticate", "Bearer")],
                keep && drained,
                &err_body("missing or invalid bearer token"),
            );
            return keep && drained;
        }
    }
    // Chunked request bodies are a session-endpoint feature: `POST
    // /jobs` decodes them inline; everywhere else the (small, JSON)
    // bodies must be `Content-Length`-framed. Answer 400 and drain the
    // stream so a keep-alive client survives its own mistake.
    if head.chunked && !(head.method == "POST" && head.path == "/jobs") {
        let drained = !head.expect_continue && drain_chunked(reader);
        let _ = respond_json(
            w,
            400,
            "Bad Request",
            &[],
            keep && drained,
            &err_body(
                "chunked request bodies are only supported on POST /jobs",
            ),
        );
        return keep && drained;
    }
    if !wants_body && head.content_length > 0 {
        if head.expect_continue {
            // Nothing was sent yet and we answer without inviting the
            // body: the stream would desynchronize if the client sent
            // it anyway, so close after responding.
            keep = false;
        } else {
            keep &= drain_body(reader, head.content_length);
        }
    }
    match (head.method.as_str(), head.path.as_str()) {
        ("GET", "/healthz") => {
            let body = format!(
                "{{\"ok\":true,\"queue_len\":{},\"queue_capacity\":{},\
                 \"draining\":{}}}",
                hub.queue.len(),
                hub.queue.capacity(),
                phase.draining(),
            );
            let _ = respond_json(w, 200, "OK", &[], keep, &body);
            keep
        }
        ("GET", "/stats") => {
            let (accepted, rejected, done, failed, cached) =
                hub.counters();
            let remote = hub.remote_counters();
            let clients: String = hub
                .clients_snapshot()
                .iter()
                .map(|(t, n)| format!("\"{}\":{n}", esc(t)))
                .collect::<Vec<_>>()
                .join(",");
            // Per-phase latency histograms ride along as percentile
            // summaries, splitting a job's life into queue wait →
            // artifact sync → run (with cache replays broken out).
            let body = format!(
                "{{\"connections\":{},\"active_connections\":{},\
                 \"requests\":{},\"throttled_429\":{},\"quota_429\":{},\
                 \"refused_503\":{},\
                 \"queue_len\":{},\"queue_capacity\":{},\
                 \"clients\":{{{clients}}},\
                 \"jobs\":{{\"accepted\":{accepted},\
                 \"rejected\":{rejected},\"done\":{done},\
                 \"failed\":{failed},\"cached\":{cached}}},\
                 \"remote\":{{\"leased\":{},\"affinity\":{},\
                 \"in_flight\":{},\"requeued\":{},\"conflicts\":{}}},\
                 \"phases\":{{\"queue_wait\":{},\"sync\":{},\"run\":{},\
                 \"cache_hit\":{}}}}}",
                c.connections.load(Ordering::Relaxed),
                c.active.load(Ordering::SeqCst),
                c.requests.load(Ordering::Relaxed),
                c.throttled.load(Ordering::Relaxed),
                c.quota_throttled.load(Ordering::Relaxed),
                c.refused.load(Ordering::Relaxed),
                hub.queue.len(),
                hub.queue.capacity(),
                remote.leased,
                remote.affinity,
                hub.n_leased(),
                remote.requeued,
                remote.conflicts,
                obs::QUEUE_WAIT_SECONDS.summary_json(),
                obs::SYNC_SECONDS.summary_json(),
                obs::RUN_SECONDS.summary_json(),
                obs::CACHE_HIT_SECONDS.summary_json(),
            );
            let _ = respond_json(w, 200, "OK", &[], keep, &body);
            keep
        }
        ("GET", "/metrics") => {
            if lopts.metrics == MetricsLevel::Off {
                let _ = respond_json(
                    w,
                    404,
                    "Not Found",
                    &[],
                    keep,
                    &err_body("metrics are disabled (--metrics off)"),
                );
                return keep;
            }
            let body = obs::render_prometheus();
            let _ = respond_text(
                w,
                200,
                "OK",
                "text/plain; version=0.0.4; charset=utf-8",
                keep,
                &body,
            );
            keep
        }
        ("GET", "/events") => {
            if lopts.metrics != MetricsLevel::Full {
                let _ = respond_json(
                    w,
                    404,
                    "Not Found",
                    &[],
                    keep,
                    &err_body(
                        "the event journal is disabled \
                         (requires --metrics full)",
                    ),
                );
                return keep;
            }
            let n = head
                .query
                .as_deref()
                .and_then(|q| query_param(q, "n"))
                .and_then(|v| v.parse::<usize>().ok())
                .unwrap_or(64);
            let mut body = obs::journal().tail(n).join("\n");
            if !body.is_empty() {
                body.push('\n');
            }
            let _ = respond_text(
                w,
                200,
                "OK",
                "application/x-ndjson",
                keep,
                &body,
            );
            keep
        }
        ("GET", "/cache") => {
            let body = match cache {
                Some(cc) => {
                    let st = cc.stats();
                    format!(
                        "{{\"enabled\":true,\"dir\":\"{}\",\
                         \"entries\":{},\"bytes\":{}}}",
                        esc(&cc.dir().display().to_string()),
                        st.entries,
                        st.bytes,
                    )
                }
                None => "{\"enabled\":false}".to_string(),
            };
            let _ = respond_json(w, 200, "OK", &[], keep, &body);
            keep
        }
        ("POST", "/shutdown") => {
            let _ = respond_json(
                w,
                200,
                "OK",
                &[],
                false,
                "{\"draining\":true}",
            );
            phase.request_drain();
            // Wake the (blocking) accept loop so it observes the flag.
            // A wildcard bind (0.0.0.0 / ::) is not connectable
            // everywhere — aim the wake-up at loopback instead.
            let mut wake = local;
            if wake.ip().is_unspecified() {
                wake.set_ip(match wake.ip() {
                    std::net::IpAddr::V4(_) => {
                        std::net::IpAddr::V4(std::net::Ipv4Addr::LOCALHOST)
                    }
                    std::net::IpAddr::V6(_) => {
                        std::net::IpAddr::V6(std::net::Ipv6Addr::LOCALHOST)
                    }
                });
            }
            let _ = TcpStream::connect(wake);
            false
        }
        ("POST", "/jobs") => {
            if phase.draining() {
                // Draining: no new sessions; the connection's body (if
                // any) was not read, so answering is safe only after a
                // bounded drain (chunked bodies decode-and-discard).
                let drained = !head.expect_continue
                    && if head.chunked {
                        drain_chunked(reader)
                    } else {
                        drain_body(reader, head.content_length)
                    };
                let _ = respond_json(
                    w,
                    503,
                    "Service Unavailable",
                    &[],
                    keep && drained,
                    "{\"error\":\"gateway is draining\"}",
                );
                return keep && drained;
            }
            if !head.chunked && head.content_length > MAX_BODY_BYTES {
                // Under Expect: 100-continue there is nothing to
                // drain — the client is still waiting on our verdict.
                // (A chunked body's size is unknown up front; its cap
                // is enforced while decoding below.)
                let drained = !head.expect_continue
                    && drain_body(reader, head.content_length);
                let _ = respond_json(
                    w,
                    413,
                    "Payload Too Large",
                    &[],
                    keep && drained,
                    &err_body(&format!(
                        "body exceeds {MAX_BODY_BYTES} bytes"
                    )),
                );
                return keep && drained;
            }
            if head.expect_continue {
                let _ = write!(w, "HTTP/1.1 100 Continue\r\n\r\n");
                let _ = w.flush();
            }
            // Read the body even when about to throttle: closing a
            // socket with unread request bytes can RST the response
            // out from under the client.
            let body = if head.chunked {
                match read_chunked_body(reader, MAX_BODY_BYTES) {
                    Ok(b) => b,
                    Err(ChunkedBodyError::TooLarge) => {
                        // Stopped mid-stream: framing is lost — close.
                        let _ = respond_json(
                            w,
                            413,
                            "Payload Too Large",
                            &[],
                            false,
                            &err_body(&format!(
                                "chunked body exceeds {MAX_BODY_BYTES} \
                                 bytes"
                            )),
                        );
                        return false;
                    }
                    Err(ChunkedBodyError::Malformed(e)) => {
                        let _ = respond_json(
                            w,
                            400,
                            "Bad Request",
                            &[],
                            false,
                            &err_body(&e),
                        );
                        return false;
                    }
                }
            } else {
                match read_body(reader, head.content_length) {
                    Ok(b) => b,
                    Err(e) => {
                        let _ = respond_json(
                            w,
                            400,
                            "Bad Request",
                            &[],
                            false,
                            &err_body(&e.to_string()),
                        );
                        return false;
                    }
                }
            };
            // Fairness gate: a token already at its in-flight quota is
            // bounced before a new session starts, in the same 429 +
            // Retry-After shape as queue saturation — its *other*
            // sessions keep streaming untouched.
            let quota = lopts.client_quota;
            if quota > 0 {
                if let Some(client) = &head.client {
                    if hub.client_in_flight(client) >= quota {
                        c.quota_throttled.fetch_add(1, Ordering::Relaxed);
                        obs::HTTP_THROTTLED.inc();
                        let _ = respond_json(
                            w,
                            429,
                            "Too Many Requests",
                            &[("Retry-After", "1")],
                            keep,
                            &err_body(&format!(
                                "client {client:?} is at its in-flight \
                                 quota ({quota}); retry"
                            )),
                        );
                        return keep;
                    }
                }
            }
            if hub.is_saturated() {
                c.throttled.fetch_add(1, Ordering::Relaxed);
                obs::HTTP_THROTTLED.inc();
                let _ = respond_json(
                    w,
                    429,
                    "Too Many Requests",
                    &[("Retry-After", "1")],
                    keep,
                    "{\"error\":\"job queue is full; retry\"}",
                );
                return keep;
            }
            let sopts = SessionOptions {
                max_in_flight: lopts.max_in_flight,
                client: head.client.clone(),
            };
            if keep {
                // Keep-alive stream: chunked transfer encoding makes
                // the session's end visible without closing, so the
                // same connection can carry the next round.
                let _ = write!(
                    w,
                    "HTTP/1.1 200 OK\r\nContent-Type: \
                     application/x-ndjson\r\nTransfer-Encoding: chunked\
                     \r\nConnection: keep-alive\r\n\r\n"
                );
                let _ = w.flush();
                let mut cw = ChunkedWriter::new(&mut *w);
                // Session stats land in the hub's live counters.
                run_session(hub, &body[..], &mut cw, &sopts);
                return cw.finish().is_ok();
            }
            let _ = write!(
                w,
                "HTTP/1.1 200 OK\r\nContent-Type: application/x-ndjson\
                 \r\nConnection: close\r\n\r\n"
            );
            let _ = w.flush();
            run_session(hub, &body[..], w, &sopts);
            false
        }
        ("GET", p) if p.starts_with("/jobs/") => {
            match parse_result_path(p) {
                Some(seq) => {
                    match hub.result_for(seq) {
                        ResultLookup::Ready(line) => {
                            let _ = respond_json(
                                w, 200, "OK", &[], keep, &line,
                            );
                        }
                        ResultLookup::Pending => {
                            let _ = respond_json(
                                w,
                                202,
                                "Accepted",
                                &[("Retry-After", "1")],
                                keep,
                                &format!(
                                    "{{\"pending\":true,\"seq\":{seq}}}"
                                ),
                            );
                        }
                        ResultLookup::Unknown => {
                            let _ = respond_json(
                                w,
                                404,
                                "Not Found",
                                &[],
                                keep,
                                &err_body(&format!(
                                    "no journaled job with seq {seq} \
                                     (resubmit the spec)"
                                )),
                            );
                        }
                    }
                    keep
                }
                None => {
                    let _ = respond_json(
                        w,
                        400,
                        "Bad Request",
                        &[],
                        keep,
                        &err_body(&format!(
                            "malformed /jobs/ path {p:?} (expected \
                             /jobs/<seq>/result)"
                        )),
                    );
                    keep
                }
            }
        }
        ("POST", "/work/lease") => {
            handle_lease(ctx, reader, w, head, keep)
        }
        ("POST", p) if p.starts_with("/work/") => {
            match parse_work_path(p) {
                Some((seq, verb)) => handle_work_post(
                    ctx, reader, w, head, keep, seq, verb,
                ),
                None => {
                    // Prefix-matching but malformed (`/work/x/result`,
                    // `/work/7/steal`, an overflowing seq, …): a 400
                    // error shape, never a panic or a misleading 404.
                    let _ = respond_json(
                        w,
                        400,
                        "Bad Request",
                        &[],
                        keep,
                        &err_body(&format!(
                            "malformed /work/ path {p:?} (expected \
                             /work/<seq>/renew|result)"
                        )),
                    );
                    keep
                }
            }
        }
        ("GET", p) if p.starts_with("/artifacts/") => {
            let fp = p.trim_start_matches("/artifacts/");
            handle_artifact_get(ctx, w, fp, keep);
            keep
        }
        (
            _,
            "/healthz" | "/stats" | "/metrics" | "/events" | "/cache"
            | "/shutdown" | "/jobs",
        ) => {
            let _ = respond_json(
                w,
                405,
                "Method Not Allowed",
                &[],
                keep,
                &err_body(&format!(
                    "{} not allowed on {}",
                    head.method, head.path
                )),
            );
            keep
        }
        (_, p)
            if p.starts_with("/work/")
                || p.starts_with("/artifacts/")
                || p.starts_with("/jobs/") =>
        {
            let _ = respond_json(
                w,
                405,
                "Method Not Allowed",
                &[],
                keep,
                &err_body(&format!(
                    "{} not allowed on {}",
                    head.method, head.path
                )),
            );
            keep
        }
        _ => {
            let _ = respond_json(
                w,
                404,
                "Not Found",
                &[],
                keep,
                &err_body(&format!("no such endpoint {}", head.path)),
            );
            keep
        }
    }
}

/// `/jobs/<seq>/result` → `seq` (the re-poll endpoint for
/// reconnecting `grid --remote` clients).
fn parse_result_path(path: &str) -> Option<u64> {
    let rest = path.strip_prefix("/jobs/")?;
    let (seq, verb) = rest.split_once('/')?;
    (verb == "result").then(|| seq.parse().ok()).flatten()
}

/// `/work/<seq>/renew` | `/work/<seq>/result` → `(seq, verb)`.
fn parse_work_path(path: &str) -> Option<(u64, &str)> {
    let rest = path.strip_prefix("/work/")?;
    let (seq, verb) = rest.split_once('/')?;
    let seq: u64 = seq.parse().ok()?;
    match verb {
        "renew" | "result" => Some((seq, verb)),
        _ => None,
    }
}

/// Read a small JSON request body (worker-protocol endpoints). Answers
/// the error response itself and returns `None` when the body is
/// over-long, unreadable, or not JSON. `keep` is the connection's
/// keep-alive eligibility; of the error paths, only "valid body,
/// not JSON" leaves the stream framed — the others force a close.
fn read_json_body<R: BufRead, W: Write>(
    reader: &mut R,
    w: &mut W,
    head: &HttpHead,
    keep: bool,
) -> (Option<Json>, bool) {
    if head.content_length > MAX_BODY_BYTES {
        let drained = !head.expect_continue
            && drain_body(reader, head.content_length);
        let _ = respond_json(
            w,
            413,
            "Payload Too Large",
            &[],
            keep && drained,
            &err_body(&format!("body exceeds {MAX_BODY_BYTES} bytes")),
        );
        return (None, keep && drained);
    }
    if head.expect_continue {
        let _ = write!(w, "HTTP/1.1 100 Continue\r\n\r\n");
        let _ = w.flush();
    }
    let body = match read_body(reader, head.content_length) {
        Ok(b) => b,
        Err(e) => {
            let _ = respond_json(
                w,
                400,
                "Bad Request",
                &[],
                false,
                &err_body(&e.to_string()),
            );
            return (None, false);
        }
    };
    let text = String::from_utf8_lossy(&body);
    match Json::parse(text.trim()) {
        Ok(j) => (Some(j), keep),
        Err(e) => {
            let _ = respond_json(
                w,
                400,
                "Bad Request",
                &[],
                keep,
                &err_body(&format!("request body is not JSON: {e}")),
            );
            (None, keep)
        }
    }
}

/// `POST /work/lease`: long-poll for one job on behalf of a remote
/// worker. Cache-hit jobs are completed inline (the worker never sees
/// them) and the poll continues, mirroring the local pool's
/// `cached_runner` fast path. Returns keep-alive eligibility.
fn handle_lease<R: BufRead, W: Write>(
    ctx: &GwCtx<'_>,
    reader: &mut R,
    w: &mut W,
    head: &HttpHead,
    keep: bool,
) -> bool {
    let (j, keep) = read_json_body(reader, w, head, keep);
    let Some(j) = j else { return keep };
    let worker = j
        .get("worker")
        .and_then(Json::as_str)
        .unwrap_or("anonymous")
        .to_string();
    // `artifacts` — the fingerprints the worker's local store already
    // holds — drives affinity placement: the scheduler prefers leasing
    // a job whose artifact set the worker needs no sync for.
    let cached_fps: HashSet<String> = j
        .get("artifacts")
        .and_then(Json::as_arr)
        .map(|a| {
            a.iter()
                .filter_map(Json::as_str)
                .map(String::from)
                .collect()
        })
        .unwrap_or_default();
    let ttl = Duration::from_secs(ctx.lopts.lease_secs.max(1));
    let deadline =
        Instant::now() + Duration::from_secs(ctx.lopts.poll_secs);
    // Short wait slices so a drain (or the deadline) is noticed
    // promptly even while blocked on an empty queue.
    let slice = Duration::from_millis(100);
    loop {
        match ctx.hub.try_lease(
            &worker,
            &cached_fps,
            ctx.lopts.affinity_window,
            ttl,
            slice,
        ) {
            LeaseReply::Granted(info) => {
                // Cache fast path: a hit completes the job without a
                // round trip, exactly like the local cached_runner.
                if let Some(cache) = ctx.cache {
                    if ctx.lopts.force {
                        cache.invalidate(&info.spec);
                    } else if let Some(out) =
                        cache.get(&info.spec, &info.afp)
                    {
                        ctx.hub.complete_remote(
                            info.seq,
                            &worker,
                            JobStatus::Done(out),
                            true,
                            0.0,
                            PhaseSecs::default(),
                        );
                        continue;
                    }
                }
                // Register the artifact set for `GET /artifacts/<fp>`
                // before the lease is answered, so the worker's fetch
                // cannot race the index.
                if info.afp != "absent" {
                    let dir = super::resolve_artifacts(
                        &info.spec.cfg.artifacts_dir,
                    );
                    ctx.artifacts.register(
                        info.afp.clone(),
                        dir,
                        info.spec.cfg.model.clone(),
                    );
                }
                // `force` rides along so a `--force` gateway defeats
                // the *workers'* local result caches too, not just its
                // own — otherwise a worker would replay the very cell
                // the operator asked to recompute.
                let body = format!(
                    "{{\"lease\":{{\"seq\":{},\"priority\":{},\
                     \"hash\":\"{}\",\"label\":\"{}\",\"model\":\"{}\",\
                     \"afp\":\"{}\",\"affine\":{},\"lease_secs\":{},\
                     \"force\":{},\"spec\":{}}}}}",
                    info.seq,
                    info.priority,
                    info.spec.hash_hex(),
                    esc(&info.spec.label()),
                    esc(&info.spec.cfg.model),
                    esc(&info.afp),
                    info.affine,
                    ttl.as_secs(),
                    ctx.lopts.force,
                    info.spec.to_wire(),
                );
                let _ = respond_json(w, 200, "OK", &[], keep, &body);
                return keep;
            }
            LeaseReply::Closed => {
                let _ = respond_json(
                    w,
                    200,
                    "OK",
                    &[],
                    keep,
                    "{\"closed\":true}",
                );
                return keep;
            }
            LeaseReply::Idle => {
                let draining = ctx.phase.draining();
                if draining || Instant::now() >= deadline {
                    let _ = respond_json(
                        w,
                        200,
                        "OK",
                        &[],
                        keep,
                        &format!("{{\"idle\":true,\"draining\":{draining}}}"),
                    );
                    return keep;
                }
            }
        }
    }
}

/// `POST /work/<seq>/renew` and `POST /work/<seq>/result`. Returns
/// keep-alive eligibility.
fn handle_work_post<R: BufRead, W: Write>(
    ctx: &GwCtx<'_>,
    reader: &mut R,
    w: &mut W,
    head: &HttpHead,
    keep: bool,
    seq: u64,
    verb: &str,
) -> bool {
    let (j, keep) = read_json_body(reader, w, head, keep);
    let Some(j) = j else { return keep };
    let worker = j
        .get("worker")
        .and_then(Json::as_str)
        .unwrap_or("anonymous")
        .to_string();
    let ttl = Duration::from_secs(ctx.lopts.lease_secs.max(1));
    if verb == "renew" {
        if ctx.hub.renew(seq, &worker, ttl) {
            let _ = respond_json(
                w,
                200,
                "OK",
                &[],
                keep,
                &format!("{{\"ok\":true,\"lease_secs\":{}}}", ttl.as_secs()),
            );
        } else {
            let _ = respond_json(
                w,
                409,
                "Conflict",
                &[],
                keep,
                &err_body(&format!(
                    "no lease on job {seq} held by {worker:?} \
                     (expired and re-dispatched?)"
                )),
            );
        }
        return keep;
    }
    // verb == "result"
    let mut outcome = None;
    let status = match j.get("status").and_then(Json::as_str) {
        Some("done") => {
            let Some(out) =
                j.get("outcome").and_then(cache::parse_outcome)
            else {
                let _ = respond_json(
                    w,
                    400,
                    "Bad Request",
                    &[],
                    keep,
                    &err_body("done result carries no valid outcome"),
                );
                return keep;
            };
            // Keep a copy for the cache write below; the original
            // moves into the dispatched result.
            outcome = Some(out.clone());
            JobStatus::Done(out)
        }
        Some("failed") => JobStatus::Failed(
            j.get("error")
                .and_then(Json::as_str)
                .unwrap_or("remote worker reported failure")
                .to_string(),
        ),
        Some("panicked") => JobStatus::Panicked(
            j.get("error")
                .and_then(Json::as_str)
                .unwrap_or("remote worker panicked")
                .to_string(),
        ),
        other => {
            let _ = respond_json(
                w,
                400,
                "Bad Request",
                &[],
                keep,
                &err_body(&format!("unknown result status {other:?}")),
            );
            return keep;
        }
    };
    let from_cache =
        j.get("cached").and_then(Json::as_bool).unwrap_or(false);
    let secs = j.get("secs").and_then(Json::as_f64).unwrap_or(0.0);
    // Worker-measured per-phase durations; absent on results from
    // older workers, which fold into the end-to-end fallback.
    let phases = PhaseSecs {
        sync: j.get("sync_secs").and_then(Json::as_f64).unwrap_or(0.0),
        run: j.get("run_secs").and_then(Json::as_f64).unwrap_or(0.0),
    };
    match ctx
        .hub
        .complete_remote(seq, &worker, status, from_cache, secs, phases)
    {
        RemoteDone::Accepted { spec, afp } => {
            // The gateway's cache learns remote results too, so the
            // next identical cell replays locally without a worker.
            // Best-effort, like every other cache write; `outcome` is
            // only set for done results, so failures never poison the
            // cache.
            if let (Some(cache), Some(out)) = (ctx.cache, outcome) {
                if let Err(e) = cache.put(&spec, &afp, &out) {
                    eprintln!(
                        "warning: cache write failed for {} ({}): {e:#}",
                        spec.label(),
                        spec.hash_hex()
                    );
                }
            }
            let _ = respond_json(w, 200, "OK", &[], keep, "{\"ok\":true}");
        }
        RemoteDone::Conflict => {
            let _ = respond_json(
                w,
                409,
                "Conflict",
                &[],
                keep,
                &err_body(&format!(
                    "no lease on job {seq} held by {worker:?}; \
                     result dropped (job was re-dispatched)"
                )),
            );
        }
    }
    keep
}

/// `GET /artifacts/<fp>`: stream the artifact set identified by a
/// fingerprint the gateway previously leased against. The fingerprint
/// is re-verified at pack time, so a worker can never download an
/// artifact set that changed since its lease ("stale fingerprint" →
/// the job fails loudly instead of computing on regenerated weights).
fn handle_artifact_get<W: Write>(
    ctx: &GwCtx<'_>,
    w: &mut W,
    fp: &str,
    keep: bool,
) {
    let Some((dir, model)) = ctx.artifacts.lookup(fp) else {
        let _ = respond_json(
            w,
            404,
            "Not Found",
            &[],
            keep,
            &err_body(&format!("unknown artifact fingerprint {fp:?}")),
        );
        return;
    };
    let current = super::artifact_fingerprint_at(&dir, &model);
    if current != fp {
        let _ = respond_json(
            w,
            409,
            "Conflict",
            &[],
            keep,
            &err_body(&format!(
                "artifact fingerprint {fp} is stale (artifacts for \
                 {model:?} changed; current {current})"
            )),
        );
        return;
    }
    match sync::pack(&dir, &model) {
        Ok(frame) => {
            let _ = respond_bytes(w, &frame, keep);
        }
        Err(e) => {
            let _ = respond_json(
                w,
                500,
                "Internal Server Error",
                &[],
                keep,
                &err_body(&format!("packing artifacts failed: {e:#}")),
            );
        }
    }
}

/// Parsed request head (the slice of HTTP/1.1 this gateway speaks).
struct HttpHead {
    method: String,
    path: String,
    /// Raw query string (`GET /events?n=32` → `"n=32"`), stripped from
    /// `path` so routing stays exact-match.
    query: Option<String>,
    content_length: usize,
    /// `Transfer-Encoding: chunked` request body. Accepted only on
    /// `POST /jobs` (a submitter can stream a session without knowing
    /// its total size); every other endpoint answers 400.
    chunked: bool,
    expect_continue: bool,
    /// The client explicitly asked for `Connection: keep-alive`. The
    /// gateway is conservative: absent the header it closes after one
    /// response (the pre-keep-alive behavior), even on HTTP/1.1.
    keep_alive: bool,
    /// `X-OMGD-Client` fairness token, if presented.
    client: Option<String>,
    /// Raw `Authorization` header value, if presented. Parsed against
    /// the configured bearer token by [`token_matches`].
    authorization: Option<String>,
}

/// Which paths the bearer token (when configured) protects: everything
/// that submits, leases, reports, fetches, or stops work. Liveness and
/// telemetry probes stay open — see [`ListenOptions::auth_token`].
fn path_needs_auth(path: &str) -> bool {
    path == "/jobs"
        || path == "/shutdown"
        || path.starts_with("/jobs/")
        || path.starts_with("/work/")
        || path.starts_with("/artifacts/")
}

/// `Authorization: Bearer <token>` check. The scheme is
/// case-insensitive per RFC 7235; the token comparison is constant
/// time ([`ct_eq`]) so a timing oracle cannot recover it byte by byte.
fn token_matches(authorization: Option<&str>, expected: &str) -> bool {
    let Some(h) = authorization else { return false };
    let Some((scheme, token)) = h.split_once(' ') else { return false };
    scheme.eq_ignore_ascii_case("bearer")
        && ct_eq(token.trim().as_bytes(), expected.as_bytes())
}

/// Read one request head. `Ok(None)` = clean EOF before any bytes (the
/// client opened and closed an idle connection). The head is capped at
/// [`MAX_HEAD_BYTES`] / [`MAX_HEADERS`]. `Transfer-Encoding: chunked`
/// is parsed into [`HttpHead::chunked`] (other codings, or chunked
/// combined with `Content-Length` — a request-smuggling shape — are
/// rejected here).
fn read_head<R: BufRead>(r: &mut R) -> Result<Option<HttpHead>> {
    let mut head = r.take(MAX_HEAD_BYTES);
    let mut line = String::new();
    if head.read_line(&mut line)? == 0 {
        return Ok(None);
    }
    let mut parts = line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let Some(path) = parts.next() else {
        bail!("malformed request line {:?}", line.trim_end())
    };
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol {version:?}");
    }
    // Query strings are split off the routed path; endpoints that take
    // parameters (`GET /events?n=K`) read them from `query`.
    let (path, query) = match path.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (path.to_string(), None),
    };
    let mut content_length = 0usize;
    let mut saw_content_length = false;
    let mut chunked = false;
    let mut expect_continue = false;
    let mut keep_alive = false;
    let mut client = None;
    let mut authorization = None;
    for _ in 0..MAX_HEADERS {
        let mut h = String::new();
        if head.read_line(&mut h)? == 0 {
            bail!("eof inside headers");
        }
        let h = h.trim_end();
        if h.is_empty() {
            if chunked && saw_content_length {
                bail!(
                    "both Transfer-Encoding and Content-Length present \
                     (ambiguous framing)"
                );
            }
            return Ok(Some(HttpHead {
                method,
                path,
                query,
                content_length,
                chunked,
                expect_continue,
                keep_alive,
                client,
                authorization,
            }));
        }
        let Some((name, value)) = h.split_once(':') else {
            bail!("malformed header {h:?}")
        };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                content_length = value
                    .parse()
                    .map_err(|_| {
                        anyhow::anyhow!("bad content-length {value:?}")
                    })?;
                saw_content_length = true;
            }
            "expect" => {
                expect_continue = value.eq_ignore_ascii_case("100-continue");
            }
            "connection" => {
                // Connection: keep-alive may carry other tokens too
                // (e.g. "keep-alive, TE"); "close" always wins.
                let mut ka = keep_alive;
                for tok in value.split(',') {
                    let tok = tok.trim();
                    if tok.eq_ignore_ascii_case("keep-alive") {
                        ka = true;
                    }
                    if tok.eq_ignore_ascii_case("close") {
                        ka = false;
                        break;
                    }
                }
                keep_alive = ka;
            }
            "x-omgd-client" => {
                if !value.is_empty() {
                    client = Some(value.to_string());
                }
            }
            "authorization" => {
                if !value.is_empty() {
                    authorization = Some(value.to_string());
                }
            }
            "transfer-encoding" => {
                // Only the plain `chunked` coding is spoken; anything
                // else (gzip, a coding chain) is rejected.
                if value.eq_ignore_ascii_case("chunked") {
                    chunked = true;
                } else {
                    bail!(
                        "unsupported transfer-encoding {value:?} \
                         (only \"chunked\")"
                    );
                }
            }
            _ => {}
        }
    }
    bail!("too many headers")
}

fn read_body<R: BufRead>(r: &mut R, len: usize) -> Result<Vec<u8>> {
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).context("reading request body")?;
    Ok(buf)
}

/// Why a chunked request body could not be read in full.
enum ChunkedBodyError {
    /// Decoded size exceeded the cap mid-stream; the connection is no
    /// longer framed (bytes of the body remain unread).
    TooLarge,
    /// Malformed chunked framing; the connection is not reusable.
    Malformed(String),
}

/// Decode a `Transfer-Encoding: chunked` request body via
/// [`ChunkedReader`], capped at `cap` decoded bytes. On success the
/// reader sits exactly past the terminal chunk — the connection stays
/// framed for keep-alive.
fn read_chunked_body<R: BufRead>(
    r: &mut R,
    cap: usize,
) -> std::result::Result<Vec<u8>, ChunkedBodyError> {
    let mut cr = ChunkedReader::new(r);
    let mut body = Vec::new();
    let mut buf = [0u8; 8 << 10];
    loop {
        match cr.read(&mut buf) {
            Ok(0) => return Ok(body),
            Ok(n) => {
                if body.len() + n > cap {
                    return Err(ChunkedBodyError::TooLarge);
                }
                body.extend_from_slice(&buf[..n]);
            }
            Err(e) => {
                return Err(ChunkedBodyError::Malformed(e.to_string()))
            }
        }
    }
}

/// Discard a chunked request body before an error response (the
/// chunked analogue of [`drain_body`], without buffering). `true` =
/// terminal chunk reached within [`MAX_DRAIN_BYTES`], so the
/// connection is still cleanly framed for another keep-alive request.
fn drain_chunked<R: BufRead>(r: &mut R) -> bool {
    let mut cr = ChunkedReader::new(r);
    match std::io::copy(
        &mut (&mut cr).take(MAX_DRAIN_BYTES),
        &mut std::io::sink(),
    ) {
        // n == cap: the terminal chunk was never seen — not framed.
        Ok(n) => n < MAX_DRAIN_BYTES,
        Err(_) => false,
    }
}

/// Discard up to `len` request-body bytes (capped at
/// [`MAX_DRAIN_BYTES`]) before an error response: closing a socket
/// with unread bytes can RST the reply out from under the client.
/// `true` = the body was consumed in full, so the connection is still
/// cleanly framed for another keep-alive request.
fn drain_body<R: BufRead>(r: &mut R, len: usize) -> bool {
    let want = (len as u64).min(MAX_DRAIN_BYTES);
    match std::io::copy(&mut r.take(want), &mut std::io::sink()) {
        Ok(n) => n == len as u64,
        Err(_) => false,
    }
}

fn err_body(msg: &str) -> String {
    format!("{{\"error\":\"{}\"}}", esc(msg))
}

/// Pull one `key=value` pair out of a raw query string. No percent
/// decoding — the gateway's parameters are plain integers.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query.split('&').find_map(|pair| {
        let (k, v) = pair.split_once('=')?;
        (k == key).then_some(v)
    })
}

/// One small self-delimited response with an explicit content type —
/// the Prometheus text exposition (`GET /metrics`) and the NDJSON
/// event tail (`GET /events`) are not JSON objects.
fn respond_text<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    content_type: &str,
    keep: bool,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\
         \r\nContent-Length: {}\r\nConnection: {}\r\n\r\n{body}",
        body.len(),
        if keep { "keep-alive" } else { "close" },
    )?;
    w.flush()
}

/// One binary response (the `GET /artifacts/<fp>` frame).
fn respond_bytes<W: Write>(
    w: &mut W,
    body: &[u8],
    keep: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 200 OK\r\nContent-Type: application/octet-stream\
         \r\nContent-Length: {}\r\nConnection: {}\r\n\r\n",
        body.len(),
        if keep { "keep-alive" } else { "close" },
    )?;
    w.write_all(body)?;
    w.flush()
}

/// One small self-delimited JSON response (everything except the
/// streamed `POST /jobs` body). `keep` picks the `Connection` header:
/// `Content-Length` framing makes every such response reusable, so the
/// caller decides based on what the *request* side of the exchange
/// left behind.
fn respond_json<W: Write>(
    w: &mut W,
    status: u16,
    reason: &str,
    extra: &[(&str, &str)],
    keep: bool,
    body: &str,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\
         \r\nContent-Length: {}\r\nConnection: {}\r\n",
        body.len(),
        if keep { "keep-alive" } else { "close" },
    )?;
    for (k, v) in extra {
        write!(w, "{k}: {v}\r\n")?;
    }
    write!(w, "\r\n{body}")?;
    w.flush()
}

/// Chunked transfer *encoding* writer for the keep-alive `POST /jobs`
/// response stream. Writes buffer internally; every `flush` emits the
/// buffered bytes as ONE chunk — the session flushes once per protocol
/// line, so lines map 1:1 to chunks. [`ChunkedWriter::finish`] writes
/// the terminal `0` chunk that marks end-of-stream without closing the
/// connection.
pub struct ChunkedWriter<W: Write> {
    inner: W,
    buf: Vec<u8>,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn new(inner: W) -> Self {
        Self { inner, buf: Vec::new() }
    }

    /// Flush any buffered bytes, then write the terminal chunk.
    pub fn finish(mut self) -> std::io::Result<()> {
        self.flush()?;
        self.inner.write_all(b"0\r\n\r\n")?;
        self.inner.flush()
    }
}

impl<W: Write> Write for ChunkedWriter<W> {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(data);
        Ok(data.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        // An empty buffer must NOT emit a chunk: a zero-length chunk
        // is the stream terminator.
        if !self.buf.is_empty() {
            write!(self.inner, "{:x}\r\n", self.buf.len())?;
            self.inner.write_all(&self.buf)?;
            self.inner.write_all(b"\r\n")?;
            self.buf.clear();
        }
        self.inner.flush()
    }
}

/// Chunked transfer *decoding* reader — the client side of the
/// keep-alive `POST /jobs` stream ([`super::remote`] and the
/// integration tests use it). After the terminal chunk, `read` returns
/// `Ok(0)` and the underlying reader is positioned exactly past the
/// stream, ready for the next keep-alive response on the same socket.
pub struct ChunkedReader<R: BufRead> {
    inner: R,
    remaining: usize,
    after_data: bool,
    done: bool,
}

impl<R: BufRead> ChunkedReader<R> {
    pub fn new(inner: R) -> Self {
        Self { inner, remaining: 0, after_data: false, done: false }
    }

    /// The underlying reader, for connection reuse after the terminal
    /// chunk.
    pub fn into_inner(self) -> R {
        self.inner
    }
}

fn bad_chunk(msg: &str) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, msg.to_string())
}

impl<R: BufRead> Read for ChunkedReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        loop {
            if self.done || buf.is_empty() {
                return Ok(0);
            }
            if self.remaining > 0 {
                let want = buf.len().min(self.remaining);
                let n = self.inner.read(&mut buf[..want])?;
                if n == 0 {
                    return Err(bad_chunk("eof inside a chunk"));
                }
                self.remaining -= n;
                if self.remaining == 0 {
                    self.after_data = true;
                }
                return Ok(n);
            }
            if self.after_data {
                // Chunk data is terminated by CRLF before the next
                // size line.
                let mut crlf = String::new();
                self.inner.read_line(&mut crlf)?;
                if !crlf.trim_end().is_empty() {
                    return Err(bad_chunk("missing chunk terminator"));
                }
                self.after_data = false;
            }
            let mut line = String::new();
            if self.inner.read_line(&mut line)? == 0 {
                return Err(bad_chunk("eof before a chunk size"));
            }
            let size_str =
                line.trim_end().split(';').next().unwrap_or("").trim();
            let size = usize::from_str_radix(size_str, 16)
                .map_err(|_| bad_chunk("malformed chunk size"))?;
            if size == 0 {
                // Terminal chunk: skip (empty) trailer lines up to the
                // final blank line.
                loop {
                    let mut t = String::new();
                    if self.inner.read_line(&mut t)? == 0
                        || t.trim_end().is_empty()
                    {
                        break;
                    }
                }
                self.done = true;
                return Ok(0);
            }
            self.remaining = size;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn head_of(req: &str) -> Result<Option<HttpHead>> {
        read_head(&mut req.as_bytes())
    }

    #[test]
    fn parses_a_minimal_request_head() {
        let h = head_of(
            "POST /jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 42\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(h.method, "POST");
        assert_eq!(h.path, "/jobs");
        assert_eq!(h.content_length, 42);
        assert!(!h.expect_continue);
    }

    #[test]
    fn header_names_are_case_insensitive_and_query_is_stripped() {
        let h = head_of(
            "GET /stats?verbose=1 HTTP/1.1\r\ncontent-LENGTH: 7\r\n\
             Expect: 100-Continue\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(h.path, "/stats");
        assert_eq!(h.query.as_deref(), Some("verbose=1"));
        assert_eq!(h.content_length, 7);
        assert!(h.expect_continue);
    }

    #[test]
    fn query_strings_split_and_parse() {
        let h = head_of("GET /events?n=32&x=y HTTP/1.1\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(h.path, "/events");
        let q = h.query.as_deref().unwrap();
        assert_eq!(query_param(q, "n"), Some("32"));
        assert_eq!(query_param(q, "x"), Some("y"));
        assert_eq!(query_param(q, "missing"), None);
        let h = head_of("GET /events HTTP/1.1\r\n\r\n").unwrap().unwrap();
        assert!(h.query.is_none());
        // Malformed pairs are skipped, not errors.
        assert_eq!(query_param("novalue&n=5", "n"), Some("5"));
    }

    #[test]
    fn respond_text_frames_with_content_type() {
        let mut out: Vec<u8> = Vec::new();
        respond_text(
            &mut out,
            200,
            "OK",
            "text/plain; version=0.0.4; charset=utf-8",
            true,
            "omgd_http_requests_total 3\n",
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text
            .contains("Content-Type: text/plain; version=0.0.4"));
        assert!(text.contains("Content-Length: 27\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\nomgd_http_requests_total 3\n"));
    }

    #[test]
    fn rejects_malformed_heads() {
        assert!(head_of("").unwrap().is_none(), "clean EOF is None");
        assert!(head_of("GARBAGE\r\n\r\n").is_err());
        assert!(head_of("GET /x SPDY/3\r\n\r\n").is_err());
        assert!(head_of("GET /x HTTP/1.1\r\nnocolon\r\n\r\n").is_err());
        assert!(head_of(
            "GET /x HTTP/1.1\r\nContent-Length: nan\r\n\r\n"
        )
        .is_err());
        assert!(
            head_of("GET /x HTTP/1.1\r\nHost: y\r\n").is_err(),
            "eof before the blank line"
        );
    }

    #[test]
    fn parses_chunked_transfer_encoding() {
        let h = head_of(
            "POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert!(h.chunked);
        assert_eq!(h.content_length, 0);
        // non-chunked codings are rejected
        assert!(head_of(
            "POST /jobs HTTP/1.1\r\nTransfer-Encoding: gzip\r\n\r\n"
        )
        .is_err());
        // chunked + Content-Length is the smuggling shape — rejected
        assert!(head_of(
            "POST /jobs HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\
             Content-Length: 10\r\n\r\n"
        )
        .is_err());
    }

    #[test]
    fn chunked_body_reader_caps_and_positions() {
        // 2-chunk body, trailing keep-alive request bytes intact.
        let wire = b"3\r\nabc\r\n4\r\ndefg\r\n0\r\n\r\nNEXT";
        let mut r = &wire[..];
        let body = read_chunked_body(&mut r, 1024).unwrap();
        assert_eq!(body, b"abcdefg");
        let mut rest = String::new();
        r.read_to_string(&mut rest).unwrap();
        assert_eq!(rest, "NEXT", "reader must sit past the body");
        // cap enforcement mid-stream
        let mut r2 = &wire[..];
        assert!(matches!(
            read_chunked_body(&mut r2, 5),
            Err(ChunkedBodyError::TooLarge)
        ));
        // malformed framing
        let mut r3 = &b"zz\r\nboom"[..];
        assert!(matches!(
            read_chunked_body(&mut r3, 1024),
            Err(ChunkedBodyError::Malformed(_))
        ));
        // drain: framed on success, not framed when truncated
        let mut r4 = &wire[..];
        assert!(drain_chunked(&mut r4));
        let mut r5 = &b"5\r\nab"[..];
        assert!(!drain_chunked(&mut r5));
    }

    #[test]
    fn respond_json_frames_a_complete_response() {
        let mut out: Vec<u8> = Vec::new();
        respond_json(
            &mut out,
            429,
            "Too Many Requests",
            &[("Retry-After", "1")],
            false,
            "{\"error\":\"full\"}",
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Content-Length: 16\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"error\":\"full\"}"));
        let mut out: Vec<u8> = Vec::new();
        respond_json(&mut out, 200, "OK", &[], true, "{}").unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.contains("Connection: keep-alive\r\n"));
    }

    #[test]
    fn head_parses_keep_alive_and_client_token() {
        let h = head_of(
            "POST /jobs HTTP/1.1\r\nConnection: Keep-Alive\r\n\
             X-OMGD-Client: grid-a\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert!(h.keep_alive);
        assert_eq!(h.client.as_deref(), Some("grid-a"));
        // Absent header = close (the conservative pre-keep-alive
        // default), and "close" beats "keep-alive" in a token list.
        let h = head_of("GET /stats HTTP/1.1\r\nHost: x\r\n\r\n")
            .unwrap()
            .unwrap();
        assert!(!h.keep_alive);
        assert!(h.client.is_none());
        let h = head_of(
            "GET /stats HTTP/1.1\r\nConnection: keep-alive, close\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert!(!h.keep_alive);
    }

    #[test]
    fn chunked_round_trip_and_reader_positioning() {
        // Writer: one chunk per flush, terminal 0-chunk on finish.
        let mut wire: Vec<u8> = Vec::new();
        {
            let mut cw = ChunkedWriter::new(&mut wire);
            cw.write_all(b"{\"accepted\":0}\n").unwrap();
            cw.flush().unwrap();
            cw.flush().unwrap(); // idempotent: no empty chunk emitted
            cw.write_all(b"{\"seq\":0}\n").unwrap();
            cw.finish().unwrap();
        }
        let text = String::from_utf8(wire.clone()).unwrap();
        assert!(text.starts_with("f\r\n{\"accepted\":0}\n\r\n"));
        assert!(text.ends_with("0\r\n\r\n"));
        // Reader: decodes the byte stream and leaves trailing bytes
        // (the next keep-alive response) untouched.
        wire.extend_from_slice(b"HTTP/1.1 200 OK\r\n");
        let mut cr = ChunkedReader::new(&wire[..]);
        let mut body = String::new();
        cr.read_to_string(&mut body).unwrap();
        assert_eq!(body, "{\"accepted\":0}\n{\"seq\":0}\n");
        let mut rest = String::new();
        cr.into_inner().read_to_string(&mut rest).unwrap();
        assert_eq!(rest, "HTTP/1.1 200 OK\r\n");
    }

    #[test]
    fn chunked_reader_rejects_garbage() {
        let mut cr = ChunkedReader::new(&b"zz\r\nboom"[..]);
        let mut s = String::new();
        assert!(cr.read_to_string(&mut s).is_err(), "bad size line");
        let mut cr = ChunkedReader::new(&b"5\r\nab"[..]);
        let mut s = String::new();
        assert!(cr.read_to_string(&mut s).is_err(), "eof inside chunk");
    }

    #[test]
    fn drained_bodies_report_framing() {
        let mut input: &[u8] = b"0123456789rest";
        assert!(drain_body(&mut input, 10), "fully drained");
        assert_eq!(input, b"rest");
        let mut short: &[u8] = b"abc";
        assert!(!drain_body(&mut short, 10), "truncated body");
    }

    #[test]
    fn result_paths_parse_strictly() {
        assert_eq!(parse_result_path("/jobs/7/result"), Some(7));
        assert_eq!(parse_result_path("/jobs/0/result"), Some(0));
        assert_eq!(parse_result_path("/jobs/x/result"), None);
        assert_eq!(parse_result_path("/jobs/7/steal"), None);
        assert_eq!(parse_result_path("/jobs/7"), None);
        assert_eq!(parse_result_path("/jobs/"), None);
        assert_eq!(parse_result_path("/work/7/result"), None);
    }

    #[test]
    fn work_paths_parse_strictly() {
        assert_eq!(parse_work_path("/work/7/renew"), Some((7, "renew")));
        assert_eq!(
            parse_work_path("/work/123/result"),
            Some((123, "result"))
        );
        assert_eq!(parse_work_path("/work/lease"), None);
        assert_eq!(parse_work_path("/work/x/result"), None);
        assert_eq!(parse_work_path("/work/7/steal"), None);
        assert_eq!(parse_work_path("/work/"), None);
        assert_eq!(parse_work_path("/jobs"), None);
    }

    #[test]
    fn auth_header_parses_and_token_matching_is_strict() {
        let h = head_of(
            "POST /jobs HTTP/1.1\r\nAuthorization: Bearer s3cret\r\n\r\n",
        )
        .unwrap()
        .unwrap();
        assert_eq!(h.authorization.as_deref(), Some("Bearer s3cret"));
        assert!(token_matches(h.authorization.as_deref(), "s3cret"));
        // Scheme is case-insensitive; the token is not.
        assert!(token_matches(Some("bearer s3cret"), "s3cret"));
        assert!(token_matches(Some("BEARER s3cret"), "s3cret"));
        assert!(!token_matches(Some("Bearer S3CRET"), "s3cret"));
        assert!(!token_matches(Some("Bearer s3cre"), "s3cret"));
        assert!(!token_matches(Some("Bearer s3crets"), "s3cret"));
        assert!(!token_matches(Some("Basic s3cret"), "s3cret"));
        assert!(!token_matches(Some("s3cret"), "s3cret"), "no scheme");
        assert!(!token_matches(None, "s3cret"));
    }

    #[test]
    fn auth_covers_state_paths_and_spares_probes() {
        for p in [
            "/jobs",
            "/jobs/7/result",
            "/work/lease",
            "/work/7/renew",
            "/work/7/result",
            "/artifacts/abcd",
            "/shutdown",
        ] {
            assert!(path_needs_auth(p), "{p} must require auth");
        }
        for p in ["/healthz", "/stats", "/metrics", "/events", "/cache"] {
            assert!(!path_needs_auth(p), "{p} must stay open");
        }
    }

    #[test]
    fn body_reader_honors_content_length() {
        let mut input: &[u8] = b"hello worldTRAILING";
        let body = read_body(&mut input, 11).unwrap();
        assert_eq!(&body, b"hello world");
        assert!(read_body(&mut input, 99).is_err(), "short body errors");
    }
}
