//! Transport-agnostic JSONL serve sessions over a shared [`JobHub`].
//!
//! One [`JobHub`] owns the bounded [`JobQueue`], the result router, and
//! the hub-lifetime counters; any number of concurrent sessions — the
//! classic stdin/stdout loop of `omgd serve`, or one per HTTP
//! connection in [`super::net`] — multiplex jobs into the same worker
//! pool and result cache. Each session speaks the JSONL protocol (one
//! JSON object per line):
//!
//! * request  → `{"kind":"finetune","task":"CoLA","method":"lisa-wor",
//!   "seed":1,"epochs":4,"priority":5}` (see [`JobSpec::from_json`] for
//!   the full field set; `priority` is optional, higher runs first)
//! * control  → `{"cmd":"shutdown"}` ends the session (input EOF too)
//! * ack      → `{"accepted":<seq>,"hash":"<spec hash>","label":"..."}`
//! * result   → `{"seq":N,"label":"...","hash":"...","status":"done",
//!   "cached":false,"final_metric":X,"tail_loss":X,"steps":N,"secs":X}`
//!   or `{"seq":N,...,"status":"failed","error":"..."}`
//! * reject   → `{"error":"...","line":N}`
//!
//! Results stream back in *completion* order (match on `seq`); a
//! request's ack always precedes its result line. The hub routes each
//! result only to the session that submitted it, so concurrent clients
//! sharing one hub never see each other's lines. Per-session
//! backpressure is [`SessionOptions::max_in_flight`]: submission of the
//! next request blocks until a result drains. Full protocol spec with
//! examples: `docs/serve-protocol.md`.
//!
//! Besides the local pool, queued jobs can be **leased** to remote
//! workers ([`JobHub::try_lease`] / [`JobHub::complete_remote`], used
//! by the gateway's `/work/*` endpoints — see [`super::net`] and
//! [`super::remote`]): a lease parks the job in a table with a TTL, a
//! completed lease dispatches through the same seq-routed channel a
//! local result would, and an expired lease is requeued **with its
//! original seq** so the submitting session's ack stays valid across
//! worker crashes.

use super::journal::{JobJournal, PendingJob, Record as JournalRecord, Replay};
use super::pool::{worker_loop, JobOutcome, JobResult, JobStatus};
use super::queue::{Job, JobQueue, PopScan, PopTimeout, TryPush};
use super::spec::JobSpec;
use crate::lifecycle::{ClientLedger, JobEvent, Lifecycle};
use crate::obs;
use crate::util::json::Json;
use anyhow::Result;
use omgd_util::lock_recover;
use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Counters for one serve session.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub accepted: usize,
    pub rejected: usize,
    pub done: usize,
    pub failed: usize,
    pub cached: usize,
}

/// Per-session knobs.
#[derive(Clone, Debug)]
pub struct SessionOptions {
    /// Cap on this session's unfinished jobs: submission of the next
    /// request blocks until a result drains. `0` = unlimited (the stdin
    /// loop's historical behavior — the bounded queue is then the only
    /// backpressure).
    pub max_in_flight: usize,
    /// Client identity this session's jobs are accounted under (the
    /// `X-OMGD-Client` token). When the hub has a client quota, every
    /// submission first acquires one of the token's in-flight slots —
    /// shared across all sessions presenting the same token — blocking
    /// until a slot drains. `None` = anonymous, never quota-throttled.
    pub client: Option<String>,
}

impl Default for SessionOptions {
    fn default() -> Self {
        Self { max_in_flight: 0, client: None }
    }
}

/// Shared serving core: the bounded queue plus the seq → session result
/// routing that lets N concurrent sessions share one worker pool.
///
/// Workers drain [`JobHub::queue`] via [`worker_loop`] and send
/// [`JobResult`]s to a single router thread (one per hub), which
/// dispatches each result to the reply channel registered by
/// [`JobHub::submit`]. [`with_hub`] wires all of that up around a
/// caller-supplied body; [`super::net`] builds the same shape with its
/// own accept loop.
pub struct JobHub {
    pub queue: JobQueue,
    /// The transition authority. Every job state change below —
    /// admission, enqueue, lease, renew, expiry, report, dispatch,
    /// replay — is applied here **first**; the maps that follow are
    /// projections of it, never the source of truth.
    lifecycle: Lifecycle,
    routes: Mutex<HashMap<u64, Route>>,
    /// Jobs currently leased to remote workers, keyed by seq. An
    /// expired entry is requeued (same seq) by [`Self::requeue_expired`]
    /// so a crashed or partitioned worker's jobs are re-dispatched.
    leases: Mutex<HashMap<u64, LeaseEntry>>,
    /// Unfinished jobs per client token, across every session that
    /// presented the token ([`Self::acquire_client_slot`] /
    /// [`Self::dispatch`]); the fairness ledger behind `--client-quota`.
    clients: ClientLedger,
    accepted: AtomicUsize,
    rejected: AtomicUsize,
    done: AtomicUsize,
    failed: AtomicUsize,
    cached: AtomicUsize,
    leased: AtomicUsize,
    affinity: AtomicUsize,
    requeued: AtomicUsize,
    conflicts: AtomicUsize,
    /// Durable write-ahead journal ([`Self::attach_journal`]) — `None`
    /// for purely in-memory hubs (local pools, stdin serve, tests).
    journal: OnceLock<JobJournal>,
    /// Every admitted-but-not-dispatched job, keyed by seq: the
    /// snapshot compaction persists, and the source of the spec-hash
    /// set cache GC must keep parked checkpoints alive for.
    live: Mutex<HashMap<u64, PendingJob>>,
    /// Dispatched results retained for `GET /jobs/<seq>/result`
    /// re-polls across reconnects/restarts (journal-attached hubs
    /// only), capped at [`RETAINED_RESULTS`].
    completed: Mutex<CompletedLog>,
    /// Replayed jobs whose submitting session died with the previous
    /// process (seq → client token). Their eventual dispatch finds no
    /// route; the token's ledger slot is released from here instead.
    orphans: Mutex<HashMap<u64, Option<String>>>,
    /// `max(seq) + 1` over every admission this hub has seen
    /// (including replay) — the `meta` floor compaction writes.
    seq_floor: AtomicU64,
}

/// Cap on results retained for by-seq re-polls; oldest evict first.
pub const RETAINED_RESULTS: usize = 4096;

#[derive(Default)]
struct CompletedLog {
    map: HashMap<u64, JobResult>,
    order: VecDeque<u64>,
}

impl CompletedLog {
    /// Insert a result, returning the seqs evicted from the retained
    /// window so the caller can drop them from the lifecycle table too.
    fn insert(&mut self, r: JobResult) -> Vec<u64> {
        if self.map.insert(r.seq, r.clone()).is_none() {
            self.order.push_back(r.seq);
        }
        let mut evicted = Vec::new();
        while self.order.len() > RETAINED_RESULTS {
            if let Some(old) = self.order.pop_front() {
                self.map.remove(&old);
                evicted.push(old);
            }
        }
        evicted
    }
}

/// What [`JobHub::result_for`] knows about a seq.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ResultLookup {
    /// The job finished; here is its protocol result line.
    Ready(String),
    /// Admitted (queued or leased) but not finished — poll again.
    Pending,
    /// Never admitted, or evicted from the retained-results window.
    Unknown,
}

/// One submitted job's reply channel plus the client token its
/// completion must be debited against.
struct Route {
    tx: mpsc::Sender<JobResult>,
    client: Option<String>,
}

struct LeaseEntry {
    spec: JobSpec,
    priority: i32,
    afp: String,
    worker: String,
    expires: Instant,
    /// Queue wait the job accrued before this lease was granted —
    /// carried so the completion's journal span can report the full
    /// enqueue → lease → run trace.
    queue_secs: f64,
}

/// Worker-reported per-phase durations for one remote completion,
/// parsed off the `/work/<seq>/result` body by the gateway and folded
/// into the phase histograms here. Zero means "not reported" (old
/// workers, failures before the phase ran).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseSecs {
    /// Artifact-set download + unpack time.
    pub sync: f64,
    /// Execution time of the runner itself (cache replays excluded).
    pub run: f64,
}

/// Hub-lifetime remote-worker counters (the `"remote"` block of
/// `GET /stats`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RemoteStats {
    /// Leases granted to remote workers.
    pub leased: usize,
    /// Leases placed by artifact affinity: the granted job's artifact
    /// fingerprint was already in the requesting worker's cache.
    pub affinity: usize,
    /// Expired leases re-dispatched into the queue.
    pub requeued: usize,
    /// Stale remote completions/renewals rejected (lease lost).
    pub conflicts: usize,
}

/// What a lease request got.
#[derive(Debug)]
pub enum LeaseReply {
    /// One job, now owned by the requesting worker until `ttl` elapses
    /// (renewable).
    Granted(LeaseInfo),
    /// Queue open but empty for the whole wait window.
    Idle,
    /// Queue closed/cancelled: no job will ever arrive again.
    Closed,
}

/// The leased job plus everything a remote worker needs to run it.
#[derive(Debug)]
pub struct LeaseInfo {
    pub seq: u64,
    pub priority: i32,
    pub spec: JobSpec,
    /// The gateway's artifact fingerprint for the spec's model
    /// (`"absent"` when the gateway has no artifacts for it) — the
    /// worker's sync key *and* the cache key on both ends.
    pub afp: String,
    /// True when the job was placed by artifact affinity — its
    /// fingerprint was already in the worker's advertised cache, so no
    /// sync round trip is needed.
    pub affine: bool,
    pub ttl: Duration,
}

/// Outcome of a remote completion ([`JobHub::complete_remote`]).
pub enum RemoteDone {
    /// The result was dispatched; the gateway may now cache it under
    /// `(spec, afp)`.
    Accepted { spec: JobSpec, afp: String },
    /// The caller no longer holds the lease (it expired and was
    /// re-dispatched, or another worker owns it): the result was
    /// dropped. Exactly-once dispatch is preserved by the re-run.
    Conflict,
}

impl JobHub {
    /// A hub whose queue holds at most `queue_capacity` pending jobs.
    pub fn new(queue_capacity: usize) -> Self {
        Self {
            queue: JobQueue::bounded(queue_capacity),
            lifecycle: Lifecycle::new(),
            routes: Mutex::new(HashMap::new()),
            leases: Mutex::new(HashMap::new()),
            clients: ClientLedger::new(),
            accepted: AtomicUsize::new(0),
            rejected: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            failed: AtomicUsize::new(0),
            cached: AtomicUsize::new(0),
            leased: AtomicUsize::new(0),
            affinity: AtomicUsize::new(0),
            requeued: AtomicUsize::new(0),
            conflicts: AtomicUsize::new(0),
            journal: OnceLock::new(),
            live: Mutex::new(HashMap::new()),
            completed: Mutex::new(CompletedLog::default()),
            orphans: Mutex::new(HashMap::new()),
            seq_floor: AtomicU64::new(0),
        }
    }

    /// Attach the durable journal. Every later admission, lease
    /// grant/renewal, completion, and cancellation is appended (and
    /// fsynced) before a client can observe the transition's effects.
    /// One journal per hub; a second attach is ignored with a warning.
    pub fn attach_journal(&self, j: JobJournal) {
        if self.journal.set(j).is_err() {
            eprintln!("warning: hub journal already attached; ignoring");
        }
    }

    pub fn has_journal(&self) -> bool {
        self.journal.get().is_some()
    }

    /// Best-effort journal append: a full disk must degrade durability,
    /// not availability (the job still runs; it just won't survive a
    /// crash).
    fn journal_append(&self, rec: &JournalRecord) {
        if let Some(j) = self.journal.get() {
            if let Err(e) = j.append(rec) {
                eprintln!("warning: journal append failed: {e:#}");
            }
        }
    }

    /// Apply a journal [`Replay`] to this (fresh) hub: raise the seq
    /// counter, requeue every still-pending admission **with its
    /// original seq** (as lease expiry does), rebuild the client
    /// ledger, and repopulate the retained-results window so
    /// reconnecting clients can re-poll by seq. Returns
    /// `(requeued, completed)` counts.
    pub fn recover(&self, rep: Replay) -> (usize, usize) {
        self.queue.resume_from(rep.next_seq);
        self.seq_floor.fetch_max(rep.next_seq, Ordering::Relaxed);
        let mut requeued = 0usize;
        for p in rep.pending {
            // Authority first: a journaled pending job is born straight
            // into `Queued`. A duplicate seq in a corrupt journal is
            // refused here and skipped instead of double-requeued.
            if let Err(e) = self.lifecycle.apply(p.seq, &JobEvent::ReplayPending) {
                eprintln!("warning: replay skipped seq {}: {e}", p.seq);
                continue;
            }
            let job = Job {
                seq: p.seq,
                priority: p.priority,
                spec: p.spec.clone(),
                enqueued: Instant::now(),
            };
            if let Err(e) = self.queue.requeue(job) {
                eprintln!(
                    "warning: replay could not requeue seq {}: {e:#}",
                    p.seq
                );
                continue;
            }
            // Quota slots were legally held before the crash: rebuild
            // without blocking on the (possibly lowered) quota.
            self.clients.restore(p.client.as_deref());
            lock_recover(&self.orphans).insert(p.seq, p.client.clone());
            lock_recover(&self.live).insert(p.seq, p);
            self.accepted.fetch_add(1, Ordering::Relaxed);
            requeued += 1;
        }
        let n_done = rep.completed.len();
        let mut log = lock_recover(&self.completed);
        for r in rep.completed {
            if let Err(e) = self.lifecycle.apply(r.seq, &JobEvent::ReplayDone) {
                eprintln!("warning: replay skipped completed seq {}: {e}", r.seq);
                continue;
            }
            self.accepted.fetch_add(1, Ordering::Relaxed);
            if r.from_cache {
                self.cached.fetch_add(1, Ordering::Relaxed);
            }
            if r.is_ok() {
                self.done.fetch_add(1, Ordering::Relaxed);
            } else {
                self.failed.fetch_add(1, Ordering::Relaxed);
            }
            for old in log.insert(r) {
                self.lifecycle.forget(old);
            }
        }
        (requeued, n_done)
    }

    /// Compact the attached journal down to a snapshot of live state
    /// (pending admissions + retained completions). No-op without a
    /// journal. Run at startup right after replay, and on clean
    /// shutdown.
    pub fn compact_journal(&self) -> Result<()> {
        let Some(j) = self.journal.get() else { return Ok(()) };
        let mut pending: Vec<PendingJob> = lock_recover(&self.live)
            .iter()
            .map(|(_, p)| p.clone())
            .collect();
        pending.sort_by_key(|p| p.seq);
        let mut completed: Vec<JobResult> = {
            let log = lock_recover(&self.completed);
            log.map.iter().map(|(_, r)| r.clone()).collect()
        };
        completed.sort_by_key(|r| r.seq);
        j.compact(
            self.seq_floor.load(Ordering::Relaxed),
            &pending,
            &completed,
        )
    }

    /// Look up the fate of a seq for a reconnecting client
    /// (`GET /jobs/<seq>/result`).
    pub fn result_for(&self, seq: u64) -> ResultLookup {
        if let Some(r) = lock_recover(&self.completed).map.get(&seq) {
            return ResultLookup::Ready(result_line(r));
        }
        if lock_recover(&self.live).contains_key(&seq)
            || lock_recover(&self.routes).contains_key(&seq)
            || lock_recover(&self.leases).contains_key(&seq)
        {
            return ResultLookup::Pending;
        }
        ResultLookup::Unknown
    }

    /// Spec hashes of every admitted-but-unfinished job — the set whose
    /// parked checkpoints the cache GC must not evict
    /// ([`super::cache::ResultCache::gc_at_protected`]).
    pub fn live_spec_hashes(&self) -> HashSet<String> {
        lock_recover(&self.live)
            .iter()
            .map(|(_, p)| p.spec.hash_hex())
            .collect()
    }

    /// The job/lease transition authority — exposed read-only for
    /// diagnostics and tests; mutations stay inside the hub methods.
    pub fn lifecycle(&self) -> &Lifecycle {
        &self.lifecycle
    }

    /// Set the per-client in-flight quota (`0` = unlimited). The
    /// gateway installs `--client-quota` here before serving; changing
    /// it mid-flight only affects future acquisitions.
    pub fn set_client_quota(&self, quota: usize) {
        self.clients.set_quota(quota);
    }

    /// Unfinished jobs currently accounted to `client` across every
    /// session presenting that token.
    pub fn client_in_flight(&self, client: &str) -> usize {
        self.clients.in_flight(client)
    }

    /// Snapshot of every client token with unfinished jobs, sorted by
    /// token (the `"clients"` block of `GET /stats`).
    pub fn clients_snapshot(&self) -> Vec<(String, usize)> {
        self.clients.snapshot()
    }

    /// Reserve one in-flight slot for `client`, blocking while the
    /// token is at quota ([`ClientLedger::acquire`]). Slots are
    /// released by [`Self::dispatch`] as the token's results (from any
    /// of its sessions) drain, so a blocked submitter always makes
    /// progress; callers on a failed submit must return the slot via
    /// [`Self::release_client_slot`].
    fn acquire_client_slot(&self, client: &str) {
        self.clients.acquire(Some(client));
    }

    /// Return a slot acquired by [`Self::acquire_client_slot`] whose
    /// job never made it into the queue.
    fn release_client_slot(&self, client: &str) {
        self.clients.release(Some(client));
    }

    /// True when the pending queue is at capacity — the signal the HTTP
    /// gateway turns into `429` + `Retry-After`.
    pub fn is_saturated(&self) -> bool {
        self.queue.len() >= self.queue.capacity()
    }

    /// Submit one job; its eventual [`JobResult`] goes to `reply`.
    /// Blocks while the queue is full; fails only once the hub drains
    /// (queue closed). `client` attributes the job to a fairness
    /// ledger token — callers must already hold one of the token's
    /// slots (`acquire_client_slot`); the dispatch path returns it
    /// when the result lands.
    ///
    /// The push and the route registration happen together under the
    /// routes lock, so a job that completes in microseconds still finds
    /// its reply channel — results are never lost to that race. The
    /// push itself is non-blocking ([`JobQueue::try_push`]); waiting
    /// for queue space happens *outside* the lock, so one session
    /// stuck on a full queue never stalls result dispatch for the
    /// others.
    pub fn submit(
        &self,
        mut spec: JobSpec,
        priority: i32,
        reply: &mpsc::Sender<JobResult>,
        client: Option<&str>,
    ) -> Result<u64> {
        let hash = spec.hash_hex();
        let rec_spec = spec.clone();
        let seq = loop {
            {
                let mut routes = lock_recover(&self.routes);
                match self.queue.try_push(spec, priority) {
                    TryPush::Pushed(seq) => {
                        // Authority first: the seq is fresh off the
                        // queue's counter, so Admit → Enqueue cannot
                        // be refused; a failure here means seq reuse
                        // and is a bug worth shouting about.
                        for ev in [JobEvent::Admit, JobEvent::Enqueue] {
                            if let Err(e) = self.lifecycle.apply(seq, &ev) {
                                eprintln!(
                                    "warning: lifecycle refused {ev:?} for fresh seq {seq}: {e}"
                                );
                            }
                        }
                        routes.insert(
                            seq,
                            Route {
                                tx: reply.clone(),
                                client: client.map(String::from),
                            },
                        );
                        // Registered under the routes lock (ordering:
                        // routes → live, matching dispatch) so even a
                        // microsecond completion finds the live entry.
                        lock_recover(&self.live).insert(
                            seq,
                            PendingJob {
                                seq,
                                priority,
                                client: client.map(String::from),
                                spec: rec_spec.clone(),
                            },
                        );
                        self.accepted.fetch_add(1, Ordering::Relaxed);
                        let mut ev = obs::Event::new("enqueue", seq);
                        ev.hash = hash;
                        ev.client =
                            client.unwrap_or_default().to_string();
                        obs::journal().push(ev);
                        break seq;
                    }
                    TryPush::Closed(_) => {
                        anyhow::bail!("job queue is closed")
                    }
                    TryPush::Full(s) => spec = s,
                }
            }
            self.queue.wait_not_full();
        };
        self.seq_floor.fetch_max(seq + 1, Ordering::Relaxed);
        // Durable admission record — fsynced outside the routes lock so
        // a slow disk never stalls result dispatch. Replay tolerates
        // the resulting done-before-admit reordering for cached jobs.
        self.journal_append(&JournalRecord::Admit {
            seq,
            priority,
            client: client.map(String::from),
            spec: rec_spec,
        });
        Ok(seq)
    }

    /// Count one request that never became a job (parse/validation
    /// reject) so `GET /stats` stays coherent with the live counters.
    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Hub-lifetime job counters:
    /// (accepted, rejected, done, failed, cached) — all updated live.
    pub fn counters(&self) -> (usize, usize, usize, usize, usize) {
        (
            self.accepted.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.done.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.cached.load(Ordering::Relaxed),
        )
    }

    /// Router loop: drain worker results and dispatch each one.
    pub(crate) fn route(&self, rx: mpsc::Receiver<JobResult>) {
        for r in rx {
            self.dispatch(r);
        }
    }

    /// Bump the completion counters and hand one result to the session
    /// that submitted it. A vanished session (send fails) is fine — the
    /// job still ran and was cached. Shared by the local-pool router and
    /// the remote completion path, so both provide exactly-once dispatch
    /// through the same `routes.remove`.
    fn dispatch(&self, r: JobResult) {
        // Authority first. Local results finalize out of Queued/
        // Requeued (cache hits and pool completions never pass through
        // a lease); remote results arrive here already `Reported` by
        // [`Self::complete_remote`]. Jobs pushed straight into the
        // public queue meet the authority for the first time here.
        if let Err(e) = self.lifecycle.apply_or_register(
            r.seq,
            &[JobEvent::Admit, JobEvent::Enqueue],
            &JobEvent::Finalize,
        ) {
            // A second result for a finalized seq would double-count
            // and double-send; the authority makes that impossible.
            eprintln!(
                "warning: dropping duplicate/illegal result for seq {}: {e}",
                r.seq
            );
            return;
        }
        if r.from_cache {
            self.cached.fetch_add(1, Ordering::Relaxed);
            obs::CACHE_HITS.inc();
        }
        if r.is_ok() {
            self.done.fetch_add(1, Ordering::Relaxed);
            obs::JOBS_COMPLETED.inc();
        } else {
            self.failed.fetch_add(1, Ordering::Relaxed);
            obs::JOBS_FAILED.inc();
        }
        // Durable completion first: once any client can observe this
        // result, a restarted gateway must reproduce it on re-poll.
        if self.journal.get().is_some() {
            self.journal_append(&JournalRecord::Done {
                seq: r.seq,
                status: r.status.clone(),
                from_cache: r.from_cache,
                secs: r.secs,
                spec: r.spec.clone(),
            });
            for old in lock_recover(&self.completed).insert(r.clone()) {
                self.lifecycle.forget(old);
            }
        } else {
            // No retained-results window: the terminal state has been
            // externalized once the route fires, so the authority can
            // forget the seq and stay O(live) in memory.
            self.lifecycle.forget(r.seq);
        }
        let reply = lock_recover(&self.routes).remove(&r.seq);
        lock_recover(&self.live).remove(&r.seq);
        let orphan = lock_recover(&self.orphans).remove(&r.seq);
        if let Some(route) = reply {
            if let Some(client) = &route.client {
                self.release_client_slot(client);
            }
            let _ = route.tx.send(r);
        } else if let Some(Some(client)) = orphan {
            // Replayed job with no live session: its quota slot was
            // rebuilt by recover(); drain it here.
            self.release_client_slot(&client);
        }
    }

    /// Lease one queued job to a remote worker: wait up to `wait` for
    /// work, then record the lease (expiring after `ttl`, renewable via
    /// [`Self::renew`]). Expired leases are swept first, so a single
    /// polling worker also drives re-dispatch.
    ///
    /// `cached_fps` is the worker's advertised artifact cache and
    /// `window` the affinity scan bound: up to `window` queued jobs (of
    /// the head's priority) are scanned for one whose artifact
    /// fingerprint the worker already holds, falling back to the
    /// oldest-first head so no job starves
    /// ([`JobQueue::pop_scan_timeout`] owns the ordering guarantees).
    /// `window <= 1` or an empty fingerprint set disables the scan —
    /// the head is leased exactly as before.
    pub fn try_lease(
        &self,
        worker: &str,
        cached_fps: &HashSet<String>,
        window: usize,
        ttl: Duration,
        wait: Duration,
    ) -> LeaseReply {
        self.requeue_expired();
        // A worker advertising nothing can never match: skip the scan
        // entirely (plain oldest-first pop, no filesystem work under
        // the queue lock).
        let (job, affine, mut memo) = if cached_fps.is_empty()
            || window <= 1
        {
            match self.queue.pop_timeout(wait) {
                PopTimeout::Job(job) => (job, false, HashMap::new()),
                PopTimeout::Empty => return LeaseReply::Idle,
                PopTimeout::Closed => return LeaseReply::Closed,
            }
        } else {
            // Fingerprinting a spec hits the filesystem and the
            // predicate runs under the queue lock, so memoize per
            // (dir, model) — a grid's cells share a handful of
            // artifact sets, bounding the scan to one or two
            // `read_dir`s per lease.
            let mut memo: HashMap<(String, String), String> =
                HashMap::new();
            let mut pred = |spec: &JobSpec| {
                let key = (
                    spec.cfg.artifacts_dir.clone(),
                    spec.cfg.model.clone(),
                );
                let fp = memo.entry(key).or_insert_with(|| {
                    super::artifact_fingerprint(&spec.cfg)
                });
                fp.as_str() != "absent"
                    && cached_fps.contains(fp.as_str())
            };
            match self.queue.pop_scan_timeout(wait, window, &mut pred) {
                PopScan::Match(job) => (job, true, memo),
                PopScan::Head(job) => (job, false, memo),
                PopScan::Empty => return LeaseReply::Idle,
                PopScan::Closed => return LeaseReply::Closed,
            }
        };
        // Authority first: the popped job transitions Queued/Requeued →
        // Leased(worker). The queue is also a public surface
        // (`hub.queue.push`), so a job may meet the authority for the
        // first time right here — `apply_or_register` admits it on the
        // spot. A refusal means the seq raced into a state that cannot
        // be leased; put the job back rather than hand out a lease the
        // authority never granted.
        if let Err(e) = self.lifecycle.apply_or_register(
            job.seq,
            &[JobEvent::Admit, JobEvent::Enqueue],
            &JobEvent::Lease(worker.to_string()),
        ) {
            eprintln!(
                "warning: lifecycle refused lease of seq {} to {worker:?}: {e}",
                job.seq
            );
            if let Err(err) = self.queue.requeue(job) {
                eprintln!("warning: could not return refused job to queue: {err:#}");
            }
            return LeaseReply::Idle;
        }
        // The scan already fingerprinted the granted job — reuse it
        // instead of re-statting the artifact files.
        let afp = memo
            .remove(&(
                job.spec.cfg.artifacts_dir.clone(),
                job.spec.cfg.model.clone(),
            ))
            .unwrap_or_else(|| super::artifact_fingerprint(&job.spec.cfg));
        let queue_secs = job.enqueued.elapsed().as_secs_f64();
        obs::QUEUE_WAIT_SECONDS.observe(queue_secs);
        let info = LeaseInfo {
            seq: job.seq,
            priority: job.priority,
            spec: job.spec.clone(),
            afp: afp.clone(),
            affine,
            ttl,
        };
        let mut ev = obs::Event::new("lease", job.seq);
        ev.hash = job.spec.hash_hex();
        ev.worker = worker.to_string();
        ev.queue_secs = queue_secs;
        obs::journal().push(ev);
        lock_recover(&self.leases).insert(
            job.seq,
            LeaseEntry {
                spec: job.spec,
                priority: job.priority,
                afp,
                worker: worker.to_string(),
                expires: Instant::now() + ttl,
                queue_secs,
            },
        );
        self.leased.fetch_add(1, Ordering::Relaxed);
        obs::LEASES_GRANTED.inc();
        if affine {
            self.affinity.fetch_add(1, Ordering::Relaxed);
        }
        self.journal_append(&JournalRecord::Lease {
            seq: info.seq,
            worker: worker.to_string(),
        });
        LeaseReply::Granted(info)
    }

    /// Extend `worker`'s lease on `seq` by `ttl` from now. `false` when
    /// the lease is gone (expired and re-dispatched) or owned by
    /// another worker — the caller should stop renewing and expect its
    /// eventual result to be rejected as a conflict.
    pub fn renew(&self, seq: u64, worker: &str, ttl: Duration) -> bool {
        let renewed = {
            // Both the transition and the expiry write happen under the
            // lease-table lock so a renew can never interleave with the
            // expiry sweep: whichever applies its transition first
            // wins, and the loser sees a typed refusal.
            let mut leases = lock_recover(&self.leases);
            match self
                .lifecycle
                .apply(seq, &JobEvent::Renew(worker.to_string()))
            {
                Ok(_) => match leases.get_mut(&seq) {
                    Some(e) => {
                        e.expires = Instant::now() + ttl;
                        true
                    }
                    None => {
                        // Authority said Leased but the projection lost
                        // the entry — a bug, not a runtime condition.
                        debug_assert!(false, "lease table out of sync for seq {seq}");
                        false
                    }
                },
                Err(_) => false,
            }
        };
        if renewed {
            self.journal_append(&JournalRecord::Renew {
                seq,
                worker: worker.to_string(),
            });
        } else {
            self.conflicts.fetch_add(1, Ordering::Relaxed);
        }
        renewed
    }

    /// Complete a remotely-leased job: verify the caller still holds
    /// the lease, then dispatch the result exactly like a local
    /// worker's. A late result from an expired lease is dropped
    /// ([`RemoteDone::Conflict`]) — the re-dispatched copy will produce
    /// the (deterministic) result instead, so a session never sees two
    /// results for one seq.
    ///
    /// `phases` carries the worker-reported per-phase durations off the
    /// result body; they feed the gateway's sync/run histograms and the
    /// `report` journal span (zeros = unreported, not observed).
    pub fn complete_remote(
        &self,
        seq: u64,
        worker: &str,
        status: JobStatus,
        from_cache: bool,
        secs: f64,
        phases: PhaseSecs,
    ) -> RemoteDone {
        let entry = {
            // Transition under the lease-table lock (same discipline
            // as renew): Leased(worker) → Reported, every other state
            // — expired-and-requeued, re-leased elsewhere, unknown —
            // is a typed refusal that becomes the 409 conflict.
            let mut leases = lock_recover(&self.leases);
            match self
                .lifecycle
                .apply(seq, &JobEvent::Report(Some(worker.to_string())))
            {
                Ok(_) => {
                    let e = leases.remove(&seq);
                    debug_assert!(e.is_some(), "lease table out of sync for seq {seq}");
                    e
                }
                Err(_) => None,
            }
        };
        match entry {
            Some(e) => {
                if phases.sync > 0.0 {
                    obs::SYNC_SECONDS.observe(phases.sync);
                }
                if from_cache {
                    obs::CACHE_HIT_SECONDS.observe(secs);
                } else if phases.run > 0.0 {
                    obs::RUN_SECONDS.observe(phases.run);
                } else if matches!(status, JobStatus::Done(_)) {
                    // Worker predates per-phase reporting: fall back
                    // to its end-to-end figure.
                    obs::RUN_SECONDS.observe(secs);
                }
                let mut ev = obs::Event::new("report", seq);
                ev.hash = e.spec.hash_hex();
                ev.worker = worker.to_string();
                ev.client = lock_recover(&self.routes)
                    .get(&seq)
                    .and_then(|r| r.client.clone())
                    .unwrap_or_default();
                ev.queue_secs = e.queue_secs;
                ev.sync_secs = phases.sync;
                ev.run_secs = phases.run;
                ev.secs = secs;
                obs::journal().push(ev);
                self.dispatch(JobResult {
                    seq,
                    spec: e.spec.clone(),
                    status,
                    from_cache,
                    secs,
                });
                RemoteDone::Accepted { spec: e.spec, afp: e.afp }
            }
            None => {
                self.conflicts.fetch_add(1, Ordering::Relaxed);
                RemoteDone::Conflict
            }
        }
    }

    /// Requeue every expired lease (same seq, same priority) so the
    /// job is re-dispatched to the local pool or the next leasing
    /// worker. If the queue refuses (cancelled), the job is reported
    /// failed instead of leaving its session waiting forever. Returns
    /// how many leases were re-dispatched.
    pub fn requeue_expired(&self) -> usize {
        let now = Instant::now();
        let expired: Vec<(u64, LeaseEntry)> = {
            let mut leases = lock_recover(&self.leases);
            let seqs: Vec<u64> = leases
                .iter()
                .filter(|(_, e)| e.expires <= now)
                .map(|(&s, _)| s)
                .collect();
            // Transition before removal, under the lease-table lock: a
            // refusal means a renew or report won the race since the
            // TTL was read, and the entry must be left alone.
            seqs.into_iter()
                .filter_map(|s| {
                    self.lifecycle.apply(s, &JobEvent::Expire).ok()?;
                    leases.remove(&s).map(|e| (s, e))
                })
                .collect()
        };
        let mut n = 0;
        for (seq, e) in expired {
            let spec = e.spec.clone();
            let job = Job {
                seq,
                priority: e.priority,
                spec: e.spec,
                enqueued: Instant::now(),
            };
            match self.queue.requeue(job) {
                Ok(()) => {
                    n += 1;
                    self.requeued.fetch_add(1, Ordering::Relaxed);
                    obs::LEASES_EXPIRED.inc();
                }
                Err(err) => self.dispatch(JobResult {
                    seq,
                    spec,
                    status: JobStatus::Failed(format!(
                        "worker lease expired and re-dispatch failed: {err}"
                    )),
                    from_cache: false,
                    secs: 0.0,
                }),
            }
        }
        n
    }

    /// Number of jobs currently leased out to remote workers.
    pub fn n_leased(&self) -> usize {
        lock_recover(&self.leases).len()
    }

    /// Hub-lifetime remote-lease counters.
    pub fn remote_counters(&self) -> RemoteStats {
        RemoteStats {
            leased: self.leased.load(Ordering::Relaxed),
            affinity: self.affinity.load(Ordering::Relaxed),
            requeued: self.requeued.load(Ordering::Relaxed),
            conflicts: self.conflicts.load(Ordering::Relaxed),
        }
    }
}

/// Run `body` against a live hub: spawns `workers` worker threads (each
/// with per-thread state from `make_worker`) plus the result router,
/// then closes the queue and drains once `body` returns.
///
/// `workers == 0` is allowed and spawns no local pool — the
/// coordinator-only shape of `omgd serve --listen --workers 0`, where
/// every job is drained by remotely-leased workers instead
/// ([`JobHub::try_lease`]). With zero workers *and* no remote leasing,
/// submitted jobs wait forever; front-ends that cannot lease remotely
/// must pass ≥ 1.
///
/// Deadlock discipline: nothing between the spawns and `queue.close()`
/// early-returns, so workers can never be left blocked on `pop()`.
pub fn with_hub<M, F, T>(
    workers: usize,
    queue_capacity: usize,
    make_worker: M,
    body: impl FnOnce(&JobHub) -> T,
) -> T
where
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let hub = JobHub::new(queue_capacity);
    std::thread::scope(|s| {
        let (tx, rx) = mpsc::channel::<JobResult>();
        let make = &make_worker;
        let hub_ref = &hub;
        for wid in 0..workers {
            let tx = tx.clone();
            s.spawn(move || {
                let mut work = make(wid);
                worker_loop(&hub_ref.queue, &mut work, &tx);
            });
        }
        drop(tx);
        let router = s.spawn(move || hub_ref.route(rx));
        // Catch a panicking body so the queue still gets closed —
        // otherwise the scoped workers would block in `pop()` forever
        // and the panic would wedge instead of propagate.
        let out = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| body(&hub)),
        );
        hub.queue.close();
        router.join().unwrap();
        match out {
            Ok(v) => v,
            Err(payload) => std::panic::resume_unwind(payload),
        }
    })
}

/// Drive one JSONL session: read requests from `input`, submit into
/// `hub`, write acks/rejects/results to `output`. Returns once input
/// hits EOF or `{"cmd":"shutdown"}` *and* every job this session
/// submitted has streamed its result (per-session drain).
///
/// A dead sink stops the session: once a write to `output` fails (the
/// client hung up), no further input lines are read or submitted, so a
/// vanished client cannot keep feeding the shared pool. Jobs already
/// submitted still drain — and still populate the cache.
pub fn run_session<R, W>(
    hub: &JobHub,
    input: R,
    output: W,
    opts: &SessionOptions,
) -> ServeStats
where
    R: BufRead,
    W: Write + Send,
{
    let out = Mutex::new(output);
    let (reply_tx, reply_rx) = mpsc::channel::<JobResult>();
    // (outstanding jobs, drained signal) — per-session backpressure.
    let in_flight = (Mutex::new(0usize), Condvar::new());
    let sink_dead = AtomicBool::new(false);

    std::thread::scope(|s| {
        let out_ref = &out;
        let infl = &in_flight;
        let dead = &sink_dead;
        let writer = s.spawn(move || {
            let (mut done, mut failed, mut cached) = (0usize, 0usize, 0usize);
            for r in reply_rx {
                if r.from_cache {
                    cached += 1;
                }
                if r.is_ok() {
                    done += 1;
                } else {
                    failed += 1;
                }
                if !write_line(out_ref, &result_line(&r)) {
                    dead.store(true, Ordering::Relaxed);
                }
                let mut n = infl.0.lock().unwrap();
                *n -= 1;
                infl.1.notify_all();
            }
            (done, failed, cached)
        });

        let (mut accepted, mut rejected) = (0usize, 0usize);
        let mut lineno = 0usize;
        for line in input.lines() {
            if dead.load(Ordering::Relaxed) {
                break; // client hung up: stop consuming input
            }
            lineno += 1;
            let line = match line {
                Ok(l) => l,
                Err(_) => break, // treat a broken pipe as EOF
            };
            let text = line.trim();
            if text.is_empty() {
                continue;
            }
            let j = match Json::parse(text) {
                Ok(j) => j,
                Err(e) => {
                    rejected += 1;
                    hub.note_rejected();
                    if !write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&e.to_string())
                        ),
                    ) {
                        dead.store(true, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            if j.get("cmd").and_then(Json::as_str) == Some("shutdown") {
                break;
            }
            let priority =
                j.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i32;
            // Two request shapes: the operator-facing field set
            // (`JobSpec::from_json`), or — under a `"spec"` key — the
            // full-fidelity wire object `grid --remote` submits so no
            // RunConfig field is lost in transit.
            let parsed = match j.get("spec") {
                Some(sj) => JobSpec::from_wire(sj),
                None => JobSpec::from_json(&j),
            };
            let spec = match parsed {
                Ok(spec) => spec,
                Err(e) => {
                    rejected += 1;
                    hub.note_rejected();
                    if !write_line(
                        out_ref,
                        &format!(
                            "{{\"error\":\"{}\",\"line\":{lineno}}}",
                            esc(&format!("{e:#}"))
                        ),
                    ) {
                        dead.store(true, Ordering::Relaxed);
                    }
                    continue;
                }
            };
            let (hash, label) = (spec.hash_hex(), spec.label());
            // Fairness first: with a hub quota, submitting blocks until
            // this client token (across ALL its sessions) is under its
            // in-flight cap. Slots drain via the hub's dispatch path,
            // never via this session's writer, so blocking here cannot
            // deadlock the stream.
            let client = opts.client.as_deref();
            if let Some(c) = client {
                hub.acquire_client_slot(c);
            }
            // Backpressure: cap this session's outstanding jobs,
            // draining a result before submitting the next request.
            {
                let mut n = infl.0.lock().unwrap();
                while opts.max_in_flight > 0 && *n >= opts.max_in_flight {
                    n = infl.1.wait(n).unwrap();
                }
                *n += 1;
            }
            // Hold the writer lock across submit + ack: a cached job
            // can complete in microseconds, and the protocol promises
            // the ack (seq ↔ request mapping) reaches the client before
            // its result line. The hub drains without this lock, so a
            // full-queue submit still makes progress.
            let mut o = out_ref.lock().unwrap();
            match hub.submit(spec, priority, &reply_tx, client) {
                Ok(seq) => {
                    accepted += 1;
                    let wrote = writeln!(
                        o,
                        "{{\"accepted\":{seq},\"hash\":\
                         \"{hash}\",\"label\":\"{}\"}}",
                        esc(&label)
                    )
                    .is_ok()
                        && o.flush().is_ok();
                    if !wrote {
                        dead.store(true, Ordering::Relaxed);
                    }
                }
                Err(_) => {
                    // Hub is draining: undo the in-flight and client
                    // reservations and keep the one-ack-or-reject-per-
                    // line promise.
                    rejected += 1;
                    hub.note_rejected();
                    if let Some(c) = client {
                        hub.release_client_slot(c);
                    }
                    let wrote = writeln!(
                        o,
                        "{{\"error\":\"job queue is closed\",\
                         \"line\":{lineno}}}"
                    )
                    .is_ok()
                        && o.flush().is_ok();
                    drop(o);
                    if !wrote {
                        dead.store(true, Ordering::Relaxed);
                    }
                    let mut n = infl.0.lock().unwrap();
                    *n -= 1;
                    infl.1.notify_all();
                }
            }
        }
        // The writer ends once the hub dispatches this session's last
        // outstanding result (each routed sender clone drops as it is
        // consumed) — the per-session drain.
        drop(reply_tx);
        let (done, failed, cached) = writer.join().unwrap();
        ServeStats { accepted, rejected, done, failed, cached }
    })
}

/// Serve one session with an arbitrary worker factory (tests inject
/// stubs): a hub with the historical `(2·workers).max(8)` queue bound
/// and an unthrottled session.
pub fn serve_with<R, W, M, F>(
    input: R,
    output: W,
    workers: usize,
    make_worker: M,
) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
    M: Fn(usize) -> F + Sync,
    F: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let workers = workers.max(1);
    Ok(with_hub(workers, (2 * workers).max(8), make_worker, |hub| {
        run_session(hub, input, output, &SessionOptions::default())
    }))
}

/// Write one protocol line and flush (clients read results live).
/// `false` = the sink is dead (client hung up).
fn write_line<W: Write>(out: &Mutex<W>, line: &str) -> bool {
    let mut o = out.lock().unwrap();
    writeln!(o, "{line}").is_ok() && o.flush().is_ok()
}

pub(crate) fn result_line(r: &JobResult) -> String {
    let head = format!(
        "{{\"seq\":{},\"label\":\"{}\",\"hash\":\"{}\",\"status\":\"{}\",\
         \"cached\":{}",
        r.seq,
        esc(&r.spec.label()),
        r.spec.hash_hex(),
        r.status.tag(),
        r.from_cache,
    );
    match &r.status {
        JobStatus::Done(o) => format!(
            "{head},\"final_metric\":{},\"tail_loss\":{},\"steps\":{},\
             \"secs\":{}}}",
            ser_f(o.final_metric),
            ser_f(o.tail_loss),
            o.steps,
            ser_f(r.secs),
        ),
        JobStatus::Failed(e) | JobStatus::Panicked(e) => {
            format!("{head},\"error\":\"{}\"}}", esc(e))
        }
    }
}

use crate::util::json::{escape_str as esc, ser_f64 as ser_f};

#[cfg(test)]
mod tests {
    use super::*;

    fn stub_factory(
        _wid: usize,
    ) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> {
        |spec: &JobSpec| {
            if spec.cfg.seed == 99 {
                anyhow::bail!("rigged failure");
            }
            Ok((
                JobOutcome {
                    final_metric: spec.cfg.seed as f64 + 0.5,
                    tail_loss: 0.25,
                    steps: 2,
                    train_secs: 0.0,
                    loss_series: vec![(0, 1.0)],
                    eval_series: vec![],
                },
                false,
            ))
        }
    }

    fn run_serve(input: &str, workers: usize) -> (ServeStats, Vec<Json>) {
        let mut out: Vec<u8> = Vec::new();
        let stats = serve_with(
            input.as_bytes(),
            &mut out,
            workers,
            stub_factory,
        )
        .unwrap();
        let lines = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(|l| Json::parse(l).expect("every output line is JSON"))
            .collect();
        (stats, lines)
    }

    fn request(seed: u64) -> String {
        format!(
            "{{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":{seed},\
             \"epochs\":1}}\n"
        )
    }

    #[test]
    fn serves_requests_and_streams_results() {
        let input = "\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":0,\"epochs\":1}\n\
{\"kind\":\"finetune\",\"task\":\"SST-2\",\"seed\":1,\"epochs\":1}\n\
{\"cmd\":\"shutdown\"}\n";
        let (stats, lines) = run_serve(input, 2);
        assert_eq!(stats.accepted, 2);
        assert_eq!(stats.rejected, 0);
        assert_eq!(stats.done, 2);
        assert_eq!(stats.failed, 0);
        let acks =
            lines.iter().filter(|j| j.get("accepted").is_some()).count();
        let results: Vec<&Json> =
            lines.iter().filter(|j| j.get("status").is_some()).collect();
        assert_eq!(acks, 2);
        assert_eq!(results.len(), 2);
        for r in &results {
            assert_eq!(r.at("status").as_str(), Some("done"));
            assert!(r.at("final_metric").as_f64().is_some());
        }
    }

    #[test]
    fn bad_lines_are_rejected_not_fatal() {
        let input = "\
this is not json\n\
{\"kind\":\"nope\"}\n\
{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":2,\"epochs\":1}\n";
        // No shutdown line: EOF also drains cleanly.
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.rejected, 2);
        assert_eq!(stats.done, 1);
        let errors =
            lines.iter().filter(|j| j.get("error").is_some()).count();
        assert_eq!(errors, 2);
    }

    #[test]
    fn failed_jobs_stream_an_error_result() {
        let input =
            "{\"kind\":\"finetune\",\"task\":\"CoLA\",\"seed\":99,\"epochs\":1}\n";
        let (stats, lines) = run_serve(input, 1);
        assert_eq!(stats.accepted, 1);
        assert_eq!(stats.failed, 1);
        let r = lines
            .iter()
            .find(|j| j.get("status").is_some())
            .expect("one result line");
        assert_eq!(r.at("status").as_str(), Some("failed"));
        assert!(r.at("error").as_str().unwrap().contains("rigged"));
    }

    #[test]
    fn in_flight_cap_still_completes_every_job() {
        let input: String = (0..6).map(request).collect();
        let mut out: Vec<u8> = Vec::new();
        let stats = with_hub(2, 8, stub_factory, |hub| {
            run_session(
                hub,
                input.as_bytes(),
                &mut out,
                &SessionOptions { max_in_flight: 1, ..Default::default() },
            )
        });
        assert_eq!(stats.accepted, 6);
        assert_eq!(stats.done, 6);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 12, "6 acks + 6 results");
        // With one in-flight slot the session fully drains each job
        // before submitting the next: ack/result strictly alternate.
        for (i, l) in text.lines().enumerate() {
            let j = Json::parse(l).unwrap();
            if i % 2 == 0 {
                assert!(j.get("accepted").is_some(), "line {i}: {l}");
            } else {
                assert!(j.get("status").is_some(), "line {i}: {l}");
            }
        }
    }

    fn mk_spec(seed: u64) -> JobSpec {
        let mut cfg = crate::config::RunConfig::default();
        cfg.seed = seed;
        // Point at a directory that cannot exist so the artifact
        // fingerprint is deterministically "absent".
        cfg.artifacts_dir = "/nonexistent/omgd-test-artifacts".into();
        JobSpec {
            kind: crate::spec::ExperimentKind::Pretrain,
            cfg,
        }
    }

    #[test]
    fn lease_renew_and_complete_lifecycle() {
        let hub = JobHub::new(4);
        let seq = hub.queue.push(mk_spec(1), 0).unwrap();
        // Grant
        let info = match hub.try_lease(
            "w1",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, seq);
        assert_eq!(info.afp, "absent");
        assert_eq!(hub.n_leased(), 1);
        // Empty queue now → Idle
        assert!(matches!(
            hub.try_lease(
                "w2",
                &HashSet::new(),
                0,
                Duration::from_secs(60),
                Duration::ZERO
            ),
            LeaseReply::Idle
        ));
        // Renewal: owner only
        assert!(hub.renew(seq, "w1", Duration::from_secs(60)));
        assert!(!hub.renew(seq, "w2", Duration::from_secs(60)));
        assert!(!hub.renew(999, "w1", Duration::from_secs(60)));
        // Wrong-worker completion is a conflict and dispatches nothing.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w2",
                JobStatus::Failed("hijack".into()),
                false,
                0.0,
                PhaseSecs::default()
            ),
            RemoteDone::Conflict
        ));
        assert_eq!(hub.n_leased(), 1);
        // Owner completion dispatches and frees the lease.
        let done = hub.complete_remote(
            seq,
            "w1",
            JobStatus::Done(JobOutcome::default()),
            false,
            0.5,
            PhaseSecs { sync: 0.1, run: 0.4 },
        );
        match done {
            RemoteDone::Accepted { spec, afp } => {
                assert_eq!(spec.cfg.seed, 1);
                assert_eq!(afp, "absent");
            }
            RemoteDone::Conflict => panic!("owner completion conflicted"),
        }
        assert_eq!(hub.n_leased(), 0);
        let (_, _, done_n, failed_n, _) = hub.counters();
        assert_eq!((done_n, failed_n), (1, 0));
        // A duplicate (late) completion is a conflict.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w1",
                JobStatus::Done(JobOutcome::default()),
                false,
                0.5,
                PhaseSecs::default()
            ),
            RemoteDone::Conflict
        ));
        // Two failed renewals + wrong-worker + duplicate completion.
        assert_eq!(hub.remote_counters().conflicts, 4);
    }

    #[test]
    fn expired_lease_requeues_with_the_same_seq() {
        let hub = JobHub::new(4);
        let seq = hub.queue.push(mk_spec(2), 7).unwrap();
        let info = match hub.try_lease(
            "dead-worker",
            &HashSet::new(),
            0,
            Duration::from_millis(5),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, seq);
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(hub.requeue_expired(), 1);
        assert_eq!(hub.n_leased(), 0);
        assert_eq!(hub.queue.len(), 1);
        // Re-leased to a healthy worker with identity intact.
        let again = match hub.try_lease(
            "w2",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!((again.seq, again.priority), (seq, 7));
        // The dead worker's late result is rejected...
        assert!(matches!(
            hub.complete_remote(
                seq,
                "dead-worker",
                JobStatus::Done(JobOutcome::default()),
                false,
                1.0,
                PhaseSecs::default()
            ),
            RemoteDone::Conflict
        ));
        // ...and the healthy worker's lands.
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w2",
                JobStatus::Done(JobOutcome::default()),
                false,
                1.0,
                PhaseSecs::default()
            ),
            RemoteDone::Accepted { .. }
        ));
        assert_eq!(hub.remote_counters().requeued, 1);
    }

    #[test]
    fn remote_completion_routes_to_the_submitting_session() {
        let hub = JobHub::new(4);
        let (tx, rx) = mpsc::channel::<JobResult>();
        let seq = hub.submit(mk_spec(3), 0, &tx, None).unwrap();
        let _info = match hub.try_lease(
            "w1",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        hub.complete_remote(
            seq,
            "w1",
            JobStatus::Done(JobOutcome {
                final_metric: 3.5,
                ..JobOutcome::default()
            }),
            true,
            0.0,
            PhaseSecs::default(),
        );
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.seq, seq);
        assert!(r.from_cache);
        assert_eq!(r.outcome().unwrap().final_metric, 3.5);
        let (_, _, _, _, cached) = hub.counters();
        assert_eq!(cached, 1);
    }

    #[test]
    fn lease_replies_closed_once_the_queue_closes() {
        let hub = JobHub::new(4);
        hub.queue.close();
        assert!(matches!(
            hub.try_lease(
                "w",
                &HashSet::new(),
                0,
                Duration::from_secs(1),
                Duration::ZERO
            ),
            LeaseReply::Closed
        ));
    }

    /// A spec whose artifact files really exist, so its fingerprint is
    /// a real hash (not `"absent"`) and affinity can match on it.
    fn art_spec(dir: &std::path::Path, model: &str, seed: u64) -> JobSpec {
        let mut cfg = crate::config::RunConfig::default();
        cfg.seed = seed;
        cfg.model = model.to_string();
        cfg.artifacts_dir = dir.to_string_lossy().into_owned();
        JobSpec {
            kind: crate::spec::ExperimentKind::Pretrain,
            cfg,
        }
    }

    #[test]
    fn affinity_lease_prefers_jobs_the_worker_already_holds() {
        let dir = std::env::temp_dir().join(format!(
            "omgd-affinity-test-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("ma.json"), b"{\"a\":1}").unwrap();
        std::fs::write(dir.join("mb.json"), b"{\"b\":1}").unwrap();
        let sa = art_spec(&dir, "ma", 0);
        let sb = art_spec(&dir, "mb", 1);
        let fp_b = crate::artifact_fingerprint(&sb.cfg);
        assert_ne!(fp_b, "absent");

        let hub = JobHub::new(8);
        hub.queue.push(sa, 0).unwrap(); // head of the queue
        hub.queue.push(sb, 0).unwrap();
        // A worker holding only model-b artifacts gets the deeper
        // model-b job, not the head.
        let fps: HashSet<String> = [fp_b.clone()].into_iter().collect();
        let info = match hub.try_lease(
            "wb",
            &fps,
            8,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.spec.cfg.model, "mb");
        assert!(info.affine, "placement was an affinity match");
        assert_eq!(info.afp, fp_b);
        assert_eq!(hub.remote_counters().affinity, 1);
        // A cache-less worker falls back to the (passed-over) head.
        let info2 = match hub.try_lease(
            "w-plain",
            &HashSet::new(),
            8,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info2.spec.cfg.model, "ma");
        assert!(!info2.affine);
        assert_eq!(hub.remote_counters().affinity, 1, "no new hit");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn client_slots_block_at_quota_and_drain_on_release() {
        let hub = JobHub::new(4);
        hub.set_client_quota(1);
        hub.acquire_client_slot("tok");
        assert_eq!(hub.client_in_flight("tok"), 1);
        assert_eq!(hub.client_in_flight("other"), 0);
        std::thread::scope(|s| {
            let waiter = s.spawn(|| {
                hub.acquire_client_slot("tok"); // blocks at quota
                hub.release_client_slot("tok");
            });
            // A different token is unaffected by "tok" being at quota.
            hub.acquire_client_slot("other");
            hub.release_client_slot("other");
            std::thread::sleep(Duration::from_millis(30));
            assert!(!waiter.is_finished(), "waiter held at quota");
            hub.release_client_slot("tok");
            waiter.join().unwrap();
        });
        assert!(hub.clients_snapshot().is_empty(), "ledger drains to 0");
    }

    #[test]
    fn quota_throttled_session_still_completes_every_job() {
        let input: String = (0..6).map(request).collect();
        let mut out: Vec<u8> = Vec::new();
        let stats = with_hub(2, 8, stub_factory, |hub| {
            hub.set_client_quota(1);
            let st = run_session(
                hub,
                input.as_bytes(),
                &mut out,
                &SessionOptions {
                    max_in_flight: 0,
                    client: Some("grid-a".into()),
                },
            );
            // Per-session drain done: the token's ledger is back to 0.
            assert!(hub.clients_snapshot().is_empty());
            st
        });
        // One slot throttles submission (a job must complete before
        // the next is accepted) but never wedges or drops work.
        // (The slot is released by the hub's dispatch path, which runs
        // just before the result line is written — so unlike
        // max_in_flight, strict ack/result alternation on the stream
        // is not guaranteed, only completion.)
        assert_eq!(stats.accepted, 6);
        assert_eq!(stats.done, 6);
        assert_eq!(stats.failed, 0);
        let text = String::from_utf8(out).unwrap();
        assert_eq!(text.lines().count(), 12, "6 acks + 6 results");
    }

    #[test]
    fn poisoned_hub_maps_recover_instead_of_panicking() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let hub = JobHub::new(4);
        // Panic while holding each shared map, poisoning the mutexes
        // the way a crashed connection/worker thread would.
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = hub.routes.lock().unwrap();
            panic!("poison routes");
        }));
        let _ = catch_unwind(AssertUnwindSafe(|| {
            let _g = hub.leases.lock().unwrap();
            panic!("poison leases");
        }));
        let _ = catch_unwind(AssertUnwindSafe(|| {
            hub.clients.with_lock(|| panic!("poison clients"));
        }));
        // Every later request must still work: submit → lease → renew
        // → complete, with the client ledger draining to zero.
        let (tx, rx) = mpsc::channel::<JobResult>();
        let seq = hub.submit(mk_spec(5), 0, &tx, Some("t")).unwrap();
        assert_eq!(hub.client_in_flight("t"), 1);
        let info = match hub.try_lease(
            "w1",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, seq);
        assert!(hub.renew(seq, "w1", Duration::from_secs(60)));
        assert!(matches!(
            hub.complete_remote(
                seq,
                "w1",
                JobStatus::Done(JobOutcome::default()),
                false,
                0.1,
                PhaseSecs::default()
            ),
            RemoteDone::Accepted { .. }
        ));
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.seq, seq);
        assert_eq!(hub.client_in_flight("t"), 0);
        assert!(hub.clients_snapshot().is_empty());
    }

    fn journal_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!(
            "omgd-hub-journal-{tag}-{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&d).ok();
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn journaled_hub_recovers_across_a_simulated_crash() {
        let dir = journal_dir("recover");
        let (s_done, s_pending);
        {
            // "Crashed" incarnation: journal attached, one job
            // completes, one stays queued, then the process state is
            // simply dropped — no compaction, no clean shutdown.
            let hub = JobHub::new(8);
            hub.attach_journal(JobJournal::open(&dir).unwrap());
            let (tx, _rx) = mpsc::channel::<JobResult>();
            s_done = hub.submit(mk_spec(1), 0, &tx, Some("grid-a")).unwrap();
            s_pending =
                hub.submit(mk_spec(2), 5, &tx, Some("grid-a")).unwrap();
            let info = match hub.try_lease(
                "w1",
                &HashSet::new(),
                0,
                Duration::from_secs(60),
                Duration::ZERO,
            ) {
                LeaseReply::Granted(i) => i,
                other => panic!("expected Granted, got {other:?}"),
            };
            assert_eq!(info.seq, s_done);
            assert!(matches!(
                hub.complete_remote(
                    s_done,
                    "w1",
                    JobStatus::Done(JobOutcome {
                        final_metric: 1.5,
                        ..JobOutcome::default()
                    }),
                    false,
                    0.25,
                    PhaseSecs::default()
                ),
                RemoteDone::Accepted { .. }
            ));
        }
        // Restarted incarnation on the same cache dir.
        let hub = JobHub::new(8);
        let rep =
            crate::journal::replay(&JobJournal::path_in(&dir))
                .unwrap();
        hub.attach_journal(JobJournal::open(&dir).unwrap());
        let (requeued, completed) = hub.recover(rep);
        assert_eq!((requeued, completed), (1, 1));
        // Reconnecting clients re-poll by seq.
        match hub.result_for(s_done) {
            ResultLookup::Ready(line) => {
                let j = Json::parse(&line).unwrap();
                assert_eq!(j.at("seq").as_f64(), Some(s_done as f64));
                assert_eq!(j.at("status").as_str(), Some("done"));
                assert_eq!(j.at("final_metric").as_f64(), Some(1.5));
            }
            other => panic!("expected Ready, got {other:?}"),
        }
        assert_eq!(hub.result_for(s_pending), ResultLookup::Pending);
        assert_eq!(hub.result_for(999), ResultLookup::Unknown);
        // The pending job is live for GC protection and re-leasable
        // with its original seq + priority.
        assert!(hub
            .live_spec_hashes()
            .contains(&mk_spec(2).hash_hex()));
        assert_eq!(hub.client_in_flight("grid-a"), 1);
        let again = match hub.try_lease(
            "w2",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!((again.seq, again.priority), (s_pending, 5));
        assert_eq!(hub.result_for(s_pending), ResultLookup::Pending);
        assert!(matches!(
            hub.complete_remote(
                s_pending,
                "w2",
                JobStatus::Done(JobOutcome::default()),
                false,
                0.1,
                PhaseSecs::default()
            ),
            RemoteDone::Accepted { .. }
        ));
        // The orphan's ledger slot drained through dispatch...
        assert_eq!(hub.client_in_flight("grid-a"), 0);
        // ...and its result is now re-pollable too.
        assert!(matches!(
            hub.result_for(s_pending),
            ResultLookup::Ready(_)
        ));
        // New admissions never reuse a journaled seq.
        let (tx2, _rx2) = mpsc::channel::<JobResult>();
        let fresh = hub.submit(mk_spec(3), 0, &tx2, None).unwrap();
        assert!(fresh > s_pending);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn compaction_preserves_recovery_state() {
        let dir = journal_dir("compact");
        let hub = JobHub::new(8);
        hub.attach_journal(JobJournal::open(&dir).unwrap());
        let (tx, rx) = mpsc::channel::<JobResult>();
        let s1 = hub.submit(mk_spec(1), 0, &tx, None).unwrap();
        let s2 = hub.submit(mk_spec(2), 0, &tx, None).unwrap();
        let info = match hub.try_lease(
            "w1",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) {
            LeaseReply::Granted(i) => i,
            other => panic!("expected Granted, got {other:?}"),
        };
        assert_eq!(info.seq, s1);
        hub.complete_remote(
            s1,
            "w1",
            JobStatus::Done(JobOutcome::default()),
            false,
            0.1,
            PhaseSecs::default(),
        );
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        hub.compact_journal().unwrap();
        // The compacted journal replays to the same live state.
        let rep =
            crate::journal::replay(&JobJournal::path_in(&dir))
                .unwrap();
        assert_eq!(rep.next_seq, s2 + 1);
        assert_eq!(
            rep.pending.iter().map(|p| p.seq).collect::<Vec<_>>(),
            vec![s2]
        );
        assert_eq!(
            rep.completed.iter().map(|r| r.seq).collect::<Vec<_>>(),
            vec![s1]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unjournaled_hub_reports_unknown_not_pending_results() {
        // Without a journal the retained-results window is off: the
        // lookup must not fabricate Pending for finished work.
        let hub = JobHub::new(4);
        let (tx, rx) = mpsc::channel::<JobResult>();
        let seq = hub.submit(mk_spec(1), 0, &tx, None).unwrap();
        assert_eq!(hub.result_for(seq), ResultLookup::Pending);
        let LeaseReply::Granted(_) = hub.try_lease(
            "w1",
            &HashSet::new(),
            0,
            Duration::from_secs(60),
            Duration::ZERO,
        ) else {
            panic!("lease refused")
        };
        hub.complete_remote(
            seq,
            "w1",
            JobStatus::Done(JobOutcome::default()),
            false,
            0.1,
            PhaseSecs::default(),
        );
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(hub.result_for(seq), ResultLookup::Unknown);
        assert!(hub.live_spec_hashes().is_empty());
    }

    #[test]
    fn concurrent_sessions_share_a_hub_without_crosstalk() {
        let input_a: String = (0..4).map(request).collect();
        let input_b: String = (10..14).map(request).collect();
        let ((st_a, out_a), (st_b, out_b)) =
            with_hub(2, 4, stub_factory, |hub| {
                std::thread::scope(|s| {
                    let a = s.spawn(|| {
                        let mut out = Vec::new();
                        let st = run_session(
                            hub,
                            input_a.as_bytes(),
                            &mut out,
                            &SessionOptions { max_in_flight: 2, ..Default::default() },
                        );
                        (st, out)
                    });
                    let b = s.spawn(|| {
                        let mut out = Vec::new();
                        let st = run_session(
                            hub,
                            input_b.as_bytes(),
                            &mut out,
                            &SessionOptions { max_in_flight: 2, ..Default::default() },
                        );
                        (st, out)
                    });
                    (a.join().unwrap(), b.join().unwrap())
                })
            });
        assert_eq!((st_a.accepted, st_a.done), (4, 4));
        assert_eq!((st_b.accepted, st_b.done), (4, 4));
        // Each session sees exactly its own results (metric = seed+0.5)
        // even though both drained through one queue and worker pool.
        let metrics = |out: Vec<u8>| -> Vec<f64> {
            let mut m: Vec<f64> = String::from_utf8(out)
                .unwrap()
                .lines()
                .map(|l| Json::parse(l).unwrap())
                .filter(|j| j.get("status").is_some())
                .map(|j| j.at("final_metric").as_f64().unwrap())
                .collect();
            m.sort_by(f64::total_cmp);
            m
        };
        assert_eq!(metrics(out_a), vec![0.5, 1.5, 2.5, 3.5]);
        assert_eq!(metrics(out_b), vec![10.5, 11.5, 12.5, 13.5]);
    }
}
