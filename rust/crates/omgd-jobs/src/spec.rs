//! Job specification: one grid cell = one [`JobSpec`], identified by a
//! stable content hash over every field that can change its result.
//!
//! The hash keys the on-disk result cache ([`super::cache`]), so it must
//! be (a) stable across processes and platforms — no `DefaultHasher`,
//! whose seed changes per process — and (b) derived only from
//! result-relevant fields. Machine-local paths (`artifacts_dir`,
//! `out_dir`) are deliberately excluded: two hosts with the same
//! artifacts produce the same cells.

use crate::config::{RunConfig, Schedule};
use crate::util::json::{escape_str as esc, ser_f64 as ser_f, Json};
use anyhow::{anyhow, bail, Result};

/// Blob-dataset sizes used by the job runner. They live here — next to
/// the hash — so the canonical string sees the same values the runner
/// uses, and a change to either invalidates stale cache entries.
pub const BLOBS_N_TRAIN: usize = 1000;
pub const BLOBS_N_TEST: usize = 400;

/// What kind of experiment a job runs (mirrors the paper tables).
///
/// For the classifier kinds, `cfg.steps` is a placeholder (the builders
/// set it to `epochs`); the runner resolves the real step count as
/// `epochs × ⌈N/B⌉` once the bundle's batch size is known, and
/// `cfg.eval_every` is interpreted in *epochs* (0 = no mid-run eval).
/// `Pretrain` uses `cfg.steps` / `cfg.eval_every` directly in steps.
#[derive(Clone, Debug, PartialEq)]
pub enum ExperimentKind {
    /// Fine-tune the classifier bundle on a named GLUE-like task from
    /// [`crate::data::GLUE_LIKE_TASKS`] (Tables 3 and 6).
    Finetune { task: String, epochs: usize },
    /// Fine-tune on a synthetic Gaussian-blob dataset (Table 5 shape).
    Blobs { dataset: String, spread: f64, data_seed: u64, epochs: usize },
    /// LM pre-training on the synthetic corpus (Fig. 5 shape).
    Pretrain,
}

impl ExperimentKind {
    /// Short dataset/workload label for tables and log lines.
    pub fn dataset(&self) -> &str {
        match self {
            ExperimentKind::Finetune { task, .. } => task,
            ExperimentKind::Blobs { dataset, .. } => dataset,
            ExperimentKind::Pretrain => "pretrain",
        }
    }

    /// Dataset-generation parameters are part of the canonical string,
    /// not just the dataset *name* — editing a task definition (or the
    /// blob sizes above) must read as a different cell, never a stale
    /// cache hit.
    fn canonical(&self) -> String {
        match self {
            ExperimentKind::Finetune { task, epochs } => {
                let def = crate::data::find_task(task)
                    .map(|t| {
                        format!(
                            "{}:{}:{}:{}:{}",
                            t.n_train, t.n_test, t.noise,
                            t.teacher_depth, t.seed
                        )
                    })
                    .unwrap_or_else(|| "unresolved".to_string());
                format!("finetune:{task}:{epochs}:def={def}")
            }
            ExperimentKind::Blobs { dataset, spread, data_seed, epochs } => {
                format!(
                    "blobs:{dataset}:{spread}:{data_seed}:{epochs}:\
                     n={BLOBS_N_TRAIN}+{BLOBS_N_TEST}"
                )
            }
            ExperimentKind::Pretrain => "pretrain".to_string(),
        }
    }
}

/// One unit of schedulable work: an experiment kind plus the full run
/// configuration (method, optimizer, mask hyper-parameters, seed).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub kind: ExperimentKind,
    pub cfg: RunConfig,
}

impl JobSpec {
    /// Canonical serialization of every result-relevant field, in a fixed
    /// order. Version-prefixed so a format change invalidates old caches
    /// instead of mis-hitting them.
    pub fn canonical(&self) -> String {
        let c = &self.cfg;
        format!(
            "omgd-spec-v1;kind={};model={};method={};opt={};lr={};b1={};\
             b2={};eps={};wd={};mom={};nesterov={};keep={};gamma={};\
             period={};rank={};topk={};sched={};steps={};eval={};seed={};\
             dsize={};dseed={}",
            self.kind.canonical(),
            c.model,
            c.method.name(),
            c.opt.family.name(),
            c.opt.lr,
            c.opt.beta1,
            c.opt.beta2,
            c.opt.eps,
            c.opt.weight_decay,
            c.opt.momentum,
            c.opt.nesterov,
            c.mask.keep_ratio,
            c.mask.gamma,
            c.mask.period,
            c.mask.rank,
            c.mask.topk,
            canonical_schedule(&c.schedule),
            c.steps,
            c.eval_every,
            c.seed,
            c.dataset_size,
            c.data_seed,
        )
    }

    /// Stable 64-bit content hash (FNV-1a over [`Self::canonical`]).
    pub fn content_hash(&self) -> u64 {
        fnv1a64(self.canonical().as_bytes())
    }

    /// Hash as the fixed-width hex string used for cache file names.
    pub fn hash_hex(&self) -> String {
        format!("{:016x}", self.content_hash())
    }

    /// Human-readable cell label: `kind/dataset/method/s<seed>`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}/s{}",
            self.kind.dataset(),
            self.cfg.method.name(),
            self.cfg.seed
        )
    }

    /// Build a spec from a JSONL request object (the `omgd serve`
    /// protocol). Unknown fields are ignored; everything has a default.
    ///
    /// ```json
    /// {"kind":"finetune","task":"CoLA","method":"lisa-wor","seed":1,
    ///  "epochs":4,"model":"mlp-glue","lr":2e-3,"gamma":4,"period":1}
    /// ```
    pub fn from_json(j: &Json) -> Result<JobSpec> {
        let f_usize = |k: &str, d: usize| {
            j.get(k).and_then(Json::as_usize).unwrap_or(d)
        };
        let f_f64 =
            |k: &str, d: f64| j.get(k).and_then(Json::as_f64).unwrap_or(d);
        let f_str = |k: &str| j.get(k).and_then(Json::as_str);

        let mut cfg = RunConfig::default();
        let kind_tag = f_str("kind").unwrap_or("finetune");
        let kind = match kind_tag {
            "finetune" => {
                let epochs = f_usize("epochs", 4);
                cfg.model = f_str("model").unwrap_or("mlp-glue").to_string();
                cfg.steps = epochs.max(1);
                // Epoch units for classifier kinds (0 = no mid-run eval).
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Finetune {
                    task: f_str("task").unwrap_or("CoLA").to_string(),
                    epochs,
                }
            }
            "blobs" => {
                let epochs = f_usize("epochs", 4);
                cfg.model = f_str("model").unwrap_or("mlp-img").to_string();
                cfg.steps = epochs.max(1);
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Blobs {
                    dataset: f_str("dataset").unwrap_or("IMG-mid").to_string(),
                    spread: f_f64("spread", 4.0),
                    data_seed: f_usize("data_seed", 6002) as u64,
                    epochs,
                }
            }
            "pretrain" => {
                cfg.model = f_str("model").unwrap_or("gpt-tiny").to_string();
                cfg.steps = f_usize("steps", 100);
                cfg.eval_every = f_usize("eval_every", 0);
                ExperimentKind::Pretrain
            }
            other => bail!("unknown job kind {other:?}"),
        };
        if let Some(m) = f_str("method") {
            cfg.method = crate::config::Method::parse(m)?;
        }
        if let Some(o) = f_str("opt") {
            cfg.opt.family = crate::config::OptFamily::parse(o)?;
        }
        cfg.opt.lr = f_f64("lr", cfg.opt.lr);
        cfg.opt.weight_decay = f_f64("wd", cfg.opt.weight_decay);
        cfg.mask.keep_ratio = f_f64("keep_ratio", cfg.mask.keep_ratio);
        cfg.mask.gamma = f_usize("gamma", cfg.mask.gamma);
        cfg.mask.period = f_usize("period", cfg.mask.period);
        cfg.mask.rank = f_usize("rank", cfg.mask.rank);
        cfg.seed = f_usize("seed", cfg.seed as usize) as u64;
        cfg.validate()?;
        Ok(JobSpec { kind, cfg })
    }
}

/// Version tag of the wire format below. Bump on any field change so a
/// gateway and a worker built from different revisions fail loudly at
/// parse time instead of running subtly different cells.
const WIRE_VERSION: u64 = 1;

impl JobSpec {
    /// Full-fidelity JSON serialization for shipping a spec between
    /// hosts (`grid --remote` submission, worker leases).
    ///
    /// Unlike the operator-facing [`Self::from_json`] request format —
    /// which exposes only the commonly-swept knobs — the wire object
    /// carries **every** field of [`Self::canonical`] (schedule, betas,
    /// momentum, topk, dataset sizing, ...), so a remote worker runs
    /// bit-for-bit the same cell a local pool would.
    ///
    /// `artifacts_dir` travels as a *location hint*, emitted only when
    /// explicitly configured (the default resolves host-locally on the
    /// receiving side): it is outside the content hash, the gateway
    /// honors it exactly like a local `--artifacts` override (a bad
    /// path fails loudly), and workers replace it with their synced
    /// copy anyway. `out_dir` never travels. Floats use
    /// shortest-round-trip `Display`, so a serialize → parse cycle
    /// reproduces the identical `f64` and therefore the identical hash
    /// — consumers verify that hash after [`Self::from_wire`] as an
    /// end-to-end fidelity check.
    pub fn to_wire(&self) -> String {
        let c = &self.cfg;
        let artifacts_hint =
            if c.artifacts_dir == RunConfig::default().artifacts_dir {
                String::new()
            } else {
                format!(
                    ",\"artifacts_dir\":\"{}\"",
                    esc(&c.artifacts_dir)
                )
            };
        let kind = match &self.kind {
            ExperimentKind::Finetune { task, epochs } => format!(
                "{{\"t\":\"finetune\",\"task\":\"{}\",\"epochs\":{epochs}}}",
                esc(task)
            ),
            ExperimentKind::Blobs { dataset, spread, data_seed, epochs } => {
                format!(
                    "{{\"t\":\"blobs\",\"dataset\":\"{}\",\"spread\":{},\
                     \"data_seed\":{data_seed},\"epochs\":{epochs}}}",
                    esc(dataset),
                    ser_f(*spread)
                )
            }
            ExperimentKind::Pretrain => "{\"t\":\"pretrain\"}".to_string(),
        };
        let schedule = match &c.schedule {
            Schedule::Constant => "{\"t\":\"constant\"}".to_string(),
            Schedule::MultiStep { milestones, gamma } => {
                let ms: Vec<String> =
                    milestones.iter().map(|m| m.to_string()).collect();
                format!(
                    "{{\"t\":\"multistep\",\"milestones\":[{}],\
                     \"gamma\":{}}}",
                    ms.join(","),
                    ser_f(*gamma)
                )
            }
            Schedule::CosineWarmup { warmup, total, min_lr } => format!(
                "{{\"t\":\"cosine\",\"warmup\":{warmup},\"total\":{total},\
                 \"min_lr\":{}}}",
                ser_f(*min_lr)
            ),
            Schedule::InvT { c0 } => {
                format!("{{\"t\":\"inv_t\",\"c0\":{}}}", ser_f(*c0))
            }
        };
        format!(
            "{{\"v\":{WIRE_VERSION},\"kind\":{kind},\"model\":\"{}\",\
             \"method\":\"{}\",\"opt\":{{\"family\":\"{}\",\"lr\":{},\
             \"beta1\":{},\"beta2\":{},\"eps\":{},\"wd\":{},\
             \"momentum\":{},\"nesterov\":{}}},\"mask\":{{\
             \"keep_ratio\":{},\"gamma\":{},\"period\":{},\"rank\":{},\
             \"topk\":{}}},\"schedule\":{schedule},\"steps\":{},\
             \"eval_every\":{},\"seed\":{},\"dataset_size\":{},\
             \"data_seed\":{}{artifacts_hint}}}",
            esc(&c.model),
            c.method.name(),
            c.opt.family.name(),
            ser_f(c.opt.lr),
            ser_f(c.opt.beta1),
            ser_f(c.opt.beta2),
            ser_f(c.opt.eps),
            ser_f(c.opt.weight_decay),
            ser_f(c.opt.momentum),
            c.opt.nesterov,
            ser_f(c.mask.keep_ratio),
            c.mask.gamma,
            c.mask.period,
            c.mask.rank,
            ser_f(c.mask.topk),
            c.steps,
            c.eval_every,
            c.seed,
            c.dataset_size,
            c.data_seed,
        )
    }

    /// Parse a [`Self::to_wire`] object. Fields absent from the wire
    /// fall back to [`RunConfig::default`] — fidelity is guarded by the
    /// consumer comparing content hashes, not by strict parsing — but
    /// an unknown wire *version* or kind/schedule tag is a hard error.
    pub fn from_wire(j: &Json) -> Result<JobSpec> {
        let v = j.get("v").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        if v != WIRE_VERSION {
            bail!("unsupported wire spec version {v} (want {WIRE_VERSION})");
        }
        let kj = j.get("kind").ok_or_else(|| anyhow!("wire spec: no kind"))?;
        let ks = |o: &Json, k: &str| {
            o.get(k).and_then(Json::as_str).map(str::to_string)
        };
        let kind = match kj.get("t").and_then(Json::as_str) {
            Some("finetune") => ExperimentKind::Finetune {
                task: ks(kj, "task")
                    .ok_or_else(|| anyhow!("finetune kind: no task"))?,
                epochs: kj.get("epochs").and_then(Json::as_usize).unwrap_or(1),
            },
            Some("blobs") => ExperimentKind::Blobs {
                dataset: ks(kj, "dataset")
                    .ok_or_else(|| anyhow!("blobs kind: no dataset"))?,
                spread: kj.get("spread").and_then(Json::as_f64).unwrap_or(4.0),
                data_seed: kj
                    .get("data_seed")
                    .and_then(Json::as_usize)
                    .unwrap_or(0) as u64,
                epochs: kj.get("epochs").and_then(Json::as_usize).unwrap_or(1),
            },
            Some("pretrain") => ExperimentKind::Pretrain,
            other => bail!("unknown wire kind tag {other:?}"),
        };
        let mut cfg = RunConfig::default();
        let f_usize =
            |k: &str, d: usize| j.get(k).and_then(Json::as_usize).unwrap_or(d);
        if let Some(m) = j.get("model").and_then(Json::as_str) {
            cfg.model = m.to_string();
        }
        if let Some(m) = j.get("method").and_then(Json::as_str) {
            cfg.method = crate::config::Method::parse(m)?;
        }
        if let Some(o) = j.get("opt") {
            if let Some(fam) = o.get("family").and_then(Json::as_str) {
                cfg.opt.family = crate::config::OptFamily::parse(fam)?;
            }
            let g = |k: &str, d: f64| o.get(k).and_then(Json::as_f64).unwrap_or(d);
            cfg.opt.lr = g("lr", cfg.opt.lr);
            cfg.opt.beta1 = g("beta1", cfg.opt.beta1);
            cfg.opt.beta2 = g("beta2", cfg.opt.beta2);
            cfg.opt.eps = g("eps", cfg.opt.eps);
            cfg.opt.weight_decay = g("wd", cfg.opt.weight_decay);
            cfg.opt.momentum = g("momentum", cfg.opt.momentum);
            if let Some(n) = o.get("nesterov").and_then(Json::as_bool) {
                cfg.opt.nesterov = n;
            }
        }
        if let Some(m) = j.get("mask") {
            let g = |k: &str, d: f64| m.get(k).and_then(Json::as_f64).unwrap_or(d);
            cfg.mask.keep_ratio = g("keep_ratio", cfg.mask.keep_ratio);
            cfg.mask.topk = g("topk", cfg.mask.topk);
            let u = |k: &str, d: usize| {
                m.get(k).and_then(Json::as_usize).unwrap_or(d)
            };
            cfg.mask.gamma = u("gamma", cfg.mask.gamma);
            cfg.mask.period = u("period", cfg.mask.period);
            cfg.mask.rank = u("rank", cfg.mask.rank);
        }
        if let Some(s) = j.get("schedule") {
            cfg.schedule = match s.get("t").and_then(Json::as_str) {
                Some("constant") => Schedule::Constant,
                Some("multistep") => Schedule::MultiStep {
                    milestones: s
                        .get("milestones")
                        .and_then(Json::as_arr)
                        .map(|a| {
                            a.iter().filter_map(Json::as_usize).collect()
                        })
                        .unwrap_or_default(),
                    gamma: s.get("gamma").and_then(Json::as_f64).unwrap_or(0.1),
                },
                Some("cosine") => Schedule::CosineWarmup {
                    warmup: s.get("warmup").and_then(Json::as_usize).unwrap_or(0),
                    total: s.get("total").and_then(Json::as_usize).unwrap_or(0),
                    min_lr: s.get("min_lr").and_then(Json::as_f64).unwrap_or(0.0),
                },
                Some("inv_t") => Schedule::InvT {
                    c0: s.get("c0").and_then(Json::as_f64).unwrap_or(1.0),
                },
                other => bail!("unknown wire schedule tag {other:?}"),
            };
        }
        cfg.steps = f_usize("steps", cfg.steps);
        cfg.eval_every = f_usize("eval_every", cfg.eval_every);
        cfg.seed = f_usize("seed", cfg.seed as usize) as u64;
        cfg.dataset_size = f_usize("dataset_size", cfg.dataset_size);
        cfg.data_seed = f_usize("data_seed", cfg.data_seed as usize) as u64;
        if let Some(dir) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = dir.to_string();
        }
        cfg.validate()?;
        Ok(JobSpec { kind, cfg })
    }
}

fn canonical_schedule(s: &Schedule) -> String {
    match s {
        Schedule::Constant => "constant".to_string(),
        Schedule::MultiStep { milestones, gamma } => {
            let ms = milestones
                .iter()
                .map(|m| m.to_string())
                .collect::<Vec<_>>()
                .join("+");
            format!("multistep:{ms}:{gamma}")
        }
        Schedule::CosineWarmup { warmup, total, min_lr } => {
            format!("cosine:{warmup}:{total}:{min_lr}")
        }
        Schedule::InvT { c0 } => format!("inv_t:{c0}"),
    }
}

/// FNV-1a 64-bit: tiny, dependency-free, stable across platforms.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    fn spec() -> JobSpec {
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 4 },
            cfg: RunConfig::default(),
        }
    }

    #[test]
    fn hash_is_stable_and_sensitive() {
        let a = spec();
        let b = spec();
        assert_eq!(a.content_hash(), b.content_hash());
        assert_eq!(a.hash_hex().len(), 16);

        let mut c = spec();
        c.cfg.seed = 1;
        assert_ne!(a.content_hash(), c.content_hash());
        let mut d = spec();
        d.cfg.method = Method::LisaWor;
        assert_ne!(a.content_hash(), d.content_hash());
        let mut e = spec();
        e.kind = ExperimentKind::Finetune { task: "SST-2".into(), epochs: 4 };
        assert_ne!(a.content_hash(), e.content_hash());
    }

    #[test]
    fn canonical_embeds_dataset_definitions() {
        // Editing a task's generative params (or the blob sizes) must
        // change the cell identity, not silently replay stale caches.
        assert!(spec().canonical().contains("def="));
        let b = JobSpec {
            kind: ExperimentKind::Blobs {
                dataset: "X".into(),
                spread: 1.0,
                data_seed: 1,
                epochs: 1,
            },
            cfg: RunConfig::default(),
        };
        assert!(b
            .canonical()
            .contains(&format!("n={BLOBS_N_TRAIN}+{BLOBS_N_TEST}")));
    }

    #[test]
    fn hash_ignores_local_paths() {
        let a = spec();
        let mut b = spec();
        b.cfg.artifacts_dir = "/somewhere/else".into();
        b.cfg.out_dir = "/tmp/out".into();
        assert_eq!(a.content_hash(), b.content_hash());
    }

    #[test]
    fn fnv_known_vector() {
        // FNV-1a("a") per the reference implementation.
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
    }

    #[test]
    fn from_json_round_trip() {
        let j = Json::parse(
            r#"{"kind":"finetune","task":"SST-2","method":"lisa-wor",
                "seed":3,"epochs":2,"gamma":4,"period":1,"lr":0.002}"#,
        )
        .unwrap();
        let s = JobSpec::from_json(&j).unwrap();
        assert_eq!(s.kind.dataset(), "SST-2");
        assert_eq!(s.cfg.method, Method::LisaWor);
        assert_eq!(s.cfg.seed, 3);
        assert_eq!(s.cfg.mask.gamma, 4);
        assert!((s.cfg.opt.lr - 0.002).abs() < 1e-12);
        assert_eq!(s.label(), "SST-2/lisa-wor/s3");
    }

    #[test]
    fn wire_round_trip_preserves_the_content_hash() {
        // The wire format must reproduce *every* canonical field —
        // including the ones `from_json` does not expose — so remote
        // workers run bit-identical cells. Exercise defaults, a
        // schedule-heavy pretrain cell, and a blobs cell.
        let mut pretrain = JobSpec {
            kind: ExperimentKind::Pretrain,
            cfg: RunConfig::default(),
        };
        pretrain.cfg.schedule = Schedule::CosineWarmup {
            warmup: 10,
            total: 100,
            min_lr: 6e-5,
        };
        pretrain.cfg.opt.beta2 = 0.95;
        pretrain.cfg.opt.eps = 1e-8;
        pretrain.cfg.opt.nesterov = false;
        pretrain.cfg.mask.topk = 0.07;
        pretrain.cfg.dataset_size = 777;
        pretrain.cfg.data_seed = 42;
        let mut multistep = spec();
        multistep.cfg.schedule = Schedule::MultiStep {
            milestones: vec![10, 20],
            gamma: 0.5,
        };
        let blobs = JobSpec {
            kind: ExperimentKind::Blobs {
                dataset: "IMG-mid".into(),
                spread: 4.25,
                data_seed: 6002,
                epochs: 3,
            },
            cfg: RunConfig::default(),
        };
        let mut invt = spec();
        invt.cfg.schedule = Schedule::InvT { c0: 2.5 };
        for s in [spec(), pretrain, multistep, blobs, invt] {
            let j = Json::parse(&s.to_wire()).expect("wire is valid JSON");
            let back = JobSpec::from_wire(&j).expect("wire parses back");
            assert_eq!(
                back.canonical(),
                s.canonical(),
                "wire round trip must preserve the canonical string"
            );
            assert_eq!(back.content_hash(), s.content_hash());
        }
    }

    #[test]
    fn wire_carries_the_artifacts_hint_but_never_out_dir() {
        // Explicit artifacts dirs travel (a location hint, honored like
        // a local --artifacts override); defaults stay host-local.
        let mut a = spec();
        a.cfg.artifacts_dir = "/shared/fs/artifacts".into();
        a.cfg.out_dir = "/client/results".into();
        let wire = a.to_wire();
        assert!(wire.contains("/shared/fs/artifacts"));
        assert!(!wire.contains("/client/results"), "out_dir never travels");
        let back =
            JobSpec::from_wire(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.cfg.artifacts_dir, "/shared/fs/artifacts");
        // Location hints stay outside the cell identity.
        assert_eq!(back.content_hash(), a.content_hash());

        let d = spec(); // default artifacts_dir
        assert!(
            !d.to_wire().contains("artifacts_dir"),
            "default dirs resolve host-locally on the receiving side"
        );
        let back =
            JobSpec::from_wire(&Json::parse(&d.to_wire()).unwrap()).unwrap();
        assert_eq!(back.cfg.artifacts_dir, RunConfig::default().artifacts_dir);
    }

    #[test]
    fn from_wire_rejects_version_skew_and_bad_tags() {
        let bad_v = Json::parse(r#"{"v":99,"kind":{"t":"pretrain"}}"#).unwrap();
        assert!(JobSpec::from_wire(&bad_v).is_err());
        let bad_kind =
            Json::parse(r#"{"v":1,"kind":{"t":"mystery"}}"#).unwrap();
        assert!(JobSpec::from_wire(&bad_kind).is_err());
        let bad_sched = Json::parse(
            r#"{"v":1,"kind":{"t":"pretrain"},"schedule":{"t":"warp"}}"#,
        )
        .unwrap();
        assert!(JobSpec::from_wire(&bad_sched).is_err());
        let no_kind = Json::parse(r#"{"v":1}"#).unwrap();
        assert!(JobSpec::from_wire(&no_kind).is_err());
    }

    #[test]
    fn from_json_rejects_unknown_kind_and_method() {
        let j = Json::parse(r#"{"kind":"nope"}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
        let j = Json::parse(r#"{"kind":"pretrain","method":"zzz"}"#).unwrap();
        assert!(JobSpec::from_json(&j).is_err());
    }
}
