//! Aggregation of per-job results into the existing table/CSV sinks.
//!
//! The report owns presentation-side determinism: results are kept in
//! submission order (the pool already sorts by `seq`), and the CSV
//! aggregate contains only run-to-run-reproducible columns — no
//! wall-clock, no cache provenance — so a 2-worker grid writes a
//! byte-identical file to a 1-worker grid, and a cache replay writes a
//! byte-identical file to the original run.

use super::pool::{JobResult, JobStatus};
use crate::bench::TablePrinter;
use crate::metrics::{format_g, CsvCell, CsvWriter};
use anyhow::Result;
use std::collections::BTreeMap;
use std::path::Path;

/// Aggregated view over one grid's results.
pub struct GridReport {
    pub results: Vec<JobResult>,
}

impl GridReport {
    pub fn new(mut results: Vec<JobResult>) -> Self {
        results.sort_by_key(|r| r.seq);
        Self { results }
    }

    pub fn n_jobs(&self) -> usize {
        self.results.len()
    }

    pub fn n_ok(&self) -> usize {
        self.results.iter().filter(|r| r.is_ok()).count()
    }

    pub fn n_failed(&self) -> usize {
        self.n_jobs() - self.n_ok()
    }

    pub fn n_cached(&self) -> usize {
        self.results.iter().filter(|r| r.from_cache).count()
    }

    /// Fraction of jobs served from the result cache, in [0, 1].
    pub fn cache_hit_rate(&self) -> f64 {
        if self.results.is_empty() {
            0.0
        } else {
            self.n_cached() as f64 / self.n_jobs() as f64
        }
    }

    /// Total wall-clock seconds spent across workers (not elapsed time).
    pub fn worker_secs(&self) -> f64 {
        self.results.iter().map(|r| r.secs).sum()
    }

    /// Per-cell table for stdout: label, status, metric, provenance.
    pub fn table(&self) -> TablePrinter {
        let mut t = TablePrinter::new(&[
            "job", "dataset", "method", "seed", "status", "metric",
            "tail loss", "src", "secs",
        ]);
        for r in &self.results {
            let (metric, tail) = match r.outcome() {
                Some(o) => {
                    (format!("{:.4}", o.final_metric),
                     format!("{:.4}", o.tail_loss))
                }
                None => ("-".to_string(), "-".to_string()),
            };
            t.row(vec![
                r.seq.to_string(),
                r.spec.kind.dataset().to_string(),
                r.spec.cfg.method.name().to_string(),
                r.spec.cfg.seed.to_string(),
                r.status.tag().to_string(),
                metric,
                tail,
                if r.from_cache { "cache" } else { "run" }.to_string(),
                format!("{:.2}", r.secs),
            ]);
        }
        t
    }

    /// Print the per-cell table plus a one-line summary (and, on
    /// stderr, every failure's full diagnostic).
    pub fn print(&self, title: &str) {
        self.table().print(title);
        println!(
            "{} job(s): {} ok, {} failed, {} from cache ({:.0}% hit), \
             {:.2}s worker time",
            self.n_jobs(),
            self.n_ok(),
            self.n_failed(),
            self.n_cached(),
            100.0 * self.cache_hit_rate(),
            self.worker_secs(),
        );
        self.print_failures();
    }

    /// Every failed/panicked cell's collected diagnostic, to stderr.
    /// The status *tag* alone ("failed") is useless for triage; the
    /// message carries the actual cause ("artifacts for ... missing").
    pub fn print_failures(&self) {
        for r in &self.results {
            match &r.status {
                JobStatus::Failed(msg) | JobStatus::Panicked(msg) => {
                    eprintln!("  {} {}: {msg}",
                              r.status.tag(), r.spec.label());
                }
                JobStatus::Done(_) => {}
            }
        }
    }

    /// Write the deterministic per-cell aggregate CSV.
    ///
    /// Columns are limited to result content (no timing/provenance):
    /// `label,kind,model,method,seed,hash,status,final_metric,tail_loss,
    /// steps`.
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w = CsvWriter::create(
            path,
            &["label", "kind", "model", "method", "seed", "hash",
              "status", "final_metric", "tail_loss", "steps"],
        )?;
        for r in &self.results {
            let (metric, tail, steps) = match r.outcome() {
                Some(o) => (
                    format_g(o.final_metric),
                    format_g(o.tail_loss),
                    o.steps.to_string(),
                ),
                None => ("".into(), "".into(), "0".into()),
            };
            w.row_mixed(&[
                CsvCell::S(r.spec.label()),
                CsvCell::S(r.spec.kind.dataset().to_string()),
                CsvCell::S(r.spec.cfg.model.clone()),
                CsvCell::S(r.spec.cfg.method.name().to_string()),
                CsvCell::I(r.spec.cfg.seed as i64),
                CsvCell::S(r.spec.hash_hex()),
                CsvCell::S(r.status.tag().to_string()),
                CsvCell::S(metric),
                CsvCell::S(tail),
                CsvCell::S(steps),
            ])?;
        }
        w.finish()
    }

    /// Write per-step training-loss curves for every successful cell
    /// (`label,step,loss`) — the Fig. 4/7-style companion file.
    pub fn write_curves_csv<P: AsRef<Path>>(&self, path: P) -> Result<()> {
        let mut w =
            CsvWriter::create(path, &["label", "step", "loss"])?;
        for r in &self.results {
            if let Some(o) = r.outcome() {
                for &(s, l) in &o.loss_series {
                    w.row_mixed(&[
                        CsvCell::S(r.spec.label()),
                        CsvCell::I(s as i64),
                        CsvCell::F(l),
                    ])?;
                }
            }
        }
        w.finish()
    }

    /// Mean of `value(outcome)` over successful cells, grouped by `key`
    /// (e.g. `(method, task)` to average across seeds). Deterministic:
    /// `BTreeMap` ordering, submission-ordered accumulation.
    pub fn mean_by<K, F, V>(&self, key: F, value: V) -> BTreeMap<K, f64>
    where
        K: Ord,
        F: Fn(&JobResult) -> K,
        V: Fn(&super::pool::JobOutcome) -> f64,
    {
        let mut acc: BTreeMap<K, (f64, usize)> = BTreeMap::new();
        for r in &self.results {
            if let Some(o) = r.outcome() {
                let e = acc.entry(key(r)).or_insert((0.0, 0));
                e.0 += value(o);
                e.1 += 1;
            }
        }
        acc.into_iter()
            .map(|(k, (sum, n))| (k, sum / n as f64))
            .collect()
    }

    /// [`Self::mean_by`] over `final_metric` — the common table cell.
    pub fn mean_metric_by<K, F>(&self, key: F) -> BTreeMap<K, f64>
    where
        K: Ord,
        F: Fn(&JobResult) -> K,
    {
        self.mean_by(key, |o| o.final_metric)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, RunConfig};
    use crate::pool::{JobOutcome, JobStatus};
    use crate::spec::{ExperimentKind, JobSpec};

    fn result(seq: u64, seed: u64, metric: f64, ok: bool) -> JobResult {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        cfg.method = Method::LisaWor;
        let spec = JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 2 },
            cfg,
        };
        let status = if ok {
            JobStatus::Done(JobOutcome {
                final_metric: metric,
                tail_loss: 0.5,
                steps: 4,
                train_secs: 0.1,
                loss_series: vec![(0, 1.0)],
                eval_series: vec![],
            })
        } else {
            JobStatus::Failed("boom".into())
        };
        JobResult { seq, spec, status, from_cache: false, secs: 0.01 }
    }

    #[test]
    fn counts_and_hit_rate() {
        let mut a = result(0, 0, 90.0, true);
        a.from_cache = true;
        let rep = GridReport::new(vec![result(1, 1, 92.0, true), a,
                                       result(2, 2, 0.0, false)]);
        assert_eq!(rep.n_jobs(), 3);
        assert_eq!(rep.n_ok(), 2);
        assert_eq!(rep.n_failed(), 1);
        assert_eq!(rep.n_cached(), 1);
        assert!((rep.cache_hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        // new() re-sorts by seq
        assert_eq!(rep.results[0].seq, 0);
    }

    #[test]
    fn csv_is_deterministic_and_excludes_timing() {
        let dir = std::env::temp_dir()
            .join(format!("omgd-report-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let make = |secs: f64| {
            let mut r0 = result(0, 0, 91.5, true);
            let mut r1 = result(1, 1, 0.0, false);
            r0.secs = secs;
            r1.secs = secs * 2.0;
            GridReport::new(vec![r1, r0])
        };
        let p1 = dir.join("a.csv");
        let p2 = dir.join("b.csv");
        make(0.5).write_csv(&p1).unwrap();
        make(123.0).write_csv(&p2).unwrap();
        let a = std::fs::read(&p1).unwrap();
        let b = std::fs::read(&p2).unwrap();
        assert_eq!(a, b, "timing must not leak into the aggregate CSV");
        let text = String::from_utf8(a).unwrap();
        assert!(text.starts_with("label,kind,model,method,seed,hash,"));
        assert!(text.contains("failed"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn mean_metric_groups_by_key() {
        let rep = GridReport::new(vec![
            result(0, 0, 90.0, true),
            result(1, 1, 92.0, true),
            result(2, 2, 0.0, false), // failed: excluded from means
        ]);
        let by_method =
            rep.mean_metric_by(|r| r.spec.cfg.method.name().to_string());
        assert_eq!(by_method.len(), 1);
        assert!((by_method["lisa-wor"] - 91.0).abs() < 1e-12);
    }
}
