//! On-disk result cache keyed by [`JobSpec`] content hash.
//!
//! Layout: one JSON file per completed cell under `target/omgd-cache/`
//! (override with `--cache-dir` / [`ResultCache::open`]). Writes are
//! atomic (unique temp file + rename) so concurrent workers — or two
//! grids racing on the same cell — can never leave a torn entry; a
//! reader either sees a complete file or a miss.
//!
//! Entries store the spec's canonical string alongside the outcome and
//! [`ResultCache::get`] verifies it, so a (vanishingly unlikely) 64-bit
//! hash collision degrades to a cache miss, never a wrong result. An
//! artifact fingerprint (`afp`, supplied by the runner from the model's
//! on-disk artifact files) is stored and verified the same way, so
//! regenerating artifacts — same model name, new weights/HLO — reads
//! as a miss instead of replaying stale results. Unparseable or
//! version-skewed entries also read as misses.
//!
//! Eviction: the cache grows without bound until a [`GcPolicy`] prunes
//! it — an age cap (entries whose last touch is older than
//! `max_age_secs`) followed by a total-size cap that evicts
//! least-recently-used-first until the directory fits in `max_bytes`.
//! Recency is the entry's mtime, refreshed on every cache *hit* as well
//! as on write, so eviction order is true LRU. GC runs
//! at open for every grid/serve front-end (via
//! [`ResultCache::open_with`]) and on demand as `omgd cache-gc`;
//! entries written after a pass's reference instant are never
//! candidates, so a worker publishing a result mid-GC cannot lose it.
//! Knobs and sizing guidance: `docs/operations.md`.
//!
//! Checkpoints: the same directory also parks training checkpoints
//! (`{spec-hash}-{step}.ckpt`, written via
//! [`ResultCache::put_checkpoint`]) so a re-leased job can resume
//! instead of recomputing. Checkpoint files are invisible to the
//! entry iterator (and thus to `len`/`stats` and the size cap) and
//! are evicted by the **age cap only** — and never while their spec
//! hash appears in the caller-supplied protected set
//! ([`ResultCache::gc_protected`]), which the gateway derives from
//! live journal entries. See `docs/durability.md`.

use super::pool::JobOutcome;
use super::spec::JobSpec;
use crate::obs;
use omgd_util::checkpoint::Checkpoint;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::HashSet;
use std::fs;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, SystemTime};

/// Bump when the entry format or [`JobOutcome`] fields change.
const SCHEMA_VERSION: u64 = 1;

/// Default cache location, relative to the working directory.
pub const DEFAULT_CACHE_DIR: &str = "target/omgd-cache";

static TMP_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Eviction policy for [`ResultCache::gc`]. Both caps are optional and
/// the default policy is a no-op, so opening a cache never surprises a
/// grid by deleting entries unless the operator asked for it.
#[derive(Clone, Copy, Debug, Default)]
pub struct GcPolicy {
    /// Evict entries whose last touch (write *or* cache hit — see
    /// [`ResultCache::get`]) is older than this many seconds.
    pub max_age_secs: Option<u64>,
    /// After the age pass, evict least-recently-used-first until the
    /// cache directory totals ≤ this many bytes. True LRU: a cache hit
    /// refreshes the entry's mtime, so hot entries survive the cap.
    pub max_bytes: Option<u64>,
    /// Report what would be evicted without deleting anything.
    pub dry_run: bool,
}

impl GcPolicy {
    /// True when neither cap is set — [`ResultCache::gc`] returns
    /// zeroed stats without touching the disk.
    pub fn is_noop(&self) -> bool {
        self.max_age_secs.is_none() && self.max_bytes.is_none()
    }
}

/// What one GC pass did (or, under `dry_run`, would have done).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct GcStats {
    pub scanned: usize,
    pub evicted: usize,
    pub evicted_bytes: u64,
    pub kept: usize,
    pub kept_bytes: u64,
}

/// Entry count + total byte size (the `GET /cache` payload).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub entries: usize,
    pub bytes: u64,
}

/// Handle to one cache directory.
pub struct ResultCache {
    dir: PathBuf,
}

impl ResultCache {
    /// Open (creating if needed) the cache at `dir`, or the default.
    pub fn open(dir: Option<&str>) -> Result<Self> {
        let dir = PathBuf::from(dir.unwrap_or(DEFAULT_CACHE_DIR));
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache dir {dir:?}"))?;
        Ok(Self { dir })
    }

    /// Open the cache and immediately run one `policy` GC pass over it
    /// — the "GC at open" hook every grid/serve front-end goes through,
    /// so a long-lived deployment's cache stays inside its caps without
    /// a separate cron job.
    pub fn open_with(
        dir: Option<&str>,
        policy: &GcPolicy,
    ) -> Result<(Self, GcStats)> {
        let cache = Self::open(dir)?;
        let stats = cache.gc(policy)?;
        Ok((cache, stats))
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn entry_path(&self, hash: &str) -> PathBuf {
        self.dir.join(format!("{hash}.json"))
    }

    /// Look up a completed outcome for `spec` computed against the
    /// artifacts identified by `afp`. Any read/parse/version/canonical/
    /// fingerprint mismatch is a miss.
    ///
    /// A hit refreshes the entry's mtime (best-effort), so GC's
    /// oldest-first eviction order is true LRU — hot entries that are
    /// read every run survive the size cap even if they were *written*
    /// long ago.
    pub fn get(&self, spec: &JobSpec, afp: &str) -> Option<JobOutcome> {
        let path = self.entry_path(&spec.hash_hex());
        let text = fs::read_to_string(&path).ok()?;
        let j = Json::parse(&text).ok()?;
        if j.get("v").and_then(Json::as_f64) != Some(SCHEMA_VERSION as f64) {
            return None;
        }
        if j.get("canon").and_then(Json::as_str)
            != Some(spec.canonical().as_str())
        {
            return None;
        }
        if j.get("afp").and_then(Json::as_str) != Some(afp) {
            return None;
        }
        let out = parse_outcome(j.get("outcome")?)?;
        // Recency touch, only once the entry has actually hit. Opening
        // for write without truncation leaves the bytes alone; failure
        // (read-only cache dir) costs nothing but LRU precision. If a
        // concurrent `put` republished the entry between our read and
        // this touch, we merely freshen an already-fresh file.
        let _ = fs::File::options()
            .write(true)
            .open(&path)
            .and_then(|f| f.set_modified(SystemTime::now()));
        Some(out)
    }

    /// Persist `outcome` for `spec` (atomic: temp file + rename).
    pub fn put(
        &self,
        spec: &JobSpec,
        afp: &str,
        outcome: &JobOutcome,
    ) -> Result<()> {
        let path = self.entry_path(&spec.hash_hex());
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            TMP_COUNTER.fetch_add(1, Ordering::Relaxed)
        ));
        fs::write(&tmp, serialize_entry(spec, afp, outcome))
            .with_context(|| format!("writing cache temp {tmp:?}"))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cache entry {path:?}"))?;
        Ok(())
    }

    /// Remove one entry; returns true if it existed.
    pub fn invalidate(&self, spec: &JobSpec) -> bool {
        fs::remove_file(self.entry_path(&spec.hash_hex())).is_ok()
    }

    /// Number of completed entries on disk.
    pub fn len(&self) -> usize {
        self.iter_entries().count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Entry count and total byte size of the cache directory.
    pub fn stats(&self) -> CacheStats {
        let mut s = CacheStats::default();
        for p in self.iter_entries() {
            if let Ok(meta) = fs::metadata(&p) {
                s.entries += 1;
                s.bytes += meta.len();
            }
        }
        s
    }

    /// Run one GC pass with `now` as the reference instant.
    pub fn gc(&self, policy: &GcPolicy) -> Result<GcStats> {
        self.gc_at(policy, SystemTime::now())
    }

    /// GC with an explicit reference instant (tests inject `now`).
    pub fn gc_at(
        &self,
        policy: &GcPolicy,
        now: SystemTime,
    ) -> Result<GcStats> {
        self.gc_at_protected(policy, now, &HashSet::new())
    }

    /// [`ResultCache::gc`] with a set of spec hashes whose parked
    /// checkpoints must survive the pass. The gateway passes the
    /// hashes of every job with a live (admitted, unfinished) journal
    /// entry, so a checkpoint parked by an expired lease is still
    /// there when the job is re-leased — however long that takes.
    pub fn gc_protected(
        &self,
        policy: &GcPolicy,
        protected: &HashSet<String>,
    ) -> Result<GcStats> {
        self.gc_at_protected(policy, SystemTime::now(), protected)
    }

    /// The full GC pass (every other variant delegates here).
    ///
    /// Entries whose mtime is later than `now` — i.e. written while
    /// this pass runs — are never eviction candidates: a worker
    /// publishing a fresh result mid-GC cannot lose it (their bytes
    /// still count against the size cap, which the pass then satisfies
    /// by evicting older entries, or not at all). The same shield
    /// covers checkpoints, which are additionally exempt from the size
    /// cap and — when their spec hash is in `protected` — from the age
    /// cap too.
    pub fn gc_at_protected(
        &self,
        policy: &GcPolicy,
        now: SystemTime,
        protected: &HashSet<String>,
    ) -> Result<GcStats> {
        // Sweep orphaned atomic-write temp files first (a crash
        // between the temp write and the rename leaks them, invisible
        // to the entry iterator): `.tmp-*` from entry `put`, `*.tmp`
        // from `Checkpoint::save`. Live writes rename within
        // milliseconds, so an hour of grace can never race one. Runs
        // under every policy — including the no-op default — so plain
        // opens self-heal.
        const TMP_ORPHAN_GRACE_SECS: u64 = 3600;
        if !policy.dry_run {
            if let Some(cutoff) =
                now.checked_sub(Duration::from_secs(TMP_ORPHAN_GRACE_SECS))
            {
                let tmps = fs::read_dir(&self.dir)
                    .into_iter()
                    .flatten()
                    .filter_map(|e| e.ok())
                    .filter(|e| {
                        let name = e.file_name();
                        let name = name.to_string_lossy();
                        name.starts_with(".tmp-") || name.ends_with(".tmp")
                    });
                for e in tmps {
                    let stale = e
                        .metadata()
                        .and_then(|m| m.modified())
                        .map(|mtime| mtime < cutoff)
                        .unwrap_or(false);
                    if stale {
                        let _ = fs::remove_file(e.path());
                    }
                }
            }
        }
        let mut stats = GcStats::default();
        if policy.is_noop() {
            return Ok(stats);
        }
        // Snapshot: (path, last touch, size); unreadable entries are
        // skipped (a concurrent invalidate is not an error).
        let mut total_bytes = 0u64;
        let mut protected_bytes = 0u64;
        let mut candidates: Vec<(PathBuf, SystemTime, u64)> = Vec::new();
        for p in self.iter_entries() {
            let Ok(meta) = fs::metadata(&p) else { continue };
            let Ok(mtime) = meta.modified() else { continue };
            stats.scanned += 1;
            total_bytes += meta.len();
            if mtime > now {
                protected_bytes += meta.len();
            } else {
                candidates.push((p, mtime, meta.len()));
            }
        }
        // Least recently touched first; path tiebreak keeps the pass
        // deterministic when mtimes collide.
        candidates
            .sort_by(|a, b| a.1.cmp(&b.1).then_with(|| a.0.cmp(&b.0)));

        let mut evict: Vec<(PathBuf, u64)> = Vec::new();
        let cutoff = policy
            .max_age_secs
            .and_then(|s| now.checked_sub(Duration::from_secs(s)));
        let mut live_bytes = protected_bytes;
        let mut survivors: Vec<(PathBuf, u64)> = Vec::new();
        for (p, mtime, len) in candidates {
            if cutoff.map(|c| mtime < c).unwrap_or(false) {
                evict.push((p, len));
            } else {
                live_bytes += len;
                survivors.push((p, len));
            }
        }
        if let Some(max) = policy.max_bytes {
            for (p, len) in survivors {
                if live_bytes <= max {
                    break;
                }
                live_bytes -= len;
                evict.push((p, len));
            }
        }
        // Checkpoint sweep: `.ckpt` files answer only to the age cap —
        // the size cap never sees them (a parked resume point is worth
        // more than cache headroom) — and a checkpoint whose spec hash
        // is protected (live journal entry: the job will be re-leased)
        // is immune even to the age cap.
        if let Some(cutoff) =
            policy.max_age_secs.and_then(|s| now.checked_sub(Duration::from_secs(s)))
        {
            for p in self.iter_checkpoints() {
                let Some(hash) = ckpt_hash_of(&p) else { continue };
                let Ok(meta) = fs::metadata(&p) else { continue };
                let Ok(mtime) = meta.modified() else { continue };
                stats.scanned += 1;
                total_bytes += meta.len();
                if mtime > now
                    || mtime >= cutoff
                    || protected.contains(&hash)
                {
                    continue;
                }
                evict.push((p, meta.len()));
            }
        }
        for (p, len) in evict {
            if !policy.dry_run && fs::remove_file(&p).is_err() && p.exists()
            {
                continue; // undeletable (perms?) — count it as kept
            }
            stats.evicted += 1;
            stats.evicted_bytes += len;
        }
        stats.kept = stats.scanned - stats.evicted;
        stats.kept_bytes = total_bytes - stats.evicted_bytes;
        Ok(stats)
    }

    /// Remove every entry; returns how many were deleted.
    pub fn clear(&self) -> Result<usize> {
        let mut n = 0;
        for p in self.iter_entries().collect::<Vec<_>>() {
            fs::remove_file(&p)?;
            n += 1;
        }
        Ok(n)
    }

    /// On-disk path of the checkpoint for spec `hash` at `step`.
    pub fn ckpt_path(&self, hash: &str, step: u64) -> PathBuf {
        self.dir.join(format!("{hash}-{step}.ckpt"))
    }

    /// Park a training checkpoint for spec `hash` (atomic via
    /// [`Checkpoint::save`]'s temp + rename). The `ckpt.write`
    /// faultpoint fires *before* any byte lands, so a killed worker
    /// leaves either the previous checkpoint or none — never a torn
    /// one.
    pub fn put_checkpoint(
        &self,
        hash: &str,
        ck: &Checkpoint,
    ) -> Result<PathBuf> {
        obs::faultpoint("ckpt.write");
        let path = self.ckpt_path(hash, ck.step);
        ck.save(&path)
            .with_context(|| format!("parking checkpoint {path:?}"))?;
        obs::CKPT_WRITES.inc();
        Ok(path)
    }

    /// Newest loadable checkpoint for spec `hash`, if any. Scans
    /// highest-step-first and skips unreadable or corrupt files, so a
    /// checkpoint torn by a crash (impossible via `put_checkpoint`,
    /// but operators copy files around) degrades to the previous one.
    pub fn latest_checkpoint(&self, hash: &str) -> Option<Checkpoint> {
        let mut steps: Vec<u64> = self
            .iter_checkpoints()
            .filter(|p| ckpt_hash_of(p).as_deref() == Some(hash))
            .filter_map(|p| ckpt_step_of(&p))
            .collect();
        steps.sort_unstable_by(|a, b| b.cmp(a));
        for step in steps {
            if let Ok(ck) = Checkpoint::load(self.ckpt_path(hash, step)) {
                if ck.step == step {
                    return Some(ck);
                }
            }
        }
        None
    }

    /// Drop every checkpoint parked for spec `hash` (the job reported
    /// its terminal result; the resume points are dead weight).
    /// Returns how many files were removed.
    pub fn clear_checkpoints(&self, hash: &str) -> usize {
        let mut n = 0;
        for p in self
            .iter_checkpoints()
            .filter(|p| ckpt_hash_of(p).as_deref() == Some(hash))
            .collect::<Vec<_>>()
        {
            if fs::remove_file(&p).is_ok() {
                n += 1;
            }
        }
        n
    }

    fn iter_checkpoints(&self) -> impl Iterator<Item = PathBuf> {
        fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|x| x == "ckpt").unwrap_or(false)
            })
    }

    fn iter_entries(&self) -> impl Iterator<Item = PathBuf> {
        fs::read_dir(&self.dir)
            .into_iter()
            .flatten()
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.extension().map(|x| x == "json").unwrap_or(false)
            })
    }
}

/// Spec hash of a `{hash}-{step}.ckpt` path; `None` when the filename
/// doesn't fit the scheme. Hashes are hex (no `-`), so splitting at
/// the last dash is unambiguous.
fn ckpt_hash_of(p: &Path) -> Option<String> {
    let stem = p.file_stem()?.to_str()?;
    let (hash, step) = stem.rsplit_once('-')?;
    step.parse::<u64>().ok()?;
    Some(hash.to_string())
}

/// Step of a `{hash}-{step}.ckpt` path.
fn ckpt_step_of(p: &Path) -> Option<u64> {
    let stem = p.file_stem()?.to_str()?;
    stem.rsplit_once('-')?.1.parse::<u64>().ok()
}

/// Serialize one entry. Floats use Rust's shortest round-trip `Display`;
/// non-finite values become `null` (JSON has no NaN) and read back as
/// NaN.
fn serialize_entry(spec: &JobSpec, afp: &str, o: &JobOutcome) -> String {
    format!(
        "{{\"v\":{SCHEMA_VERSION},\"hash\":\"{}\",\"label\":\"{}\",\
         \"canon\":\"{}\",\"afp\":\"{}\",\"outcome\":{}}}",
        spec.hash_hex(),
        esc(&spec.label()),
        esc(&spec.canonical()),
        esc(afp),
        ser_outcome(o),
    )
}

/// Serialize a [`JobOutcome`] as a JSON object. Shared by the cache
/// entry format above and the remote-worker result wire
/// ([`super::remote`]), so a result computed remotely round-trips into
/// the gateway's cache byte-for-byte like a local one.
pub(crate) fn ser_outcome(o: &JobOutcome) -> String {
    let loss: Vec<String> = o
        .loss_series
        .iter()
        .map(|(s, l)| format!("[{s},{}]", ser_f(*l)))
        .collect();
    let eval: Vec<String> = o
        .eval_series
        .iter()
        .map(|(s, l, a)| format!("[{s},{},{}]", ser_f(*l), ser_f(*a)))
        .collect();
    format!(
        "{{\"final_metric\":{},\"tail_loss\":{},\"steps\":{},\
         \"train_secs\":{},\"loss_series\":[{}],\"eval_series\":[{}]}}",
        ser_f(o.final_metric),
        ser_f(o.tail_loss),
        o.steps,
        ser_f(o.train_secs),
        loss.join(","),
        eval.join(","),
    )
}

/// Parse a [`ser_outcome`] object back; `None` on any shape mismatch.
pub(crate) fn parse_outcome(j: &Json) -> Option<JobOutcome> {
    let f = |k: &str| -> Option<f64> {
        match j.get(k)? {
            Json::Null => Some(f64::NAN),
            v => v.as_f64(),
        }
    };
    let mut out = JobOutcome {
        final_metric: f("final_metric")?,
        tail_loss: f("tail_loss")?,
        steps: j.get("steps")?.as_usize()?,
        train_secs: f("train_secs")?,
        loss_series: Vec::new(),
        eval_series: Vec::new(),
    };
    for row in j.get("loss_series")?.as_arr()? {
        let row = row.as_arr()?;
        if row.len() != 2 {
            return None;
        }
        out.loss_series
            .push((row[0].as_usize()?, null_to_nan(&row[1])?));
    }
    for row in j.get("eval_series")?.as_arr()? {
        let row = row.as_arr()?;
        if row.len() != 3 {
            return None;
        }
        out.eval_series.push((
            row[0].as_usize()?,
            null_to_nan(&row[1])?,
            null_to_nan(&row[2])?,
        ));
    }
    Some(out)
}

fn null_to_nan(j: &Json) -> Option<f64> {
    match j {
        Json::Null => Some(f64::NAN),
        v => v.as_f64(),
    }
}

use crate::util::json::{escape_str as esc, ser_f64 as ser_f};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::spec::ExperimentKind;

    fn tmp_cache(tag: &str) -> ResultCache {
        let dir = std::env::temp_dir()
            .join(format!("omgd-cache-test-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        ResultCache::open(Some(dir.to_str().unwrap())).unwrap()
    }

    fn spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 2 },
            cfg,
        }
    }

    fn outcome() -> JobOutcome {
        JobOutcome {
            final_metric: 91.25,
            tail_loss: 0.123456789012345,
            steps: 3,
            train_secs: 1.5,
            loss_series: vec![(0, 2.5), (1, 1.25), (2, 0.625)],
            eval_series: vec![(1, 1.0, 50.0), (2, 0.5, 75.0)],
        }
    }

    #[test]
    fn miss_then_hit_round_trips_exactly() {
        let c = tmp_cache("roundtrip");
        let s = spec(0);
        assert!(c.get(&s, "afp-1").is_none());
        c.put(&s, "afp-1", &outcome()).unwrap();
        let got = c.get(&s, "afp-1").expect("hit after put");
        let want = outcome();
        assert_eq!(got.final_metric, want.final_metric);
        assert_eq!(got.tail_loss, want.tail_loss);
        assert_eq!(got.steps, want.steps);
        assert_eq!(got.train_secs, want.train_secs);
        assert_eq!(got.loss_series, want.loss_series);
        assert_eq!(got.eval_series, want.eval_series);
        assert_eq!(c.len(), 1);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn entries_are_per_spec() {
        let c = tmp_cache("perspec");
        c.put(&spec(0), "afp-1", &outcome()).unwrap();
        assert!(c.get(&spec(1), "afp-1").is_none(), "different seed, different cell");
        assert_eq!(c.len(), 1);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn invalidate_and_clear() {
        let c = tmp_cache("inval");
        c.put(&spec(0), "afp-1", &outcome()).unwrap();
        c.put(&spec(1), "afp-1", &outcome()).unwrap();
        assert!(c.invalidate(&spec(0)));
        assert!(!c.invalidate(&spec(0)), "second invalidate is a no-op");
        assert!(c.get(&spec(0), "afp-1").is_none());
        assert!(c.get(&spec(1), "afp-1").is_some());
        assert_eq!(c.clear().unwrap(), 1);
        assert!(c.is_empty());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn nan_survives_the_round_trip_as_nan() {
        let c = tmp_cache("nan");
        let s = spec(2);
        let mut o = outcome();
        o.final_metric = f64::NAN;
        o.eval_series = vec![(0, f64::NAN, 0.0)];
        c.put(&s, "afp-1", &o).unwrap();
        let got = c.get(&s, "afp-1").unwrap();
        assert!(got.final_metric.is_nan());
        assert!(got.eval_series[0].1.is_nan());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn corrupt_entry_reads_as_miss() {
        let c = tmp_cache("corrupt");
        let s = spec(3);
        c.put(&s, "afp-1", &outcome()).unwrap();
        std::fs::write(c.entry_path(&s.hash_hex()), "{not json").unwrap();
        assert!(c.get(&s, "afp-1").is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn canonical_mismatch_reads_as_miss() {
        let c = tmp_cache("canon");
        let a = spec(4);
        c.put(&a, "afp-1", &outcome()).unwrap();
        // Simulate a hash collision: copy a's entry under b's hash.
        let b = spec(5);
        std::fs::copy(
            c.entry_path(&a.hash_hex()),
            c.entry_path(&b.hash_hex()),
        )
        .unwrap();
        assert!(c.get(&b, "afp-1").is_none(), "foreign canon must not hit");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_age_cap_evicts_only_expired_entries() {
        let c = tmp_cache("gc-age");
        c.put(&spec(10), "afp-1", &outcome()).unwrap();
        c.put(&spec(11), "afp-1", &outcome()).unwrap();
        let now = SystemTime::now();
        let policy =
            GcPolicy { max_age_secs: Some(3600), ..GcPolicy::default() };
        // Both entries were written seconds ago: nothing is older than
        // an hour.
        let st = c.gc_at(&policy, now).unwrap();
        assert_eq!(st.evicted, 0);
        assert_eq!(st.kept, 2);
        assert_eq!(c.len(), 2);
        // Two hours later both exceed the age cap.
        let later = now + Duration::from_secs(7200);
        let st = c.gc_at(&policy, later).unwrap();
        assert_eq!(st.evicted, 2);
        assert!(st.evicted_bytes > 0);
        assert_eq!(c.len(), 0);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_size_cap_evicts_oldest_first() {
        let c = tmp_cache("gc-size");
        // Distinct mtimes: sleep past filesystem timestamp granularity.
        c.put(&spec(20), "afp-1", &outcome()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        c.put(&spec(21), "afp-1", &outcome()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        c.put(&spec(22), "afp-1", &outcome()).unwrap();
        let one = c.stats().bytes / 3;
        // Room for roughly one entry: the two oldest go, newest stays.
        let policy = GcPolicy {
            max_bytes: Some(one + one / 2),
            ..GcPolicy::default()
        };
        let st = c.gc(&policy).unwrap();
        assert_eq!(st.evicted, 2);
        assert_eq!(st.kept, 1);
        assert!(c.get(&spec(22), "afp-1").is_some(), "newest survives");
        assert!(c.get(&spec(20), "afp-1").is_none());
        assert!(c.get(&spec(21), "afp-1").is_none());
        assert!(c.stats().bytes <= one + one / 2);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn cache_hit_refreshes_recency_so_hot_entries_survive_gc() {
        let c = tmp_cache("gc-lru");
        // Oldest-written first; sleeps beat fs timestamp granularity.
        c.put(&spec(70), "afp-1", &outcome()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        c.put(&spec(71), "afp-1", &outcome()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        c.put(&spec(72), "afp-1", &outcome()).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        // A *hit* on the oldest entry must refresh its recency...
        assert!(c.get(&spec(70), "afp-1").is_some());
        // ...so a size cap with room for one entry evicts 71 and 72
        // (least recently used), not the hot 70.
        let one = c.stats().bytes / 3;
        let policy = GcPolicy {
            max_bytes: Some(one + one / 2),
            ..GcPolicy::default()
        };
        let st = c.gc(&policy).unwrap();
        assert_eq!(st.evicted, 2);
        assert!(
            c.get(&spec(70), "afp-1").is_some(),
            "recently-read entry survives the size cap"
        );
        assert!(c.get(&spec(71), "afp-1").is_none());
        assert!(c.get(&spec(72), "afp-1").is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn outcome_wire_round_trips_through_ser_and_parse() {
        let o = outcome();
        let j = Json::parse(&ser_outcome(&o)).unwrap();
        let back = parse_outcome(&j).expect("outcome parses back");
        assert_eq!(back.final_metric, o.final_metric);
        assert_eq!(back.tail_loss, o.tail_loss);
        assert_eq!(back.steps, o.steps);
        assert_eq!(back.loss_series, o.loss_series);
        assert_eq!(back.eval_series, o.eval_series);
    }

    #[test]
    fn gc_never_evicts_entries_written_during_the_run() {
        let c = tmp_cache("gc-fresh");
        // Reference instant an hour in the past: the entry's write time
        // is later, i.e. it appeared "during" this GC pass.
        let gc_start = SystemTime::now() - Duration::from_secs(3600);
        c.put(&spec(30), "afp-1", &outcome()).unwrap();
        let policy = GcPolicy {
            max_age_secs: Some(1),
            max_bytes: Some(0),
            ..GcPolicy::default()
        };
        let st = c.gc_at(&policy, gc_start).unwrap();
        assert_eq!(st.evicted, 0, "mid-run writes are protected");
        assert!(c.get(&spec(30), "afp-1").is_some());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_dry_run_reports_without_deleting() {
        let c = tmp_cache("gc-dry");
        c.put(&spec(40), "afp-1", &outcome()).unwrap();
        let policy = GcPolicy {
            max_bytes: Some(0),
            dry_run: true,
            ..GcPolicy::default()
        };
        let st =
            c.gc_at(&policy, SystemTime::now() + Duration::from_secs(60))
                .unwrap();
        assert_eq!(st.evicted, 1, "dry run reports the plan");
        assert_eq!(c.len(), 1, "…but deletes nothing");
        // Noop policy touches nothing and reports zeros.
        let st = c.gc(&GcPolicy::default()).unwrap();
        assert_eq!(st, GcStats::default());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_sweeps_orphaned_tmp_files() {
        let c = tmp_cache("gc-tmp");
        c.put(&spec(60), "afp-1", &outcome()).unwrap();
        let orphan = c.dir().join(".tmp-99999-0");
        std::fs::write(&orphan, "torn write").unwrap();
        // Two hours in the future, the fresh orphan exceeds the grace
        // period; the real entry is untouched even by a no-op policy.
        let later = SystemTime::now() + Duration::from_secs(7200);
        c.gc_at(&GcPolicy::default(), later).unwrap();
        assert!(!orphan.exists(), "stale tmp file swept");
        assert!(c.get(&spec(60), "afp-1").is_some());
        // A *fresh* orphan (within grace) survives.
        std::fs::write(&orphan, "in-flight write").unwrap();
        c.gc_at(&GcPolicy::default(), SystemTime::now()).unwrap();
        assert!(orphan.exists(), "live tmp file untouched");
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn open_with_runs_gc_at_open() {
        let c = tmp_cache("gc-open");
        c.put(&spec(50), "afp-1", &outcome()).unwrap();
        let dir = c.dir().to_str().unwrap().to_string();
        let policy = GcPolicy {
            max_age_secs: Some(3600),
            ..GcPolicy::default()
        };
        // Fresh entry: open_with keeps it.
        let (c2, st) = ResultCache::open_with(Some(&dir), &policy).unwrap();
        assert_eq!(st.evicted, 0);
        assert_eq!(c2.len(), 1);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn regenerated_artifacts_read_as_miss() {
        let c = tmp_cache("afp");
        let s = spec(6);
        c.put(&s, "afp-old", &outcome()).unwrap();
        assert!(c.get(&s, "afp-old").is_some());
        // Same spec, regenerated artifacts → different fingerprint →
        // miss, never a stale replay.
        assert!(c.get(&s, "afp-new").is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    fn ckpt(step: u64) -> Checkpoint {
        let mut ck = Checkpoint::new(step, 42);
        ck.insert("params", vec![step as f32; 4]);
        ck
    }

    #[test]
    fn checkpoints_park_resume_and_clear() {
        let c = tmp_cache("ckpt");
        let h = spec(80).hash_hex();
        c.put_checkpoint(&h, &ckpt(100)).unwrap();
        c.put_checkpoint(&h, &ckpt(200)).unwrap();
        let latest = c.latest_checkpoint(&h).expect("parked checkpoint");
        assert_eq!(latest.step, 200);
        assert_eq!(latest.get("params"), Some(&[200.0f32; 4][..]));
        // Corrupting the newest file falls back to the previous step
        // instead of failing the resume outright.
        std::fs::write(c.ckpt_path(&h, 200), b"torn").unwrap();
        assert_eq!(c.latest_checkpoint(&h).unwrap().step, 100);
        // Foreign hashes never see each other's checkpoints.
        assert!(c.latest_checkpoint(&spec(81).hash_hex()).is_none());
        assert_eq!(c.clear_checkpoints(&h), 2);
        assert!(c.latest_checkpoint(&h).is_none());
        // Checkpoints are invisible to the *entry* surface.
        c.put_checkpoint(&h, &ckpt(1)).unwrap();
        assert_eq!(c.len(), 0);
        assert_eq!(c.stats().entries, 0);
        std::fs::remove_dir_all(c.dir()).ok();
    }

    /// Regression (durability PR): GC must never evict a parked
    /// checkpoint whose spec hash has a live journal entry — the job
    /// will be re-leased and must resume, however stale the file.
    #[test]
    fn gc_never_evicts_protected_checkpoints() {
        let c = tmp_cache("gc-ckpt");
        let live = spec(90).hash_hex();
        let dead = spec(91).hash_hex();
        c.put_checkpoint(&live, &ckpt(10)).unwrap();
        c.put_checkpoint(&dead, &ckpt(10)).unwrap();
        // Size cap alone never touches checkpoints at all.
        let later = SystemTime::now() + Duration::from_secs(7200);
        let policy =
            GcPolicy { max_bytes: Some(0), ..GcPolicy::default() };
        c.gc_at_protected(&policy, later, &HashSet::new()).unwrap();
        assert!(c.latest_checkpoint(&live).is_some());
        assert!(c.latest_checkpoint(&dead).is_some());
        // Age cap evicts the unprotected checkpoint, keeps the
        // journal-live one.
        let policy =
            GcPolicy { max_age_secs: Some(1), ..GcPolicy::default() };
        let protected: HashSet<String> = [live.clone()].into();
        let st = c.gc_at_protected(&policy, later, &protected).unwrap();
        assert!(
            c.latest_checkpoint(&live).is_some(),
            "protected checkpoint survives the age cap"
        );
        assert!(c.latest_checkpoint(&dead).is_none());
        assert_eq!(st.evicted, 1);
        // Once the journal entry is gone (protection lifted), the age
        // cap reclaims it like any other cold file.
        c.gc_at(&policy, later).unwrap();
        assert!(c.latest_checkpoint(&live).is_none());
        std::fs::remove_dir_all(c.dir()).ok();
    }

    #[test]
    fn gc_sweeps_orphaned_checkpoint_tmp_files() {
        let c = tmp_cache("gc-ckpt-tmp");
        // A crash inside Checkpoint::save leaks `{hash}-{step}.tmp`.
        let orphan = c.dir().join("deadbeef00000000-5.tmp");
        std::fs::write(&orphan, b"half a checkpoint").unwrap();
        let later = SystemTime::now() + Duration::from_secs(7200);
        c.gc_at(&GcPolicy::default(), later).unwrap();
        assert!(!orphan.exists(), "stale checkpoint temp swept");
        std::fs::remove_dir_all(c.dir()).ok();
    }
}
