//! Async job orchestration: grids of training runs as schedulable work.
//!
//! The paper's sweeps (Tables 3/5/6, Fig. 5) are embarrassingly parallel
//! across methods × seeds × keep-ratios — every cell is one
//! [`JobSpec`]. This subsystem turns the repo's one-run-per-process
//! entry points into a schedulable system:
//!
//! * [`spec`] — [`JobSpec`] (experiment kind + `RunConfig` + seed) with
//!   a stable content hash;
//! * [`queue`] — bounded MPMC priority queue with cancellation;
//! * [`pool`] — `std::thread` worker pool, one PJRT runtime per worker,
//!   panic isolation per job;
//! * [`cache`] — on-disk result cache keyed by spec hash (`--force`
//!   invalidates; age/size GC via [`cache::GcPolicy`], run at open and
//!   as `omgd cache-gc`);
//! * [`journal`] — crash-safe write-ahead job journal (`journal.log`
//!   under the cache dir): fsynced admission/lease/completion records,
//!   replayed by `omgd serve` at startup so queued work and completed
//!   results survive a coordinator crash;
//! * [`report`] — aggregation into [`crate::bench::TablePrinter`] /
//!   [`crate::metrics::CsvWriter`] sinks;
//! * [`serve`] — transport-agnostic JSONL sessions multiplexed over a
//!   shared [`serve::JobHub`] (queue + worker pool + result routing);
//! * [`net`] — HTTP/1.1 gateway (`omgd serve --listen`): N concurrent
//!   connections share one hub, with `429` backpressure (global queue
//!   saturation + per-client `X-OMGD-Client` quotas), HTTP keep-alive
//!   (chunked `POST /jobs` streams), and graceful drain;
//! * [`remote`] — distributed execution over the gateway: the
//!   `omgd worker --connect` pull agent (lease → sync → run → report)
//!   and the `omgd grid --remote` submission client;
//! * [`sync`] — content-addressed artifact sync (frame format +
//!   worker-side [`sync::ArtifactStore`]), keyed by
//!   [`artifact_fingerprint`].
//!
//! * [`lifecycle`] — the transition authority every job/lease/session
//!   state mutation in this crate routes through: one totalized
//!   `(state, event)` match, typed errors for every illegal move.
//!
//! Front-ends: `omgd grid` (local pool or `--remote` gateway),
//! `omgd serve` (stdin or `--listen`), `omgd worker`, and
//! `omgd cache-gc` (see `main.rs`), plus the Table 3/5/6 bench
//! binaries, which submit grids built by the experiment drivers in
//! `omgd-train`.
//!
//! Layering: this crate never sees the training engine. Execution is
//! abstracted behind [`JobExecutor`]; `omgd-train::runner` provides
//! the trainer-backed executor and the concrete `run_grid`/`serve`/
//! `serve_listen`/`run_worker` entry points, which the `omgd` facade
//! re-exports under the historical `omgd::jobs::*` paths.

pub mod cache;
pub mod journal;
pub mod lifecycle;
pub mod net;
pub mod pool;
pub mod queue;
pub mod remote;
pub mod report;
pub mod serve;
pub mod spec;
pub mod sync;

pub use cache::{
    CacheStats, GcPolicy, GcStats, ResultCache, DEFAULT_CACHE_DIR,
};
pub use journal::{JobJournal, PendingJob, Record, Replay};
pub use lifecycle::{
    ClientLedger, GatewayPhase, JobEvent, JobState, Lifecycle, PhaseCell,
    TransitionError, WorkerLeases,
};
pub use net::{run_gateway, serve_listen_with, GatewayStats, ListenOptions};
pub use pool::{run_pool, JobOutcome, JobResult, JobStatus};
pub use queue::{Job, JobQueue, PopScan, PopTimeout, TryPush};
pub use remote::{
    gateway_get, run_grid_remote, run_grid_remote_auth, run_worker_with,
    WorkerOptions, WorkerStats,
};
pub use report::GridReport;
pub use serve::{
    JobHub, LeaseInfo, LeaseReply, PhaseSecs, RemoteDone, RemoteStats,
    ResultLookup, ServeStats, SessionOptions,
};
pub use spec::{ExperimentKind, JobSpec};
pub use sync::{ArtifactStore, DEFAULT_STORE_DIR};

// Path-compatibility aliases: files moved here from the monolithic
// crate keep their historical `crate::config`, `crate::obs`,
// `crate::data`, ... paths and resolve them through the lower layers.
pub use omgd_core::{data, runtime};
pub use omgd_util::{bench, cli, config, manifest, metrics, obs, util};

use crate::config::RunConfig;
use crate::runtime::artifacts_dir;
use anyhow::Result;
use std::path::PathBuf;

/// Options shared by `omgd grid`, `omgd serve`, and the bench drivers.
#[derive(Clone, Debug)]
pub struct GridOptions {
    /// Worker threads; each owns its own PJRT runtime + bundle cache.
    pub workers: usize,
    /// Invalidate and recompute cached cells.
    pub force: bool,
    /// Cache directory override (default [`DEFAULT_CACHE_DIR`]).
    pub cache_dir: Option<String>,
    /// Cache GC policy, run once at cache open (default: no-op).
    pub gc: GcPolicy,
}

impl Default for GridOptions {
    fn default() -> Self {
        Self {
            workers: 1,
            force: false,
            cache_dir: None,
            gc: GcPolicy::default(),
        }
    }
}

/// `OMGD_FORCE` env override for the bench drivers: truthy values only
/// (`1`/`true`/`yes`), matching [`crate::cli::Args::bool`] — a merely
/// *present* `OMGD_FORCE=0` must not blow the cache away.
pub fn force_from_env() -> bool {
    matches!(
        std::env::var("OMGD_FORCE").ok().as_deref(),
        Some("1") | Some("true") | Some("yes")
    )
}

/// Worker-count default: `OMGD_WORKERS` env override, else available
/// parallelism clamped to 4 (each worker compiles its own executables,
/// so memory — not cores — is the practical ceiling).
pub fn default_workers() -> usize {
    if let Some(n) = std::env::var("OMGD_WORKERS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
    {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, 4)
}

/// The execution seam between the job layer and whatever actually
/// runs a spec. `omgd-jobs` schedules, caches, journals, leases, and
/// routes jobs without ever seeing the training engine; the engine
/// (`omgd-train::runner::SpecRunner`) plugs in here. Tests plug in
/// stubs via [`FnExecutor`].
pub trait JobExecutor {
    /// Execute one spec to completion. Implementations may keep
    /// per-worker state (runtimes, bundle caches) across calls.
    fn execute(&mut self, spec: &JobSpec) -> Result<JobOutcome>;
}

/// Closure adapter for [`JobExecutor`] (a blanket `impl for F: FnMut`
/// would forbid downstream executor types by coherence).
pub struct FnExecutor<F>(pub F);

impl<F> JobExecutor for FnExecutor<F>
where
    F: FnMut(&JobSpec) -> Result<JobOutcome>,
{
    fn execute(&mut self, spec: &JobSpec) -> Result<JobOutcome> {
        (self.0)(spec)
    }
}

/// Run a grid of specs to completion over `make_exec`-built executors:
/// enqueue all cells, shard them across `opts.workers` threads, reuse
/// cached results unless `opts.force`, and return the
/// (submission-ordered) report. The trainer-backed wrapper is
/// `omgd-train::runner::run_grid` (re-exported as
/// `omgd::jobs::run_grid`).
pub fn run_grid_with<E, M>(
    specs: Vec<JobSpec>,
    opts: &GridOptions,
    make_exec: M,
) -> Result<GridReport>
where
    E: JobExecutor,
    M: Fn(usize) -> E + Sync,
{
    let cache = open_cache(opts)?;
    let queue = JobQueue::bounded(specs.len().max(1));
    for s in specs {
        queue.push(s, 0)?;
    }
    queue.close();
    // Per-cell progress to stderr as workers finish — a paper-shaped
    // grid runs for hours, and silence is indistinguishable from a hung
    // runtime. (Panicked cells get no line here; the report's failure
    // summary covers them.)
    let results = run_pool(&queue, opts.workers, |wid| {
        let mut inner = cached_runner_with(&cache, opts.force, make_exec(wid));
        move |spec: &JobSpec| {
            let r = inner(spec);
            match &r {
                Ok((_, true)) => eprintln!("  [cache] {}", spec.label()),
                Ok((_, false)) => eprintln!("  [done ] {}", spec.label()),
                Err(e) => {
                    eprintln!("  [fail ] {}: {e:#}", spec.label())
                }
            }
            r
        }
    });
    Ok(GridReport::new(results))
}

/// Open the result cache, run the configured GC policy once, and
/// report evictions to stderr — the shared open path for every
/// front-end (grid, serve, gateway).
pub fn open_cache(opts: &GridOptions) -> Result<ResultCache> {
    let (cache, gc) =
        ResultCache::open_with(opts.cache_dir.as_deref(), &opts.gc)?;
    report_gc(&gc);
    Ok(cache)
}

/// One shared eviction report, so the at-open and periodic GC paths
/// cannot drift apart.
pub fn report_gc(st: &GcStats) {
    if st.evicted > 0 {
        eprintln!(
            "cache gc: evicted {} entries ({} bytes)",
            st.evicted, st.evicted_bytes
        );
    }
}

/// The production worker function around an arbitrary executor:
/// consult the cache, else execute the spec, then persist the fresh
/// outcome. Returns `(outcome, from_cache)`. The trainer-backed
/// wrapper is `omgd-train::runner::cached_runner`.
pub fn cached_runner_with<'a, E: JobExecutor + 'a>(
    cache: &'a ResultCache,
    force: bool,
    mut exec: E,
) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> + 'a {
    move |spec| {
        let afp = artifact_fingerprint(&spec.cfg);
        if force {
            cache.invalidate(spec);
        } else if let Some(out) = cache.get(spec, &afp) {
            return Ok((out, true));
        }
        let out = exec.execute(spec)?;
        // The cache is best-effort: a full disk or read-only cache dir
        // must not discard an outcome that already cost a training run.
        if let Err(e) = cache.put(spec, &afp, &out) {
            eprintln!(
                "warning: cache write failed for {} ({}): {e:#}",
                spec.label(),
                spec.hash_hex()
            );
        }
        Ok((out, false))
    }
}

/// Fingerprint of the on-disk artifact files backing `cfg.model`
/// (`<model>.*`: manifest, HLO texts, init dump): FNV over sorted
/// (name, size, mtime) triples. Part of the cache-entry identity, so
/// regenerating artifacts under the same model name invalidates cached
/// cells instead of silently replaying pre-regeneration results.
/// mtime-based, so an identical regeneration also misses — conservative
/// in the safe direction.
///
/// The fingerprint is also the content address of artifact sync
/// ([`sync`] / `GET /artifacts/<fp>`): a remote worker caches synced
/// artifact sets — and its results — under the *gateway's* fingerprint,
/// so both ends key their caches identically.
pub fn artifact_fingerprint(cfg: &RunConfig) -> String {
    artifact_fingerprint_at(&resolve_artifacts(&cfg.artifacts_dir), &cfg.model)
}

/// [`artifact_fingerprint`] with the directory already resolved — the
/// shape `GET /artifacts/<fp>` uses to re-verify a fingerprint against
/// the current on-disk state before packing.
pub(crate) fn artifact_fingerprint_at(
    dir: &std::path::Path,
    model: &str,
) -> String {
    let prefix = format!("{model}.");
    let mut entries: Vec<String> = match std::fs::read_dir(dir) {
        Err(_) => return "absent".to_string(),
        Ok(rd) => rd
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().starts_with(&prefix)
            })
            .filter_map(|e| {
                let meta = e.metadata().ok()?;
                let mtime = meta
                    .modified()
                    .ok()?
                    .duration_since(std::time::UNIX_EPOCH)
                    .ok()?;
                Some(format!(
                    "{}:{}:{}.{:09}",
                    e.file_name().to_string_lossy(),
                    meta.len(),
                    mtime.as_secs(),
                    mtime.subsec_nanos()
                ))
            })
            .collect(),
    };
    if entries.is_empty() {
        return "absent".to_string();
    }
    entries.sort();
    format!("{:016x}", spec::fnv1a64(entries.join(";").as_bytes()))
}

/// An explicitly-configured artifacts dir is honored verbatim (a typo'd
/// path then fails loudly in the executor's existence check, naming
/// that path). Only the unset/default value falls back to the usual
/// env/CWD/manifest-dir resolution, so grids built from
/// `RunConfig::default()` work under `cargo test` too.
pub fn resolve_artifacts(configured: &str) -> PathBuf {
    if configured.is_empty()
        || configured == RunConfig::default().artifacts_dir
    {
        artifacts_dir(None)
    } else {
        PathBuf::from(configured)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_workers_is_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn fn_executor_adapts_closures() {
        let mut calls = 0usize;
        let mut exec = FnExecutor(|_spec: &JobSpec| {
            calls += 1;
            anyhow::bail!("stub")
        });
        let spec = JobSpec {
            kind: ExperimentKind::Pretrain,
            cfg: RunConfig::default(),
        };
        assert!(exec.execute(&spec).is_err());
        drop(exec);
        assert_eq!(calls, 1);
    }
}
