//! Sharded worker pool: N `std::thread` workers drain a [`JobQueue`].
//!
//! Each worker owns its per-thread state (for real grids: a PJRT
//! `Runtime` + compiled [`crate::runtime::ModelBundle`]s — XLA handles
//! never cross threads), created by a factory closure the caller
//! supplies. Workers are panic-isolated: a poisoned job is caught with
//! `catch_unwind`, reported as [`JobStatus::Panicked`], and the worker
//! keeps draining the queue.
//!
//! Results are streamed over an `mpsc` channel, then sorted by
//! submission order so aggregation is deterministic regardless of how
//! the OS interleaved the workers.

use super::queue::{Job, JobQueue};
use super::spec::JobSpec;
use crate::metrics::Timer;
use crate::obs;
use anyhow::Result;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;

/// The deterministic slice of a training outcome a job reports (and the
/// cache persists). Wall-clock fields are carried for display but are
/// excluded from CSV aggregates, which must be run-to-run identical.
/// Built from the engine's `TrainOutcome` via the `From` impl in
/// `omgd-train` (this crate never sees the engine).
#[derive(Clone, Debug, Default)]
pub struct JobOutcome {
    /// Final test accuracy % (classifier) or final eval loss (LM).
    pub final_metric: f64,
    /// Mean train loss over the last 20 logged steps.
    pub tail_loss: f64,
    /// Number of optimizer steps taken.
    pub steps: usize,
    /// Wall-clock seconds in the train loop (non-deterministic).
    pub train_secs: f64,
    /// (step, train loss) series — kept so curve CSVs replay from cache.
    pub loss_series: Vec<(usize, f64)>,
    /// (step, eval loss, eval acc%) series.
    pub eval_series: Vec<(usize, f64, f64)>,
}

/// Terminal state of one job.
#[derive(Clone, Debug)]
pub enum JobStatus {
    Done(JobOutcome),
    /// The runner returned an error (missing artifacts, bad config, ...).
    Failed(String),
    /// The runner panicked; the pool survived and kept going.
    Panicked(String),
}

impl JobStatus {
    pub fn tag(&self) -> &'static str {
        match self {
            JobStatus::Done(_) => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Panicked(_) => "panicked",
        }
    }
}

/// One job's result, tagged with its queue identity and provenance.
#[derive(Clone, Debug)]
pub struct JobResult {
    pub seq: u64,
    pub spec: JobSpec,
    pub status: JobStatus,
    /// True if the outcome came from the result cache, not a fresh run.
    pub from_cache: bool,
    /// Wall-clock seconds spent on this job inside the worker.
    pub secs: f64,
}

impl JobResult {
    pub fn outcome(&self) -> Option<&JobOutcome> {
        match &self.status {
            JobStatus::Done(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self.status, JobStatus::Done(_))
    }
}

/// Drain `queue` with `workers` threads; `make_worker(worker_id)` builds
/// each thread's worker function *on that thread* (so per-worker state
/// like a PJRT client never crosses threads). Returns all results
/// sorted by submission sequence.
pub fn run_pool<M, W>(
    queue: &JobQueue,
    workers: usize,
    make_worker: M,
) -> Vec<JobResult>
where
    M: Fn(usize) -> W + Sync,
    W: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    let (tx, rx) = mpsc::channel::<JobResult>();
    let mut results = std::thread::scope(|s| {
        let make = &make_worker;
        for wid in 0..workers.max(1) {
            let tx = tx.clone();
            s.spawn(move || {
                let mut work = make(wid);
                worker_loop(queue, &mut work, &tx);
            });
        }
        drop(tx);
        // Collect on the scope's owning thread; ends when every worker
        // has dropped its sender clone.
        rx.iter().collect::<Vec<_>>()
    });
    results.sort_by_key(|r| r.seq);
    results
}

/// One worker's drain loop, shared by [`run_pool`] and `omgd serve`.
/// Every job is wrapped in `catch_unwind` so a panicking run is reported
/// instead of tearing down the pool.
pub fn worker_loop<W>(
    queue: &JobQueue,
    work: &mut W,
    tx: &mpsc::Sender<JobResult>,
) where
    W: FnMut(&JobSpec) -> Result<(JobOutcome, bool)>,
{
    while let Some(job) = queue.pop() {
        let queue_secs = job.enqueued.elapsed().as_secs_f64();
        obs::QUEUE_WAIT_SECONDS.observe(queue_secs);
        let t = Timer::start();
        let run = catch_unwind(AssertUnwindSafe(|| work(&job.spec)));
        let (status, from_cache) = match run {
            Ok(Ok((outcome, cached))) => (JobStatus::Done(outcome), cached),
            Ok(Err(e)) => (JobStatus::Failed(format!("{e:#}")), false),
            Err(payload) => {
                (JobStatus::Panicked(panic_message(payload.as_ref())), false)
            }
        };
        let secs = t.total();
        if from_cache {
            obs::CACHE_HIT_SECONDS.observe(secs);
        } else {
            obs::RUN_SECONDS.observe(secs);
        }
        let mut ev = obs::Event::new("run", job.seq);
        ev.hash = job.spec.hash_hex();
        ev.worker = "local".to_string();
        ev.queue_secs = queue_secs;
        ev.run_secs = secs;
        ev.secs = queue_secs + secs;
        obs::journal().push(ev);
        let Job { seq, spec, .. } = job;
        // Receiver gone (caller bailed) → stop draining.
        if tx
            .send(JobResult { seq, spec, status, from_cache, secs: t.total() })
            .is_err()
        {
            return;
        }
    }
}

/// Best-effort human-readable panic payload; shared with the remote
/// worker agent, which panic-isolates jobs the same way this pool does.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic with non-string payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;
    use crate::spec::ExperimentKind;

    fn spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        JobSpec { kind: ExperimentKind::Pretrain, cfg }
    }

    fn stub_outcome(spec: &JobSpec) -> JobOutcome {
        // Deterministic function of the spec identity only.
        let h = spec.content_hash();
        JobOutcome {
            final_metric: (h % 1000) as f64 / 10.0,
            tail_loss: (h % 97) as f64 / 100.0,
            steps: 10,
            train_secs: 0.0,
            loss_series: vec![(0, 1.0), (1, 0.5)],
            eval_series: vec![],
        }
    }

    fn filled_queue(n: u64) -> JobQueue {
        let q = JobQueue::bounded(n as usize + 1);
        for i in 0..n {
            q.push(spec(i), 0).unwrap();
        }
        q.close();
        q
    }

    fn ok_runner(
    ) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> {
        |s: &JobSpec| Ok((stub_outcome(s), false))
    }

    #[test]
    fn pool_runs_all_jobs_sorted_by_seq() {
        let q = filled_queue(12);
        let results = run_pool(&q, 3, |_| ok_runner());
        assert_eq!(results.len(), 12);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.seq, i as u64);
            assert!(r.is_ok());
            assert!(!r.from_cache);
        }
    }

    #[test]
    fn panicking_job_does_not_kill_the_pool() {
        let q = filled_queue(10);
        let results = run_pool(&q, 2, |_| {
            |s: &JobSpec| -> Result<(JobOutcome, bool)> {
                if s.cfg.seed == 3 {
                    panic!("poisoned job");
                }
                if s.cfg.seed == 7 {
                    anyhow::bail!("soft failure");
                }
                Ok((stub_outcome(s), false))
            }
        });
        assert_eq!(results.len(), 10);
        let panicked: Vec<u64> = results
            .iter()
            .filter(|r| matches!(r.status, JobStatus::Panicked(_)))
            .map(|r| r.spec.cfg.seed)
            .collect();
        let failed: Vec<u64> = results
            .iter()
            .filter(|r| matches!(r.status, JobStatus::Failed(_)))
            .map(|r| r.spec.cfg.seed)
            .collect();
        assert_eq!(panicked, vec![3]);
        assert_eq!(failed, vec![7]);
        assert_eq!(results.iter().filter(|r| r.is_ok()).count(), 8);
        match &results[3].status {
            JobStatus::Panicked(msg) => assert!(msg.contains("poisoned")),
            other => panic!("expected panic status, got {other:?}"),
        }
    }

    #[test]
    fn worker_id_factory_runs_on_each_thread() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let made = AtomicUsize::new(0);
        let q = filled_queue(4);
        let results = run_pool(&q, 4, |_wid| {
            made.fetch_add(1, Ordering::SeqCst);
            ok_runner()
        });
        assert_eq!(results.len(), 4);
        assert_eq!(made.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn single_worker_equals_multi_worker_results() {
        let run = |workers: usize| {
            let q = filled_queue(9);
            run_pool(&q, workers, |_| ok_runner())
                .into_iter()
                .map(|r| (r.seq, r.outcome().unwrap().final_metric))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(2));
        assert_eq!(run(1), run(4));
    }
}
