//! The trainer-backed job executor: where `omgd-jobs` meets the
//! training engine.
//!
//! The job layer schedules, caches, journals, and leases work against
//! the [`JobExecutor`] trait it defines, never this crate. This module
//! supplies the production implementation — [`SpecRunner`], one PJRT
//! runtime + compiled-bundle cache per worker thread — plus the
//! concrete front-end wrappers (`run_grid`, `serve`, `serve_listen`,
//! `run_worker`, `cached_runner`) that `main.rs` and the bench drivers
//! call. They are re-exported under the historical `omgd::jobs::*`
//! paths by the facade crate.

use crate::config::{OptFamily, RunConfig};
use crate::data::ClassTask;
use crate::obs;
use crate::runtime::bundle::UpdateKind;
use crate::runtime::{ModelBundle, Runtime};
use crate::train::{
    train_classifier_ckpt, train_lm_ckpt, CkptCtl, TrainOutcome,
};
use anyhow::{anyhow, bail, Result};
use omgd_jobs::serve::serve_with;
use omgd_jobs::{
    cached_runner_with, open_cache, resolve_artifacts, run_grid_with,
    run_worker_with, serve_listen_with, ExperimentKind, GatewayStats,
    GridOptions, GridReport, JobExecutor, JobOutcome, JobSpec,
    ListenOptions, ResultCache, ServeStats, WorkerOptions, WorkerStats,
    DEFAULT_CACHE_DIR,
};
use std::collections::HashMap;
use std::io::{BufRead, Write};
use std::path::{Path, PathBuf};

/// [`JobOutcome`] is the wire/cache-stable digest of a training run;
/// this is the only place the job layer's outcome type and the
/// engine's [`TrainOutcome`] meet (the orphan rule pins the impl to
/// this crate, which is exactly the layering the workspace wants).
impl From<&TrainOutcome> for JobOutcome {
    fn from(out: &TrainOutcome) -> Self {
        Self {
            final_metric: out.final_metric,
            tail_loss: out.tail_loss(20),
            steps: out.loss_series.len(),
            train_secs: out.train_secs,
            loss_series: out.loss_series.clone(),
            eval_series: out.eval_series.clone(),
        }
    }
}

/// Per-worker execution state: one PJRT runtime (created on the first
/// non-cached job, so cache replays never touch XLA) plus compiled
/// bundles keyed by `(model, optimizer family)`.
pub struct SpecRunner {
    rt: Option<Runtime>,
    bundles: HashMap<String, ModelBundle>,
    /// Checkpointing: `(cache dir, period in steps)`. Set by workers
    /// running under `--ckpt-period`; `None` (the default) trains
    /// straight through like before.
    ckpt: Option<(PathBuf, usize)>,
}

impl Default for SpecRunner {
    fn default() -> Self {
        Self::new()
    }
}

impl SpecRunner {
    pub fn new() -> Self {
        Self { rt: None, bundles: HashMap::new(), ckpt: None }
    }

    /// Enable periodic checkpointing into `cache_dir` (see
    /// [`crate::train::CkptCtl`]); `period == 0` disables it.
    pub fn set_ckpt(&mut self, cache_dir: &Path, period: usize) {
        self.ckpt = (period > 0)
            .then(|| (cache_dir.to_path_buf(), period));
    }

    /// Build the checkpoint control for one spec: resume from the
    /// newest parked checkpoint (if any) and park new ones every
    /// `period` steps under the spec's hash. Checkpointing is strictly
    /// best-effort at this layer — an unopenable cache dir degrades to
    /// a plain straight-through run.
    fn ckpt_ctl(&self, spec: &JobSpec) -> CkptCtl<'static> {
        let Some((dir, period)) = self.ckpt.clone() else {
            return CkptCtl::default();
        };
        let dir = dir.to_string_lossy().into_owned();
        let Ok(cache) = ResultCache::open(Some(&dir)) else {
            return CkptCtl::default();
        };
        let hash = spec.hash_hex();
        let resume = cache.latest_checkpoint(&hash);
        if let Some(ck) = &resume {
            obs::CKPT_RESUMES.inc();
            eprintln!(
                "  [ckpt ] resuming {} from step {}",
                spec.label(),
                ck.step
            );
        }
        CkptCtl {
            period,
            resume,
            sink: Some(Box::new(move |ck| {
                cache.put_checkpoint(&hash, ck).map(|_| ())
            })),
        }
    }

    fn bundle(&mut self, cfg: &RunConfig) -> Result<&ModelBundle> {
        let key = format!("{}:{}", cfg.model, cfg.opt.family.name());
        if !self.bundles.contains_key(&key) {
            let dir = resolve_artifacts(&cfg.artifacts_dir);
            let man = dir.join(format!("{}.json", cfg.model));
            // Cheap existence check before spinning up PJRT.
            if !man.exists() {
                bail!(
                    "artifacts for {:?} missing at {} (run `make artifacts`)",
                    cfg.model,
                    man.display()
                );
            }
            if self.rt.is_none() {
                self.rt = Some(Runtime::cpu()?);
            }
            let update = match cfg.opt.family {
                OptFamily::AdamW => UpdateKind::AdamW,
                OptFamily::Sgdm => UpdateKind::Sgdm,
            };
            let bundle = ModelBundle::load(
                self.rt.as_ref().unwrap(),
                &dir,
                &cfg.model,
                update,
            )?;
            self.bundles.insert(key.clone(), bundle);
        }
        Ok(&self.bundles[&key])
    }

    /// Execute one spec to completion on this worker's runtime,
    /// resuming from a parked checkpoint when one exists.
    pub fn run(&mut self, spec: &JobSpec) -> Result<JobOutcome> {
        spec.cfg.validate()?;
        let ctl = self.ckpt_ctl(spec);
        match &spec.kind {
            ExperimentKind::Finetune { task, epochs } => {
                let ts = crate::data::find_task(task)
                    .ok_or_else(|| anyhow!("unknown task {task:?}"))?;
                let bundle = self.bundle(&spec.cfg)?;
                let t = ClassTask::from_spec(
                    ts,
                    bundle.man.data.d_in,
                    bundle.man.data.n_class,
                );
                classifier_outcome(bundle, &spec.cfg, &t, *epochs, ctl)
            }
            ExperimentKind::Blobs { dataset, spread, data_seed, epochs } => {
                let bundle = self.bundle(&spec.cfg)?;
                let t = ClassTask::gaussian_blobs(
                    dataset,
                    bundle.man.data.d_in,
                    bundle.man.data.n_class,
                    omgd_jobs::spec::BLOBS_N_TRAIN,
                    omgd_jobs::spec::BLOBS_N_TEST,
                    *spread,
                    *data_seed,
                );
                classifier_outcome(bundle, &spec.cfg, &t, *epochs, ctl)
            }
            ExperimentKind::Pretrain => {
                let bundle = self.bundle(&spec.cfg)?;
                let corpus =
                    crate::experiments::pretrain_corpus(bundle, spec.cfg.steps);
                let out = train_lm_ckpt(bundle, &spec.cfg, &corpus, ctl)?;
                Ok(JobOutcome::from(&out))
            }
        }
    }
}

impl JobExecutor for SpecRunner {
    fn execute(&mut self, spec: &JobSpec) -> Result<JobOutcome> {
        self.run(spec)
    }
}

/// For classifier kinds the spec's `steps`/`eval_every` are in *epochs*
/// (the bundle's batch size is unknown at spec-build time); resolve them
/// to steps here.
fn classifier_outcome(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    task: &ClassTask,
    epochs: usize,
    ctl: CkptCtl<'_>,
) -> Result<JobOutcome> {
    let steps_per_epoch = task.n_train().div_ceil(bundle.man.data.batch);
    let mut cfg = cfg.clone();
    cfg.steps = epochs.max(1) * steps_per_epoch;
    cfg.eval_every = cfg.eval_every.saturating_mul(steps_per_epoch);
    let out = train_classifier_ckpt(bundle, &cfg, task, ctl)?;
    Ok(JobOutcome::from(&out))
}

/// The production worker function: consult the cache, else execute the
/// spec with this worker's lazily-created runtime, then persist the
/// fresh outcome. Returns `(outcome, from_cache)`.
pub fn cached_runner(
    cache: &ResultCache,
    force: bool,
) -> impl FnMut(&JobSpec) -> Result<(JobOutcome, bool)> + '_ {
    cached_runner_with(cache, force, SpecRunner::new())
}

/// Run a grid of specs to completion with the production runner:
/// enqueue all cells, shard them across `opts.workers` threads, reuse
/// cached results unless `opts.force`, and return the
/// (submission-ordered) report.
pub fn run_grid(specs: Vec<JobSpec>, opts: &GridOptions) -> Result<GridReport> {
    run_grid_with(specs, opts, |_wid| SpecRunner::new())
}

/// Serve one stdin/stdout-style session with the production cache-aware
/// runner (runs the configured cache GC policy at open).
pub fn serve<R, W>(input: R, output: W, opts: &GridOptions) -> Result<ServeStats>
where
    R: BufRead,
    W: Write + Send,
{
    let cache = open_cache(opts)?;
    serve_with(input, output, opts.workers, |_wid| {
        cached_runner(&cache, opts.force)
    })
}

/// Bind `addr` and run the gateway with the production cache-aware
/// runner until `POST /shutdown`. `--listen 127.0.0.1:0` binds a free
/// port; the actual address is printed to stderr.
pub fn serve_listen(
    addr: &str,
    opts: &GridOptions,
    lopts: &ListenOptions,
) -> Result<GatewayStats> {
    serve_listen_with(addr, opts, lopts, |_wid| SpecRunner::new())
}

/// Run a worker agent with the production [`SpecRunner`] (PJRT runtime
/// per thread) until the gateway drains.
pub fn run_worker(opts: &WorkerOptions) -> Result<WorkerStats> {
    let ckpt_dir = PathBuf::from(
        opts.cache_dir.as_deref().unwrap_or(DEFAULT_CACHE_DIR),
    );
    run_worker_with(opts, move |_wid| {
        let mut runner = SpecRunner::new();
        runner.set_ckpt(&ckpt_dir, opts.ckpt_period);
        move |spec: &JobSpec| runner.run(spec)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use omgd_jobs::JobStatus;

    fn missing_model_spec(seed: u64) -> JobSpec {
        let mut cfg = RunConfig::default();
        cfg.seed = seed;
        // A model name no artifacts dir can contain, so the runner fails
        // fast without touching PJRT.
        cfg.model = "no-such-model-xyz".into();
        JobSpec {
            kind: ExperimentKind::Finetune { task: "CoLA".into(), epochs: 1 },
            cfg,
        }
    }

    fn tmp_dir(tag: &str) -> String {
        let d = std::env::temp_dir()
            .join(format!("omgd-grid-test-{tag}-{}", std::process::id()));
        std::fs::remove_dir_all(&d).ok();
        d.to_string_lossy().into_owned()
    }

    #[test]
    fn grid_reports_missing_artifacts_as_failed_cells() {
        let dir = tmp_dir("missing");
        let opts = GridOptions {
            workers: 2,
            force: false,
            cache_dir: Some(dir.clone()),
            ..GridOptions::default()
        };
        let specs = vec![missing_model_spec(0), missing_model_spec(1)];
        let report = run_grid(specs, &opts).unwrap();
        assert_eq!(report.n_jobs(), 2);
        assert_eq!(report.n_failed(), 2);
        assert_eq!(report.n_cached(), 0);
        match &report.results[0].status {
            JobStatus::Failed(msg) => assert!(msg.contains("artifacts")),
            other => panic!("expected Failed, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn failed_cells_are_not_cached() {
        let dir = tmp_dir("nocache");
        let opts = GridOptions {
            workers: 1,
            force: false,
            cache_dir: Some(dir.clone()),
            ..GridOptions::default()
        };
        let report =
            run_grid(vec![missing_model_spec(0)], &opts).unwrap();
        assert_eq!(report.n_failed(), 1);
        // Re-running must fail again (no poisoned cache entry), not hit.
        let report2 =
            run_grid(vec![missing_model_spec(0)], &opts).unwrap();
        assert_eq!(report2.n_failed(), 1);
        assert_eq!(report2.n_cached(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
