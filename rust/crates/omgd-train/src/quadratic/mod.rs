//! §5.1 illustrative-example testbed: SGD on a quadratic with the four
//! gradient forms of the paper, plus the exact error decomposition.
//!
//! The SGD recursion `θ_{t+1} − θ* = (I − η_t A)(θ_t − θ*) + η_t(∇F − g_t)`
//! splits `θ_t − θ*` into three exactly-tracked accumulators:
//!
//! * decay term       `D_{t+1} = (I − η_t A) D_t`,  `D_0 = θ_0 − θ*`
//! * data-reshuffle   `R_{t+1} = (I − η_t A) R_t + η_t (∇F(θ_t) − ∇f(θ_t; z_t))`
//! * compression-err  `C_{t+1} = (I − η_t A) C_t + η_t (∇f(θ_t; z_t) − g_t)`
//!
//! with `θ_t − θ* = D_t + R_t + C_t` as an identity — this regenerates all
//! four panels of Figure 2 and verifies Theorems 5.3/5.4's rates
//! (`O(t⁻²)` for RR / RR_mask_wor, `Ω(t⁻¹)` for RR_mask_iid / RR_proj).

use crate::coordinator::{DataSampler, MaskRuns, MaskSet, OmgdCycle};
use crate::data::LinRegData;
use crate::exec::{self, ExecEngine};
use crate::linalg::{axpy, stiefel};
use crate::rng::Rng;

/// Stochastic-gradient forms of §5.1 (+ appendix i.i.d.-sampling forms).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GradForm {
    /// Plain RR-SGD.
    Rr,
    /// OMGD: Algorithm 1 with coordinate-partition masks, keep ratio r.
    RrMaskWor { r: f64 },
    /// i.i.d. Bernoulli(r)/r mask over RR sampling (Remark 4.10).
    RrMaskIid { r: f64 },
    /// i.i.d. Stiefel low-rank projection (1/r)·P Pᵀ over RR (GoLore-like).
    RrProj { r: f64 },
    /// With-replacement sampling (appendix Theorem A.3 baselines).
    Iid,
    /// With-replacement sampling + i.i.d. mask.
    IidMaskIid { r: f64 },
}

impl GradForm {
    pub fn name(&self) -> &'static str {
        match self {
            GradForm::Rr => "RR",
            GradForm::RrMaskWor { .. } => "RR_mask_wor",
            GradForm::RrMaskIid { .. } => "RR_mask_iid",
            GradForm::RrProj { .. } => "RR_proj",
            GradForm::Iid => "IID",
            GradForm::IidMaskIid { .. } => "IID_mask_iid",
        }
    }
}

/// Trace of squared norms at checkpoints (single run or mean over reps).
#[derive(Clone, Debug)]
pub struct Trace {
    pub steps: Vec<usize>,
    /// ‖θ_t − θ*‖²
    pub overall: Vec<f64>,
    /// ‖decay term‖²
    pub decay: Vec<f64>,
    /// ‖data-reshuffle term‖²
    pub reshuffle: Vec<f64>,
    /// ‖compression-error term‖²
    pub compression: Vec<f64>,
}

/// Experiment parameters (Appendix B.1 defaults).
#[derive(Clone, Copy, Debug)]
pub struct QuadParams {
    /// Step-size constant: η_t = c0 / max(t, t0).
    pub c0: f64,
    /// Iterations.
    pub t_max: usize,
    /// Compression activates after this many steps (paper: 100).
    pub warmup: usize,
    /// Log-spaced checkpoints per decade.
    pub points_per_decade: usize,
}

impl Default for QuadParams {
    fn default() -> Self {
        Self { c0: 2.0, t_max: 100_000, warmup: 100,
               points_per_decade: 8 }
    }
}

/// Log-spaced checkpoint schedule in `[10, t_max]`.
pub fn checkpoints(t_max: usize, per_decade: usize) -> Vec<usize> {
    let mut pts = Vec::new();
    let mut last = 0usize;
    let decades = (t_max as f64).log10();
    let n = (decades * per_decade as f64).ceil() as usize;
    for i in 0..=n {
        let t = (10f64.powf(1.0 + (decades - 1.0) * i as f64 / n as f64))
            .round() as usize;
        let t = t.min(t_max);
        if t > last {
            pts.push(t);
            last = t;
        }
    }
    pts
}

/// One full run of a gradient form; returns the four traces.
pub fn run(data: &LinRegData, form: GradForm, params: QuadParams,
           seed: u64) -> Trace {
    let d = data.d;
    let n = data.n;
    let mut rng = Rng::seed_from_u64(seed);

    // Stability: η_t λ_max < 1 requires t ≥ t0 > c0 λ_max.
    let t0 = (params.c0 * data.lambda_max).ceil() as usize + 1;
    let eta = |t: usize| params.c0 / (t.max(t0) as f64);

    let mut theta = vec![0.0f64; d];
    let mut decay: Vec<f64> =
        theta.iter().zip(&data.theta_star).map(|(t, s)| t - s).collect();
    let mut resh = vec![0.0f64; d];
    let mut comp = vec![0.0f64; d];

    let pts = checkpoints(params.t_max, params.points_per_decade);
    let mut trace = Trace {
        steps: Vec::new(),
        overall: Vec::new(),
        decay: Vec::new(),
        reshuffle: Vec::new(),
        compression: Vec::new(),
    };

    // Sampling state.
    let mut rr = DataSampler::rr(n);
    let use_rr = !matches!(form, GradForm::Iid | GradForm::IidMaskIid { .. });

    // OMGD state (masks over [M]×[N] cycle).
    let (mut omgd, mut mask_set) = match form {
        GradForm::RrMaskWor { r } => {
            let m = (1.0 / r).ceil() as usize;
            (Some(OmgdCycle::new(m, n)), Some(MaskSet::coordinate_partition(
                d, d, r, &mut rng)))
        }
        _ => (None, None),
    };

    // Step-loop scratch, hoisted: one allocation per run, not per step
    // (at 10⁶ steps the per-iteration `vec![0.0; d]` churn dominated
    // the masked forms' runtime). The shared pool drives the masked
    // fill shard-parallel when `d` is large enough to amortize it.
    let mut gf = vec![0.0f64; d];
    let mut gfull = vec![0.0f64; d];
    let mut g = vec![0.0f64; d];
    let mut src = vec![0.0f64; d];
    let mut av = vec![0.0f64; d];
    let pool = ExecEngine::from_env();

    let mut next_pt = 0usize;
    for t in 0..params.t_max {
        let et = eta(t);
        let compress = t >= params.warmup;

        // --- choose sample (and mask index for OMGD) ---
        let (i, mask_j) = if let Some(cyc) = omgd.as_mut() {
            if compress {
                let (pair, fresh) = cyc.next(&mut rng);
                if fresh {
                    // Algorithm 1 line 4: fresh mask set per cycle.
                    if let GradForm::RrMaskWor { r } = form {
                        mask_set = Some(MaskSet::coordinate_partition(
                            d, d, r, &mut rng));
                    }
                }
                (pair.sample, Some(pair.mask))
            } else {
                (rr.next(&mut rng).0, None)
            }
        } else if use_rr {
            (rr.next(&mut rng).0, None)
        } else {
            (rng.index(n), None)
        };

        // --- gradients ---
        data.grad_sample_into(&theta, i, &mut gf); // ∇f(θ_t; z_t)
        data.grad_full_into(&theta, &mut gfull); // ∇F(θ_t)
        if !compress {
            g.copy_from_slice(&gf);
        } else {
            match form {
                GradForm::Rr | GradForm::Iid => g.copy_from_slice(&gf),
                GradForm::RrMaskWor { .. } => {
                    // Walk the mask's segment runs: only the active
                    // coordinates are multiplied — frozen ones get a
                    // single memset, so the 10⁶-step runs cost
                    // O(active) per masked gradient, not O(d) work.
                    let set = mask_set.as_ref().unwrap();
                    let mask = &set.masks[mask_j.unwrap()];
                    masked_grad_fill(&pool, mask.runs(), &gf, &mut g);
                }
                GradForm::RrMaskIid { r }
                | GradForm::IidMaskIid { r } => {
                    // Remark 4.10: exactly r·d coords, scale 1/r.
                    let k = ((d as f64) * r).round() as usize;
                    let sel = rng.choose_k(d, k);
                    g.fill(0.0);
                    for &c in &sel {
                        g[c] = gf[c] / r;
                    }
                }
                GradForm::RrProj { r } => {
                    let k = ((d as f64) * r).round() as usize;
                    let p = stiefel(d, k, &mut rng);
                    // (1/r) P Pᵀ g
                    let pt_g = p.transpose().matvec(&gf);
                    let proj = p.matvec(&pt_g);
                    for (o, x) in g.iter_mut().zip(&proj) {
                        *o = x / r;
                    }
                }
            }
        }

        // --- decomposition recursions: v ← (I − η A) v + η src ---
        data.a.matvec_into(&decay, &mut av);
        axpy(-et, &av, &mut decay);
        data.a.matvec_into(&resh, &mut av);
        axpy(-et, &av, &mut resh);
        for ((s, f), gs) in src.iter_mut().zip(&gfull).zip(&gf) {
            *s = f - gs; // ∇F − ∇f: data-reshuffle source
        }
        axpy_into(&mut resh, et, &src);
        data.a.matvec_into(&comp, &mut av);
        axpy(-et, &av, &mut comp);
        for ((s, gs), gg) in src.iter_mut().zip(&gf).zip(&g) {
            *s = gs - gg; // ∇f − g: compression-error source
        }
        axpy_into(&mut comp, et, &src);

        // --- parameter update ---
        axpy(-et, &g, &mut theta);

        // --- record ---
        if next_pt < pts.len() && t + 1 == pts[next_pt] {
            trace.steps.push(t + 1);
            trace.overall.push(data.err_sq(&theta));
            trace.decay.push(sq(&decay));
            trace.reshuffle.push(sq(&resh));
            trace.compression.push(sq(&comp));
            next_pt += 1;
        }
    }
    trace
}

/// Masked-gradient fill for `RR_mask_wor`: zero `g` (one memset), then
/// write `gf[i] · scale` over each active run. Shard-parallel over the
/// mask's runs when the active set is large enough to amortize the
/// hand-off ([`exec::PAR_MIN_ACTIVE`]); shards own disjoint coordinate
/// windows of `g`, so the result is bitwise-identical to the serial
/// walk for every thread count.
fn masked_grad_fill(pool: &ExecEngine, runs: &MaskRuns, gf: &[f64],
                    g: &mut [f64]) {
    g.fill(0.0);
    if pool.threads() > 1 && runs.active_count() >= exec::PAR_MIN_ACTIVE {
        let mut shards = exec::partition(runs, pool.threads());
        let base = g.as_mut_ptr() as usize;
        pool.run_tasks(&mut shards, |_, sh| {
            for r in &sh.runs {
                // SAFETY: `partition` hands each shard a disjoint
                // contiguous coordinate window, so these mutable
                // sub-slices never alias across tasks, and `base`
                // outlives the `run_tasks` call.
                let gw = unsafe {
                    std::slice::from_raw_parts_mut(
                        (base as *mut f64).add(r.offset), r.len)
                };
                for (k, o) in gw.iter_mut().enumerate() {
                    *o = gf[r.offset + k] * r.scale as f64;
                }
            }
        });
    } else {
        for r in runs.runs() {
            for i in r.offset..r.end() {
                g[i] = gf[i] * r.scale as f64;
            }
        }
    }
}

/// Mean trace over `reps` independent runs (E‖·‖² estimates).
pub fn run_mean(data: &LinRegData, form: GradForm, params: QuadParams,
                reps: usize, seed: u64) -> Trace {
    let mut acc: Option<Trace> = None;
    for r in 0..reps {
        let t = run(data, form, params, seed.wrapping_add(r as u64 * 7919));
        acc = Some(match acc {
            None => t,
            Some(mut a) => {
                for i in 0..a.overall.len() {
                    a.overall[i] += t.overall[i];
                    a.decay[i] += t.decay[i];
                    a.reshuffle[i] += t.reshuffle[i];
                    a.compression[i] += t.compression[i];
                }
                a
            }
        });
    }
    let mut a = acc.expect("reps >= 1");
    let k = reps as f64;
    for v in [&mut a.overall, &mut a.decay, &mut a.reshuffle,
              &mut a.compression] {
        for x in v.iter_mut() {
            *x /= k;
        }
    }
    a
}

/// Least-squares slope of `log y` vs `log t` over the tail fraction of a
/// trace (rate estimator: slope ≈ −2 for O(t⁻²), −1 for Θ(t⁻¹)).
pub fn loglog_slope(steps: &[usize], ys: &[f64], tail_frac: f64) -> f64 {
    let n = steps.len();
    let start = ((1.0 - tail_frac) * n as f64) as usize;
    let xs: Vec<f64> = steps[start..]
        .iter()
        .map(|&t| (t as f64).ln())
        .collect();
    let ls: Vec<f64> = ys[start..]
        .iter()
        .map(|&y| y.max(1e-300).ln())
        .collect();
    let m = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / m;
    let my = ls.iter().sum::<f64>() / m;
    let num: f64 = xs.iter().zip(&ls).map(|(x, y)| (x - mx) * (y - my))
        .sum();
    let den: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    num / den
}

/// First-passage iteration counts: smallest t with ‖θ_t − θ*‖ ≤ ε for
/// each ε (running min), for the Table 1 complexity experiment.
pub fn first_passage(data: &LinRegData, form: GradForm,
                     params: QuadParams, eps: &[f64], seed: u64)
                     -> Vec<Option<usize>> {
    let trace = run(data, form, params, seed);
    let mut out = vec![None; eps.len()];
    let mut best = f64::INFINITY;
    for (idx, &t) in trace.steps.iter().enumerate() {
        best = best.min(trace.overall[idx].sqrt());
        for (e_i, &e) in eps.iter().enumerate() {
            if out[e_i].is_none() && best <= e {
                out[e_i] = Some(t);
            }
        }
    }
    out
}

fn sq(v: &[f64]) -> f64 {
    v.iter().map(|x| x * x).sum()
}

fn axpy_into(y: &mut [f64], s: f64, x: &[f64]) {
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += s * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_data() -> LinRegData {
        LinRegData::generate(6, 100, 42)
    }

    fn fast_params() -> QuadParams {
        QuadParams { c0: 2.0, t_max: 20_000, warmup: 100,
                     points_per_decade: 6 }
    }

    #[test]
    fn decomposition_identity_holds() {
        // θ_t − θ* = decay + reshuffle + compression, exactly.
        let data = small_data();
        let params = QuadParams { t_max: 2000, ..fast_params() };
        for form in [GradForm::Rr, GradForm::RrMaskIid { r: 0.5 },
                     GradForm::RrMaskWor { r: 0.5 }] {
            let tr = run(&data, form, params, 7);
            // ‖θ−θ*‖ ≤ ‖D‖+‖R‖+‖C‖ (triangle); and the sum of sq-norms
            // must dominate overall/3 (parallelogram). Check the sharper
            // statement numerically by re-deriving overall from terms is
            // not possible from norms alone, so check consistency bound:
            for i in 0..tr.steps.len() {
                let bound = 3.0 * (tr.decay[i] + tr.reshuffle[i]
                    + tr.compression[i]);
                assert!(tr.overall[i] <= bound + 1e-9,
                        "{} > {bound} at {}", tr.overall[i], tr.steps[i]);
            }
        }
    }

    #[test]
    fn rr_converges_fast() {
        let data = small_data();
        let tr = run_mean(&data, GradForm::Rr, fast_params(), 3, 1);
        let last = *tr.overall.last().unwrap();
        assert!(last < 1e-4, "RR final err {last}");
        let slope = loglog_slope(&tr.steps, &tr.overall, 0.5);
        assert!(slope < -1.4, "RR slope {slope} (want ≈ −2)");
    }

    #[test]
    fn wor_mask_matches_rr_rate() {
        let data = small_data();
        let tr = run_mean(&data, GradForm::RrMaskWor { r: 0.5 },
                          fast_params(), 3, 2);
        let slope = loglog_slope(&tr.steps, &tr.overall, 0.5);
        assert!(slope < -1.4, "OMGD slope {slope} (want ≈ −2)");
    }

    #[test]
    fn iid_mask_is_slower() {
        let data = small_data();
        let tr = run_mean(&data, GradForm::RrMaskIid { r: 0.5 },
                          fast_params(), 3, 3);
        let slope = loglog_slope(&tr.steps, &tr.overall, 0.5);
        assert!(slope > -1.5, "iid-mask slope {slope} (want ≈ −1)");
        // and strictly worse than wor at the horizon
        let wor = run_mean(&data, GradForm::RrMaskWor { r: 0.5 },
                           fast_params(), 3, 3);
        assert!(
            *tr.overall.last().unwrap() > 3.0 * wor.overall.last().unwrap(),
            "iid {} vs wor {}", tr.overall.last().unwrap(),
            wor.overall.last().unwrap()
        );
    }

    #[test]
    fn compression_term_dominates_for_iid() {
        let data = small_data();
        let tr = run_mean(&data, GradForm::RrMaskIid { r: 0.5 },
                          fast_params(), 3, 4);
        let i = tr.steps.len() - 1;
        assert!(tr.compression[i] > tr.decay[i]);
        assert!(tr.compression[i] > tr.reshuffle[i]);
    }

    #[test]
    fn compression_term_zero_for_rr() {
        let data = small_data();
        let tr = run(&data, GradForm::Rr, fast_params(), 5);
        assert!(tr.compression.iter().all(|&c| c == 0.0));
    }

    #[test]
    fn masked_grad_fill_parallel_matches_serial() {
        // d·r = 2¹⁴ active coords: exactly at PAR_MIN_ACTIVE, so the
        // 4-thread engine takes the sharded path. Stale buffer contents
        // must be cleared by the fill.
        let d = 1 << 15;
        let mut rng = Rng::seed_from_u64(11);
        let set = MaskSet::coordinate_partition(d, d, 0.5, &mut rng);
        let mask = &set.masks[0];
        let gf: Vec<f64> = (0..d).map(|i| (i as f64).sin()).collect();
        let mut serial = vec![0.5f64; d];
        masked_grad_fill(&ExecEngine::new(1), mask.runs(), &gf,
                         &mut serial);
        let mut par = vec![1.5f64; d];
        masked_grad_fill(&ExecEngine::new(4), mask.runs(), &gf, &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn checkpoints_monotone_and_bounded() {
        let pts = checkpoints(100_000, 8);
        assert!(pts.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*pts.last().unwrap(), 100_000);
        assert!(pts[0] >= 10);
    }

    #[test]
    fn loglog_slope_recovers_known_rate() {
        let steps: Vec<usize> = (1..=50).map(|i| i * 100).collect();
        let ys: Vec<f64> =
            steps.iter().map(|&t| 3.0 / (t as f64).powi(2)).collect();
        let s = loglog_slope(&steps, &ys, 1.0);
        assert!((s + 2.0).abs() < 1e-9, "slope {s}");
    }

    #[test]
    fn first_passage_monotone_in_eps() {
        let data = small_data();
        let eps = [0.3, 0.1, 0.03];
        let fp = first_passage(&data, GradForm::Rr, fast_params(), &eps, 6);
        let mut prev = 0usize;
        for t in fp.iter().flatten() {
            assert!(*t >= prev);
            prev = *t;
        }
    }
}
