//! Trainer: drives (sampler × coordinator × runtime × optimizer).
//!
//! The hot loop is pure rust + PJRT: pack batch → execute the AOT `train`
//! HLO (loss, grad) → refresh the method's mask on period boundaries →
//! apply the fused masked-update HLO (the L1 Pallas kernel) or a native
//! baseline optimizer. Python is never invoked.
//!
//! [`MethodEngine`] encapsulates the paper's method roster behind one
//! interface, so every experiment (Tables 3–6, Fig. 3–5, 7) is a loop
//! over `Method` values with shared data and seeds.

pub use omgd_util::checkpoint;
pub mod engine;

pub use omgd_util::checkpoint::Checkpoint;
pub use engine::MethodEngine;

use crate::config::RunConfig;
use crate::coordinator::DataSampler;
use crate::data::{ClassTask, Corpus};
use crate::metrics::Timer;
use crate::rng::Rng;
use crate::runtime::ModelBundle;
use anyhow::{ensure, Context, Result};
use omgd_util::checkpoint::{pack_u64s, unpack_u64s};

/// Checkpoint control threaded into the training loops.
///
/// With `period == 0` (the [`Default`]) the loops behave exactly as
/// before — no state capture, no resume. Otherwise `sink` receives a
/// full loop snapshot every `period` steps (params, optimizer state,
/// mask traversal cursor, RNG, data cursor, and the series history so
/// a resumed run's CSV is byte-identical), and `resume` — typically
/// [`crate::jobs::ResultCache::latest_checkpoint`] — fast-forwards
/// the loop to the checkpointed step before the first batch is drawn.
///
/// Native-backend methods (GaLore/GoLore/SIFT) cannot snapshot
/// ([`MethodEngine::snapshot`]); the loops detect this on the first
/// tick and silently stop checkpointing rather than failing the run.
#[derive(Default)]
pub struct CkptCtl<'a> {
    /// Snapshot every this many steps; 0 disables checkpointing.
    pub period: usize,
    /// Resume point; `None` starts from scratch.
    pub resume: Option<Checkpoint>,
    /// Receives each periodic snapshot (parks it on disk).
    pub sink: Option<Box<dyn FnMut(&Checkpoint) -> Result<()> + 'a>>,
}

/// Outcome of one training run.
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    /// (step, train loss) at every step.
    pub loss_series: Vec<(usize, f64)>,
    /// (step, eval loss, eval accuracy%) at eval points (acc 0 for LM).
    pub eval_series: Vec<(usize, f64, f64)>,
    /// Final test accuracy % (classifier) or final eval loss (LM).
    pub final_metric: f64,
    /// Wall-clock seconds in the train loop.
    pub train_secs: f64,
    /// Steps per second.
    pub steps_per_sec: f64,
    /// Final flat parameter vector (checkpointing / further eval).
    pub final_params: Vec<f32>,
    /// Residency diagnostics sampled at every period boundary:
    /// `(step, keep_ratio, optimizer state bytes)`, both derived from
    /// the mask's segment-run view in O(1) — a metrics tick never
    /// rescans the parameter space.
    pub residency_series: Vec<(usize, f64, usize)>,
}

impl TrainOutcome {
    /// Mean train loss over the last `k` logged steps (smoothing for
    /// table comparisons).
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.loss_series.len();
        let k = k.min(n).max(1);
        self.loss_series[n - k..]
            .iter()
            .map(|&(_, l)| l)
            .sum::<f64>()
            / k as f64
    }
}

/// Fine-tune the MLP classifier bundle on a [`ClassTask`].
///
/// Period unit = *epochs* (the paper's fine-tuning setting: LISA switches
/// layers every K epochs).
pub fn train_classifier(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    task: &ClassTask,
) -> Result<TrainOutcome> {
    train_classifier_ckpt(bundle, cfg, task, CkptCtl::default())
}

/// [`train_classifier`] with checkpoint/resume (see [`CkptCtl`]).
pub fn train_classifier_ckpt(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    task: &ClassTask,
    mut ctl: CkptCtl<'_>,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    ensure!(bundle.man.kind == "mlp", "classifier needs an mlp bundle");
    ensure!(task.d_in == bundle.man.data.d_in, "task d_in mismatch");

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut engine = MethodEngine::new(&bundle.man, cfg, &mut rng)?;
    let mut flat = bundle.init_params()?;
    let mut sampler = DataSampler::rr(task.n_train());
    let batch = bundle.man.data.batch;

    let mut out = TrainOutcome::default();
    let timer = Timer::start();
    // Batch buffers hoisted out of the step loop: one allocation per
    // run, refilled in place every step.
    let (mut x, mut y) = (Vec::new(), Vec::new());
    let mut epoch = 0usize;
    let mut epochs_since_period = 0usize;
    let start_step = match ctl.resume.take() {
        Some(ck) => {
            let s = restore_loop_state(
                &ck, &mut engine, &mut rng, &mut sampler, &mut flat,
                &mut out,
            )?;
            (epoch, epochs_since_period) = restore_clf_state(&ck)?;
            s
        }
        None => {
            engine.on_period(&mut rng)?; // initial mask
            out.residency_series.push((0, engine.keep_ratio(),
                                       engine.state_bytes()));
            0
        }
    };

    for step in start_step..cfg.steps {
        // Epoch bookkeeping: an epoch is ⌈N/B⌉ batches.
        let steps_per_epoch = task.n_train().div_ceil(batch);
        if step > 0 && step % steps_per_epoch == 0 {
            epoch += 1;
            epochs_since_period += 1;
            if epochs_since_period >= cfg.mask.period {
                epochs_since_period = 0;
                engine.on_period(&mut rng)?;
                out.residency_series.push((step, engine.keep_ratio(),
                                           engine.state_bytes()));
            }
        }
        let idx = sampler.next_batch(batch, &mut rng);
        task.pack_train_into(&idx, batch, &mut x, &mut y);
        let (loss, grad) = bundle.train_step_clf(&flat, &x, &y)?;
        let lr = cfg.schedule.lr_at(cfg.opt.lr, step) as f32;
        engine.apply(bundle, &mut flat, &grad, lr)?;
        out.loss_series.push((step, loss as f64));

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let (el, acc) = eval_classifier(bundle, &flat, task)?;
            out.eval_series.push((step, el, acc));
        }
        ckpt_tick(
            &mut ctl, step + 1, cfg.steps, &engine, &rng, &sampler,
            &flat, &out, Some((epoch, epochs_since_period)),
        )?;
    }
    out.train_secs = timer.total();
    out.steps_per_sec = cfg.steps as f64 / out.train_secs.max(1e-9);
    let (_, acc) = eval_classifier(bundle, &flat, task)?;
    out.final_metric = acc;
    out.final_params = flat;
    Ok(out)
}

/// Evaluate classifier accuracy (%) and mean loss over the test split.
pub fn eval_classifier(
    bundle: &ModelBundle,
    flat: &[f32],
    task: &ClassTask,
) -> Result<(f64, f64)> {
    let batch = bundle.man.data.batch;
    let n = task.test_x.len();
    let mut correct = 0.0f64;
    let mut loss_sum = 0.0f64;
    let mut batches = 0usize;
    let mut start = 0usize;
    while start < n {
        let (x, y) = task.pack_test(start, batch);
        let take = batch.min(n - start);
        let (loss, c) = bundle.eval_step_clf(flat, &x, &y)?;
        // pack_test wraps; only credit the non-wrapped prefix on the
        // final partial batch by rescaling.
        correct += c as f64 * take as f64 / batch as f64;
        loss_sum += loss as f64;
        batches += 1;
        start += batch;
    }
    Ok((loss_sum / batches as f64, 100.0 * correct / n as f64))
}

/// Pre-train the GPT bundle on a synthetic [`Corpus`].
///
/// Period unit = *steps* (the paper's pre-training setting: switch active
/// layers every K iterations).
pub fn train_lm(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    corpus: &Corpus,
) -> Result<TrainOutcome> {
    train_lm_ckpt(bundle, cfg, corpus, CkptCtl::default())
}

/// [`train_lm`] with checkpoint/resume (see [`CkptCtl`]).
pub fn train_lm_ckpt(
    bundle: &ModelBundle,
    cfg: &RunConfig,
    corpus: &Corpus,
    mut ctl: CkptCtl<'_>,
) -> Result<TrainOutcome> {
    cfg.validate()?;
    ensure!(bundle.man.kind == "gpt", "LM training needs a gpt bundle");
    ensure!(corpus.seq == bundle.man.data.seq, "corpus seq mismatch");

    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut engine = MethodEngine::new(&bundle.man, cfg, &mut rng)?;
    let mut flat = bundle.init_params()?;
    let n_train = corpus.n_samples().saturating_sub(8).max(1);
    let mut sampler = DataSampler::rr(n_train);
    let batch = bundle.man.data.batch;

    let mut out = TrainOutcome::default();
    let timer = Timer::start();
    // Batch buffers hoisted out of the step loop: one allocation per
    // run, refilled in place every step.
    let (mut x, mut y) = (Vec::new(), Vec::new());
    let start_step = match ctl.resume.take() {
        Some(ck) => restore_loop_state(
            &ck, &mut engine, &mut rng, &mut sampler, &mut flat,
            &mut out,
        )?,
        None => {
            engine.on_period(&mut rng)?;
            out.residency_series.push((0, engine.keep_ratio(),
                                       engine.state_bytes()));
            0
        }
    };

    for step in start_step..cfg.steps {
        if step > 0 && step % cfg.mask.period == 0 {
            engine.on_period(&mut rng)?;
            out.residency_series.push((step, engine.keep_ratio(),
                                       engine.state_bytes()));
        }
        let idx = sampler.next_batch(batch, &mut rng);
        corpus.pack_into(&idx, batch, &mut x, &mut y);
        let (loss, grad) = bundle.train_step_lm(&flat, &x, &y)?;
        let lr = cfg.schedule.lr_at(cfg.opt.lr, step) as f32;
        engine.apply(bundle, &mut flat, &grad, lr)?;
        out.loss_series.push((step, loss as f64));

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let el = eval_lm(bundle, &flat, corpus, n_train)?;
            out.eval_series.push((step, el, 0.0));
        }
        ckpt_tick(
            &mut ctl, step + 1, cfg.steps, &engine, &rng, &sampler,
            &flat, &out, None,
        )?;
    }
    out.train_secs = timer.total();
    out.steps_per_sec = cfg.steps as f64 / out.train_secs.max(1e-9);
    out.final_metric = eval_lm(bundle, &flat, corpus, n_train)?;
    out.final_params = flat;
    Ok(out)
}

/// Held-out LM loss over the last 8 windows (disjoint from training).
pub fn eval_lm(
    bundle: &ModelBundle,
    flat: &[f32],
    corpus: &Corpus,
    train_n: usize,
) -> Result<f64> {
    let batch = bundle.man.data.batch;
    let held: Vec<usize> =
        (train_n..corpus.n_samples()).take(batch.max(1)).collect();
    if held.is_empty() {
        return Ok(f64::NAN);
    }
    let (x, y) = corpus.pack(&held, batch);
    Ok(bundle.eval_step_lm(flat, &x, &y)? as f64)
}

/// Periodic checkpoint write. `done` is the number of completed steps.
/// A final-step snapshot is skipped (the job is about to report its
/// terminal result anyway); an engine that cannot snapshot (native
/// backend) disables further ticks instead of failing the run. Sink
/// errors (disk full, unwritable cache) *do* fail the run: silently
/// running on without the durability the operator asked for would
/// surprise them at the next crash.
#[allow(clippy::too_many_arguments)]
fn ckpt_tick(
    ctl: &mut CkptCtl<'_>,
    done: usize,
    total_steps: usize,
    engine: &MethodEngine,
    rng: &Rng,
    sampler: &DataSampler,
    flat: &[f32],
    out: &TrainOutcome,
    clf: Option<(usize, usize)>,
) -> Result<()> {
    if ctl.period == 0 || done % ctl.period != 0 || done >= total_steps
    {
        return Ok(());
    }
    if ctl.sink.is_none() {
        return Ok(());
    }
    let ck = match snapshot_loop_state(
        done, engine, rng, sampler, flat, out, clf,
    ) {
        Ok(ck) => ck,
        Err(_) => {
            ctl.period = 0; // native backend: resume unsupported
            return Ok(());
        }
    };
    (ctl.sink.as_mut().unwrap())(&ck)
}

/// Capture the *entire* training-loop state at `done` completed steps:
/// engine (`eng_*` sections), params, RNG, data cursor, and the series
/// history (`trn_*`) so a resumed run replays its CSV byte-identically.
fn snapshot_loop_state(
    done: usize,
    engine: &MethodEngine,
    rng: &Rng,
    sampler: &DataSampler,
    flat: &[f32],
    out: &TrainOutcome,
    clf: Option<(usize, usize)>,
) -> Result<Checkpoint> {
    let rng_state = rng.state();
    let mut ck = Checkpoint::new(done as u64, rng_state[0]);
    engine.snapshot(&mut ck)?;
    ck.insert("params", flat.to_vec());
    ck.insert("trn_rng", pack_u64s(&rng_state));
    let (tag, n, a, b, order): (u64, u64, u64, u64, &[usize]) =
        match sampler {
            DataSampler::Rr { n, order, pos, epochs } => {
                (1, *n as u64, *pos as u64, *epochs as u64, order)
            }
            DataSampler::Iid { n, draws } => {
                (2, *n as u64, *draws as u64, 0, &[])
            }
            DataSampler::Sequential { n, pos } => {
                (3, *n as u64, *pos as u64, 0, &[])
            }
        };
    ck.insert("trn_sampler", pack_u64s(&[tag, n, a, b]));
    let ord: Vec<u64> = order.iter().map(|&i| i as u64).collect();
    ck.insert("trn_sampler.order", pack_u64s(&ord));
    ck.insert(
        "trn_loss.steps",
        pack_usizes(out.loss_series.iter().map(|&(s, _)| s)),
    );
    ck.insert(
        "trn_loss.vals",
        pack_f64_bits(out.loss_series.iter().map(|&(_, l)| l)),
    );
    ck.insert(
        "trn_eval.steps",
        pack_usizes(out.eval_series.iter().map(|&(s, ..)| s)),
    );
    ck.insert(
        "trn_eval.loss",
        pack_f64_bits(out.eval_series.iter().map(|&(_, l, _)| l)),
    );
    ck.insert(
        "trn_eval.acc",
        pack_f64_bits(out.eval_series.iter().map(|&(.., a)| a)),
    );
    ck.insert(
        "trn_res.steps",
        pack_usizes(out.residency_series.iter().map(|&(s, ..)| s)),
    );
    ck.insert(
        "trn_res.keep",
        pack_f64_bits(out.residency_series.iter().map(|&(_, k, _)| k)),
    );
    ck.insert(
        "trn_res.bytes",
        pack_usizes(out.residency_series.iter().map(|&(.., b)| b)),
    );
    if let Some((epoch, espp)) = clf {
        ck.insert(
            "trn_clf",
            pack_u64s(&[epoch as u64, espp as u64]),
        );
    }
    Ok(ck)
}

/// Inverse of [`snapshot_loop_state`] minus the classifier counters
/// ([`restore_clf_state`]). Returns the step to resume from.
fn restore_loop_state(
    ck: &Checkpoint,
    engine: &mut MethodEngine,
    rng: &mut Rng,
    sampler: &mut DataSampler,
    flat: &mut Vec<f32>,
    out: &mut TrainOutcome,
) -> Result<usize> {
    engine.restore(ck)?;
    let p = ck.require("params")?;
    ensure!(
        p.len() == flat.len(),
        "checkpoint params sized {} vs model {}",
        p.len(),
        flat.len()
    );
    *flat = p.to_vec();
    let rs = unpack_u64s(ck.require("trn_rng")?)
        .context("corrupt trn_rng section")?;
    let rs: [u64; 4] = rs
        .try_into()
        .map_err(|_| anyhow::anyhow!("trn_rng: expected 4 words"))?;
    *rng = Rng::from_state(rs);
    let sm = unpack_u64s(ck.require("trn_sampler")?)
        .context("corrupt trn_sampler section")?;
    ensure!(sm.len() == 4, "trn_sampler: expected 4 values");
    ensure!(
        sm[1] as usize == sampler.n(),
        "checkpoint sampler over {} samples, job has {}",
        sm[1],
        sampler.n()
    );
    let order = unpack_u64s(ck.require("trn_sampler.order")?)
        .context("corrupt trn_sampler.order section")?;
    *sampler = match sm[0] {
        1 => {
            let order: Vec<usize> =
                order.into_iter().map(|i| i as usize).collect();
            ensure!(
                sm[2] as usize <= order.len()
                    && order.iter().all(|&i| i < sm[1] as usize),
                "RR cursor out of range"
            );
            DataSampler::Rr {
                n: sm[1] as usize,
                order,
                pos: sm[2] as usize,
                epochs: sm[3] as usize,
            }
        }
        2 => DataSampler::Iid {
            n: sm[1] as usize,
            draws: sm[2] as usize,
        },
        3 => DataSampler::Sequential {
            n: sm[1] as usize,
            pos: sm[2] as usize,
        },
        t => anyhow::bail!("unknown sampler tag {t} in checkpoint"),
    };
    out.loss_series = zip2(
        ck.require("trn_loss.steps")?,
        ck.require("trn_loss.vals")?,
    )?;
    let es = unpack_usizes(ck.require("trn_eval.steps")?)?;
    let el = unpack_f64_bits(ck.require("trn_eval.loss")?)?;
    let ea = unpack_f64_bits(ck.require("trn_eval.acc")?)?;
    ensure!(
        es.len() == el.len() && es.len() == ea.len(),
        "eval series sections disagree"
    );
    out.eval_series = es
        .into_iter()
        .zip(el)
        .zip(ea)
        .map(|((s, l), a)| (s, l, a))
        .collect();
    let rs_ = unpack_usizes(ck.require("trn_res.steps")?)?;
    let rk = unpack_f64_bits(ck.require("trn_res.keep")?)?;
    let rb = unpack_usizes(ck.require("trn_res.bytes")?)?;
    ensure!(
        rs_.len() == rk.len() && rs_.len() == rb.len(),
        "residency series sections disagree"
    );
    out.residency_series = rs_
        .into_iter()
        .zip(rk)
        .zip(rb)
        .map(|((s, k), b)| (s, k, b))
        .collect();
    Ok(ck.step as usize)
}

/// Classifier epoch counters out of a checkpoint.
fn restore_clf_state(ck: &Checkpoint) -> Result<(usize, usize)> {
    let c = unpack_u64s(ck.require("trn_clf")?)
        .context("corrupt trn_clf section")?;
    ensure!(c.len() == 2, "trn_clf: expected 2 values");
    Ok((c[0] as usize, c[1] as usize))
}

fn pack_usizes(xs: impl Iterator<Item = usize>) -> Vec<f32> {
    pack_u64s(&xs.map(|x| x as u64).collect::<Vec<_>>())
}

fn unpack_usizes(fs: &[f32]) -> Result<Vec<usize>> {
    Ok(unpack_u64s(fs)
        .context("corrupt packed-usize section")?
        .into_iter()
        .map(|x| x as usize)
        .collect())
}

/// f64s ride the packing by bit pattern — exact, NaN included.
fn pack_f64_bits(xs: impl Iterator<Item = f64>) -> Vec<f32> {
    pack_u64s(&xs.map(f64::to_bits).collect::<Vec<_>>())
}

fn unpack_f64_bits(fs: &[f32]) -> Result<Vec<f64>> {
    Ok(unpack_u64s(fs)
        .context("corrupt packed-f64 section")?
        .into_iter()
        .map(f64::from_bits)
        .collect())
}

fn zip2(steps: &[f32], vals: &[f32]) -> Result<Vec<(usize, f64)>> {
    let s = unpack_usizes(steps)?;
    let v = unpack_f64_bits(vals)?;
    ensure!(s.len() == v.len(), "series sections disagree");
    Ok(s.into_iter().zip(v).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;
    use crate::manifest::Manifest;
    use crate::util::json::Json;
    use std::path::Path;

    /// 8 middle layers so keep-ratio 0.05 still rounds to a non-empty
    /// active set under every masked method.
    fn toy_manifest() -> Manifest {
        let mut params = vec![format!(
            r#"{{"name":"in_w","shape":[16],"layer":"embed",
                 "offset":0,"len":16}}"#
        )];
        for i in 0..8 {
            params.push(format!(
                r#"{{"name":"block_{i}.w","shape":[16],
                     "layer":"block_{i}","offset":{},"len":16}}"#,
                16 * (i + 1)
            ));
        }
        params.push(
            r#"{"name":"out_w","shape":[16],"layer":"head",
                "offset":144,"len":16}"#
                .into(),
        );
        let text = format!(
            r#"{{"name":"toy","kind":"mlp","block":8,
                 "total_len":160,"padded_len":160,
                 "params":[{}],
                 "data":{{"batch":2}},
                 "artifacts":{{"train":"t","eval":"e","init":"i",
                               "update":{{"adamw":"a","sgdm":"s"}}}}}}"#,
            params.join(",")
        );
        Manifest::from_json(&Json::parse(&text).unwrap(), Path::new("/tmp"))
            .unwrap()
    }

    fn grad_at(step: usize, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| (((step * 31 + i * 7 + 3) as f32) * 0.01).sin())
            .collect()
    }

    /// The `train_lm` loop skeleton against synthetic gradients (no
    /// PJRT): mask periods, sampler draws, native update, series
    /// bookkeeping, and [`ckpt_tick`] — everything a checkpoint must
    /// capture, minus the HLO executions themselves.
    #[allow(clippy::too_many_arguments)]
    fn drive(
        engine: &mut MethodEngine,
        rng: &mut Rng,
        sampler: &mut DataSampler,
        flat: &mut Vec<f32>,
        out: &mut TrainOutcome,
        steps: std::ops::Range<usize>,
        total: usize,
        ctl: &mut CkptCtl<'_>,
    ) {
        for step in steps {
            if step > 0 && step % 3 == 0 {
                engine.on_period(rng).unwrap();
                out.residency_series.push((
                    step,
                    engine.keep_ratio(),
                    engine.state_bytes(),
                ));
            }
            let idx = sampler.next_batch(2, rng);
            let g = grad_at(step, flat.len());
            engine.apply_native(flat, &g, 0.01);
            // Loss folds the drawn batch in, so a drifted data cursor
            // shows up as a diverging series.
            let loss =
                idx.iter().sum::<usize>() as f64 + step as f64 * 0.5;
            out.loss_series.push((step, loss));
            if (step + 1) % 5 == 0 {
                out.eval_series.push((step, loss * 0.5, 42.0));
            }
            ckpt_tick(
                ctl, step + 1, total, engine, rng, sampler, flat, out,
                None,
            )
            .unwrap();
        }
    }

    /// Satellite guarantee (docs/durability.md): a run killed right
    /// after a checkpoint and resumed from it is *bitwise identical*
    /// to the uninterrupted run — final params, optimizer state, and
    /// every CSV series — at keep ratios 1.0, 0.25, and 0.05.
    #[test]
    fn resumed_run_is_bitwise_identical_across_keep_ratios() {
        let man = toy_manifest();
        let total = 13usize;
        for &keep in &[1.0f64, 0.25, 0.05] {
            for method in
                [Method::IidMask, Method::WorMask, Method::LisaWor]
            {
                let mut cfg = RunConfig::default();
                cfg.method = method;
                cfg.mask.gamma = 1;
                cfg.mask.keep_ratio = keep;
                let tag = format!("{method:?} keep={keep}");
                let init: Vec<f32> =
                    (0..man.padded_len).map(|i| (i as f32 * 0.1).cos()).collect();

                // Run A: uninterrupted, checkpointing every 4 steps.
                let mut parked: Vec<Checkpoint> = Vec::new();
                let mut rng = Rng::seed_from_u64(9);
                let mut eng =
                    MethodEngine::new(&man, &cfg, &mut rng).unwrap();
                let mut sampler = DataSampler::rr(11);
                let mut flat = init.clone();
                let mut out = TrainOutcome::default();
                eng.on_period(&mut rng).unwrap();
                out.residency_series.push((
                    0,
                    eng.keep_ratio(),
                    eng.state_bytes(),
                ));
                {
                    let mut ctl = CkptCtl {
                        period: 4,
                        resume: None,
                        sink: Some(Box::new(|ck: &Checkpoint| {
                            parked.push(ck.clone());
                            Ok(())
                        })),
                    };
                    drive(
                        &mut eng, &mut rng, &mut sampler, &mut flat,
                        &mut out, 0..total, total, &mut ctl,
                    );
                }
                assert_eq!(
                    parked.iter().map(|c| c.step).collect::<Vec<_>>(),
                    vec![4, 8, 12],
                    "{tag}: checkpoint cadence"
                );

                // Run B: "killed" after the step-8 checkpoint, resumed
                // on a *fresh* process (foreign RNG seed, fresh engine)
                // from the parked snapshot.
                let ck = parked[1].clone();
                let mut rng_b = Rng::seed_from_u64(777);
                let mut eng_b =
                    MethodEngine::new(&man, &cfg, &mut rng_b).unwrap();
                let mut sampler_b = DataSampler::rr(11);
                let mut flat_b = init.clone();
                let mut out_b = TrainOutcome::default();
                let start = restore_loop_state(
                    &ck, &mut eng_b, &mut rng_b, &mut sampler_b,
                    &mut flat_b, &mut out_b,
                )
                .unwrap();
                assert_eq!(start, 8, "{tag}");
                let mut no_ckpt = CkptCtl::default();
                drive(
                    &mut eng_b, &mut rng_b, &mut sampler_b, &mut flat_b,
                    &mut out_b, start..total, total, &mut no_ckpt,
                );

                // Bitwise: params, every series, and the full engine
                // state (compared through its own snapshot sections).
                assert_eq!(flat.len(), flat_b.len(), "{tag}");
                for i in 0..flat.len() {
                    assert_eq!(
                        flat[i].to_bits(),
                        flat_b[i].to_bits(),
                        "{tag}: param {i}"
                    );
                }
                let bits = |s: &[(usize, f64)]| -> Vec<(usize, u64)> {
                    s.iter().map(|&(a, b)| (a, b.to_bits())).collect()
                };
                assert_eq!(
                    bits(&out.loss_series),
                    bits(&out_b.loss_series),
                    "{tag}: loss series"
                );
                assert_eq!(
                    out.eval_series.len(),
                    out_b.eval_series.len(),
                    "{tag}"
                );
                for (a, b) in
                    out.eval_series.iter().zip(&out_b.eval_series)
                {
                    assert_eq!(a.0, b.0, "{tag}");
                    assert_eq!(a.1.to_bits(), b.1.to_bits(), "{tag}");
                    assert_eq!(a.2.to_bits(), b.2.to_bits(), "{tag}");
                }
                assert_eq!(
                    out.residency_series.len(),
                    out_b.residency_series.len(),
                    "{tag}: residency series"
                );
                for (a, b) in out
                    .residency_series
                    .iter()
                    .zip(&out_b.residency_series)
                {
                    assert_eq!(a.0, b.0, "{tag}");
                    assert_eq!(a.1.to_bits(), b.1.to_bits(), "{tag}");
                    assert_eq!(a.2, b.2, "{tag}");
                }
                let fin_a = snapshot_loop_state(
                    total, &eng, &rng, &sampler, &flat, &out, None,
                )
                .unwrap();
                let fin_b = snapshot_loop_state(
                    total, &eng_b, &rng_b, &sampler_b, &flat_b, &out_b,
                    None,
                )
                .unwrap();
                assert_eq!(
                    fin_a.sections, fin_b.sections,
                    "{tag}: engine/loop state diverged"
                );
            }
        }
    }

    /// `ckpt_tick` contract: period 0 never snapshots, the final step
    /// is skipped, and a native-backend engine (cannot snapshot)
    /// disables itself instead of failing the run.
    #[test]
    fn ckpt_tick_skips_final_step_and_disables_on_native_backend() {
        let man = toy_manifest();
        let mut cfg = RunConfig::default();
        cfg.method = Method::IidMask;
        cfg.mask.gamma = 1;
        cfg.mask.keep_ratio = 0.5;
        let mut rng = Rng::seed_from_u64(3);
        let mut eng = MethodEngine::new(&man, &cfg, &mut rng).unwrap();
        eng.on_period(&mut rng).unwrap();
        let sampler = DataSampler::rr(5);
        let flat = vec![0.0f32; man.padded_len];
        let out = TrainOutcome::default();

        let mut saved = 0usize;
        {
            let mut ctl = CkptCtl {
                period: 2,
                resume: None,
                sink: Some(Box::new(|_ck: &Checkpoint| {
                    saved += 1;
                    Ok(())
                })),
            };
            for done in 1..=6 {
                ckpt_tick(
                    &mut ctl, done, 6, &eng, &rng, &sampler, &flat,
                    &out, None,
                )
                .unwrap();
            }
        }
        assert_eq!(saved, 2, "done=2,4 snapshot; done=6 (final) skips");

        // Native backend: first tick flips period to 0, no error.
        let mut cfg_n = RunConfig::default();
        cfg_n.method = Method::Sift;
        let mut rng_n = Rng::seed_from_u64(4);
        let mut eng_n =
            MethodEngine::new(&man, &cfg_n, &mut rng_n).unwrap();
        eng_n.on_period(&mut rng_n).unwrap();
        let mut native_saves = 0usize;
        {
            let mut ctl = CkptCtl {
                period: 2,
                resume: None,
                sink: Some(Box::new(|_ck: &Checkpoint| {
                    native_saves += 1;
                    Ok(())
                })),
            };
            ckpt_tick(
                &mut ctl, 2, 6, &eng_n, &rng_n, &sampler, &flat, &out,
                None,
            )
            .unwrap();
            assert_eq!(ctl.period, 0, "native backend disables ticks");
        }
        assert_eq!(native_saves, 0);
    }
}
