//! Method engine: one interface over the paper's method roster.
//!
//! Owns the current mask, the period-boundary refresh logic (the OMGD
//! traversal state), and the optimizer backend:
//!
//! * HLO backend — the fused masked-update Pallas kernel via PJRT, used
//!   by Full / mask / LISA methods (the paper's "plug-and-play into
//!   mainstream optimizers" path — this IS the request-path hot loop).
//!   Dispatch is runs-first: the mask's `(offset, len, scale)`
//!   descriptors go to [`ModelBundle::adamw_update_runs`] /
//!   [`sgdm_update_runs`](ModelBundle::sgdm_update_runs), which expand
//!   them into the kernel's dense multiplier only when the mask actually
//!   changed. No dense mask vector is materialized on the steady-state
//!   step path (`omgd_mask_densify_total` stays 0). The kernel keeps
//!   full-length `m`/`v` device-shaped buffers; its **native mirror**
//!   ([`MethodEngine::apply_native`] — tests, benches, and the pure-rust
//!   §5.1-style long runs) walks the same segment-run view, so a native
//!   step costs O(active), never touching frozen coordinates.
//! * native backend — GaLore/GoLore/SIFT baselines, whose projections
//!   don't fit the fused elementwise kernel. Driven through the
//!   runs-first [`crate::optim::Optimizer::step`]; period boundaries
//!   rebuild their active-region index maps via `on_mask_refresh`.

use crate::config::{Method, OptFamily, RunConfig};
use crate::coordinator::{LisaScheduler, LisaVariant, Mask, MaskRuns,
                         MaskSet};
use crate::exec::{self, ExecEngine};
use crate::manifest::Manifest;
use crate::metrics::Timer;
use crate::obs;
use crate::optim::{galore, par_adamw_segments, par_sgdm_segments,
                   Optimizer, SiftOptimizer};
use crate::rng::Rng;
use crate::runtime::bundle::{RunDesc, UpdateKind};
use crate::runtime::{ModelBundle, RunsScratch};
use crate::train::checkpoint::{pack_u64s, unpack_u64s, Checkpoint};
use anyhow::{bail, ensure, Context, Result};

/// Which update path executes the step.
enum Backend {
    /// Fused HLO kernel; optimizer state lives in rust-owned flat vecs
    /// (the kernel's contract is full-length buffers).
    HloAdamW { m: Vec<f32>, v: Vec<f32>, t: u64 },
    HloSgdm { buf: Vec<f32> },
    /// Native baseline optimizer (run-aware).
    Native(Box<dyn Optimizer>),
}

/// Mask-refresh strategy at period boundaries.
enum MaskPlan {
    /// Fixed full mask.
    Full,
    /// Tensorwise i.i.d. resample (scale 1, the §5.2 naïve baseline).
    TensorIid { r: f64 },
    /// Tensorwise WOR: walk an eq.-(3) partition; fresh set per cycle.
    TensorWor { r: f64, set: MaskSet, order: Vec<usize>, pos: usize },
    /// LISA family via the Algorithm 2 scheduler.
    Lisa { sched: LisaScheduler },
    /// Mask fixed to full; the method lives in the native backend.
    Passthrough,
}

/// The per-run method engine.
pub struct MethodEngine {
    pub method: Method,
    man: Manifest,
    mask: Mask,
    plan: MaskPlan,
    backend: Backend,
    opt: crate::config::OptConfig,
    /// Shard-parallel execution engine (`--threads` / `OMGD_THREADS`,
    /// default = available parallelism). Owned per engine: each run
    /// has its own pool, sized once at construction.
    exec: ExecEngine,
    /// Serial (one-thread) engine the step path routes tiny masks
    /// through: below [`exec::PAR_MIN_ACTIVE`] active coordinates the
    /// dispatch wakeups cost more than the walk. Pure policy — both
    /// paths are bitwise identical.
    serial: ExecEngine,
    /// Per-engine dense-multiplier scratch for the HLO bridge
    /// (replaces the old global `Mutex<RunsScratch>` in `ModelBundle`).
    scratch: RunsScratch,
    /// Cached `(offset, len, scale)` descriptors of the current mask —
    /// rebuilt at period boundaries / restore, not per step.
    desc: Vec<RunDesc>,
    /// Period boundaries seen (diagnostics).
    pub periods: usize,
}

impl MethodEngine {
    pub fn new(man: &Manifest, cfg: &RunConfig, rng: &mut Rng)
               -> Result<Self> {
        let n = man.padded_len;
        let r = cfg.mask.keep_ratio;
        let plan = match cfg.method {
            Method::Full => MaskPlan::Full,
            Method::IidMask => MaskPlan::TensorIid { r },
            Method::WorMask => {
                let set = MaskSet::tensor_partition(man, r, rng)?;
                let order = rng.permutation(set.m());
                MaskPlan::TensorWor { r, set, order, pos: 0 }
            }
            Method::Lisa | Method::LisaScale | Method::LisaWorNoScale
            | Method::LisaWor => {
                let variant = match cfg.method {
                    Method::Lisa => LisaVariant::Lisa,
                    Method::LisaScale => LisaVariant::LisaScale,
                    Method::LisaWorNoScale => LisaVariant::LisaWorNoScale,
                    _ => LisaVariant::LisaWor,
                };
                let middle = man.middle_layers();
                ensure!(!middle.is_empty(),
                        "{} has no middle layers for LISA", man.name);
                MaskPlan::Lisa {
                    sched: LisaScheduler::new(variant, middle,
                                              cfg.mask.gamma),
                }
            }
            Method::Galore | Method::Golore | Method::Sift => {
                MaskPlan::Passthrough
            }
        };

        let backend = match cfg.method {
            Method::Galore => Backend::Native(Box::new(galore::galore(
                &man.params, n, cfg.mask.rank, refresh_steps(cfg),
                cfg.seed,
            ))),
            Method::Golore => Backend::Native(Box::new(galore::golore(
                &man.params, n, cfg.mask.rank, refresh_steps(cfg),
                cfg.seed,
            ))),
            Method::Sift => Backend::Native(Box::new(SiftOptimizer::new(
                n, man.total_len, cfg.mask.topk, refresh_steps(cfg),
            ))),
            _ => match cfg.opt.family {
                OptFamily::AdamW => Backend::HloAdamW {
                    m: vec![0.0; n],
                    v: vec![0.0; n],
                    t: 0,
                },
                OptFamily::Sgdm => Backend::HloSgdm { buf: vec![0.0; n] },
            },
        };

        // Mask starts full-over-real-params (padding frozen).
        let mut mask = Mask::zeros(n);
        mask.set_segment(0, man.total_len, 1.0)?;
        let exec_engine = ExecEngine::from_env();
        obs::STEP_THREADS.set(exec_engine.threads() as f64);
        let desc = mask.runs().descriptors();
        Ok(Self {
            method: cfg.method,
            man: man.clone(),
            mask,
            plan,
            backend,
            opt: cfg.opt.clone(),
            exec: exec_engine,
            serial: ExecEngine::new(1),
            scratch: RunsScratch::new(),
            desc,
            periods: 0,
        })
    }

    /// Concurrency the step path runs at (pool threads + caller).
    pub fn threads(&self) -> usize {
        self.exec.threads()
    }

    /// Refresh the mask at a period boundary (K epochs / K steps) and
    /// rebuild the native backend's active-region index map for the new
    /// support. Errors (e.g. a malformed manifest's tensor table)
    /// surface to the caller instead of panicking a worker thread.
    pub fn on_period(&mut self, rng: &mut Rng) -> Result<()> {
        let t = Timer::start();
        self.periods += 1;
        let total = self.man.total_len;
        match &mut self.plan {
            MaskPlan::Full | MaskPlan::Passthrough => {}
            MaskPlan::TensorIid { r } => {
                let mut mask = MaskSet::tensor_iid(&self.man, *r, rng)?;
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
            MaskPlan::TensorWor { r, set, order, pos } => {
                if *pos >= order.len() {
                    // Cycle exhausted: fresh partition + fresh order
                    // (Algorithm 1 line 4, epochwise instantiation).
                    *set = MaskSet::tensor_partition(&self.man, *r, rng)?;
                    *order = rng.permutation(set.m());
                    *pos = 0;
                }
                let j = order[*pos];
                *pos += 1;
                let mut mask = set.masks[j].clone();
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
            MaskPlan::Lisa { sched } => {
                let act = sched.next_period(rng);
                let mut mask =
                    MaskSet::layerwise(&self.man, &act.layers, act.scale)?;
                clamp_to_total(&mut mask, total)?;
                self.mask = mask;
            }
        }
        // Period boundary = the one place compact optimizer state is
        // remapped (carry still-active, reset re-activated, free the
        // rest). The step path then only walks the runs. Carry-copies
        // run shard-parallel (disjoint destination windows).
        if let Backend::Native(opt) = &mut self.backend {
            opt.on_mask_refresh_sharded(self.mask.runs(), &self.exec);
        }
        // Descriptor cache: rebuilt here once, reused by every step
        // until the next boundary (no per-step Vec churn).
        self.mask.runs().descriptors_into(&mut self.desc);
        if self.exec.threads() > 1 {
            let shards =
                exec::partition(self.mask.runs(), self.exec.threads());
            obs::EXEC_SHARD_IMBALANCE
                .observe(exec::shard_imbalance(&shards));
        }
        obs::STEP_THREADS.set(self.exec.threads() as f64);
        obs::MASK_REFRESH_SECONDS.observe(t.total());
        obs::STATE_BYTES.set(self.state_bytes() as f64);
        obs::KEEP_RATIO.set(self.keep_ratio());
        Ok(())
    }

    /// Apply one optimizer step (dispatches HLO kernel or native).
    pub fn apply(&mut self, bundle: &ModelBundle, p: &mut Vec<f32>,
                 g: &[f32], lr: f32) -> Result<()> {
        let t = Timer::start();
        let Self { backend, mask, opt, exec, serial, scratch, desc, .. } =
            self;
        let out = match backend {
            Backend::HloAdamW { m, v, t } => {
                ensure!(bundle.update_kind == UpdateKind::AdamW,
                        "bundle update kind mismatch");
                *t += 1;
                let bc1 = 1.0 - (opt.beta1 as f32).powi(*t as i32);
                let bc2 = 1.0 - (opt.beta2 as f32).powi(*t as i32);
                let hp = [
                    lr,
                    opt.beta1 as f32,
                    opt.beta2 as f32,
                    opt.eps as f32,
                    opt.weight_decay as f32,
                    bc1,
                    bc2,
                    0.0,
                ];
                bundle.adamw_update_runs(p, g, desc, m, v, &hp, scratch)
            }
            Backend::HloSgdm { buf } => {
                ensure!(bundle.update_kind == UpdateKind::Sgdm,
                        "bundle update kind mismatch");
                let hp = [
                    lr,
                    opt.momentum as f32,
                    opt.weight_decay as f32,
                    if opt.nesterov { 1.0 } else { 0.0 },
                ];
                bundle.sgdm_update_runs(p, g, desc, buf, &hp, scratch)
            }
            Backend::Native(o) => {
                let runs = mask.runs();
                let e = if runs.active_count() >= exec::PAR_MIN_ACTIVE {
                    &*exec
                } else {
                    &*serial
                };
                o.step_sharded(p, g, runs, lr, e);
                Ok(())
            }
        };
        obs::STEP_SECONDS.observe(t.total());
        out
    }

    /// Apply a step with a *native* optimizer mirroring the HLO kernel —
    /// used by tests and the pure-rust fast path (no PJRT dispatch).
    /// Walks the mask's segment runs: O(active) work, frozen
    /// coordinates are never read.
    pub fn apply_native(&mut self, p: &mut [f32], g: &[f32], lr: f32) {
        let t = Timer::start();
        let Self { backend, mask, opt, exec, serial, .. } = self;
        let runs = mask.runs();
        let e = if runs.active_count() >= exec::PAR_MIN_ACTIVE {
            &*exec
        } else {
            &*serial
        };
        match backend {
            Backend::HloAdamW { m, v, t } => {
                *t += 1;
                let bc1 = 1.0 - (opt.beta1 as f32).powi(*t as i32);
                let bc2 = 1.0 - (opt.beta2 as f32).powi(*t as i32);
                let hp = (
                    opt.beta1 as f32,
                    opt.beta2 as f32,
                    bc1,
                    bc2,
                    opt.eps as f32,
                    opt.weight_decay as f32,
                );
                // The mirror keeps full-length (coordinate-indexed)
                // moments — the shared dense-segment kernel walks the
                // runs shard-parallel with the same per-coordinate
                // arithmetic as the HLO kernel.
                par_adamw_segments(e, runs.runs(), m, v, p, g, hp, lr);
            }
            Backend::HloSgdm { buf } => {
                let hp = (
                    opt.momentum as f32,
                    opt.weight_decay as f32,
                    opt.nesterov,
                );
                par_sgdm_segments(e, runs.runs(), buf, p, g, hp, lr);
            }
            Backend::Native(o) => o.step_sharded(p, g, runs, lr, e),
        }
        obs::STEP_SECONDS.observe(t.total());
    }

    /// Current mask (read-only view).
    pub fn mask(&self) -> &Mask {
        &self.mask
    }

    /// Current mask's segment-run view (O(1)).
    pub fn runs(&self) -> &MaskRuns {
        self.mask.runs()
    }

    /// Current mask keep-ratio (runs-derived, O(1)).
    pub fn keep_ratio(&self) -> f64 {
        self.mask.keep_ratio()
    }

    /// Bytes of optimizer state under the paper's residency model
    /// (frozen coordinates hold no state). For the native backends this
    /// is the *live* figure reported by the optimizer itself; for the
    /// HLO arms it is runs-derived (the kernel bridge keeps full-length
    /// buffers device-side).
    pub fn state_bytes(&self) -> usize {
        match &self.backend {
            Backend::HloAdamW { .. } => self.mask.active_count() * 8,
            Backend::HloSgdm { .. } => self.mask.active_count() * 4,
            Backend::Native(opt) => opt.state_bytes(),
        }
    }

    /// Serialize the engine's whole mutable state into `ck` under
    /// `eng_`-prefixed sections: the current mask (as run triples, not
    /// dense — O(runs) on disk), the traversal plan's cursor (WOR
    /// partition + permutation + position, or the LISA pool), and the
    /// optimizer buffers. Restoring into a freshly-constructed engine
    /// ([`MethodEngine::restore`]) and continuing is bitwise identical
    /// to never having stopped — the resume-determinism contract of
    /// `docs/durability.md`.
    ///
    /// Native-backend methods (GaLore/GoLore/SIFT) hold projection
    /// state behind the `Optimizer` trait and refuse to snapshot; their
    /// jobs restart from scratch on re-lease rather than resume wrong.
    pub fn snapshot(&self, ck: &mut Checkpoint) -> Result<()> {
        ck.insert("eng_method", pack_u64s(&[method_code(self.method)]));
        ck.insert("eng_periods", pack_u64s(&[self.periods as u64]));
        let (meta, scales) = mask_to_sections(&self.mask);
        ck.insert("eng_mask.meta", meta);
        ck.insert("eng_mask.scales", scales);
        match &self.plan {
            MaskPlan::Full
            | MaskPlan::TensorIid { .. }
            | MaskPlan::Passthrough => {}
            MaskPlan::TensorWor { set, order, pos, .. } => {
                ck.insert("eng_wor.pos", pack_u64s(&[*pos as u64]));
                let ord: Vec<u64> =
                    order.iter().map(|&i| i as u64).collect();
                ck.insert("eng_wor.order", pack_u64s(&ord));
                ck.insert(
                    "eng_wor.set_len",
                    pack_u64s(&[set.m() as u64]),
                );
                for (j, m) in set.masks.iter().enumerate() {
                    let (meta, scales) = mask_to_sections(m);
                    ck.insert(&format!("eng_wor.set.{j}.meta"), meta);
                    ck.insert(
                        &format!("eng_wor.set.{j}.scales"),
                        scales,
                    );
                }
            }
            MaskPlan::Lisa { sched } => {
                ck.insert(
                    "eng_lisa.cycles",
                    pack_u64s(&[sched.cycles as u64]),
                );
                let pool: Vec<u64> =
                    sched.pool().iter().map(|&i| i as u64).collect();
                ck.insert("eng_lisa.pool", pack_u64s(&pool));
            }
        }
        match &self.backend {
            Backend::HloAdamW { m, v, t } => {
                ck.insert("eng_m", m.clone());
                ck.insert("eng_v", v.clone());
                ck.insert("eng_t", pack_u64s(&[*t]));
            }
            Backend::HloSgdm { buf } => ck.insert("eng_buf", buf.clone()),
            Backend::Native(_) => bail!(
                "checkpoint/resume is not supported for native-backend \
                 methods (GaLore/GoLore/SIFT); the job restarts instead"
            ),
        }
        Ok(())
    }

    /// Inverse of [`MethodEngine::snapshot`]: overwrite this (freshly
    /// constructed, same config) engine's state from `ck`. Validates
    /// the method tag, mask geometry, and every cursor before touching
    /// anything the step path trusts — a corrupt or foreign checkpoint
    /// errors out instead of resuming wrong or panicking mid-step.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<()> {
        let code = section_u64(ck, "eng_method")?;
        ensure!(
            code == method_code(self.method),
            "checkpoint method tag {code} does not match {:?}",
            self.method
        );
        self.periods = section_u64(ck, "eng_periods")? as usize;
        let mask = mask_from_sections(
            ck.require("eng_mask.meta")?,
            ck.require("eng_mask.scales")?,
        )?;
        ensure!(
            mask.len() == self.man.padded_len,
            "checkpoint mask length {} vs manifest padded length {}",
            mask.len(),
            self.man.padded_len
        );
        self.mask = mask;
        self.mask.runs().descriptors_into(&mut self.desc);
        match &mut self.plan {
            MaskPlan::Full
            | MaskPlan::TensorIid { .. }
            | MaskPlan::Passthrough => {}
            MaskPlan::TensorWor { set, order, pos, .. } => {
                let new_pos = section_u64(ck, "eng_wor.pos")? as usize;
                let ord = unpack_u64s(ck.require("eng_wor.order")?)
                    .context("corrupt eng_wor.order section")?;
                let m = section_u64(ck, "eng_wor.set_len")? as usize;
                ensure!(
                    ord.len() == m && new_pos <= m,
                    "WOR cursor out of range: pos {new_pos}, \
                     order {} over {m} masks",
                    ord.len()
                );
                ensure!(
                    ord.iter().all(|&i| (i as usize) < m),
                    "WOR order indexes past the partition"
                );
                let mut masks = Vec::with_capacity(m);
                for j in 0..m {
                    masks.push(mask_from_sections(
                        ck.require(&format!("eng_wor.set.{j}.meta"))?,
                        ck.require(&format!(
                            "eng_wor.set.{j}.scales"
                        ))?,
                    )?);
                }
                *set = MaskSet { masks };
                *order = ord.into_iter().map(|i| i as usize).collect();
                *pos = new_pos;
            }
            MaskPlan::Lisa { sched } => {
                let cycles =
                    section_u64(ck, "eng_lisa.cycles")? as usize;
                let pool = unpack_u64s(ck.require("eng_lisa.pool")?)
                    .context("corrupt eng_lisa.pool section")?
                    .into_iter()
                    .map(|i| i as usize)
                    .collect();
                sched.set_state(pool, cycles)?;
            }
        }
        let n = self.man.padded_len;
        match &mut self.backend {
            Backend::HloAdamW { m, v, t } => {
                let nm = ck.require("eng_m")?;
                let nv = ck.require("eng_v")?;
                ensure!(
                    nm.len() == n && nv.len() == n,
                    "checkpoint optimizer buffers sized {}/{} vs {n}",
                    nm.len(),
                    nv.len()
                );
                *m = nm.to_vec();
                *v = nv.to_vec();
                *t = section_u64(ck, "eng_t")?;
            }
            Backend::HloSgdm { buf } => {
                let nb = ck.require("eng_buf")?;
                ensure!(
                    nb.len() == n,
                    "checkpoint momentum buffer sized {} vs {n}",
                    nb.len()
                );
                *buf = nb.to_vec();
            }
            Backend::Native(_) => bail!(
                "checkpoint/resume is not supported for native-backend \
                 methods (GaLore/GoLore/SIFT); the job restarts instead"
            ),
        }
        Ok(())
    }
}

/// Stable per-method tag written into checkpoints and validated on
/// restore, so a checkpoint parked by one method can never silently
/// seed another (enum order is not a wire format).
fn method_code(m: Method) -> u64 {
    match m {
        Method::Full => 1,
        Method::IidMask => 2,
        Method::WorMask => 3,
        Method::Lisa => 4,
        Method::LisaScale => 5,
        Method::LisaWorNoScale => 6,
        Method::LisaWor => 7,
        Method::Galore => 8,
        Method::Golore => 9,
        Method::Sift => 10,
    }
}

/// One u64 out of a packed single-value section.
fn section_u64(ck: &Checkpoint, name: &str) -> Result<u64> {
    let xs = unpack_u64s(ck.require(name)?)
        .with_context(|| format!("corrupt {name} section"))?;
    ensure!(xs.len() == 1, "{name}: expected 1 value, got {}", xs.len());
    Ok(xs[0])
}

/// Mask → (packed `[n, offset, len, ...]`, raw `[scale, ...]`) section
/// pair. Offsets/lengths ride the lossless u64 packing — f32 mantissas
/// would corrupt coordinates past 2²⁴ on large models.
fn mask_to_sections(mask: &Mask) -> (Vec<f32>, Vec<f32>) {
    let rs = mask.runs().runs();
    let mut meta = Vec::with_capacity(1 + rs.len() * 2);
    meta.push(mask.len() as u64);
    let mut scales = Vec::with_capacity(rs.len());
    for r in rs {
        meta.push(r.offset as u64);
        meta.push(r.len as u64);
        scales.push(r.scale);
    }
    (pack_u64s(&meta), scales)
}

/// Inverse of [`mask_to_sections`]; errors on any geometry mismatch.
fn mask_from_sections(meta: &[f32], scales: &[f32]) -> Result<Mask> {
    let meta =
        unpack_u64s(meta).context("corrupt mask meta section")?;
    ensure!(
        meta.len() == 1 + 2 * scales.len(),
        "mask sections disagree: {} meta values for {} runs",
        meta.len(),
        scales.len()
    );
    let mut mask = Mask::zeros(meta[0] as usize);
    for (k, &s) in scales.iter().enumerate() {
        mask.set_segment(
            meta[1 + 2 * k] as usize,
            meta[2 + 2 * k] as usize,
            s,
        )?;
    }
    Ok(mask)
}

fn refresh_steps(cfg: &RunConfig) -> usize {
    cfg.mask.period.max(1)
}

/// Freeze the padding tail `total..len` (defensive: the constructors
/// already leave padding at zero).
fn clamp_to_total(mask: &mut Mask, total: usize) -> Result<()> {
    let n = mask.len();
    if total < n {
        mask.set_segment(total, n - total, 0.0)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;
    use std::path::Path;

    fn toy_manifest() -> Manifest {
        let j = Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 4,
 "total_len": 20, "padded_len": 24,
 "params": [
  {"name": "in_w", "shape": [4], "layer": "embed", "offset": 0, "len": 4},
  {"name": "block_0.w", "shape": [4], "layer": "block_0", "offset": 4, "len": 4},
  {"name": "block_1.w", "shape": [4], "layer": "block_1", "offset": 8, "len": 4},
  {"name": "block_2.w", "shape": [4], "layer": "block_2", "offset": 12, "len": 4},
  {"name": "out_w", "shape": [4], "layer": "head", "offset": 16, "len": 4}
 ],
 "data": {"batch": 2},
 "artifacts": {"train": "t", "eval": "e", "init": "i",
               "update": {"adamw": "a", "sgdm": "s"}}
}"#,
        )
        .unwrap();
        Manifest::from_json(&j, Path::new("/tmp")).unwrap()
    }

    fn cfg_with(method: Method) -> RunConfig {
        let mut cfg = RunConfig::default();
        cfg.method = method;
        cfg.mask.gamma = 1;
        cfg.mask.keep_ratio = 0.5;
        cfg
    }

    #[test]
    fn full_mask_covers_real_params_only() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(0);
        let eng =
            MethodEngine::new(&man, &cfg_with(Method::Full), &mut rng)
                .unwrap();
        assert_eq!(eng.mask().active_count(), 20);
        assert!(eng.mask().dense_bridge()[20..].iter().all(|&v| v == 0.0));
        // the run view is the single segment over the real params
        assert_eq!(eng.runs().runs().len(), 1);
        assert_eq!(eng.runs().active_count(), 20);
    }

    #[test]
    fn lisa_wor_traverses_all_middle_layers() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(1);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        let mut active_union = vec![false; 24];
        for _ in 0..3 {
            eng.on_period(&mut rng).unwrap();
            for (i, &v) in eng.mask().dense_bridge().iter().enumerate() {
                if v != 0.0 {
                    active_union[i] = true;
                }
            }
            // exactly embed + head + 1 middle layer active
            assert_eq!(eng.mask().active_count(), 12);
            // middle scale = N_L/γ = 3
            let mid_scales: Vec<f32> = eng.mask().dense_bridge()[4..16]
                .iter()
                .cloned()
                .filter(|&v| v != 0.0)
                .collect();
            assert!(mid_scales.iter().all(|&v| (v - 3.0).abs() < 1e-6));
        }
        // after 3 periods every middle layer was visited
        assert!(active_union[..20].iter().all(|&b| b));
    }

    #[test]
    fn lisa_no_scale_uses_unit_scale() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(2);
        let mut eng = MethodEngine::new(
            &man, &cfg_with(Method::LisaWorNoScale), &mut rng,
        )
        .unwrap();
        eng.on_period(&mut rng).unwrap();
        assert!(eng.mask().dense_bridge().iter()
            .all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn wor_mask_cycles_cover_everything_with_scale_m() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(3);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::WorMask), &mut rng)
                .unwrap();
        let mut sum = vec![0.0f32; 24];
        for _ in 0..2 {
            // one cycle = M = 2 periods
            eng.on_period(&mut rng).unwrap();
            for (s, &v) in sum.iter_mut().zip(eng.mask().dense_bridge()) {
                *s += v;
            }
        }
        // eq. (3): over a cycle, Σ masks = M·1 on real params
        assert!(sum[..20].iter().all(|&s| (s - 2.0).abs() < 1e-6),
                "{sum:?}");
        assert!(sum[20..].iter().all(|&s| s == 0.0));
    }

    #[test]
    fn iid_mask_varies_across_periods() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(4);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::IidMask), &mut rng)
                .unwrap();
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..12 {
            eng.on_period(&mut rng).unwrap();
            distinct.insert(
                eng.runs()
                    .runs()
                    .iter()
                    .map(|r| (r.offset, r.len))
                    .collect::<Vec<(usize, usize)>>(),
            );
        }
        assert!(distinct.len() > 1, "iid mask never changed");
    }

    #[test]
    fn native_backends_step_without_bundle() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(5);
        for method in [Method::Galore, Method::Golore, Method::Sift,
                       Method::Full] {
            let mut eng =
                MethodEngine::new(&man, &cfg_with(method), &mut rng)
                    .unwrap();
            eng.on_period(&mut rng).unwrap();
            let mut p = vec![0.5f32; 24];
            let g = vec![0.1f32; 24];
            eng.apply_native(&mut p, &g, 0.01);
            // some coordinate moved (SIFT may pick a non-head subset)
            assert!(p.iter().any(|&x| (x - 0.5).abs() > 0.0),
                    "{method:?} did not update");
        }
    }

    #[test]
    fn state_bytes_reflect_masking() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(6);
        let mut full =
            MethodEngine::new(&man, &cfg_with(Method::Full), &mut rng)
                .unwrap();
        full.on_period(&mut rng).unwrap();
        let mut lisa =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        lisa.on_period(&mut rng).unwrap();
        assert!(lisa.state_bytes() < full.state_bytes());
    }

    /// Deterministic synthetic gradient for the resume tests.
    fn grad(step: usize, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| ((step * 31 + i * 7 + 3) as f32 * 0.01).sin())
            .collect()
    }

    #[test]
    fn snapshot_restore_resumes_bitwise_identically() {
        // For every snapshottable method: run, snapshot mid-flight,
        // keep running → p_straight. Then rebuild a fresh engine,
        // restore, run the same tail → p_resumed. The two must match
        // to the bit, optimizer state included (verified implicitly:
        // any m/v/t divergence shows up in the params within a step).
        let man = toy_manifest();
        let n = man.padded_len;
        for method in [Method::Full, Method::IidMask, Method::WorMask,
                       Method::Lisa, Method::LisaWor] {
            let cfg = cfg_with(method);
            let mut rng = Rng::seed_from_u64(99);
            let mut eng =
                MethodEngine::new(&man, &cfg, &mut rng).unwrap();
            let mut p = vec![0.5f32; n];
            let mut step = 0usize;
            for _ in 0..3 {
                eng.on_period(&mut rng).unwrap();
                for _ in 0..4 {
                    eng.apply_native(&mut p, &grad(step, n), 1e-2);
                    step += 1;
                }
            }
            // --- snapshot point ---
            let mut ck = Checkpoint::new(step as u64, 0);
            eng.snapshot(&mut ck).unwrap();
            let rng_state = rng.state();
            let p_at_ck = p.clone();
            let tail = |eng: &mut MethodEngine,
                        rng: &mut Rng,
                        p: &mut Vec<f32>,
                        step0: usize| {
                let mut s = step0;
                for _ in 0..3 {
                    eng.on_period(rng).unwrap();
                    for _ in 0..4 {
                        eng.apply_native(p, &grad(s, n), 1e-2);
                        s += 1;
                    }
                }
            };
            tail(&mut eng, &mut rng, &mut p, step);

            let mut rng2 = Rng::seed_from_u64(7); // foreign seed:
            let mut eng2 = // construction draws must not matter
                MethodEngine::new(&man, &cfg, &mut rng2).unwrap();
            eng2.restore(&ck).unwrap();
            let mut rng2 = Rng::from_state(rng_state);
            let mut p2 = p_at_ck;
            tail(&mut eng2, &mut rng2, &mut p2, step);

            for i in 0..n {
                assert_eq!(
                    p[i].to_bits(),
                    p2[i].to_bits(),
                    "{method:?} diverged at coord {i}"
                );
            }
            assert_eq!(eng.periods, eng2.periods, "{method:?}");
        }
    }

    #[test]
    fn native_methods_refuse_to_snapshot() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(8);
        let eng =
            MethodEngine::new(&man, &cfg_with(Method::Galore), &mut rng)
                .unwrap();
        let mut ck = Checkpoint::new(0, 0);
        assert!(eng.snapshot(&mut ck).is_err());
    }

    #[test]
    fn restore_rejects_foreign_method_checkpoints() {
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(9);
        let full =
            MethodEngine::new(&man, &cfg_with(Method::Full), &mut rng)
                .unwrap();
        let mut ck = Checkpoint::new(0, 0);
        full.snapshot(&mut ck).unwrap();
        let mut wor =
            MethodEngine::new(&man, &cfg_with(Method::WorMask), &mut rng)
                .unwrap();
        assert!(wor.restore(&ck).is_err(), "method tag must gate");
    }

    #[test]
    fn native_mirror_skips_frozen_runs_but_matches_dense_math() {
        // The run-walking HLO mirror must equal the dense reference on
        // a LISA-shaped mask, and leave frozen coords bit-identical.
        let man = toy_manifest();
        let mut rng = Rng::seed_from_u64(7);
        let mut eng =
            MethodEngine::new(&man, &cfg_with(Method::LisaWor), &mut rng)
                .unwrap();
        eng.on_period(&mut rng).unwrap();
        let n = 24;
        let p0: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal32()).collect();
        let mut p = p0.clone();
        eng.apply_native(&mut p, &g, 1e-3);
        let mut pd = p0.clone();
        let mut dense =
            crate::optim::reference::DenseAdamW::default_hp(n);
        dense.step(&mut pd, &g, eng.mask().dense_bridge(), 1e-3);
        for i in 0..n {
            assert_eq!(p[i].to_bits(), pd[i].to_bits(), "coord {i}");
            if eng.mask().value(i) == 0.0 {
                assert_eq!(p[i].to_bits(), p0[i].to_bits());
            }
        }
    }
}
