//! Experiment drivers shared by `benches/` and `examples/`.
//!
//! Each paper table/figure maps to one driver here (see DESIGN.md's
//! experiment index); the bench binaries are thin wrappers that call
//! these and print/persist the rows. Keeping the logic in the library
//! means integration tests can assert on the *shape* of each result
//! (who wins, slopes, reduction factors) without duplicating setup.

use crate::config::{Method, OptFamily, RunConfig, Schedule};
use crate::data::{ClassTask, Corpus, CorpusConfig, TaskSpec,
                  GLUE_LIKE_TASKS};
use crate::jobs::{ExperimentKind, JobSpec};
use crate::runtime::bundle::UpdateKind;
use crate::runtime::{artifacts_dir, ModelBundle, Runtime};
use crate::train::{train_classifier, train_lm, TrainOutcome};
use anyhow::Result;
use std::path::Path;

/// Scale knob for bench runtimes: `OMGD_BENCH_SCALE` ∈ (0, 1] shrinks
/// epochs/steps for smoke runs (default 1.0 = paper-shaped runs).
pub fn bench_scale() -> f64 {
    parse_bench_scale(std::env::var("OMGD_BENCH_SCALE").ok().as_deref())
}

/// Pure parser behind [`bench_scale`], split out so the env-var edge
/// cases are unit-testable without process-global state.
///
/// `f64::parse` accepts `"NaN"` and `"inf"`; NaN in particular is
/// treacherous in a filter chain (every comparison is false, so which
/// arm "wins" depends on how the predicate is phrased). Reject anything
/// non-finite explicitly, then require (0, 1].
pub fn parse_bench_scale(raw: Option<&str>) -> f64 {
    raw.and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|x| x.is_finite() && *x > 0.0 && *x <= 1.0)
        .unwrap_or(1.0)
}

/// Scaled count, at least `min`.
pub fn scaled(base: usize, min: usize) -> usize {
    ((base as f64 * bench_scale()).round() as usize).max(min)
}

/// Common fine-tuning configuration for the Tables 3/5/6 experiments.
#[derive(Clone, Debug)]
pub struct FinetuneSetup {
    pub model: String,
    pub epochs: usize,
    pub lr: f64,
    pub gamma: usize,
    pub period: usize,
    pub keep_ratio: f64,
    pub rank: usize,
    pub seed: u64,
}

impl Default for FinetuneSetup {
    fn default() -> Self {
        Self {
            model: "mlp-glue".into(),
            epochs: 12,
            lr: 2e-3,
            gamma: 4,
            period: 1,
            keep_ratio: 0.5,
            rank: 8,
            seed: 0,
        }
    }
}

/// Load a bundle for a config (AdamW update artifact).
pub fn load_bundle(rt: &Runtime, model: &str) -> Result<ModelBundle> {
    let dir = artifacts_dir(None);
    ModelBundle::load(rt, &dir, model, UpdateKind::AdamW)
}

/// Load a bundle with the SGDM update artifact (Table 4).
pub fn load_bundle_sgdm(rt: &Runtime, model: &str) -> Result<ModelBundle> {
    let dir = artifacts_dir(None);
    ModelBundle::load(rt, &dir, model, UpdateKind::Sgdm)
}

/// The one place a [`FinetuneSetup`] becomes a [`RunConfig`] — shared
/// by the direct driver ([`finetune_cell`]) and the grid/cache path
/// ([`finetune_spec`]), so the two can never drift apart and hand a
/// stale-but-valid cache key different semantics. `steps`/`eval_every`
/// are left for the caller (step units here, epoch units in specs).
pub fn finetune_config(
    method: Method,
    setup: &FinetuneSetup,
    opt_family: OptFamily,
) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = setup.model.clone();
    cfg.method = method;
    cfg.opt.family = opt_family;
    cfg.opt.lr = setup.lr;
    cfg.mask.gamma = setup.gamma;
    cfg.mask.period = setup.period;
    cfg.mask.keep_ratio = setup.keep_ratio;
    cfg.mask.rank = setup.rank;
    cfg.seed = setup.seed;
    cfg
}

/// Fine-tune one (method, task) cell.
pub fn finetune_cell(
    bundle: &ModelBundle,
    task: &ClassTask,
    method: Method,
    setup: &FinetuneSetup,
    opt_family: OptFamily,
) -> Result<TrainOutcome> {
    let steps_per_epoch =
        task.n_train().div_ceil(bundle.man.data.batch);
    let mut cfg = finetune_config(method, setup, opt_family);
    cfg.steps = setup.epochs * steps_per_epoch;
    cfg.eval_every = 0;
    train_classifier(bundle, &cfg, task)
}

/// Build the task for a spec sized to the bundle.
pub fn task_for(bundle: &ModelBundle, spec: &TaskSpec) -> ClassTask {
    ClassTask::from_spec(spec, bundle.man.data.d_in,
                         bundle.man.data.n_class)
}

/// Table 3/5-style method roster.
pub fn adamw_method_roster() -> Vec<Method> {
    vec![
        Method::Full,
        Method::Golore,
        Method::Sift,
        Method::Lisa,
        Method::LisaScale,
        Method::LisaWorNoScale,
        Method::LisaWor,
    ]
}

/// Table 4 roster (SGDM tensorwise masks).
pub fn sgdm_method_roster() -> Vec<Method> {
    vec![Method::Full, Method::IidMask, Method::WorMask]
}

/// Pre-training setup for Fig. 5 (LISA vs LISA-WOR on the LM).
#[derive(Clone, Debug)]
pub struct PretrainSetup {
    pub model: String,
    pub steps: usize,
    pub lr: f64,
    pub gamma: usize,
    pub period: usize,
    pub seed: u64,
    pub eval_every: usize,
}

impl Default for PretrainSetup {
    fn default() -> Self {
        Self {
            model: "gpt-tiny".into(),
            steps: 300,
            lr: 6e-4,
            gamma: 2,
            period: 20,
            seed: 0,
            eval_every: 25,
        }
    }
}

/// The one place a [`PretrainSetup`] becomes a [`RunConfig`] — shared
/// by the direct driver ([`pretrain_cell`]) and `omgd grid`'s pretrain
/// kind, so the warmup+cosine schedule (and everything else) can't
/// silently diverge between the two paths (cf. [`finetune_config`]).
pub fn pretrain_config(method: Method, setup: &PretrainSetup) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.model = setup.model.clone();
    cfg.method = method;
    cfg.opt.lr = setup.lr;
    cfg.mask.gamma = setup.gamma;
    cfg.mask.period = setup.period;
    cfg.steps = setup.steps;
    cfg.eval_every = setup.eval_every;
    cfg.seed = setup.seed;
    cfg.schedule = Schedule::CosineWarmup {
        warmup: setup.steps / 10,
        total: setup.steps,
        min_lr: setup.lr * 0.1,
    };
    cfg
}

/// Run one pre-training leg; the corpus is derived from the bundle
/// geometry so all methods share data.
pub fn pretrain_cell(
    bundle: &ModelBundle,
    method: Method,
    setup: &PretrainSetup,
) -> Result<TrainOutcome> {
    let corpus = pretrain_corpus(bundle, setup.steps);
    let cfg = pretrain_config(method, setup);
    train_lm(bundle, &cfg, &corpus)
}

/// Corpus sized so an experiment sees a few epochs of distinct windows.
pub fn pretrain_corpus(bundle: &ModelBundle, steps: usize) -> Corpus {
    let windows = (bundle.man.data.batch * steps / 4).clamp(64, 4096);
    Corpus::generate(
        CorpusConfig {
            vocab: bundle.man.data.vocab,
            tokens: windows * (bundle.man.data.seq + 1),
            branching: 8,
            zipf_s: 1.1,
            seed: 7,
        },
        bundle.man.data.seq,
    )
}

// ---------------------------------------------------------------------
// Grid builders: the Table 3/5/6 drivers expressed as job submissions.
// The bench binaries (and `omgd grid`) hand these to `jobs::run_grid`
// instead of hand-rolling nested loops, so cells shard across workers
// and completed cells replay from the result cache.
// ---------------------------------------------------------------------

/// One fine-tuning grid cell as a job spec. Built from the same
/// [`finetune_config`] as [`finetune_cell`]; here `cfg.steps` /
/// `cfg.eval_every` are in epoch units, resolved against the bundle
/// batch size by the job runner.
pub fn finetune_spec(
    task: &str,
    method: Method,
    setup: &FinetuneSetup,
    opt_family: OptFamily,
    eval_every_epochs: usize,
) -> JobSpec {
    let mut cfg = finetune_config(method, setup, opt_family);
    cfg.steps = setup.epochs.max(1);
    cfg.eval_every = eval_every_epochs;
    JobSpec {
        kind: ExperimentKind::Finetune {
            task: task.to_string(),
            epochs: setup.epochs,
        },
        cfg,
    }
}

/// Table 3 grid: every GLUE-like task × the AdamW roster × `seeds`,
/// method-major then task then seed (the aggregation order the table
/// printer expects).
pub fn table3_grid(seeds: &[u64]) -> Vec<JobSpec> {
    let setup = FinetuneSetup {
        epochs: scaled(30, 4),
        gamma: 4,
        period: 1,
        ..FinetuneSetup::default()
    };
    let mut specs = Vec::new();
    for method in adamw_method_roster() {
        for spec_t in &GLUE_LIKE_TASKS {
            for &seed in seeds {
                let s = FinetuneSetup { seed, ..setup.clone() };
                specs.push(finetune_spec(
                    spec_t.name,
                    method,
                    &s,
                    OptFamily::AdamW,
                    0,
                ));
            }
        }
    }
    specs
}

/// Table 5's three Gaussian-blob datasets: (name, spread, data seed).
pub const TABLE5_DATASETS: [(&str, f64, u64); 3] = [
    ("IMG-easy", 3.0, 6001),
    ("IMG-mid", 4.0, 6002),
    ("IMG-hard", 5.5, 6003),
];

/// Table 5 grid: blob datasets × the AdamW roster on the `mlp-img`
/// bundle, with per-epoch eval (the Fig. 3 test-loss curves).
pub fn table5_grid() -> Vec<JobSpec> {
    let epochs = scaled(15, 3);
    let mut specs = Vec::new();
    for method in adamw_method_roster() {
        for (name, spread, data_seed) in TABLE5_DATASETS {
            let mut cfg = RunConfig::default();
            cfg.model = "mlp-img".into();
            cfg.method = method;
            cfg.opt.family = OptFamily::AdamW;
            cfg.opt.lr = 1e-3;
            cfg.mask.gamma = 3;
            cfg.mask.period = 5.min(epochs);
            cfg.mask.rank = 8;
            cfg.steps = epochs;
            cfg.eval_every = 1; // per-epoch test loss
            cfg.seed = 11;
            specs.push(JobSpec {
                kind: ExperimentKind::Blobs {
                    dataset: name.to_string(),
                    spread,
                    data_seed,
                    epochs,
                },
                cfg,
            });
        }
    }
    specs
}

/// Table 6 grid: LISA-WOR γ × K ablation on CoLA-like, γ-major then K.
pub fn table6_grid() -> Vec<JobSpec> {
    let epochs = scaled(20, 4);
    let gammas = [1usize, 2, 3, 4, 6];
    let periods = [1usize, 2, 3, 5, 6];
    let mut specs = Vec::new();
    for &gamma in &gammas {
        for &period in &periods {
            let setup = FinetuneSetup {
                epochs,
                gamma,
                period,
                ..FinetuneSetup::default()
            };
            specs.push(finetune_spec(
                GLUE_LIKE_TASKS[0].name,
                Method::LisaWor,
                &setup,
                OptFamily::AdamW,
                0,
            ));
        }
    }
    specs
}

/// True if the artifacts for `model` exist (benches skip gracefully
/// when `make artifacts` hasn't been run for larger configs).
pub fn artifacts_present(model: &str) -> bool {
    artifacts_dir(None).join(format!("{model}.json")).exists()
}

/// Results directory for bench CSV outputs.
pub fn results_dir() -> std::path::PathBuf {
    let p = Path::new("results");
    std::fs::create_dir_all(p).ok();
    p.to_path_buf()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Method;

    #[test]
    fn scaled_respects_minimum() {
        // With no env override the scale is 1.0.
        assert_eq!(scaled(100, 5), (100.0 * bench_scale()) as usize);
        assert!(scaled(1, 5) >= 5);
        assert!(scaled(0, 3) >= 3);
    }

    #[test]
    fn rosters_cover_the_paper_tables() {
        let adamw = adamw_method_roster();
        // Table 3/5 roster: full + 2 compressors + 4 LISA variants.
        assert_eq!(adamw.len(), 7);
        assert!(adamw.contains(&Method::Full));
        assert!(adamw.contains(&Method::LisaWor));
        assert!(adamw.contains(&Method::Golore));
        assert!(adamw.contains(&Method::Sift));
        // exactly two wor methods (lisa-wor and its no-scale ablation)
        assert_eq!(adamw.iter().filter(|m| m.is_wor()).count(), 2);
        let sgdm = sgdm_method_roster();
        assert_eq!(sgdm,
                   vec![Method::Full, Method::IidMask, Method::WorMask]);
    }

    #[test]
    fn bench_scale_parser_edge_cases() {
        // Unset / empty / garbage → default 1.0.
        assert_eq!(parse_bench_scale(None), 1.0);
        assert_eq!(parse_bench_scale(Some("")), 1.0);
        assert_eq!(parse_bench_scale(Some("abc")), 1.0);
        // Non-finite values parse as f64 but must be rejected.
        assert_eq!(parse_bench_scale(Some("NaN")), 1.0);
        assert_eq!(parse_bench_scale(Some("nan")), 1.0);
        assert_eq!(parse_bench_scale(Some("inf")), 1.0);
        assert_eq!(parse_bench_scale(Some("-inf")), 1.0);
        // Out of (0, 1] → default.
        assert_eq!(parse_bench_scale(Some("0")), 1.0);
        assert_eq!(parse_bench_scale(Some("-0.5")), 1.0);
        assert_eq!(parse_bench_scale(Some("1.5")), 1.0);
        // In range (with whitespace tolerance) → accepted.
        assert_eq!(parse_bench_scale(Some("0.05")), 0.05);
        assert_eq!(parse_bench_scale(Some(" 0.5 ")), 0.5);
        assert_eq!(parse_bench_scale(Some("1")), 1.0);
        assert_eq!(parse_bench_scale(Some("1e-3")), 1e-3);
    }

    #[test]
    fn table_grids_have_the_paper_shapes() {
        let seeds = [0u64, 1];
        let t3 = table3_grid(&seeds);
        // 7 methods × 8 tasks × 2 seeds
        assert_eq!(t3.len(), 7 * 8 * 2);
        let t5 = table5_grid();
        assert_eq!(t5.len(), 7 * 3);
        let t6 = table6_grid();
        assert_eq!(t6.len(), 5 * 5);
        // Within a grid every cell hashes distinctly (the cache key
        // space is the grid). Cross-grid overlap is allowed — under
        // OMGD_BENCH_SCALE clamping, Table 3's and Table 6's shared
        // (lisa-wor, CoLA) cell can be the same computation, and cache
        // sharing it is exactly the point.
        for (name, grid) in
            [("t3", &t3), ("t5", &t5), ("t6", &t6)]
        {
            let mut hashes: Vec<u64> =
                grid.iter().map(|s| s.content_hash()).collect();
            let n = hashes.len();
            hashes.sort_unstable();
            hashes.dedup();
            assert_eq!(hashes.len(), n, "{name} cells must not collide");
        }
    }

    #[test]
    fn finetune_spec_mirrors_finetune_cell_layout() {
        let setup = FinetuneSetup { seed: 3, epochs: 5,
                                    ..FinetuneSetup::default() };
        let s = finetune_spec("CoLA", Method::LisaWor, &setup,
                              OptFamily::AdamW, 2);
        assert_eq!(s.cfg.method, Method::LisaWor);
        assert_eq!(s.cfg.seed, 3);
        assert_eq!(s.cfg.eval_every, 2);
        match &s.kind {
            crate::jobs::ExperimentKind::Finetune { task, epochs } => {
                assert_eq!(task, "CoLA");
                assert_eq!(*epochs, 5);
            }
            other => panic!("wrong kind {other:?}"),
        }
    }

    #[test]
    fn setups_have_sane_defaults() {
        let f = FinetuneSetup::default();
        assert!(f.epochs > 0 && f.gamma > 0 && f.period > 0);
        assert!(f.lr > 0.0 && f.keep_ratio > 0.0);
        let p = PretrainSetup::default();
        assert!(p.steps > 0 && p.period > 0 && p.lr > 0.0);
    }
}
