//! # omgd-train — training engine and experiment drivers
//!
//! The layer that turns omgd-core numerics into runs: the masked
//! training engine and checkpointed loops ([`train`]), the §5.1
//! quadratic testbed ([`quadratic`]), the paper's experiment grid
//! builders ([`experiments`]), and [`runner`] — the concrete
//! [`omgd_jobs::JobExecutor`] that lets the job layer execute training
//! specs without depending on this crate.
//!
//! Layering contract: this is the only crate that sees both
//! `omgd-jobs` and the training engine. The job layer calls into us
//! exclusively through the `JobExecutor` trait object it defines.

pub mod experiments;
pub mod quadratic;
pub mod runner;
pub mod train;

// Path-compatibility aliases: moved files keep their historical
// `crate::coordinator`, `crate::config`, `crate::jobs::JobSpec`, ...
// paths and resolve them through the lower layers.
pub use omgd_core::{coordinator, data, exec, linalg, memory, optim, prop, rng, runtime};
pub use omgd_jobs as jobs;
pub use omgd_util::{bench, cli, config, manifest, metrics, obs, util};
