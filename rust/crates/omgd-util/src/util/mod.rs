//! Small shared utilities (JSON parsing for manifests).

pub mod json;
