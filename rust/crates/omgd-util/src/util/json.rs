//! Minimal JSON parser (objects, arrays, strings, numbers, bools, null).
//!
//! Replaces `serde_json` for reading the AOT manifests. Parsing is strict
//! enough for machine-written JSON; it is not a general-purpose validator.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; panics with a useful message if the
    /// path is missing (manifests are trusted build outputs).
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("json: missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Escape a string for embedding in a JSON string literal: backslash,
/// quote, and *every* control character (RFC 8259 §7 — strict readers
/// like `jq` reject raw controls even though this parser tolerates
/// them). One shared helper so every writer (cache entries, serve
/// JSONL) agrees.
pub fn escape_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Serialize an `f64` as a JSON number using Rust's shortest
/// round-trip `Display`; non-finite values become `null` (JSON has no
/// NaN/inf) and should be read back as NaN.
pub fn ser_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self
                                .bump()
                                .ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("bad codepoint"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Collect the full UTF-8 sequence starting at c.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        self.pos = start + len;
                        let s = std::str::from_utf8(
                            &self.src[start..self.pos],
                        )
                        .map_err(|_| self.err("bad utf8"))?;
                        out.push_str(s);
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(
            Json::parse("\"hi\\n\"").unwrap(),
            Json::Str("hi\n".into())
        );
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(
            r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#,
        )
        .unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at("a").as_arr().unwrap()[2].at("b").as_str(),
            Some("c")
        );
        assert_eq!(j.at("d").at("e").as_bool(), Some(false));
    }

    #[test]
    fn parse_manifest_shape() {
        let j = Json::parse(
            r#"{"name":"gpt-nano","params":[{"name":"wte","shape":[256,64],"offset":0,"len":16384}],"padded_len":139264}"#,
        )
        .unwrap();
        assert_eq!(j.at("name").as_str(), Some("gpt-nano"));
        let p = &j.at("params").as_arr().unwrap()[0];
        assert_eq!(p.at("offset").as_usize(), Some(0));
        assert_eq!(p.at("shape").as_arr().unwrap()[0].as_usize(), Some(256));
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(j.as_str(), Some("é café ☕"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn escape_round_trips_through_parser() {
        let nasty = "a\tb\nc\r\"d\"\\e\u{8}f\u{c}g\u{1b}h";
        let escaped = escape_str(nasty);
        assert!(!escaped.chars().any(|c| (c as u32) < 0x20),
                "no raw control chars may survive: {escaped:?}");
        let doc = format!("{{\"k\":\"{escaped}\"}}");
        let j = Json::parse(&doc).unwrap();
        assert_eq!(j.at("k").as_str(), Some(nasty));
    }

    #[test]
    fn ser_f64_round_trip_and_nonfinite() {
        for x in [0.0, 1.5, -2.25, 0.123456789012345, 1e-12, 1e15] {
            let j = Json::parse(&ser_f64(x)).unwrap();
            assert_eq!(j.as_f64(), Some(x));
        }
        assert_eq!(ser_f64(f64::NAN), "null");
        assert_eq!(ser_f64(f64::INFINITY), "null");
        assert_eq!(ser_f64(f64::NEG_INFINITY), "null");
    }
}
