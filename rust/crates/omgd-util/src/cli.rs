//! Tiny CLI argument parser (clap replacement).
//!
//! Grammar: `omgd <subcommand> [--flag value]... [--switch]... [pos]...`
//! Flags may also be written `--flag=value`. Unknown flags are collected
//! and reported by the subcommand that consumes them.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub cmd: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv`[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut it = argv.into_iter().peekable();
        let cmd = it.next().unwrap_or_default();
        let mut flags = BTreeMap::new();
        let mut positional = Vec::new();
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    flags.insert(name.to_string(), v);
                } else {
                    // boolean switch
                    flags.insert(name.to_string(), "true".to_string());
                }
            } else {
                positional.push(arg);
            }
        }
        Ok(Args { cmd, flags, positional })
    }

    pub fn parse_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// A flag that has no sensible default (`omgd worker --connect`):
    /// absent is an error naming the flag and what it expects.
    pub fn require(&self, name: &str, what: &str) -> Result<String> {
        match self.get(name) {
            Some(v) if !v.is_empty() && v != "true" => Ok(v.to_string()),
            _ => bail!("--{name} <{what}> is required"),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(x) => Ok(x),
                Err(_) => bail!("--{name} expects a number, got {v:?}"),
            },
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        Ok(self.usize_or(name, default as usize)? as u64)
    }

    /// Optional integer flag: `None` when absent (no default exists —
    /// e.g. the cache-GC caps, where "unset" means "no cap"), an error
    /// when present but unparseable.
    pub fn opt_u64(&self, name: &str) -> Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => match v.parse() {
                Ok(x) => Ok(Some(x)),
                Err(_) => bail!("--{name} expects an integer, got {v:?}"),
            },
        }
    }

    pub fn bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }

    /// Optional identity token destined for an HTTP header value
    /// (`grid --client`): `None` when absent; an error when present
    /// but empty, over-long, or containing whitespace/control
    /// characters that would corrupt header framing.
    pub fn token_opt(&self, name: &str) -> Result<Option<String>> {
        let Some(v) = self.get(name) else { return Ok(None) };
        let ok = !v.is_empty()
            && v != "true"
            && v.len() <= 64
            && v.chars().all(|c| c.is_ascii_graphic());
        if !ok {
            bail!(
                "--{name} expects a token of up to 64 printable \
                 non-whitespace ASCII characters, got {v:?}"
            );
        }
        Ok(Some(v.to_string()))
    }

    /// Validated-choice flag (`--metrics off|summary|full`): absent →
    /// `default`, present-but-unknown → an error listing the accepted
    /// values.
    pub fn str_choice_or(
        &self,
        name: &str,
        default: &str,
        choices: &[&str],
    ) -> Result<String> {
        let v = self.str_or(name, default);
        if !choices.contains(&v.as_str()) {
            bail!(
                "--{name} expects one of {}, got {v:?}",
                choices.join("|")
            );
        }
        Ok(v)
    }

    /// Comma-separated list flag (`--tasks CoLA,SST-2`). Empty items are
    /// dropped, whitespace around items is trimmed.
    pub fn list_or(&self, name: &str, default: &str) -> Vec<String> {
        self.str_or(name, default)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }

    /// Comma-separated parsed list: empty items dropped, whitespace
    /// trimmed, any unparseable item is an error naming the flag.
    fn parsed_list_or<T>(
        &self,
        name: &str,
        default: &[T],
        what: &str,
    ) -> Result<Vec<T>>
    where
        T: std::str::FromStr + Clone,
    {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(raw) => raw
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| {
                    s.parse().map_err(|_| {
                        anyhow::anyhow!(
                            "--{name} expects comma-separated {what}, \
                             got {s:?}"
                        )
                    })
                })
                .collect(),
        }
    }

    /// Comma-separated integer list (`--seeds 0,1,2`).
    pub fn u64_list_or(&self, name: &str, default: &[u64]) -> Result<Vec<u64>> {
        self.parsed_list_or(name, default, "integers")
    }

    /// Comma-separated float list (`--keep-ratios 0.25,0.5`).
    pub fn f64_list_or(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        self.parsed_list_or(name, default, "numbers")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_subcommand_and_flags() {
        let a = args("train --model gpt-tiny --steps 100 --verbose");
        assert_eq!(a.cmd, "train");
        assert_eq!(a.get("model"), Some("gpt-tiny"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert!(a.bool("verbose"));
    }

    #[test]
    fn equals_form() {
        let a = args("bench --method=lisa-wor --lr=0.01");
        assert_eq!(a.get("method"), Some("lisa-wor"));
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.01);
    }

    #[test]
    fn positional_args() {
        // NOTE: a bare `--switch` followed by a non-flag token consumes
        // it as a value (documented grammar), so switches go last.
        let a = args("run config.toml second --fast");
        assert_eq!(a.positional, vec!["config.toml", "second"]);
        assert!(a.bool("fast"));
    }

    #[test]
    fn defaults() {
        let a = args("x");
        assert_eq!(a.str_or("missing", "d"), "d");
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert!(!a.bool("missing"));
    }

    #[test]
    fn bad_numbers_error() {
        let a = args("x --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
        assert!(a.f64_or("steps", 0.0).is_err());
    }

    #[test]
    fn optional_integer_flags() {
        let a = args("cache-gc --max-bytes 1048576 --max-age-secs oops");
        assert_eq!(a.opt_u64("max-bytes").unwrap(), Some(1_048_576));
        assert_eq!(a.opt_u64("absent").unwrap(), None);
        assert!(a.opt_u64("max-age-secs").is_err());
    }

    #[test]
    fn header_tokens_validate() {
        let a = args("grid --client grid-a");
        assert_eq!(a.token_opt("client").unwrap().as_deref(),
                   Some("grid-a"));
        assert_eq!(a.token_opt("absent").unwrap(), None);
        assert!(args("grid --client").token_opt("client").is_err(),
                "bare switch is not a token");
        let b = Args {
            cmd: "grid".into(),
            flags: [("client".to_string(), "has space".to_string())]
                .into_iter()
                .collect(),
            positional: vec![],
        };
        assert!(b.token_opt("client").is_err(), "whitespace rejected");
    }

    #[test]
    fn choice_flags_validate() {
        let a = args("serve --metrics summary");
        assert_eq!(
            a.str_choice_or("metrics", "full", &["off", "summary", "full"])
                .unwrap(),
            "summary"
        );
        assert_eq!(
            a.str_choice_or("absent", "full", &["off", "summary", "full"])
                .unwrap(),
            "full"
        );
        let bad = args("serve --metrics loud");
        let err = bad
            .str_choice_or("metrics", "full", &["off", "summary", "full"])
            .unwrap_err();
        assert!(format!("{err:#}").contains("off|summary|full"));
    }

    #[test]
    fn trailing_switch() {
        let a = args("x --flag");
        assert!(a.bool("flag"));
    }

    #[test]
    fn required_flags_error_when_absent_or_valueless() {
        let a = args("worker --connect 127.0.0.1:8080");
        assert_eq!(
            a.require("connect", "host:port").unwrap(),
            "127.0.0.1:8080"
        );
        assert!(a.require("missing", "host:port").is_err());
        // A bare `--connect` (parsed as a boolean switch) is not a
        // usable address either.
        let b = args("worker --connect");
        assert!(b.require("connect", "host:port").is_err());
    }

    #[test]
    fn list_flags() {
        let a = args("grid --tasks CoLA,SST-2 --seeds 0,1,2 \
                      --keep-ratios 0.25,0.5");
        assert_eq!(a.list_or("tasks", "x"), vec!["CoLA", "SST-2"]);
        assert_eq!(a.u64_list_or("seeds", &[9]).unwrap(), vec![0, 1, 2]);
        assert_eq!(
            a.f64_list_or("keep-ratios", &[1.0]).unwrap(),
            vec![0.25, 0.5]
        );
        // Defaults when absent.
        assert_eq!(a.list_or("methods", "full,lisa"),
                   vec!["full", "lisa"]);
        assert_eq!(a.u64_list_or("missing", &[7]).unwrap(), vec![7]);
        assert_eq!(a.f64_list_or("missing", &[0.5]).unwrap(), vec![0.5]);
    }

    #[test]
    fn list_flags_trim_and_reject_garbage() {
        let a = args("grid --tasks=CoLA,,SST-2 --seeds 0,x --keep-ratios ,");
        assert_eq!(a.list_or("tasks", ""), vec!["CoLA", "SST-2"]);
        assert!(a.u64_list_or("seeds", &[]).is_err());
        assert_eq!(a.f64_list_or("keep-ratios", &[1.0]).unwrap(),
                   Vec::<f64>::new());
    }
}
