//! AOT manifest: the contract between the python compile path and rust.
//!
//! `python/compile/aot.py` writes one JSON manifest per model config; it
//! describes the flat parameter layout (name/shape/layer/offset/len per
//! tensor), the data shapes the train/eval artifacts were lowered for,
//! and which HLO files implement each entry point. Everything the
//! coordinator needs to build tensorwise/layerwise masks lives here — the
//! rust side never inspects HLO.

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One parameter tensor in the flat layout.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    /// Layer tag: `"embed"`, `"block_<i>"`, `"final"`, `"head"`.
    pub layer: String,
    pub offset: usize,
    pub len: usize,
}

/// Data shapes the artifacts were lowered for.
#[derive(Clone, Debug, Default)]
pub struct DataShapes {
    pub batch: usize,
    /// GPT: sequence length; MLP: 0.
    pub seq: usize,
    /// GPT: vocab size; MLP: 0.
    pub vocab: usize,
    /// MLP: input features; GPT: 0.
    pub d_in: usize,
    /// MLP: classes; GPT: 0.
    pub n_class: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    /// `"gpt"` or `"mlp"`.
    pub kind: String,
    pub block: usize,
    pub total_len: usize,
    pub padded_len: usize,
    pub params: Vec<ParamInfo>,
    pub data: DataShapes,
    /// Artifact file names (relative to the artifacts dir).
    pub train_hlo: String,
    pub eval_hlo: String,
    pub init_bin: String,
    pub update_adamw_hlo: String,
    pub update_sgdm_hlo: String,
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(artifacts_dir: &Path, name: &str) -> Result<Manifest> {
        let path = artifacts_dir.join(format!("{name}.json"));
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading manifest {path:?}"))?;
        let j = Json::parse(&text)
            .with_context(|| format!("parsing manifest {path:?}"))?;
        Self::from_json(&j, artifacts_dir)
    }

    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let params = j
            .at("params")
            .as_arr()
            .context("params not an array")?
            .iter()
            .map(|p| {
                Ok(ParamInfo {
                    name: p.at("name").as_str().context("name")?.to_string(),
                    shape: p
                        .at("shape")
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|s| s.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    layer: p.at("layer").as_str().context("layer")?
                        .to_string(),
                    offset: p.at("offset").as_usize().context("offset")?,
                    len: p.at("len").as_usize().context("len")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let data = j.at("data");
        let g = |k: &str| data.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        let upd = j.at("artifacts").at("update");
        let man = Manifest {
            name: j.at("name").as_str().context("name")?.to_string(),
            kind: j.at("kind").as_str().context("kind")?.to_string(),
            block: j.at("block").as_usize().context("block")?,
            total_len: j.at("total_len").as_usize().context("total_len")?,
            padded_len: j.at("padded_len").as_usize()
                .context("padded_len")?,
            params,
            data: DataShapes {
                batch: g("batch"),
                seq: g("seq"),
                vocab: g("vocab"),
                d_in: g("d_in"),
                n_class: g("n_class"),
            },
            train_hlo: j.at("artifacts").at("train").as_str()
                .context("train")?.to_string(),
            eval_hlo: j.at("artifacts").at("eval").as_str()
                .context("eval")?.to_string(),
            init_bin: j.at("artifacts").at("init").as_str()
                .context("init")?.to_string(),
            update_adamw_hlo: upd.at("adamw").as_str().context("adamw")?
                .to_string(),
            update_sgdm_hlo: upd.at("sgdm").as_str().context("sgdm")?
                .to_string(),
            dir: dir.to_path_buf(),
        };
        man.check()?;
        Ok(man)
    }

    /// Structural invariants: contiguous offsets, shapes match lengths,
    /// padding consistent.
    pub fn check(&self) -> Result<()> {
        let mut off = 0usize;
        for p in &self.params {
            if p.offset != off {
                bail!("param {} offset {} != expected {}", p.name, p.offset,
                      off);
            }
            let shape_len: usize = p.shape.iter().product();
            if shape_len != p.len {
                bail!("param {} shape/len mismatch", p.name);
            }
            off += p.len;
        }
        if off != self.total_len {
            bail!("total_len {} != sum of params {}", self.total_len, off);
        }
        if self.padded_len < self.total_len
            || self.padded_len % self.block != 0
        {
            bail!("bad padded_len {}", self.padded_len);
        }
        Ok(())
    }

    /// Names of the middle layers in order (`block_0`, `block_1`, ...).
    pub fn middle_layers(&self) -> Vec<String> {
        let mut seen = Vec::new();
        for p in &self.params {
            if p.layer.starts_with("block_")
                && seen.last() != Some(&p.layer)
            {
                seen.push(p.layer.clone());
            }
        }
        seen
    }

    /// Params belonging to a given layer tag.
    pub fn layer_params(&self, layer: &str) -> Vec<&ParamInfo> {
        self.params.iter().filter(|p| p.layer == layer).collect()
    }

    /// Load the initial flat parameter vector (raw little-endian f32).
    pub fn load_init(&self) -> Result<Vec<f32>> {
        let path = self.dir.join(&self.init_bin);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading init {path:?}"))?;
        if bytes.len() != 4 * self.padded_len {
            bail!("init file {} has {} bytes, want {}", self.init_bin,
                  bytes.len(), 4 * self.padded_len);
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn hlo_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
 "name": "toy", "kind": "mlp", "block": 8,
 "total_len": 14, "padded_len": 16,
 "params": [
   {"name": "in_w", "shape": [2, 3], "layer": "embed", "offset": 0, "len": 6},
   {"name": "block_0.w", "shape": [2, 2], "layer": "block_0", "offset": 6, "len": 4},
   {"name": "block_1.w", "shape": [2, 1], "layer": "block_1", "offset": 10, "len": 2},
   {"name": "out_w", "shape": [2], "layer": "head", "offset": 12, "len": 2}
 ],
 "data": {"batch": 4, "d_in": 2, "n_class": 2},
 "artifacts": {"train": "t.hlo.txt", "eval": "e.hlo.txt",
               "init": "i.bin",
               "update": {"adamw": "a.hlo.txt", "sgdm": "s.hlo.txt"}}
}"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_checks() {
        let m = Manifest::from_json(&sample_json(), Path::new("/tmp"))
            .unwrap();
        assert_eq!(m.name, "toy");
        assert_eq!(m.params.len(), 4);
        assert_eq!(m.data.batch, 4);
        assert_eq!(m.middle_layers(), vec!["block_0", "block_1"]);
        assert_eq!(m.layer_params("embed").len(), 1);
        assert_eq!(m.update_adamw_hlo, "a.hlo.txt");
    }

    #[test]
    fn rejects_gap_in_offsets() {
        let mut j = sample_json();
        if let Json::Obj(ref mut o) = j {
            if let Some(Json::Arr(ref mut ps)) = o.get_mut("params") {
                if let Json::Obj(ref mut p1) = ps[1] {
                    p1.insert("offset".into(), Json::Num(7.0));
                }
            }
        }
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn rejects_bad_padding() {
        let mut j = sample_json();
        if let Json::Obj(ref mut o) = j {
            o.insert("padded_len".into(), Json::Num(15.0));
        }
        assert!(Manifest::from_json(&j, Path::new("/tmp")).is_err());
    }

    #[test]
    fn loads_real_artifact_manifest_if_present() {
        // Integration-ish: validate the checked-in AOT output when built.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("gpt-nano.json").exists() {
            let m = Manifest::load(&dir, "gpt-nano").unwrap();
            assert_eq!(m.kind, "gpt");
            assert!(m.padded_len % m.block == 0);
            assert_eq!(m.middle_layers().len(), 2);
            let init = m.load_init().unwrap();
            assert_eq!(init.len(), m.padded_len);
        }
    }
}
